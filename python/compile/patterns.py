"""Dropout-pattern index math shared by the L2 model graphs.

Mirrors ``rust/src/patterns/`` (the Rust side owns sampling and host-side
mask generation; this module owns the in-graph gather/compaction). All
functions take the divisor ``dp`` as a *static* Python int (it determines
shapes, hence which AOT executable this graph becomes) and the bias ``b0``
as a *dynamic* int32 scalar (``b0 = b - 1`` in the paper's 1-based notation,
uniform over {0..dp-1}), so one executable per ``dp`` serves all biases.

Row-based pattern (RDP, paper section III-A), 0-based:
    kept neuron indices  = { b0 + dp*j : j in [0, M // dp) }
so exactly ``M // dp`` of ``M`` neurons are kept and the kept sets across the
``dp`` biases partition {0..dp*(M//dp)}.

Tile-based pattern (TDP, paper section III-B): the weight matrix is split in
``t_r x t_c`` tiles (32x32 when the dims allow, the paper's choice for the
32 shared-memory banks; adapted down for non-divisible dims). The paper
keeps one tile in every ``dp`` successive tiles in row-major order; when
``dp`` divides the tile-column count that degenerates into keeping entire
tile-columns, so we skew the stripe by the tile-row index (kept tile at
(r, c) iff ``(c - b0 - r) mod dp == 0``) — same keep ratio 1/dp, same
bias-partition property, but every output tile-column receives
contributions. See DESIGN.md section 9.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Row-based (RDP)
# ---------------------------------------------------------------------------

def row_kept_count(m: int, dp: int) -> int:
    """Number of kept neurons out of ``m`` for divisor ``dp`` (any bias)."""
    return m // dp


def row_kept_indices(dp: int, b0, count: int):
    """Kept indices b0 + dp*j as an int32 vector (b0 may be traced)."""
    return (jnp.asarray(b0, jnp.int32) + dp * jnp.arange(count, dtype=jnp.int32))


def gather_cols(w: jax.Array, dp: int, b0) -> jax.Array:
    """Keep columns {b0 + dp*j} of ``w`` [K, M] -> [K, M//dp].

    Implemented as a reshape + dynamic index so the transpose (gradient) is a
    cheap pad/scatter rather than a general gather.
    """
    k, m = w.shape
    cnt = m // dp
    w3 = w[:, : cnt * dp].reshape(k, cnt, dp)
    return lax.dynamic_index_in_dim(w3, b0, axis=2, keepdims=False)


def gather_rows(w: jax.Array, dp: int, b0) -> jax.Array:
    """Keep rows {b0 + dp*j} of ``w`` [M, N] -> [M//dp, N]."""
    m, n = w.shape
    cnt = m // dp
    w3 = w[: cnt * dp].reshape(cnt, dp, n)
    return lax.dynamic_index_in_dim(w3, b0, axis=1, keepdims=False)


def gather_vec(v: jax.Array, dp: int, b0) -> jax.Array:
    """Keep elements {b0 + dp*j} of a vector (e.g. a bias) [M] -> [M//dp]."""
    (m,) = v.shape
    cnt = m // dp
    return lax.dynamic_index_in_dim(v[: cnt * dp].reshape(cnt, dp), b0, axis=1,
                                    keepdims=False)


def scatter_rows(rows: jax.Array, m: int, dp: int, b0) -> jax.Array:
    """Inverse of :func:`gather_rows`: place compact rows back at stride dp,
    zeros elsewhere. Output [m, N]. Used to re-expand compact activations
    when a dense view is needed (e.g. the paper's Fig 3 output matrix whose
    other rows "are set to zero by default")."""
    cnt, n = rows.shape
    buf = jnp.zeros((cnt, dp, n), rows.dtype)
    buf = lax.dynamic_update_index_in_dim(buf, rows[:, None, :], b0, axis=1)
    out = buf.reshape(cnt * dp, n)
    if cnt * dp < m:
        out = jnp.concatenate([out, jnp.zeros((m - cnt * dp, n), rows.dtype)], 0)
    return out


# ---------------------------------------------------------------------------
# Tile-based (TDP)
# ---------------------------------------------------------------------------

def tile_dims(k: int, n: int, t: int = 32) -> tuple[int, int]:
    """Tile edge sizes (t_r, t_c) for a [k, n] weight matrix: the largest
    divisors <= t (paper uses 32x32; 784 -> 28, 10 -> 10, ...)."""
    from .kernels.matmul import pick_block

    return pick_block(k, t), pick_block(n, t)


def tile_kept_count(k: int, n: int, dp: int, t: int = 32) -> int:
    """Kept-tile count — static (identical for every bias b0).

    Requires dp | tn or dp | tk so the count does not depend on b0 (this is
    what makes one AOT executable serve all biases).
    """
    tr, tc = tile_dims(k, n, t)
    tk, tn = k // tr, n // tc
    if tn % dp == 0:
        return tk * (tn // dp)
    if tk % dp == 0:
        return (tk // dp) * tn
    raise ValueError(
        f"dp={dp} must divide one tile-grid edge of {tk}x{tn} "
        f"(weight {k}x{n}, tile {tr}x{tc})")


def tile_kept_rc(k: int, n: int, dp: int, b0, t: int = 32):
    """(rows, cols) int32 vectors of kept tiles in row-major ("successive
    tiles") order.

    Kept tile (r, c) iff (c - b0 - r) mod dp == 0 — diagonal stripes: same
    1/dp keep ratio as the paper's row-major stride, same bias-partition
    property, but every output tile-column receives contributions even when
    dp divides the tile-column count (see module docstring).
    """
    tr, tc = tile_dims(k, n, t)
    tk, tn = k // tr, n // tc
    cnt = tile_kept_count(k, n, dp, t)
    r = jnp.arange(tk, dtype=jnp.int32)[:, None]
    c = jnp.arange(tn, dtype=jnp.int32)[None, :]
    keep = ((c - jnp.asarray(b0, jnp.int32) - r) % dp) == 0
    rows, cols = jnp.nonzero(keep, size=cnt)
    return rows.astype(jnp.int32), cols.astype(jnp.int32)


def gather_tiles(w: jax.Array, rows: jax.Array, cols: jax.Array,
                 t: int = 32) -> jax.Array:
    """Gather kept tiles of ``w`` [K, N] -> [J, t_r, t_c]."""
    k, n = w.shape
    tr, tc = tile_dims(k, n, t)
    tk, tn = k // tr, n // tc
    w4 = w.reshape(tk, tr, tn, tc).transpose(0, 2, 1, 3).reshape(tk * tn, tr, tc)
    return jnp.take(w4, rows * tn + cols, axis=0)


def tile_mask(k: int, n: int, dp: int, b0, t: int = 32) -> jax.Array:
    """Dense 0/1 mask equivalent of the tile pattern (oracle/testing only —
    using this in training would be the conventional-dropout slow path)."""
    tr, tc = tile_dims(k, n, t)
    tk, tn = k // tr, n // tc
    r = jnp.arange(tk, dtype=jnp.int32)[:, None]
    c = jnp.arange(tn, dtype=jnp.int32)[None, :]
    keep = ((c - jnp.asarray(b0, jnp.int32) - r) % dp) == 0
    return jnp.repeat(jnp.repeat(keep.astype(jnp.float32), tr, 0), tc, 1)


def row_mask(m: int, dp: int, b0) -> jax.Array:
    """Dense 0/1 keep-mask vector for the row pattern (oracle/testing)."""
    i = jnp.arange(m, dtype=jnp.int32)
    cnt = m // dp
    keep = ((i % dp) == jnp.asarray(b0, jnp.int32)) & (i < cnt * dp)
    return keep.astype(jnp.float32)


# ---------------------------------------------------------------------------
# TDP matmul dispatcher
# ---------------------------------------------------------------------------

def _tdp_matmul_grouped(x, w, dp: int, b0, tile: int):
    """Exact dense reformulation of the diagonal-stripe tile pattern.

    Rows in tile-row residue class rho (r = rho mod dp) keep exactly the
    tile-columns with c = (b0 + rho) mod dp, so the sparse matmul
    decomposes into ``dp`` independent dense compact matmuls of 1/dp^2 the
    size (total work 1/dp), stitched back by column class. Requires
    dp | tk and dp | tn. This is the fast path: it uses only the dense
    Pallas matmul plus reshape/slice glue that XLA fuses away.
    """
    from .kernels.matmul import matmul

    m = x.shape[0]
    k, n = w.shape
    tr, tc = tile_dims(k, n, tile)
    tk, tn = k // tr, n // tc
    q_r, q_c = tk // dp, tn // dp

    # x grouped by tile-row residue: [m, q_r, dp, tr]
    x4 = x.reshape(m, q_r, dp, tr)
    # w as tile grid split both ways: [q_r, dp, tr, q_c, dp, tc]
    w6 = w.reshape(q_r, dp, tr, tn, tc).reshape(q_r, dp, tr, q_c, dp, tc)

    y = jnp.zeros((m, q_c, dp, tc), x.dtype)
    b0 = jnp.asarray(b0, jnp.int32)
    for rho in range(dp):
        s = (b0 + rho) % dp  # column class owned by this row class
        x_rho = x4[:, :, rho, :].reshape(m, q_r * tr)
        w_rho = lax.dynamic_index_in_dim(
            w6[:, rho], s, axis=3, keepdims=False)       # [q_r, tr, q_c, tc]
        w_rho = w_rho.reshape(q_r * tr, q_c * tc)
        y_rho = matmul(x_rho, w_rho).reshape(m, q_c, tc)
        y = lax.dynamic_update_index_in_dim(
            y, y_rho[:, :, None, :], s, axis=2)
    return y.reshape(m, n)


def tdp_matmul(x, w, dp: int, b0, tile: int):
    """Tile-pattern matmul ``x @ (w * tile_mask)`` (no scale), dispatching
    to the grouped-dense reformulation when the tile grid allows, else the
    scalar-prefetch sparse kernel."""
    from .kernels.tile_sparse import tile_sparse_matmul

    k, n = w.shape
    # NOTE: no dp == 1 shortcut — the grouped path handles it as one dense
    # matmul while still consuming ``b0``, keeping the AOT input signature
    # identical across dp (XLA would otherwise DCE the unused parameter).
    tr, tc = tile_dims(k, n, tile)
    tk, tn = k // tr, n // tc
    if tk % dp == 0 and tn % dp == 0:
        return _tdp_matmul_grouped(x, w, dp, b0, tile)
    rows, cols = tile_kept_rc(k, n, dp, b0, tile)
    wt = gather_tiles(w, rows, cols, tile)
    return tile_sparse_matmul(x, wt, rows, cols, n)
