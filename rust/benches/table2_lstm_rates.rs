//! Table II — LSTM (2x1500-per-paper; 2x1536 tile-aligned here, or 2x256
//! reduced unless AD_BENCH_FULL=1) on the 8800-word corpus, rates
//! (0.3,0.3)/(0.5,0.5)/(0.7,0.7).
//!
//! Paper shape to reproduce: ROW speedup 1.18 -> 1.53, TILE 1.18 -> 1.49
//! as the rate grows; accuracy within ~1% of the baseline.

use approx_dropout::bench::drivers::{env_usize, run_lstm, BenchCtx};
use approx_dropout::bench::{fmt_time, Table};
use approx_dropout::coordinator::{speedup, Variant};
use approx_dropout::data::Corpus;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new()?;
    let full = env_usize("AD_BENCH_FULL", 0) == 1;
    let (tag, vocab) = if full {
        ("lstm2x1536v8800b20", 8800)
    } else {
        ("lstm2x256v2048b20", 2048)
    };
    println!("== Table II: {tag}, rate sweep, {} timed steps/config ==",
             ctx.timed_steps);
    let corpus = Corpus::generate(vocab, 120_000, 12_000, 12_000, 11);

    let mut table = Table::new(&["rate", "pattern", "step", "speedup",
                                 "valid ppl", "token acc"]);
    for &r in &[0.3, 0.5, 0.7] {
        let (t_conv, q_conv) = run_lstm(&ctx, tag, Variant::Conv, r, 2,
                                        &corpus, 0.1, 42)?;
        table.row(&[format!("({r},{r})"), "original".into(),
                    fmt_time(t_conv), "1.00x".into(),
                    q_conv.map(|(p, _)| format!("{p:.1}"))
                        .unwrap_or("-".into()),
                    q_conv.map(|(_, a)| format!("{:.2}%", a * 100.0))
                        .unwrap_or("-".into())]);
        for (label, variant) in [("ROW", Variant::Rdp),
                                 ("TILE", Variant::Tdp)] {
            let (t, q) = run_lstm(&ctx, tag, variant, r, 2, &corpus, 0.1,
                                  42)?;
            table.row(&[format!("({r},{r})"), label.into(), fmt_time(t),
                        format!("{:.2}x", speedup(t_conv, t)),
                        q.map(|(p, _)| format!("{p:.1}"))
                            .unwrap_or("-".into()),
                        q.map(|(_, a)| format!("{:.2}%", a * 100.0))
                            .unwrap_or("-".into())]);
            println!("  rate {r} {label}: {:.2}x", speedup(t_conv, t));
        }
    }
    println!();
    table.print();
    println!("\npaper: ROW 1.18/1.47/1.53, TILE 1.18/1.43/1.49; accuracy \
              drop < 1.5% (AD_BENCH_TRAIN_STEPS>0 adds quality columns; \
              AD_BENCH_FULL=1 uses the paper-scale model for timing)");
    Ok(())
}
