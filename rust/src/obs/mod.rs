//! Zero-dependency observability layer: a process-wide metrics
//! [`registry`] (atomic counters/gauges/fixed-bucket histograms),
//! phase-scoped [`trace`] spans gated by `AD_TRACE`, and the
//! `METRICS_<run>.json` export every `train-*`/`serve`/`infer` run
//! writes through `bench/report.rs`.
//!
//! Layer map — where each named instrument is fed:
//!
//! | instrument                  | fed by                                |
//! |-----------------------------|---------------------------------------|
//! | `dispatch_total`            | `coordinator/driver.rs` per step      |
//! | `sparse_{rows,tiles}_*`     | `runtime/sparse/kernels.rs` per GEMM  |
//! | `sparse_panel_bytes`        | sparse `prep` panel packing           |
//! | `sparse_dyn_rows_*`         | sparse dyn-mask node paths (bwd)      |
//! | `gate_{wait,hold}_s`, depth | `service/scheduler.rs` `SlotGate`     |
//! | `infer_*`                   | `service/infer.rs` worker loop        |
//! | `worker_sync_wait_s`        | `coordinator/driver.rs` sharded step  |
//! | `allreduce_total`           | `coordinator/driver.rs` per reduction |
//! | `phase_time_s` rows         | `trace` spans (trainer + interpreter) |
//!
//! Naming scheme: `snake_case`, `<subsystem>_<what>[_<unit>]`; units in
//! the name (`_s`, `_bytes`). Schema of the export (validated by
//! `tools/check_metrics.py`) is documented on [`metrics_report`].

pub mod registry;
pub mod trace;

use crate::bench::report::BenchReport;
use crate::util::json::Json;
use registry::InstrumentSnapshot;

/// Snapshot the whole registry + phase-aggregation table into one
/// report, named `metrics`, tagged with the run kind (`train-mlp`,
/// `serve`, `infer`, ...).
///
/// Row schema (one row per instrument cell):
///
/// * counters — `{instrument, kind:"counter", value}` plus an optional
///   `label` for labeled cells (`dispatch_total`);
/// * gauges — `{instrument, kind:"gauge", value, peak}`;
/// * histograms — `{instrument, kind:"histogram", bounds:[..],
///   counts:[..], total, sum}` where `counts` has one trailing overflow
///   cell and `sum(counts) == total` by construction;
/// * phases — `{instrument:"phase_time_s", kind:"phase", scope, phase,
///   count, total_s, max_s}` (present only after traced spans fired).
pub fn metrics_report(run: &str) -> BenchReport {
    let mut r = BenchReport::new("metrics", "rust/src/obs/mod.rs");
    r.set("run", Json::str(run));
    r.set("trace", Json::Bool(trace::enabled()));
    for snap in registry::snapshot_all() {
        match snap {
            InstrumentSnapshot::Counter { name, value } => {
                r.row(vec![
                    ("instrument", Json::str(name)),
                    ("kind", Json::str("counter")),
                    ("value", Json::num(value as f64)),
                ]);
            }
            InstrumentSnapshot::Labeled { name, cells } => {
                // Always emit the aggregate row so required-instrument
                // checks hold even before the first dispatch.
                let total: u64 = cells.iter().map(|(_, v)| v).sum();
                r.row(vec![
                    ("instrument", Json::str(name)),
                    ("kind", Json::str("counter")),
                    ("value", Json::num(total as f64)),
                ]);
                for (label, value) in cells {
                    r.row(vec![
                        ("instrument", Json::str(name)),
                        ("kind", Json::str("counter")),
                        ("label", Json::str(&label)),
                        ("value", Json::num(value as f64)),
                    ]);
                }
            }
            InstrumentSnapshot::Gauge { name, value, peak } => {
                r.row(vec![
                    ("instrument", Json::str(name)),
                    ("kind", Json::str("gauge")),
                    ("value", Json::num(value as f64)),
                    ("peak", Json::num(peak as f64)),
                ]);
            }
            InstrumentSnapshot::Histogram { name, h } => {
                r.row(vec![
                    ("instrument", Json::str(name)),
                    ("kind", Json::str("histogram")),
                    ("bounds",
                     Json::Arr(h.bounds.iter().copied().map(Json::num)
                               .collect())),
                    ("counts",
                     Json::Arr(h.counts.iter().map(|&c| Json::num(c as f64))
                               .collect())),
                    ("total", Json::num(h.total as f64)),
                    ("sum", Json::num(h.sum)),
                ]);
            }
        }
    }
    for p in trace::phase_snapshot() {
        r.row(vec![
            ("instrument", Json::str("phase_time_s")),
            ("kind", Json::str("phase")),
            ("scope", Json::str(&p.scope)),
            ("phase", Json::str(p.phase)),
            ("count", Json::num(p.agg.count as f64)),
            ("total_s", Json::num(p.agg.total_s)),
            ("max_s", Json::num(p.agg.max_s)),
        ]);
    }
    r
}

/// Write `METRICS_<run>.json` next to the `BENCH_*`/`REPORT_*` files
/// (`AD_BENCH_OUT` redirects) and return where it landed. Called at the
/// end of every CLI run; failures are the caller's to report loudly —
/// metrics must never abort a run that already trained.
pub fn write_metrics(run: &str) -> anyhow::Result<std::path::PathBuf> {
    metrics_report(run).write_default(&format!("METRICS_{run}.json"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn metrics_report_shape_parses_and_has_catalog() {
        let r = metrics_report("unit");
        let v = json::parse(&r.to_json().pretty()).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("metrics"));
        assert_eq!(v.get("run").unwrap().as_str(), Some("unit"));
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        let has = |name: &str| {
            rows.iter().any(|r| {
                r.get("instrument").and_then(|i| i.as_str()) == Some(name)
            })
        };
        for name in ["dispatch_total", "sparse_rows_kept",
                     "sparse_dyn_rows_kept", "sparse_dyn_rows_dropped",
                     "gate_wait_s",
                     "gate_queue_depth", "infer_latency_s",
                     "infer_batch_occupancy", "worker_sync_wait_s",
                     "allreduce_total"] {
            assert!(has(name), "missing instrument {name}");
        }
        // Histogram rows: counts sum to total (the checker invariant).
        for row in rows {
            if row.get("kind").and_then(|k| k.as_str()) == Some("histogram")
            {
                let counts: u64 = row.get("counts").unwrap().as_arr()
                    .unwrap().iter()
                    .map(|c| c.as_f64().unwrap() as u64).sum();
                assert_eq!(row.get("total").unwrap().as_f64(),
                           Some(counts as f64));
            }
        }
    }
}
