"""Ablation: the two TDP execution strategies must agree exactly.

`patterns.tdp_matmul` dispatches between (a) the grouped-dense
reformulation (dp | both tile-grid edges) and (b) the scalar-prefetch
sparse kernel. Both must match the dense tile-mask model on every shape
the artifact registry uses — this pins the §Perf optimization against the
reference semantics (DESIGN.md §8b item 2).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import patterns
from compile.kernels import tile_sparse_matmul

# (K, N, dp, tile) drawn from the real artifact shapes.
REGISTRY_SHAPES = [
    (784, 2048, 2, 128),    # mlp2048 W1: grouped unavailable (tk=7)
    (784, 2048, 4, 128),
    (2048, 2048, 4, 128),   # mlp2048 W2: grouped
    (2048, 2048, 8, 128),
    (1024, 64, 8, 128),     # mlp1024x64 W2: tn=1, dp | tk
    (256, 1024, 4, 128),    # lstm2x256 wx
    (512, 2048, 8, 128),    # lstm3 wx
    (64, 64, 2, 16),        # tiny test arch
]


def _dense_ref(x, w, dp, b0, tile):
    return x @ (w * patterns.tile_mask(w.shape[0], w.shape[1], dp, b0,
                                       tile))


@pytest.mark.parametrize("k,n,dp,tile", REGISTRY_SHAPES)
def test_dispatcher_matches_dense_reference(k, n, dp, tile):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, k)) * 0.2
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.2
    for b0v in {0, dp - 1}:
        b0 = jnp.int32(b0v)
        out = patterns.tdp_matmul(x, w, dp, b0, tile)
        np.testing.assert_allclose(out, _dense_ref(x, w, dp, b0, tile),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("k,n,dp,tile", [(2048, 2048, 4, 128),
                                         (512, 2048, 4, 128)])
def test_grouped_equals_sparse_kernel(k, n, dp, tile):
    """Where both strategies apply, they must agree bitwise-closely."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, k)) * 0.2
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)) * 0.2
    b0 = jnp.int32(1)
    grouped = patterns._tdp_matmul_grouped(x, w, dp, b0, tile)
    rows, cols = patterns.tile_kept_rc(k, n, dp, b0, tile)
    wt = patterns.gather_tiles(w, rows, cols, tile)
    sparse = tile_sparse_matmul(x, wt, rows, cols, n)
    np.testing.assert_allclose(grouped, sparse, rtol=1e-4, atol=1e-4)


def test_grouped_grads_match_sparse_grads():
    k, n, dp, tile = 256, 256, 2, 128
    x = jax.random.normal(jax.random.PRNGKey(4), (4, k)) * 0.2
    w = jax.random.normal(jax.random.PRNGKey(5), (k, n)) * 0.2
    b0 = jnp.int32(0)

    def f_grouped(x, w):
        return jnp.sum(patterns._tdp_matmul_grouped(x, w, dp, b0, tile)**2)

    def f_dense(x, w):
        return jnp.sum(_dense_ref(x, w, dp, b0, tile) ** 2)

    ga = jax.grad(f_grouped, (0, 1))(x, w)
    gb = jax.grad(f_dense, (0, 1))(x, w)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_dispatcher_consumes_bias_even_for_dp1():
    """dp=1 must keep b0 in the graph (AOT input-signature stability —
    XLA DCEs unused parameters; see DESIGN.md §8b)."""
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64))
    w = jax.random.normal(jax.random.PRNGKey(7), (64, 64))

    def fn(b0):
        return patterns.tdp_matmul(x, w, 1, b0, 32)

    jaxpr = jax.make_jaxpr(fn)(jnp.int32(0))
    # b0 must appear as a used invar, not be dropped.
    assert len(jaxpr.jaxpr.invars) == 1
    used = any(
        v is jaxpr.jaxpr.invars[0]
        for eqn in jaxpr.jaxpr.eqns for v in eqn.invars
        if isinstance(v, type(jaxpr.jaxpr.invars[0]))
    )
    assert used, "b0 dropped from the dp=1 graph"
    np.testing.assert_allclose(fn(jnp.int32(0)), x @ w, rtol=1e-4,
                               atol=1e-4)
