//! Batch iterators: shuffled epochs for image classification, contiguous
//! BPTT windows for language modeling (the standard PTB protocol).

use crate::data::mnist::{MnistSyn, IMG_PIXELS};
use crate::util::rng::Rng;

/// Shuffled mini-batch iterator over an image dataset. Reuses internal
/// buffers; each `next_batch` returns (x: [batch * 784], y: [batch]).
#[derive(Debug)]
pub struct MnistBatcher {
    order: Vec<usize>,
    cursor: usize,
    pub batch: usize,
    x: Vec<f32>,
    y: Vec<i32>,
    pub epoch: usize,
}

impl MnistBatcher {
    pub fn new(n: usize, batch: usize) -> Self {
        assert!(batch <= n);
        MnistBatcher {
            order: (0..n).collect(),
            cursor: usize::MAX, // force shuffle on first call
            batch,
            x: vec![0.0; batch * IMG_PIXELS],
            y: vec![0; batch],
            epoch: 0,
        }
    }

    /// Fill the next batch from `data`; reshuffles at epoch boundaries
    /// (drops the ragged tail batch, as Caffe does).
    pub fn next_batch<'a>(&'a mut self, data: &MnistSyn, rng: &mut Rng)
                          -> (&'a [f32], &'a [i32]) {
        if self.cursor == usize::MAX
            || self.cursor + self.batch > self.order.len()
        {
            rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        for (bi, &i) in
            self.order[self.cursor..self.cursor + self.batch].iter()
                .enumerate()
        {
            self.x[bi * IMG_PIXELS..(bi + 1) * IMG_PIXELS]
                .copy_from_slice(data.image(i));
            self.y[bi] = data.labels[i] as i32;
        }
        self.cursor += self.batch;
        (&self.x, &self.y)
    }
}

/// Contiguous BPTT batcher: the token stream is laid out as `batch`
/// parallel contiguous tracks; each call yields the next `seq`-token
/// window with targets shifted by one. x/y layout: [batch, seq] row-major.
#[derive(Debug)]
pub struct BpttBatcher {
    tracks: Vec<i32>, // batch x track_len, row-major
    track_len: usize,
    pub batch: usize,
    pub seq: usize,
    pos: usize,
    x: Vec<i32>,
    y: Vec<i32>,
    pub epoch: usize,
}

impl BpttBatcher {
    pub fn new(tokens: &[i32], batch: usize, seq: usize) -> Self {
        let track_len = tokens.len() / batch;
        assert!(track_len > seq, "corpus too small for batch x seq");
        let mut tracks = vec![0i32; batch * track_len];
        for b in 0..batch {
            tracks[b * track_len..(b + 1) * track_len]
                .copy_from_slice(&tokens[b * track_len..(b + 1) * track_len]);
        }
        BpttBatcher {
            tracks,
            track_len,
            batch,
            seq,
            pos: 0,
            x: vec![0; batch * seq],
            y: vec![0; batch * seq],
            epoch: 0,
        }
    }

    /// Number of windows per epoch.
    pub fn windows_per_epoch(&self) -> usize {
        (self.track_len - 1) / self.seq
    }

    pub fn next_batch(&mut self) -> (&[i32], &[i32]) {
        if self.pos + self.seq + 1 > self.track_len {
            self.pos = 0;
            self.epoch += 1;
        }
        for b in 0..self.batch {
            let base = b * self.track_len + self.pos;
            self.x[b * self.seq..(b + 1) * self.seq]
                .copy_from_slice(&self.tracks[base..base + self.seq]);
            self.y[b * self.seq..(b + 1) * self.seq]
                .copy_from_slice(&self.tracks[base + 1..base + self.seq + 1]);
        }
        self.pos += self.seq;
        (&self.x, &self.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist::MnistSyn;

    #[test]
    fn mnist_batches_cover_epoch_without_repeats() {
        let data = MnistSyn::generate(64, 1);
        let mut b = MnistBatcher::new(64, 16);
        let mut rng = Rng::new(2);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let (_, y) = b.next_batch(&data, &mut rng);
            assert_eq!(y.len(), 16);
            // Track coverage via the shuffled order indices instead of
            // labels (labels repeat); recover by comparing x rows.
            seen.extend(y.iter().cloned().map(|v| v as i64));
        }
        assert_eq!(b.epoch, 1);
        // After one epoch a new shuffle starts.
        b.next_batch(&data, &mut rng);
        assert_eq!(b.epoch, 2);
        assert!(!seen.is_empty());
    }

    #[test]
    fn mnist_batch_contents_match_dataset() {
        let data = MnistSyn::generate(32, 3);
        let mut b = MnistBatcher::new(32, 8);
        let mut rng = Rng::new(4);
        let (x, y) = b.next_batch(&data, &mut rng);
        // Every batch row must be an exact dataset image with its label.
        for bi in 0..8 {
            let row = &x[bi * IMG_PIXELS..(bi + 1) * IMG_PIXELS];
            let found = (0..data.n).any(|i| {
                data.image(i) == row && data.labels[i] as i32 == y[bi]
            });
            assert!(found, "batch row {bi} not found in dataset");
        }
    }

    #[test]
    fn bptt_windows_are_contiguous_and_shifted() {
        let tokens: Vec<i32> = (0..103).collect();
        let mut b = BpttBatcher::new(&tokens, 2, 5);
        let (x, y) = b.next_batch();
        // Track 0 starts at 0, track 1 at track_len = 51.
        assert_eq!(&x[..5], &[0, 1, 2, 3, 4]);
        assert_eq!(&y[..5], &[1, 2, 3, 4, 5]);
        assert_eq!(&x[5..10], &[51, 52, 53, 54, 55]);
        let (x2, _) = b.next_batch();
        assert_eq!(&x2[..5], &[5, 6, 7, 8, 9]);
    }

    #[test]
    fn bptt_epoch_wraps() {
        let tokens: Vec<i32> = (0..40).collect();
        let mut b = BpttBatcher::new(&tokens, 2, 6);
        let per_epoch = b.windows_per_epoch();
        assert_eq!(per_epoch, (20 - 1) / 6);
        for _ in 0..per_epoch {
            b.next_batch();
        }
        assert_eq!(b.epoch, 0);
        b.next_batch();
        assert_eq!(b.epoch, 1);
    }
}
