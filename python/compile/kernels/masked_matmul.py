"""L1 Pallas kernel: matmul with the dropout mask fused on the input side.

This is the *baseline* the paper compares against (Fig. 1a): conventional
random dropout zeroes activations with a Bernoulli 0/1 mask and the next
layer then consumes the masked matrix — the full-size matmul still runs,
which is exactly the inefficiency Approximate Random Dropout removes. Fusing
``(a * mask * scale) @ b`` into one kernel (mask applied tile-by-tile in
VMEM as the operand streams in) is the strongest fair baseline: it saves the
materialization of the masked activation but cannot shrink the matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import matmul, pick_block


def _masked_mm_kernel(a_ref, m_ref, b_ref, s_ref, o_ref):
    h = pl.program_id(2)

    @pl.when(h == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...] * m_ref[...] * s_ref[0]
    o_ref[...] += jnp.dot(a, b_ref[...], preferred_element_type=o_ref.dtype)


def _masked_matmul_impl(a, mask, b, scale):
    m, k = a.shape
    _, n = b.shape
    assert mask.shape == (m, k), f"mask {mask.shape} != lhs ({m},{k})"
    bm, bn, bk = pick_block(m), pick_block(n), pick_block(k)
    grid = (m // bm, n // bn, k // bk)
    scale_arr = jnp.reshape(jnp.asarray(scale, a.dtype), (1,))
    return pl.pallas_call(
        _masked_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
            pl.BlockSpec((1,), lambda i, j, h: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, mask, b, scale_arr)


@jax.custom_vjp
def masked_matmul(a: jax.Array, mask: jax.Array, b: jax.Array,
                  scale: jax.Array) -> jax.Array:
    """``(a * mask * scale) @ b`` — dropout fused into the consuming matmul.

    ``mask`` is a 0/1 float matrix of ``a``'s shape; ``scale`` the
    inverted-dropout correction (1/keep_prob) as a float scalar.
    """
    return _masked_matmul_impl(a, mask, b, scale)


def _fwd(a, mask, b, scale):
    return _masked_matmul_impl(a, mask, b, scale), (a, mask, b, scale)


def _bwd(res, g):
    a, mask, b, scale = res
    # d/da [(a*m*s) @ b] = (g @ b^T) * m * s; d/db = (a*m*s)^T @ g.
    da = matmul(g, b.T) * mask * scale
    db = matmul((a * mask * scale).T, g)
    return da, None, db, None


masked_matmul.defvjp(_fwd, _bwd)
