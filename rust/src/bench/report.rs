//! Machine-readable bench reports: the one writer behind every checked-in
//! `BENCH_*.json` (`benches/sparse_speedup.rs`, `benches/micro_hotpath.rs`).
//!
//! Schema (stable; downstream tooling and the ROADMAP's perf-trajectory
//! tracking parse these):
//!
//! ```json
//! {
//!   "bench": "<name>",
//!   "version": 1,
//!   "provenance": "<which harness produced the numbers>",
//!   ... free-form meta (threads, backend, smoke, ...) ...,
//!   "rows": [ { per-measurement fields }, ... ]
//! }
//! ```
//!
//! Reports land in the repo root by default (next to ROADMAP.md) so runs
//! from `rust/` always overwrite the same checked-in files; `AD_BENCH_OUT`
//! redirects the directory (CI points it at an artifact dir).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::{info, warn_};

pub struct BenchReport {
    name: String,
    meta: BTreeMap<String, Json>,
    rows: Vec<Json>,
}

impl BenchReport {
    /// New report named `name`, with `provenance` identifying the harness
    /// that produced the numbers (file path of the bench binary).
    pub fn new(name: &str, provenance: &str) -> BenchReport {
        let mut meta = BTreeMap::new();
        meta.insert("version".to_string(), Json::num(1.0));
        meta.insert("provenance".to_string(), Json::str(provenance));
        BenchReport { name: name.to_string(), meta, rows: Vec::new() }
    }

    /// Set one top-level meta field.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        self.meta.insert(key.to_string(), value);
        self
    }

    /// Append one measurement row.
    pub fn row(&mut self, fields: Vec<(&str, Json)>) -> &mut Self {
        self.rows.push(Json::obj(fields));
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::str(&self.name));
        for (k, v) in &self.meta {
            obj.insert(k.clone(), v.clone());
        }
        obj.insert("rows".to_string(), Json::Arr(self.rows.clone()));
        Json::Obj(obj)
    }

    /// Write pretty JSON (+ trailing newline) to `path`.
    pub fn write(&self, path: &Path) -> Result<()> {
        let text = format!("{}\n", self.to_json().pretty());
        std::fs::write(path, text)
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Default location of `file_name`: `$AD_BENCH_OUT/` when set, else
    /// the repo root (one level above the cargo manifest) — but only if
    /// that baked build-machine path exists *at run time*. A relocated
    /// binary (CI artifact, another checkout, a container without the
    /// build tree) falls back to the current directory instead of trying
    /// to write into a directory that is not there.
    pub fn default_path(file_name: &str) -> PathBuf {
        let baked = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/.."));
        let (dir, fell_back) = resolve_out_dir(
            std::env::var_os("AD_BENCH_OUT").map(PathBuf::from), baked);
        if fell_back {
            warn_!("bench report: baked repo root {} is absent on this \
                    machine — writing {file_name} to the current \
                    directory (set AD_BENCH_OUT to choose)",
                   baked.display());
        }
        dir.join(file_name)
    }

    /// Write to [`Self::default_path`] and return where it landed.
    pub fn write_default(&self, file_name: &str) -> Result<PathBuf> {
        let path = Self::default_path(file_name);
        self.write(&path)?;
        info!("bench report: wrote {}", path.display());
        Ok(path)
    }
}

/// The report-directory policy, pure so the relocated-binary behavior is
/// unit-testable: explicit `AD_BENCH_OUT` wins unconditionally; the
/// baked repo root is used only when it exists on the running machine;
/// otherwise the current directory (second element reports the
/// fallback, for the loud log).
fn resolve_out_dir(env_out: Option<PathBuf>, baked: &Path)
                   -> (PathBuf, bool) {
    match env_out {
        Some(d) => (d, false),
        None if baked.is_dir() => (baked.to_path_buf(), false),
        None => (PathBuf::from("."), true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn report_shape_roundtrips() {
        let mut r = BenchReport::new("sparse_speedup", "benches/x.rs");
        r.set("threads", Json::num(4.0));
        r.row(vec![("arch", Json::str("mlpsyn")),
                   ("median_step_s", Json::num(0.01))]);
        r.row(vec![("arch", Json::str("lstmsyn")),
                   ("median_step_s", Json::num(0.02))]);
        assert_eq!(r.n_rows(), 2);
        let v = json::parse(&r.to_json().pretty()).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("sparse_speedup"));
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("threads").unwrap().as_usize(), Some(4));
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].path("arch").unwrap().as_str(), Some("mlpsyn"));
    }

    #[test]
    fn out_dir_resolution_survives_relocated_binaries() {
        // Explicit override always wins, even over an existing baked dir.
        let tmp = std::env::temp_dir();
        let (d, fell) = resolve_out_dir(Some(PathBuf::from("/x/y")), &tmp);
        assert_eq!(d, PathBuf::from("/x/y"));
        assert!(!fell);
        // Baked path exists (build machine): use it.
        let (d, fell) = resolve_out_dir(None, &tmp);
        assert_eq!(d, tmp);
        assert!(!fell);
        // Baked path is gone (binary relocated): fall back to cwd — the
        // pre-fix behavior was to return the dead build-machine path.
        let dead = tmp.join(format!("ad-gone-{}", std::process::id()));
        let (d, fell) = resolve_out_dir(None, &dead);
        assert_eq!(d, PathBuf::from("."));
        assert!(fell, "fallback must be loud");
        // A *file* at the baked path is not a usable directory either.
        let f = tmp.join(format!("ad-file-{}", std::process::id()));
        std::fs::write(&f, b"x").unwrap();
        let (d, _) = resolve_out_dir(None, &f);
        assert_eq!(d, PathBuf::from("."));
        std::fs::remove_file(&f).ok();
    }

    #[test]
    fn write_and_reload() {
        let dir = std::env::temp_dir().join(format!(
            "ad-bench-report-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let mut r = BenchReport::new("t", "here");
        r.row(vec![("x", Json::num(1.0))]);
        r.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(json::parse(text.trim()).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
