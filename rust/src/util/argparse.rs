//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Model: `binary <subcommand> [--key value]... [--flag]...`. Typed
//! accessors with defaults; `--help` text is assembled from registered
//! options so every subcommand self-documents.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list: `--rates 0.3,0.5,0.7`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.options.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
        }
    }

    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.options.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare `--name value` pair is greedy (option, not flag);
        // flags must come last or use `--name=value` style for options.
        let a = parse(&["train-mlp", "pos1", "--steps", "100", "--lr=0.05",
                        "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train-mlp"));
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("lr", 0.0), 0.05);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--rates", "0.3,0.5,0.7", "--sizes", "20,40"]);
        assert_eq!(a.f64_list_or("rates", &[]), vec![0.3, 0.5, 0.7]);
        assert_eq!(a.usize_list_or("sizes", &[]), vec![20, 40]);
        assert_eq!(a.f64_list_or("missing", &[1.0]), vec![1.0]);
    }

    #[test]
    fn flag_at_end_and_defaults() {
        let a = parse(&["run", "--dry-run"]);
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.str_or("out", "default.txt"), "default.txt");
    }
}
