//! `approx-dropout` CLI: train MLPs/LSTMs with conventional or approximate
//! random dropout, run the pattern search, generate data, inspect
//! artifacts. See `approx-dropout help`.

use std::path::Path;

use anyhow::{bail, Result};

use approx_dropout::bench::BenchReport;
use approx_dropout::config::TrainConfig;
use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, TrainMetrics, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::obs;
use approx_dropout::search::{self, SearchConfig};
use approx_dropout::service;
use approx_dropout::util::argparse::Args;
use approx_dropout::util::json::Json;
use approx_dropout::util::log;
use approx_dropout::util::Timer;
use approx_dropout::{info, warn_};

const HELP: &str = "\
approx-dropout — Approximate Random Dropout (Song et al. 2018) repro

USAGE: approx-dropout <command> [options]

COMMANDS:
  train-mlp    Train an MLP on synthetic MNIST
               --tag mlp2048x2048 --variant conv|rdp|tdp --rates 0.5,0.5
               --steps 200 --lr 0.01 --seed 42 --n-train 10000
               --n-test 2000 [--shared-dp] [--pipeline] [--workers N]
               [--config file.toml]
  train-lstm   Train an LSTM LM on the synthetic corpus
               --tag lstm2x256v2048b20 --variant rdp --rate 0.5
               --steps 100 --lr 0.5 --seed 42 [--tokens 200000]
               [--pipeline] [--workers N]
               (--pipeline: double-buffered step assembly; identical
                trajectories, assembly overlapped with execution)
               (--workers: data-parallel gradient threads over a fixed
                leaf partition of each batch; trajectories, dispatch
                sequences and checkpoint bits are identical for any N,
                and checkpoints resume elastically across N — hermetic
                backends only; see rust/DESIGN.md section 13)
  search       Run the SGD-based pattern search (Algorithm 1)
               --rate 0.7 [--support 1,2,4,8 | --n 10 (paper {1..N})]
  serve        Run a fleet of training jobs from a TOML manifest
               --jobs jobs.toml [--workers N] [--tick N]
               [--checkpoint-every N] [--ckpt-dir DIR] [--out DIR]
               (jobs with an existing <ckpt-dir>/<name>.ckpt resume from
                it; per-job REPORT_<name>.json lands in --out)
  infer        Serve checkpointed models with dynamic micro-batching and
               benchmark request latency
               --ckpt FILE [--tag mlpsyn] [--model default]
               [--requests 64] [--clients 8] [--slots 2] [--max-batch 0]
               [--seed 42] [--tokens 20000] [--expect-hash HEX]
               [--check-parity]
               (hermetic backends only: per-example eval outputs are an
                interpreter extension. Concurrent requests coalesce into
                one padded eval dispatch per slot turn; --check-parity
                proves coalesced results bit-identical to sequential
                ones; --expect-hash pins the checkpoint's config hash.
                Writes BENCH_infer.json: p50/p99 latency + QPS)
  info         List artifacts in the manifest [--filter substr]
  help         This message

CHECKPOINTS (train-mlp / train-lstm):
  --ckpt-out FILE     write a *.ckpt at the end of the run
  --resume-from FILE  restore a *.ckpt before training (--steps then run
                      on top; the trajectory continues bit-exactly)
  --curve-out FILE    write the recorded loss curve as JSON
  --trace-out FILE    write a Chrome trace-event JSON of phase spans
                      (implies AD_TRACE=on; open in chrome://tracing
                      or Perfetto)

OBSERVABILITY: every train-mlp/train-lstm/serve/infer run exports the
     process metrics registry as METRICS_<run>.json (validate with
     tools/check_metrics.py); with AD_TRACE=on, per-phase timing rows
     (sample/assemble/marshal/execute, prep/fwd/softmax/bptt/sgd) are
     included. Tracing never perturbs trajectories — runs are
     bit-identical with it on or off.

ENV: AD_ARTIFACTS (artifacts dir), AD_LOG (error|warn|info|debug|trace),
     AD_TRACE (on|off; default off — phase-scoped span timing),
     AD_BACKEND (pjrt|reference|sparse; reference = pure-Rust
     masked-dense interpreter, sparse = multithreaded row/tile-skipping
     compute engine — both run with no artifacts, e.g. train-mlp
     --tag mlpsyn on the built-in synthetic registry),
     AD_THREADS (sparse backend worker count; default = all cores),
     AD_WORKERS (data-parallel gradient workers for train-mlp/
     train-lstm; --workers wins; empty = unset = single-threaded),
     AD_TIME_WINDOW (LSTM pattern window in timesteps; default \"seq\" =
     one draw per step; W dividing seq re-draws the pattern bias within
     the step, W = k*seq holds one draw across k steps — incompatible
     values warn and fall back; see rust/DESIGN.md section 3e)";

fn main() -> Result<()> {
    log::init_from_env();
    obs::trace::init_from_env();
    let args = Args::parse(std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;
    // --trace-out implies tracing (and event collection): asking for a
    // trace file with AD_TRACE unset should produce a trace, not an
    // empty JSON array.
    if args.get("trace-out").is_some() {
        obs::trace::force_enabled(true);
        obs::trace::collect_events(true);
    }
    match args.subcommand.as_deref() {
        Some("train-mlp") => train_mlp(&args),
        Some("train-lstm") => train_lstm(&args),
        Some("search") => run_search(&args),
        Some("serve") => serve(&args),
        Some("infer") => infer(&args),
        Some("info") => info_cmd(&args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}' (try help)"),
    }
}

/// Resolve the data-parallel worker count for the train commands:
/// `--workers` wins over `AD_WORKERS`; an *empty* env value counts as
/// unset (the CI matrix sets `AD_WORKERS: ""` on non-sharded legs);
/// `None` keeps the plain single-threaded step path. Zero and negative
/// counts are rejected loudly — the sharded N=1 path exists (it is the
/// bit-identity baseline), but "no workers" is spelled by omission.
fn workers_from_args(args: &Args) -> Result<Option<usize>> {
    let src = match args.get("workers") {
        Some(v) => Some(("--workers", v.to_string())),
        None => match std::env::var("AD_WORKERS") {
            Ok(v) if !v.is_empty() => Some(("AD_WORKERS", v)),
            _ => None,
        },
    };
    match src {
        None => Ok(None),
        Some((what, v)) => match v.parse::<i64>() {
            Ok(n) if n >= 1 => Ok(Some(n as usize)),
            _ => bail!("{what}={v:?}: worker count must be an integer \
                        >= 1 (omit it entirely for the single-threaded \
                        path)"),
        },
    }
}

fn config_from_args(args: &Args, default_rates: &[f64]) -> Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_toml(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    if let Some(tag) = args.get("tag") {
        cfg.tag = tag.to_string();
    }
    if let Some(v) = args.get("variant") {
        cfg.variant = Variant::parse(v)?;
    }
    cfg.rates = args.f64_list_or("rates", default_rates);
    if let Some(r) = args.get("rate") {
        let r: f64 = r.parse().map_err(|_| anyhow::anyhow!("bad --rate"))?;
        cfg.rates = vec![r; cfg.rates.len()];
    }
    cfg.support = args.usize_list_or("support", &cfg.support.clone());
    cfg.shared_dp = cfg.shared_dp || args.has_flag("shared-dp");
    cfg.steps = args.usize_or("steps", cfg.steps);
    cfg.lr = args.f64_or("lr", cfg.lr);
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.n_train = args.usize_or("n-train", cfg.n_train);
    cfg.n_test = args.usize_or("n-test", cfg.n_test);
    cfg.validate()?;
    Ok(cfg)
}

fn train_mlp(args: &Args) -> Result<()> {
    let cfg = config_from_args(args, &[0.5, 0.5])?;
    info!("config: {cfg:?}");
    let manifest = approx_dropout::manifest_or_builtin()?;
    let cache = ExecutorCache::from_env(manifest)?;
    info!("backend: {}", cache.backend().name());
    let schedule = Schedule::new(cfg.variant, &cfg.rates, &cfg.support,
                                 cfg.shared_dp)?;
    if cfg.variant != Variant::Conv {
        for (i, d) in schedule.dists.iter().enumerate() {
            info!("site {i}: K = {:?} (rate {:.4}, entropy {:.3})",
                  d.probs.iter().map(|p| (p * 1e3).round() / 1e3)
                      .collect::<Vec<_>>(),
                  d.expected_rate(), d.entropy());
        }
    }
    let (train, test) = MnistSyn::train_test(cfg.n_train, cfg.n_test,
                                             cfg.seed);
    let mut tr = MlpTrainer::new(&cache, &cfg.tag, schedule, cfg.n_train,
                                 cfg.lr as f32, cfg.seed)?;
    if let Some(p) = args.get("resume-from") {
        tr.resume_from(Path::new(p))?;
        info!("resumed from {p} at step {}", tr.state.step);
    }
    info!("compiling {} executable(s)...", tr.executable_names().len());
    tr.warmup()?;
    let workers = workers_from_args(args)?;
    if workers.is_some() && args.has_flag("pipeline") {
        bail!("--pipeline and --workers are mutually exclusive (the \
               sharded path already spreads each step across threads)");
    }
    let report_every = (cfg.steps / 10).max(1);
    if let Some(w) = workers {
        info!("data-parallel: {w} gradient worker(s)");
        for s in 0..cfg.steps {
            let (loss, acc) = tr.sharded(w)?.step_with(&train)?;
            if (s + 1) % report_every == 0 {
                info!("step {:>5}: loss {loss:.4} acc {acc:.3} \
                       ({:.1} ms/step)", s + 1,
                      tr.metrics.steady_mean_step_s(1) * 1e3);
            }
        }
    } else if args.has_flag("pipeline") {
        let mut done = 0;
        while done < cfg.steps {
            let n = report_every.min(cfg.steps - done);
            tr.train_pipelined(&train, n)?;
            done += n;
            info!("step {:>5}: loss {:.4} acc {:.3} ({:.1} ms/step)", done,
                  tr.metrics.window_mean_loss(n),
                  tr.metrics.running_train_acc(),
                  tr.metrics.steady_mean_step_s(1) * 1e3);
        }
    } else {
        for s in 0..cfg.steps {
            let (loss, acc) = tr.step(&train)?;
            if (s + 1) % report_every == 0 {
                info!("step {:>5}: loss {loss:.4} acc {acc:.3} \
                       ({:.1} ms/step)", s + 1,
                      tr.metrics.steady_mean_step_s(1) * 1e3);
            }
        }
    }
    let (eval_loss, eval_acc) = tr.evaluate(&test)?;
    println!("final: test loss {eval_loss:.4}, test accuracy \
              {:.2}%, median step {:.1} ms",
             eval_acc * 100.0, tr.metrics.median_step_s() * 1e3);
    finish_run(args, &tr.metrics, &cfg.tag, "train-mlp",
               |p| tr.save_checkpoint(p))
}

/// Shared `--curve-out` / `--ckpt-out` / telemetry epilogue for the
/// train commands. `run` names the METRICS_<run>.json export.
fn finish_run<F>(args: &Args, metrics: &TrainMetrics, tag: &str,
                 run: &str, save: F) -> Result<()>
where
    F: FnOnce(&Path) -> Result<()>,
{
    if let Some(p) = args.get("curve-out") {
        write_curve(metrics, tag, Path::new(p))?;
        info!("loss curve written to {p}");
    }
    if let Some(p) = args.get("ckpt-out") {
        save(Path::new(p))?;
        info!("checkpoint written to {p}");
    }
    if let Some(p) = args.get("trace-out") {
        let n = obs::trace::write_chrome_trace(Path::new(p))?;
        info!("chrome trace ({n} events) written to {p}");
    }
    write_metrics_logged(run);
    Ok(())
}

/// Export the process metrics registry; a failed write warns loudly but
/// never fails a run that already trained successfully.
fn write_metrics_logged(run: &str) {
    match obs::write_metrics(run) {
        Ok(p) => info!("metrics written to {}", p.display()),
        Err(e) => warn_!("metrics export failed ({e:#})"),
    }
}

/// Loss curve as JSON (absolute step numbers — a resumed run's curve
/// concatenates exactly onto its parent's, which the CI resume smoke
/// checks).
fn write_curve(metrics: &TrainMetrics, tag: &str, path: &Path)
               -> Result<()> {
    let mut r = BenchReport::new("curve", "approx-dropout --curve-out");
    r.set("tag", Json::str(tag));
    for p in &metrics.curve {
        r.row(vec![
            ("step", Json::num(p.step as f64)),
            ("loss", Json::num(p.loss)),
            ("acc", Json::num(p.acc)),
        ]);
    }
    r.write(path)
}

fn train_lstm(args: &Args) -> Result<()> {
    let mut cfg = config_from_args(args, &[0.5, 0.5])?;
    if args.get("config").is_none() && args.get("tag").is_none() {
        cfg.tag = "lstm2x256v2048b20".into();
    }
    let n_tokens = args.usize_or("tokens", 200_000);
    info!("config: {cfg:?}");
    let manifest = approx_dropout::manifest_or_builtin()?;
    // Infer layer count (sites) and vocab from the conv artifact.
    let conv = manifest.get(&format!("{}_conv", cfg.tag))?;
    let sites = conv.sites;
    let vocab = match &conv.arch {
        approx_dropout::runtime::ArchMeta::Lstm { vocab, .. } => *vocab,
        _ => bail!("not an lstm tag"),
    };
    if cfg.rates.len() != sites {
        let r = cfg.rates[0];
        cfg.rates = vec![r; sites];
    }
    let cache = ExecutorCache::from_env(manifest)?;
    info!("backend: {}", cache.backend().name());
    // LSTM artifacts cover equal-dp combos only -> shared dp sampling.
    let schedule = Schedule::new(cfg.variant, &cfg.rates, &cfg.support,
                                 cfg.variant != Variant::Conv)?;
    let corpus = Corpus::generate(vocab, n_tokens, n_tokens / 10,
                                  n_tokens / 10, cfg.seed);
    let mut tr = LstmTrainer::new(&cache, &cfg.tag, schedule, &corpus.train,
                                  cfg.lr as f32, cfg.seed)?;
    if let Some(p) = args.get("resume-from") {
        tr.resume_from(Path::new(p))?;
        info!("resumed from {p} at step {}", tr.state.step);
    }
    info!("compiling {} executable(s)...", tr.executable_names().len());
    tr.warmup()?;
    let workers = workers_from_args(args)?;
    if workers.is_some() && args.has_flag("pipeline") {
        bail!("--pipeline and --workers are mutually exclusive (the \
               sharded path already spreads each step across threads)");
    }
    let report_every = (cfg.steps / 10).max(1);
    if let Some(w) = workers {
        info!("data-parallel: {w} gradient worker(s)");
        for s in 0..cfg.steps {
            let (loss, acc) = tr.sharded(w)?.step_with(&())?;
            if (s + 1) % report_every == 0 {
                info!("step {:>5}: loss {loss:.4} ppl {:.1} acc \
                       {acc:.3} ({:.0} ms/step)", s + 1, loss.exp(),
                      tr.metrics.steady_mean_step_s(1) * 1e3);
            }
        }
    } else if args.has_flag("pipeline") {
        let mut done = 0;
        while done < cfg.steps {
            let n = report_every.min(cfg.steps - done);
            tr.train_pipelined(&(), n)?;
            done += n;
            let loss = tr.metrics.window_mean_loss(n);
            info!("step {:>5}: loss {loss:.4} ppl {:.1} acc {:.3} \
                   ({:.0} ms/step)", done, loss.exp(),
                  tr.metrics.running_train_acc(),
                  tr.metrics.steady_mean_step_s(1) * 1e3);
        }
    } else {
        for s in 0..cfg.steps {
            let (loss, acc) = tr.step()?;
            if (s + 1) % report_every == 0 {
                info!("step {:>5}: loss {loss:.4} ppl {:.1} acc {acc:.3} \
                       ({:.0} ms/step)", s + 1, loss.exp(),
                      tr.metrics.steady_mean_step_s(1) * 1e3);
            }
        }
    }
    let (xent, ppl, acc) = tr.evaluate(&corpus.valid)?;
    println!("final: valid xent {xent:.4} nats, perplexity {ppl:.1}, \
              token accuracy {:.2}%, median step {:.0} ms \
              (unigram baseline ppl {:.1})",
             acc * 100.0, tr.metrics.median_step_s() * 1e3,
             corpus.unigram_xent(&corpus.valid).exp());
    finish_run(args, &tr.metrics, &cfg.tag, "train-lstm",
               |p| tr.save_checkpoint(p))
}

fn serve(args: &Args) -> Result<()> {
    let jobs_path = args.get("jobs").ok_or_else(
        || anyhow::anyhow!("serve requires --jobs <file.toml> (see \
                            examples/jobs.toml)"))?;
    let (specs, mut cfg) =
        service::load_jobs_manifest(Path::new(jobs_path))?;
    if let Some(w) = args.get("workers") {
        cfg.slots = w.parse()
            .map_err(|_| anyhow::anyhow!("bad --workers"))?;
    }
    if let Some(t) = args.get("tick") {
        cfg.tick_steps = t.parse()
            .map_err(|_| anyhow::anyhow!("bad --tick"))?;
    }
    if let Some(c) = args.get("checkpoint-every") {
        cfg.checkpoint_every = c.parse()
            .map_err(|_| anyhow::anyhow!("bad --checkpoint-every"))?;
    }
    if let Some(d) = args.get("ckpt-dir") {
        cfg.ckpt_dir = Some(d.into());
    }
    if let Some(d) = args.get("out") {
        cfg.out_dir = Some(d.into());
    }
    if cfg.slots == 0 || cfg.tick_steps == 0 {
        bail!("serve: workers and tick must be positive");
    }
    let manifest = approx_dropout::manifest_or_builtin()?;
    let cache = ExecutorCache::from_env(manifest)?;
    info!("serving {} job(s) over {} slot(s) (tick {} steps, backend \
           {})", specs.len(), cfg.slots, cfg.tick_steps,
          cache.backend().name());
    let report = service::run_jobs(&cache, &specs, &cfg)?;
    print!("{}", service::summarize(&report));
    write_metrics_logged("serve");
    service::ensure_all_ok(&report)
}

fn infer(args: &Args) -> Result<()> {
    let ckpt = args.get("ckpt").ok_or_else(
        || anyhow::anyhow!("infer requires --ckpt <file.ckpt> (write one \
                            with train-mlp/train-lstm --ckpt-out or a \
                            serve --ckpt-dir)"))?;
    let tag = args.str_or("tag", "mlpsyn");
    let model = args.str_or("model", "default");
    let requests = args.usize_or("requests", 64).max(1);
    let clients = args.usize_or("clients", 8).max(1);
    let slots = args.usize_or("slots", 2).max(1);
    let max_batch = args.usize_or("max-batch", 0);
    let seed = args.u64_or("seed", 42);
    let expect_hash = args.get("expect-hash")
        .map(service::checkpoint::parse_hex_u64)
        .transpose()?;
    let manifest = approx_dropout::manifest_or_builtin()?;
    let cache = ExecutorCache::from_env(manifest)?;
    info!("backend: {}", cache.backend().name());
    let examples = example_pool(&cache, &tag, requests, seed,
                                args.usize_or("tokens", 20_000))?;
    let spec = service::ModelSpec {
        name: model.clone(),
        tag: tag.clone(),
        ckpt: ckpt.into(),
        expect_hash,
    };

    if args.has_flag("check-parity") {
        check_parity(&cache, &spec, &examples)?;
        println!("parity: coalesced results bit-identical to sequential \
                  dispatches ({} requests)", examples.len());
    }

    let server = service::InferServer::start(
        &cache, std::slice::from_ref(&spec),
        &service::InferConfig { slots, max_batch })?;
    let wall = Timer::start();
    let lat_ms = std::thread::scope(|scope| -> Result<Vec<f64>> {
        let server = &server;
        let model = &model;
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                // Interleaved split so every client sees the same mix.
                let chunk: Vec<service::Example> = examples.iter().cloned()
                    .skip(c).step_by(clients).collect();
                scope.spawn(move || -> Result<Vec<f64>> {
                    let mut out = Vec::with_capacity(chunk.len());
                    for ex in chunk {
                        let r = recv_response(
                            server.submit(service::InferRequest {
                                model: model.clone(),
                                example: ex,
                            })?)?;
                        out.push(r.latency_s * 1e3);
                    }
                    Ok(out)
                })
            })
            .collect();
        let mut all = Vec::with_capacity(requests);
        for h in handles {
            all.extend(h.join().map_err(
                |_| anyhow::anyhow!("client thread panicked"))??);
        }
        Ok(all)
    })?;
    let wall_s = wall.elapsed_s();
    let st = server.stats().into_iter().next()
        .expect("one model was registered");
    let qps = requests as f64 / wall_s.max(1e-9);
    let p50 = approx_dropout::util::stats::percentile(&lat_ms, 50.0);
    let p99 = approx_dropout::util::stats::percentile(&lat_ms, 99.0);
    println!("served {requests} request(s) from {clients} client(s) in \
              {wall_s:.3}s: {qps:.1} req/s, p50 {p50:.3} ms, p99 \
              {p99:.3} ms, max coalesced batch {}",
             st.max_batch_observed);

    let mut r = BenchReport::new("infer", "approx-dropout infer");
    r.set("backend", Json::str(cache.backend().name()));
    r.set("tag", Json::str(&tag));
    r.set("slots", Json::num(slots as f64));
    r.set("step", Json::num(st.step as f64));
    r.set("config_hash",
          Json::str(&service::checkpoint::hex_u64(st.config_hash)));
    r.row(vec![
        ("model", Json::str(&st.name)),
        ("requests", Json::num(requests as f64)),
        ("clients", Json::num(clients as f64)),
        ("qps", Json::num(qps)),
        ("p50_ms", Json::num(p50)),
        ("p99_ms", Json::num(p99)),
        ("mean_ms", Json::num(
            approx_dropout::util::stats::mean(&lat_ms))),
        ("max_batch_observed", Json::num(st.max_batch_observed as f64)),
    ]);
    let path = r.write_default("BENCH_infer.json")?;
    println!("report: {}", path.display());
    write_metrics_logged("infer");
    Ok(())
}

/// Deterministic request pool for `infer`: MLP tags get synthetic
/// images (any `n_in`, the toy test archs included), LSTM tags get
/// consecutive windows of the synthetic corpus' validation split — the
/// same generator the trainers evaluate on.
fn example_pool(cache: &ExecutorCache, tag: &str, requests: usize,
                seed: u64, tokens: usize)
                -> Result<Vec<service::Example>> {
    use approx_dropout::runtime::ArchMeta;
    use approx_dropout::util::rng::Rng;
    let conv = cache.manifest().get(&format!("{tag}_conv"))?;
    Ok(match &conv.arch {
        ArchMeta::Mlp { n_in, n_out, .. } => {
            let mut rng = Rng::new(seed);
            (0..requests)
                .map(|i| {
                    let x: Vec<f32> = (0..*n_in)
                        .map(|_| rng.uniform(0.0, 1.0) as f32)
                        .collect();
                    service::Example::Mlp { x, y: (i % n_out) as i32 }
                })
                .collect()
        }
        ArchMeta::Lstm { vocab, seq, .. } => {
            let corpus = Corpus::generate(*vocab, tokens, tokens / 10,
                                          tokens / 10, seed);
            let v = &corpus.valid;
            if v.len() < seq + 1 {
                bail!("--tokens {tokens} leaves a validation split of {} \
                       tokens — too small for one {seq}-token window",
                      v.len());
            }
            (0..requests)
                .map(|i| {
                    let start = (i * seq) % (v.len() - seq);
                    service::Example::Lstm {
                        x: v[start..start + seq].to_vec(),
                        y: v[start + 1..start + seq + 1].to_vec(),
                    }
                })
                .collect()
        }
    })
}

/// `--check-parity`: per-request results from coalesced dispatches must
/// be bit-identical to a server that dispatches every request alone
/// (`max_batch = 1`) — the correctness contract of micro-batching.
fn check_parity(cache: &ExecutorCache, spec: &service::ModelSpec,
                examples: &[service::Example]) -> Result<()> {
    let solo = service::InferServer::start(
        cache, std::slice::from_ref(spec),
        &service::InferConfig { slots: 1, max_batch: 1 })?;
    let mut seq = Vec::with_capacity(examples.len());
    for ex in examples {
        let r = recv_response(solo.submit(service::InferRequest {
            model: spec.name.clone(),
            example: ex.clone(),
        })?)?;
        seq.push((r.loss, r.correct));
    }
    drop(solo);

    let srv = service::InferServer::start(
        cache, std::slice::from_ref(spec),
        &service::InferConfig { slots: 1, max_batch: 0 })?;
    // Hold the only slot while every request queues: the worker wakes
    // with a full queue and coalesces maximally.
    let hold = srv.gate().acquire();
    let tickets: Vec<service::Ticket> = examples.iter()
        .map(|ex| srv.submit(service::InferRequest {
            model: spec.name.clone(),
            example: ex.clone(),
        }))
        .collect::<Result<_>>()?;
    drop(hold);
    let mut max_seen = 0;
    for (i, t) in tickets.into_iter().enumerate() {
        let r = recv_response(t)?;
        max_seen = max_seen.max(r.batch);
        if r.loss.to_bits() != seq[i].0.to_bits()
            || r.correct.to_bits() != seq[i].1.to_bits()
        {
            bail!("parity violation at request {i}: coalesced (loss {}, \
                   correct {}) != sequential (loss {}, correct {})",
                  r.loss, r.correct, seq[i].0, seq[i].1);
        }
    }
    if examples.len() > 1 && max_seen < 2 {
        bail!("parity run never coalesced (max batch {max_seen} over {} \
               requests)", examples.len());
    }
    Ok(())
}

fn recv_response(t: service::Ticket) -> Result<service::InferResponse> {
    t.recv()
        .map_err(|_| anyhow::anyhow!("inference worker hung up"))?
        .map_err(|e| anyhow::anyhow!(e))
}

fn run_search(args: &Args) -> Result<()> {
    let rate = args.f64_or("rate", 0.5);
    let cfg = SearchConfig::default();
    let result = if let Some(n) = args.get("n") {
        let n: usize = n.parse().map_err(|_| anyhow::anyhow!("bad --n"))?;
        search::search_paper(rate, n, &cfg)
    } else {
        let support = args.usize_list_or("support", &[1, 2, 4, 8]);
        search::search(rate, &support, &cfg)
    };
    println!("target rate     : {rate}");
    println!("achieved rate   : {:.5}", result.achieved_rate);
    println!("iterations      : {}", result.iters);
    println!("entropy         : {:.4} nats",
             result.distribution.entropy());
    println!("distribution K  :");
    for (dp, p) in result.distribution.support.iter()
        .zip(&result.distribution.probs)
    {
        println!("  dp={dp:<3} p_u={:<6.4} k={p:.5}",
                 (*dp as f64 - 1.0) / *dp as f64);
    }
    Ok(())
}

fn info_cmd(args: &Args) -> Result<()> {
    let manifest = approx_dropout::manifest_or_builtin()?;
    let filter = args.str_or("filter", "");
    println!("{:<34} {:>7} {:>6} {:>8} {:>9}", "artifact", "variant",
             "dp", "inputs", "exists");
    let mut shown = 0;
    for (name, a) in &manifest.artifacts {
        if !name.contains(&filter) {
            continue;
        }
        let dp: Vec<String> = a.dp.iter().map(|d| d.to_string()).collect();
        println!("{:<34} {:>7} {:>6} {:>8} {:>9}", name, a.variant,
                 dp.join(","), a.inputs.len(),
                 manifest.hlo_path(a).exists());
        shown += 1;
    }
    println!("{shown} artifacts (dp support {:?}, momentum {}, tile {})",
             manifest.dp_support, manifest.momentum, manifest.tile);
    Ok(())
}
