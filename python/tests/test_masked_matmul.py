"""Masked (conventional-dropout) matmul kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_matmul, matmul


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def bern_mask(key, shape, keep):
    return (jax.random.uniform(jax.random.PRNGKey(key), shape)
            < keep).astype(jnp.float32)


@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([4, 8, 20, 32]), k=st.sampled_from([16, 64, 96]),
       n=st.sampled_from([8, 32, 64]),
       keep=st.sampled_from([0.3, 0.5, 0.7, 1.0]),
       seed=st.integers(0, 2**16))
def test_masked_matmul_matches_ref(m, k, n, keep, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))
    mask = bern_mask(seed + 2, (m, k), keep)
    scale = jnp.float32(1.0 / keep)
    out = masked_matmul(a, mask, b, scale)
    expected = (a * mask * scale) @ b
    np.testing.assert_allclose(out, expected, rtol=1e-4, atol=1e-4)


def test_zero_mask_zero_output():
    a = rand(0, (8, 16))
    b = rand(1, (16, 8))
    out = masked_matmul(a, jnp.zeros((8, 16)), b, jnp.float32(2.0))
    np.testing.assert_allclose(out, jnp.zeros((8, 8)), atol=1e-7)


def test_ones_mask_equals_plain_matmul():
    a = rand(2, (8, 32))
    b = rand(3, (32, 16))
    out = masked_matmul(a, jnp.ones((8, 32)), b, jnp.float32(1.0))
    np.testing.assert_allclose(out, matmul(a, b), rtol=1e-5, atol=1e-5)


def test_gradients_respect_mask():
    # d/da must be zero exactly where the mask is zero (those activations
    # never contributed), and the mask itself gets no gradient.
    a = rand(4, (4, 8))
    b = rand(5, (8, 4))
    mask = bern_mask(6, (4, 8), 0.5)

    def f(a, b):
        return jnp.sum(masked_matmul(a, mask, b, jnp.float32(2.0)) ** 2)

    da, db = jax.grad(f, argnums=(0, 1))(a, b)
    np.testing.assert_array_equal(np.asarray(da)[np.asarray(mask) == 0], 0.0)

    def f_ref(a, b):
        return jnp.sum(((a * mask * 2.0) @ b) ** 2)

    da_r, db_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(da, da_r, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(db, db_r, rtol=1e-3, atol=1e-4)
