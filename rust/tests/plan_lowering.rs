//! Property tests of the `SparsityPlan` lowering contract
//! (`runtime::plan`): plan nodes are the ONE path from pattern structure
//! to kernel dispatch, so
//!
//! * the dense kernels must lower every node bit-compatibly with the
//!   raw `Skip`-based entry points they wrap (the refactor invariant —
//!   reference trajectories cannot move), ignoring dynamic masks,
//! * the sparse kernels' dynamic-backward paths (`TnNode::dyn_rows`,
//!   `NtNode::dyn_cols`) must match the static paths bitwise on the
//!   scalar microkernels and dense-under-mask within the 1e-5 contract
//!   otherwise, across randomized shapes, divisors, and masks,
//! * end to end, enabling dynamic backward sparsity must not move a
//!   training trajectory at all: same dispatch sequence, bit-identical
//!   losses, on both architectures and across time windows.

use std::sync::Arc;

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::obs::registry;
use approx_dropout::patterns::{RowPattern, TilePattern};
use approx_dropout::runtime::{DenseKernels, DynMask, GemmNode, Kernels,
                              Manifest, NtNode, Skip, SparseBackend,
                              SparseKernels, TnNode};
use approx_dropout::util::testkit::{self, gen_choice, gen_range,
                                    gen_vec_f32};

const D: Skip = Skip::Dense;

/// Zero the columns of `a [m,k]` that `pat` drops, plus every column in
/// `extra_dead` (simulating ReLU killing whole kept columns at runtime).
fn mask_cols(a: &mut [f32], m: usize, k: usize, pat: &RowPattern,
             extra_dead: &[usize]) {
    for i in 0..m {
        for p in 0..k {
            if !pat.keeps(p) || extra_dead.contains(&p) {
                a[i * k + p] = 0.0;
            }
        }
    }
}

/// Random subset of the pattern's kept columns to force dead.
fn pick_extra_dead(rng: &mut approx_dropout::util::rng::Rng, k: usize,
                   pat: &RowPattern) -> Vec<usize> {
    (0..k)
        .filter(|&p| pat.keeps(p) && gen_range(rng, 0, 3) == 0)
        .collect()
}

// ---------------------------------------------------------------------------
// Dense lowering: node methods == raw dispatch, bitwise
// ---------------------------------------------------------------------------

/// The refactor invariant: for randomized shapes and skips, every
/// `DenseKernels` node entry point returns bit-identical results to the
/// raw `Skip`-based call it replaced — with dynamic masks attached and
/// ignored. This is what keeps reference trajectories, checkpoints, and
/// dispatch sequences frozen through the plan-IR migration.
#[test]
fn dense_node_lowering_bitwise_matches_raw_kernels() {
    let kern = DenseKernels;
    assert!(!kern.dyn_backward(), "dense kernels never honor dyn masks");
    testkit::quickcheck("dense node lowering", |rng| {
        let m = gen_range(rng, 1, 10);
        let dp = *gen_choice(rng, &[1usize, 2, 4]);
        let k = dp * gen_range(rng, 1, 16);
        let n = gen_range(rng, 1, 32);
        let pat = RowPattern::new(k, dp, gen_range(rng, 0, dp));
        let skip = Skip::Rows(pat);
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let extra = pick_extra_dead(rng, k, &pat);
        mask_cols(&mut a, m, k, &pat, &extra);
        let w = gen_vec_f32(rng, k * n, -1.0, 1.0);

        // Forward node, with and without a prepared weight.
        let node = GemmNode::new(skip, D);
        assert_eq!(kern.gemm_node(&a, &w, &node, m, k, n),
                   kern.gemm(&a, &w, m, k, n, &skip, &D));
        let pw = kern.prep(&w, k, n, &skip);
        let node = GemmNode::new(skip, D).with_pw(&pw);
        assert_eq!(kern.gemm_node(&a, &w, &node, m, k, n),
                   kern.gemm_pw(&a, &w, &pw, m, k, n, &skip, &D));

        // Backward nodes carry a live dyn mask; dense must ignore it.
        let mask = DynMask::scan_cols(&a, m, k, &skip)
            .expect("Rows skip always scans");
        assert!(mask.dropped() >= extra.len(),
                "scan must at least find the forced-dead columns");
        let dout = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let tn = TnNode::new(skip, D).with_dyn(Some(&mask));
        let mut got = vec![0.5f32; k * n];
        let mut want = got.clone();
        kern.gemm_tn_acc_node(&a, &dout, &tn, m, k, n, &mut got);
        kern.gemm_tn_acc(&a, &dout, m, k, n, &skip, &D, &mut want);
        assert_eq!(got, want, "dense TN node must ignore dyn_rows");

        let nt = NtNode::new(skip).with_dyn(Some(&mask));
        assert_eq!(kern.gemm_nt_node(&dout, &w, &nt, m, n, k),
                   kern.gemm_nt(&dout, &w, m, n, k, &skip),
                   "dense NT node must ignore dyn_cols");

        // Tile skips lower through the same node path.
        let (tk, tn_dim) = *gen_choice(rng, &[(32usize, 64usize),
                                              (64, 32), (64, 64)]);
        let dpt = *gen_choice(rng, &[2usize, 4]);
        let tpat = TilePattern::new(tk, tn_dim, dpt,
                                    gen_range(rng, 0, dpt), 16);
        let tskip = Skip::Tiles(tpat);
        let at = gen_vec_f32(rng, m * tk, -1.0, 1.0);
        let wt = gen_vec_f32(rng, tk * tn_dim, -1.0, 1.0);
        let pwt = kern.prep(&wt, tk, tn_dim, &tskip);
        let node = GemmNode::new(tskip, D).with_pw(&pwt);
        assert_eq!(kern.gemm_node(&at, &wt, &node, m, tk, tn_dim),
                   kern.gemm_pw(&at, &wt, &pwt, m, tk, tn_dim, &tskip,
                                &D));
    });
}

// ---------------------------------------------------------------------------
// DynMask semantics
// ---------------------------------------------------------------------------

/// `scan_cols` finds exactly (static kept set) ∩ (columns with any
/// nonzero entry), never resurrects a dropped column, and consumes no
/// randomness. Tiles skips refuse the scan by contract.
#[test]
fn dyn_mask_live_set_is_kept_intersect_nonzero() {
    testkit::quickcheck("scan_cols", |rng| {
        let m = gen_range(rng, 1, 12);
        let dp = *gen_choice(rng, &[1usize, 2, 3, 4]);
        let k = dp * gen_range(rng, 1, 16);
        let pat = RowPattern::new(k, dp, gen_range(rng, 0, dp));
        let skip = Skip::Rows(pat);
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let extra = pick_extra_dead(rng, k, &pat);
        mask_cols(&mut a, m, k, &pat, &extra);
        let mask = DynMask::scan_cols(&a, m, k, &skip).unwrap();
        for &j in &mask.live {
            assert!(pat.keeps(j), "live col {j} outside static kept set");
            assert!((0..m).any(|i| a[i * k + j] != 0.0),
                    "live col {j} is all-zero");
        }
        for j in 0..k {
            let nonzero = (0..m).any(|i| a[i * k + j] != 0.0);
            assert_eq!(mask.live.contains(&j), pat.keeps(j) && nonzero,
                       "col {j}");
        }
        assert_eq!(mask.total, pat.kept_indices().len());

        let tpat = TilePattern::new(32, 64, 2, 0, 16);
        let probe = vec![1f32; 32];
        assert!(DynMask::scan_cols(&probe, 1, 32,
                                   &Skip::Tiles(tpat)).is_none(),
                "Tiles must refuse the column scan");
    });
}

// ---------------------------------------------------------------------------
// Sparse dynamic backward: bitwise vs static (scalar), dense-under-mask
// ---------------------------------------------------------------------------

/// Weight-gradient path: the dyn row restriction is bitwise exact — a
/// runtime-dead unit contributes only exact zeros, so skipping it is an
/// IEEE no-op. Dyn-on vs dyn-off sparse (scalar) AND dense-under-mask
/// must all agree bit for bit, and dropped gradient rows keep their
/// prior bytes.
#[test]
fn sparse_dyn_tn_bitwise_matches_static_and_dense() {
    let sdyn = SparseKernels::scalar().with_dyn(true);
    let sstat = SparseKernels::scalar().with_dyn(false);
    assert!(sdyn.dyn_backward() && !sstat.dyn_backward());
    testkit::quickcheck("dyn TN", |rng| {
        let m = gen_range(rng, 1, 12);
        let dpr = *gen_choice(rng, &[1usize, 2, 3, 4]);
        let dpc = *gen_choice(rng, &[1usize, 2]);
        let k = dpr * gen_range(rng, 1, 12);
        let n = dpc * gen_range(rng, 1, 12);
        let pr = RowPattern::new(k, dpr, gen_range(rng, 0, dpr));
        let qc = RowPattern::new(n, dpc, gen_range(rng, 0, dpc));
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let extra = pick_extra_dead(rng, k, &pr);
        mask_cols(&mut a, m, k, &pr, &extra);
        let mut b = gen_vec_f32(rng, m * n, -1.0, 1.0);
        mask_cols(&mut b, m, n, &qc, &[]);
        let (rskip, cskip) = (Skip::Rows(pr), Skip::Rows(qc));
        let mask = DynMask::scan_cols(&a, m, k, &rskip).unwrap();

        let prior = 0.25f32;
        let node = TnNode::new(rskip, cskip).with_dyn(Some(&mask));
        let mut got = vec![prior; k * n];
        sdyn.gemm_tn_acc_node(&a, &b, &node, m, k, n, &mut got);
        let mut stat = vec![prior; k * n];
        sstat.gemm_tn_acc_node(&a, &b, &node, m, k, n, &mut stat);
        assert_eq!(got, stat, "dyn TN != static TN (scalar)");
        let mut dense = vec![prior; k * n];
        DenseKernels.gemm_tn_acc(&a, &b, m, k, n, &D, &D, &mut dense);
        assert_eq!(got, dense, "dyn TN != dense-under-mask");
        for p in 0..k {
            if !mask.live.contains(&p) {
                for j in 0..n {
                    assert_eq!(got[p * n + j], prior,
                               "dyn-dead grad row {p} must stay frozen");
                }
            }
        }

        // The zero-initial-state mask (LSTM t==0): an all-zero operand
        // plus an empty live set must leave the accumulator untouched
        // and still agree with the static walk bitwise.
        let warm = DynMask::zero_state(k);
        assert_eq!(warm.dropped(), k);
        let zeros = vec![0f32; m * k];
        let node = TnNode::new(D, D).with_dyn(Some(&warm));
        let mut got = vec![prior; k * n];
        sdyn.gemm_tn_acc_node(&zeros, &b, &node, m, k, n, &mut got);
        let mut stat = vec![prior; k * n];
        sstat.gemm_tn_acc_node(&zeros, &b, &node, m, k, n, &mut stat);
        assert_eq!(got, stat, "zero-state skip changed bytes");
        assert!(got.iter().all(|&v| v == prior));
    });
}

/// Input-gradient path: the dyn column restriction leaves dyn-dead
/// output columns exactly zero; live columns are bitwise equal to the
/// static result (scalar). Exactness of the step program comes from the
/// downstream ReLU-derivative gate — emulated here — which zeroes
/// exactly the elements the restriction skipped.
#[test]
fn sparse_dyn_nt_exact_under_relu_gate() {
    let sdyn = SparseKernels::scalar().with_dyn(true);
    let sstat = SparseKernels::scalar().with_dyn(false);
    testkit::quickcheck("dyn NT", |rng| {
        let m = gen_range(rng, 1, 12);
        let dp = *gen_choice(rng, &[1usize, 2, 4]);
        let k = dp * gen_range(rng, 1, 12);
        let n = gen_range(rng, 1, 24);
        let pat = RowPattern::new(k, dp, gen_range(rng, 0, dp));
        let skip = Skip::Rows(pat);
        // `act` plays out1: post-ReLU activations with dropped + dead
        // columns; `dout` the upstream gradient; `w` the next weight.
        let mut act = gen_vec_f32(rng, m * k, 0.0, 1.0);
        let extra = pick_extra_dead(rng, k, &pat);
        mask_cols(&mut act, m, k, &pat, &extra);
        let mask = DynMask::scan_cols(&act, m, k, &skip).unwrap();
        let dout = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let w = gen_vec_f32(rng, k * n, -1.0, 1.0);

        let node = NtNode::new(skip).with_dyn(Some(&mask));
        let got = sdyn.gemm_nt_node(&dout, &w, &node, m, n, k);
        let stat = sstat.gemm_nt_node(&dout, &w, &node, m, n, k);
        for i in 0..m {
            for j in 0..k {
                if mask.live.contains(&j) {
                    assert_eq!(got[i * k + j], stat[i * k + j],
                               "live col ({i},{j})");
                } else {
                    assert_eq!(got[i * k + j], 0.0,
                               "dyn-dead col ({i},{j}) must be zero");
                }
            }
        }
        // After the gate (relu'(act) elementwise) the two are
        // bit-identical everywhere: the gate is 0.0 on every element of
        // a dyn-dead column.
        let gate =
            |d: &[f32]| -> Vec<f32> {
                d.iter().zip(&act)
                    .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
                    .collect()
            };
        assert_eq!(gate(&got), gate(&stat),
                   "gated dyn NT must equal gated static NT bitwise");
    });
}

/// SIMD microkernels (when present) honor the same dyn restriction
/// within the cross-kernel 1e-5 relative contract.
#[test]
fn sparse_dyn_simd_within_contract_of_scalar() {
    let Some(simd) = SparseKernels::simd() else {
        eprintln!("SKIP: no SIMD microkernel on this CPU \
                   (sparse_dyn_simd_within_contract_of_scalar)");
        return;
    };
    let sdyn = simd.with_dyn(true);
    let scalar = SparseKernels::scalar().with_dyn(true);
    testkit::quickcheck("dyn SIMD vs scalar", |rng| {
        let m = gen_range(rng, 1, 10);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let k = dp * gen_range(rng, 2, 16);
        let n = gen_range(rng, 1, 32);
        let pat = RowPattern::new(k, dp, gen_range(rng, 0, dp));
        let skip = Skip::Rows(pat);
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let extra = pick_extra_dead(rng, k, &pat);
        mask_cols(&mut a, m, k, &pat, &extra);
        let b = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let mask = DynMask::scan_cols(&a, m, k, &skip).unwrap();
        let node = TnNode::new(skip, D).with_dyn(Some(&mask));
        let mut got = vec![0f32; k * n];
        sdyn.gemm_tn_acc_node(&a, &b, &node, m, k, n, &mut got);
        let mut want = vec![0f32; k * n];
        scalar.gemm_tn_acc_node(&a, &b, &node, m, k, n, &mut want);
        for (i, (&x, &y)) in got.iter().zip(&want).enumerate() {
            assert!((x - y).abs()
                    <= 1e-5 * x.abs().max(y.abs()).max(1.0),
                    "tn[{i}]: {x} vs {y}");
        }
    });
}

// ---------------------------------------------------------------------------
// End to end: dynamic backward sparsity must not move a trajectory
// ---------------------------------------------------------------------------

fn scalar_cache(dyn_bwd: bool) -> ExecutorCache {
    ExecutorCache::new(
        Arc::new(SparseBackend::with_kernels(
            SparseKernels::scalar().with_dyn(dyn_bwd))),
        Manifest::builtin_test(),
    )
}

/// Both architectures, all three variants, plus a windowed LSTM cell:
/// a scalar-kernel sparse trainer with dynamic backward sparsity ON
/// produces the byte-identical dispatch sequence and bit-identical loss
/// curve as the same trainer with it OFF — the "dyn masks change work,
/// never results" contract, end to end. Also pins that the dyn runs
/// actually exercised the counters (the masks fired at all).
#[test]
fn dyn_backward_trajectories_bit_identical_both_archs() {
    let (mnist, _) = MnistSyn::train_test(256, 64, 27);
    let corpus = Corpus::generate(64, 6000, 600, 600, 7);
    let steps = 6;
    let kept0 = registry::SPARSE_DYN_ROWS_KEPT.get();
    let dropped0 = registry::SPARSE_DYN_ROWS_DROPPED.get();

    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        let run_mlp = |cache: &ExecutorCache| {
            let schedule =
                Schedule::new(variant, &[0.5, 0.5], &[1, 2], false)
                    .unwrap();
            let mut tr = MlpTrainer::new(cache, "mlpsyn", schedule,
                                         mnist.n, 0.01, 19)
                .unwrap();
            for _ in 0..steps {
                tr.step(&mnist).unwrap();
            }
            (tr.metrics.dispatched.clone(),
             tr.metrics.curve.iter().map(|p| p.loss).collect::<Vec<_>>())
        };
        let (on_names, on_losses) = run_mlp(&scalar_cache(true));
        let (off_names, off_losses) = run_mlp(&scalar_cache(false));
        assert_eq!(on_names, off_names, "{variant:?}: mlp dispatch moved");
        assert_eq!(on_losses, off_losses,
                   "{variant:?}: mlp losses not bit-identical");

        let shared = variant != Variant::Conv;
        for window in [None, Some(4usize)] {
            let run_lstm = |cache: &ExecutorCache| {
                let schedule =
                    Schedule::new(variant, &[0.5, 0.5], &[1, 2], shared)
                        .unwrap();
                let mut tr = LstmTrainer::new_with_window(
                    cache, "lstmsyn", schedule, &corpus.train, 0.1, 13,
                    window)
                    .unwrap();
                for _ in 0..steps {
                    tr.step().unwrap();
                }
                (tr.metrics.dispatched.clone(),
                 tr.metrics.curve.iter().map(|p| p.loss)
                     .collect::<Vec<_>>())
            };
            let (on_names, on_losses) = run_lstm(&scalar_cache(true));
            let (off_names, off_losses) = run_lstm(&scalar_cache(false));
            assert_eq!(on_names, off_names,
                       "{variant:?} W={window:?}: lstm dispatch moved");
            assert_eq!(on_losses, off_losses,
                       "{variant:?} W={window:?}: lstm losses moved");
        }
    }

    // The dyn paths must have actually fired during the "on" runs: the
    // LSTM t==0 warmup alone guarantees dropped > 0, and the MLP ReLU
    // scans guarantee kept > 0. (Counters are process-global and
    // monotone, so concurrent tests can only add.)
    assert!(registry::SPARSE_DYN_ROWS_DROPPED.get() > dropped0,
            "no dyn mask ever dropped a row — paths not exercised");
    assert!(registry::SPARSE_DYN_ROWS_KEPT.get() > kept0,
            "no dyn mask ever kept a row — paths not exercised");
}
