//! Bernoulli mask generation for the conventional-dropout baseline.
//!
//! This is on the baseline's hot path: one `[batch, width]` 0/1 mask per
//! dropout site per iteration, exactly like Caffe's cuRAND fill (paper
//! Fig. 1a). Buffers are reused across iterations to keep the baseline
//! allocation-free in steady state.

use crate::util::rng::Rng;

#[derive(Debug, Default)]
pub struct MaskGen {
    buf: Vec<f32>,
}

impl MaskGen {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fill and return a `len`-element 0/1 mask with keep probability
    /// `keep`. The returned slice is valid until the next call.
    pub fn fill(&mut self, rng: &mut Rng, keep: f64, len: usize) -> &[f32] {
        self.buf.resize(len, 0.0);
        rng.fill_mask(keep, &mut self.buf);
        &self.buf[..len]
    }

    /// Empirical keep fraction of the last generated mask (diagnostics).
    pub fn last_keep_fraction(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().filter(|&&v| v == 1.0).count() as f64
            / self.buf.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    #[test]
    fn mask_values_and_rate() {
        let mut rng = Rng::new(5);
        let mut gen = MaskGen::new();
        let m = gen.fill(&mut rng, 0.3, 50_000);
        assert_eq!(m.len(), 50_000);
        assert!(m.iter().all(|&v| v == 0.0 || v == 1.0));
        let keep = m.iter().filter(|&&v| v == 1.0).count() as f64 / 5e4;
        assert!((keep - 0.3).abs() < 0.01, "keep {keep}");
    }

    #[test]
    fn buffer_reuse_no_stale_tail() {
        let mut rng = Rng::new(6);
        let mut gen = MaskGen::new();
        gen.fill(&mut rng, 1.0, 1000);
        let m = gen.fill(&mut rng, 0.0, 500);
        assert_eq!(m.len(), 500);
        assert!(m.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn masks_differ_between_calls() {
        testkit::quickcheck("mask independence", |rng| {
            let mut gen = MaskGen::new();
            let a: Vec<f32> = gen.fill(rng, 0.5, 256).to_vec();
            let b: Vec<f32> = gen.fill(rng, 0.5, 256).to_vec();
            assert_ne!(a, b, "two draws should differ");
        });
    }
}
