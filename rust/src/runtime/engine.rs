//! PJRT engine: load HLO-text artifacts, compile them on the CPU client,
//! and execute train/eval steps with host-side tensor state.
//!
//! Design notes:
//! * Interchange is HLO text (`HloModuleProto::from_text_file`) — see
//!   /opt/xla-example/README.md for why serialized protos are rejected.
//! * Train-step graphs return a single tuple; the `xla` crate's execute
//!   does not set `untuple_result`, so the result comes back as one tuple
//!   buffer which we convert to host literals and decompose. Params
//!   therefore live host-side between steps; upload cost is identical for
//!   the baseline and the pattern variants, so speedup ratios are
//!   unaffected (EXPERIMENTS.md section Perf quantifies this).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{ArtifactMeta, Dtype, Manifest,
                               TensorMeta};

/// Owns the PJRT client. One per process.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, manifest: &Manifest, name: &str) -> Result<Executable> {
        let meta = manifest.get(name)?.clone();
        let path = manifest.hlo_path(&meta);
        self.load_from(&path, meta)
    }

    pub fn load_from(&self, path: &Path, meta: ArtifactMeta)
                     -> Result<Executable> {
        if !path.exists() {
            bail!("artifact file missing: {} (run `make artifacts`)",
                  path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable { exe, meta })
    }
}

/// Host-side tensor: shape + dtype-tagged storage. The unit of state the
/// coordinator moves in and out of executables.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } =>
                shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn scalar(&self) -> Result<f64> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 =>
                Ok(data[0] as f64),
            HostTensor::I32 { data, .. } if data.len() == 1 =>
                Ok(data[0] as f64),
            _ => bail!("tensor is not a scalar"),
        }
    }

    /// Single-copy conversion to an XLA literal. Rank-0 tensors take the
    /// dedicated scalar constructor so coordinator-assembled host steps
    /// produce literals identical to the direct `lit_scalar_*` path.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32 { shape, data } if shape.is_empty() =>
                Ok(crate::runtime::state::lit_scalar_f32(data[0])),
            HostTensor::I32 { shape, data } if shape.is_empty() =>
                Ok(crate::runtime::state::lit_scalar_i32(data[0])),
            HostTensor::F32 { shape, data } =>
                crate::runtime::state::lit_f32(shape, data),
            HostTensor::I32 { shape, data } =>
                crate::runtime::state::lit_i32(shape, data),
        }
    }

    fn from_literal(lit: &xla::Literal, meta: &TensorMeta)
                    -> Result<HostTensor> {
        match meta.dtype {
            Dtype::F32 => Ok(HostTensor::F32 {
                shape: meta.shape.clone(),
                data: lit.to_vec::<f32>()
                    .map_err(|e| anyhow!("to_vec f32 {}: {e:?}", meta.name))?,
            }),
            Dtype::I32 => Ok(HostTensor::I32 {
                shape: meta.shape.clone(),
                data: lit.to_vec::<i32>()
                    .map_err(|e| anyhow!("to_vec i32 {}: {e:?}", meta.name))?,
            }),
        }
    }

    /// Validate against a manifest tensor description.
    pub fn check(&self, meta: &TensorMeta) -> Result<()> {
        if self.shape() != meta.shape.as_slice() {
            bail!("tensor {}: shape {:?} != manifest {:?}", meta.name,
                  self.shape(), meta.shape);
        }
        let ok = matches!(
            (self, meta.dtype),
            (HostTensor::F32 { .. }, Dtype::F32)
                | (HostTensor::I32 { .. }, Dtype::I32)
        );
        if !ok {
            bail!("tensor {}: dtype mismatch", meta.name);
        }
        Ok(())
    }
}

/// A compiled artifact plus its manifest metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl Executable {
    /// Execute with pre-built literals (manifest input order) and return
    /// the decomposed output literals. This is the hot path: no per-tensor
    /// host copies beyond PJRT's own transfers (`decompose_tuple` is
    /// zero-copy).
    pub fn run_raw(&self, inputs: &[&xla::Literal])
                   -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: {} inputs given, manifest says {}", self.meta.name,
                  inputs.len(), self.meta.inputs.len());
        }
        let result = self.exe.execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.meta.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = tuple.to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.meta.outputs.len() {
            bail!("{}: {} outputs returned, manifest says {}",
                  self.meta.name, parts.len(), self.meta.outputs.len());
        }
        Ok(parts)
    }

    /// Execute with the full input list (manifest order), with shape/dtype
    /// validation. Returns host tensors in manifest output order.
    /// Convenience path for tests/examples; trainers use `run_raw`.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: {} inputs given, manifest says {}", self.meta.name,
                  inputs.len(), self.meta.inputs.len());
        }
        for (t, m) in inputs.iter().zip(&self.meta.inputs) {
            t.check(m).with_context(|| format!("artifact {}",
                                               self.meta.name))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        let parts = self.run_raw(&refs)?;
        parts
            .iter()
            .zip(&self.meta.outputs)
            .map(|(lit, m)| HostTensor::from_literal(lit, m))
            .collect()
    }
}
