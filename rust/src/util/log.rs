//! Leveled stderr logger with wall-clock timestamps. Controlled by the
//! `AD_LOG` env var (error|warn|info|debug|trace; default info —
//! unrecognized values warn loudly). Fleet runner threads tag their
//! lines with [`set_job_prefix`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: std::sync::Once = std::sync::Once::new();

pub fn init_from_env() {
    INIT.call_once(|| {
        let (lvl, unknown) = match std::env::var("AD_LOG").as_deref() {
            Ok("error") => (Level::Error, None),
            Ok("warn") => (Level::Warn, None),
            Ok("info") => (Level::Info, None),
            Ok("debug") => (Level::Debug, None),
            Ok("trace") => (Level::Trace, None),
            // Unset: the documented default, silently.
            Err(_) => (Level::Info, None),
            // A *set but unrecognized* value is a typo'd config, not a
            // default — warn loudly (same policy as AD_SIMD) instead of
            // silently running at info.
            Ok(v) => (Level::Info, Some(v.to_string())),
        };
        MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
        if let Some(v) = unknown {
            log(Level::Warn,
                format_args!("AD_LOG={v:?} is not a recognized level \
                              (use error|warn|info|debug|trace); \
                              logging at info"));
        }
    });
}

thread_local! {
    /// This thread's attribution: (job name, optional worker index).
    /// Stored structurally — not pre-rendered — so the sharded trainer
    /// can read the owning job back via [`current_job`] when naming its
    /// gradient worker threads.
    static JOB_TAG: RefCell<(String, Option<usize>)> =
        const { RefCell::new((String::new(), None)) };
}

/// Tag every subsequent log line from *this thread* with `[job=<name>]`
/// — fleet runner threads call this so interleaved multi-job output
/// stays attributable. An empty name clears the tag.
pub fn set_job_prefix(name: &str) {
    JOB_TAG.with(|p| {
        let mut p = p.borrow_mut();
        p.0.clear();
        p.0.push_str(name);
        p.1 = None;
    });
}

/// Tag every subsequent log line from *this thread* with
/// `[job=<name>/w<k>]` — gradient worker threads of a sharded trainer
/// call this so quarantine and kernel messages from worker `k` stay
/// attributable to both the job and the shard.
pub fn set_worker_prefix(name: &str, k: usize) {
    JOB_TAG.with(|p| {
        let mut p = p.borrow_mut();
        p.0.clear();
        p.0.push_str(name);
        p.1 = Some(k);
    });
}

/// The job name this thread is tagged with (empty when untagged). The
/// sharded trainer reads this to propagate the fleet job's name onto
/// its worker threads.
pub fn current_job() -> String {
    JOB_TAG.with(|p| p.borrow().0.clone())
}

fn render_prefix() -> String {
    JOB_TAG.with(|p| {
        let p = p.borrow();
        match (&p.0, p.1) {
            (name, _) if name.is_empty() => String::new(),
            (name, None) => format!("[job={name}] "),
            (name, Some(k)) => format!("[job={name}/w{k}] "),
        }
    })
}

pub fn set_level(lvl: Level) {
    MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = t.as_secs();
    let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let job = render_prefix();
    eprintln!("[{h:02}:{m:02}:{s:02}.{:03} {tag}] {job}{args}",
              t.subsec_millis());
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn job_prefix_is_thread_local_and_clearable() {
        set_job_prefix("mlp-a");
        assert_eq!(render_prefix(), "[job=mlp-a] ");
        assert_eq!(current_job(), "mlp-a");
        // Another thread sees no tag.
        std::thread::spawn(|| {
            assert!(render_prefix().is_empty());
            assert!(current_job().is_empty());
        })
        .join()
        .unwrap();
        set_job_prefix("");
        assert!(render_prefix().is_empty());
    }

    #[test]
    fn worker_prefix_renders_job_slash_w_index() {
        set_worker_prefix("lstm-b", 3);
        assert_eq!(render_prefix(), "[job=lstm-b/w3] ");
        // The owning job stays readable without the worker suffix.
        assert_eq!(current_job(), "lstm-b");
        // Re-tagging as a plain job drops the worker suffix.
        set_job_prefix("lstm-b");
        assert_eq!(render_prefix(), "[job=lstm-b] ");
        set_job_prefix("");
    }
}
