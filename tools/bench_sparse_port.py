#!/usr/bin/env python3
"""Numpy port of the structured-sparse kernel library + speedup bench.

Two jobs:

1. ``--validate`` — re-derive the kernel contracts of
   ``rust/src/runtime/sparse/kernels.rs`` in numpy and check them against
   masked-dense math for randomized shapes/skips/tilings, then run a full
   MLP train-step parity check (reference masked-dense vs sparse skipping
   math, all three variants) mirroring the placement of every `Skip` in
   ``rust/src/runtime/step/mod.rs``. This is the cross-language check of
   the sparse subsystem's *math* (the same technique PR 2 used to
   validate the reference interpreter against the JAX graphs).

2. ``--bench`` — produce ``BENCH_sparse.json`` with the same schema as
   ``rust/benches/sparse_speedup.rs``, from a *scale model* of the Rust
   kernels: every kernel is executed as a loop whose iteration count is
   proportional to the multiply-accumulates actually touched (row/tile
   loops with 16-wide column blocks), so skipped rows/tiles translate
   into skipped iterations exactly as they do in the blocked Rust loops.
   Absolute times are python's, but the dense-vs-skip *ratios* model the
   scalar Rust kernels. The report's ``provenance`` field records this;
   rerun the Rust harness (``cargo run --release --bin sparse_speedup``)
   to replace it with native numbers when a cargo toolchain is present.

Both run by default. Exit code is nonzero on any validation failure.
"""

import argparse
import json
import os
import sys
import time

import numpy as np

# ---------------------------------------------------------------------------
# Patterns (mirror rust/src/patterns/{row,tile}.rs)
# ---------------------------------------------------------------------------


def pick_block(dim, cap):
    if dim <= cap:
        return dim
    for b in range(cap, 0, -1):
        if dim % b == 0:
            return b
    return 1


def row_kept(m, dp, b0):
    """Kept indices {b0 + dp*j} of an m-wide site."""
    return np.arange(b0, (m // dp) * dp, dp)


def row_mask(m, dp, b0):
    mask = np.zeros(m, np.float32)
    mask[row_kept(m, dp, b0)] = 1.0
    return mask


class TilePat:
    def __init__(self, k, n, dp, b0, tile):
        self.k, self.n, self.dp, self.b0 = k, n, dp, b0
        self.tr, self.tc = pick_block(k, tile), pick_block(n, tile)
        self.tk, self.tn = k // self.tr, n // self.tc
        assert self.tn % dp == 0 or self.tk % dp == 0

    def keeps(self, r, c):
        dp, b0 = self.dp, self.b0
        return (c % dp + dp - (b0 + r) % dp) % dp == 0

    def kept_tiles(self):
        return [(r, c) for r in range(self.tk) for c in range(self.tn)
                if self.keeps(r, c)]

    def mask(self):
        m = np.zeros((self.k, self.n), np.float32)
        for r, c in self.kept_tiles():
            m[r * self.tr:(r + 1) * self.tr,
              c * self.tc:(c + 1) * self.tc] = 1.0
        return m


# ---------------------------------------------------------------------------
# Scale-model kernels: iteration count proportional to touched MACs
# ---------------------------------------------------------------------------

NB = 16  # column-block width: one loop iteration covers <= NB columns


# Every kernel below executes one python/numpy op per (shared-dimension
# index, <= NB-wide column block) — the same granularity across the
# dense, row-skip, and tile-skip paths — so wall-clock ratios track the
# ratio of touched MACs, which is what the blocked scalar Rust kernels
# deliver.


def k_gemm(a, b, kept_k=None, kept_n=None, tiles=None):
    """out[m,n] = a[m,k] @ b[k,n] under skips (cf. SparseKernels::gemm)."""
    m, k = a.shape
    _, n = b.shape
    out = np.zeros((m, n), np.float32)
    if tiles is not None:
        tr, tc = tiles.tr, tiles.tc
        qall = np.arange(n)
        for r, c in tiles.kept_tiles():
            k0, j0 = r * tr, c * tc
            for p in range(k0, k0 + tr):
                ap = a[:, p:p + 1]
                bp = b[p]
                for q0 in range(j0, j0 + tc, NB):
                    js = qall[q0:min(q0 + NB, j0 + tc)]
                    out[:, js] += ap * bp[js]
        return out
    kk = np.arange(k) if kept_k is None else kept_k
    nn = np.arange(n) if kept_n is None else kept_n
    for p in kk:
        ap = a[:, p:p + 1]
        bp = b[p]
        for j0 in range(0, len(nn), NB):
            js = nn[j0:j0 + NB]
            out[:, js] += ap * bp[js]
    return out


def k_nt(a, b, kept_j=None, tiles=None):
    """out[m,k] = a[m,n] @ b[k,n].T under skips (cf. gemm_nt)."""
    m, n = a.shape
    k, _ = b.shape
    out = np.zeros((m, k), np.float32)
    if tiles is not None:
        tr, tc = tiles.tr, tiles.tc
        qall = np.arange(n)
        for r, c in tiles.kept_tiles():
            c0 = c * tc
            for j in range(r * tr, (r + 1) * tr):
                for q0 in range(c0, c0 + tc, NB):
                    qs = qall[q0:min(q0 + NB, c0 + tc)]
                    out[:, j] += a[:, qs] @ b[j, qs]
        return out
    jj = np.arange(k) if kept_j is None else kept_j
    qall = np.arange(n)
    for j in jj:
        for q0 in range(0, n, NB):
            qs = qall[q0:q0 + NB]
            out[:, j] += a[:, qs] @ b[j, qs]
    return out


def k_tn(a, b, kept_p=None, kept_n=None, tiles=None, out=None):
    """out[k,n] += a[m,k].T @ b[m,n] under skips (cf. gemm_tn_acc)."""
    m, k = a.shape
    _, n = b.shape
    if out is None:
        out = np.zeros((k, n), np.float32)
    if tiles is not None:
        tr, tc = tiles.tr, tiles.tc
        qall = np.arange(n)
        for r, c in tiles.kept_tiles():
            c0 = c * tc
            for p in range(r * tr, (r + 1) * tr):
                for q0 in range(c0, c0 + tc, NB):
                    qs = qall[q0:min(q0 + NB, c0 + tc)]
                    out[p, qs] += a[:, p] @ b[:, qs]
        return out
    pp = np.arange(k) if kept_p is None else kept_p
    nn = np.arange(n) if kept_n is None else kept_n
    for p in pp:
        ap = a[:, p]
        for j0 in range(0, len(nn), NB):
            js = nn[j0:j0 + NB]
            out[p, js] += ap @ b[:, js]
    return out


# ---------------------------------------------------------------------------
# Kernel-contract validation (mirror rust/tests/sparse_kernels.rs)
# ---------------------------------------------------------------------------


def check(name, got, want, atol=2e-5):
    if not np.allclose(got, want, atol=atol, rtol=1e-5):
        err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
        raise AssertionError(f"{name}: max err {err}")


def validate_kernels(seed=0):
    rng = np.random.default_rng(seed)
    for case in range(40):
        m = int(rng.integers(1, 12))
        dp = int(rng.choice([1, 2, 4]))
        k = dp * int(rng.integers(1, 16))
        n = int(rng.integers(1, 40))
        b0 = int(rng.integers(0, dp))
        kept = row_kept(k, dp, b0)
        a = rng.standard_normal((m, k)).astype(np.float32)
        am = a * row_mask(k, dp, b0)[None, :]
        b = rng.standard_normal((k, n)).astype(np.float32)
        check(f"gemm rows case {case}", k_gemm(am, b, kept_k=kept),
              am @ b)
        # Out-column restriction: kept columns match, dropped exactly 0.
        dpn = int(rng.choice([2, 4]))
        n2 = dpn * int(rng.integers(1, 10))
        b0n = int(rng.integers(0, dpn))
        b2 = rng.standard_normal((k, n2)).astype(np.float32)
        got = k_gemm(a, b2, kept_n=row_kept(n2, dpn, b0n))
        want = (a @ b2) * row_mask(n2, dpn, b0n)[None, :]
        check(f"gemm out-cols case {case}", got, want)
        # NT with output-column restriction.
        a3 = rng.standard_normal((m, n)).astype(np.float32)
        b3 = rng.standard_normal((k, n)).astype(np.float32)
        got = k_nt(a3, b3, kept_j=kept)
        want = (a3 @ b3.T) * row_mask(k, dp, b0)[None, :]
        check(f"nt rows case {case}", got, want)
        # TN with row + column restriction (gradient freeze).
        b4 = rng.standard_normal((m, n2)).astype(np.float32)
        b4m = b4 * row_mask(n2, dpn, b0n)[None, :]
        got = k_tn(am, b4m, kept_p=kept, kept_n=row_kept(n2, dpn, b0n))
        want = am.T @ b4m
        check(f"tn rows/cols case {case}", got, want)

    # Tile skips.
    for case in range(40):
        m = int(rng.integers(1, 10))
        k, n = [(32, 64), (64, 32), (64, 64), (32, 128), (784, 64)][
            case % 5]
        dp = int(rng.choice([2, 4]))
        pat = TilePat(k, n, dp, int(rng.integers(0, dp)), 16)
        a = rng.standard_normal((m, k)).astype(np.float32)
        w = rng.standard_normal((k, n)).astype(np.float32)
        wm = w * pat.mask()
        check(f"gemm tiles case {case}", k_gemm(a, w, tiles=pat), a @ wm)
        a2 = rng.standard_normal((m, n)).astype(np.float32)
        check(f"nt tiles case {case}", k_nt(a2, w, tiles=pat), a2 @ wm.T)
        b2 = rng.standard_normal((m, n)).astype(np.float32)
        check(f"tn tiles case {case}", k_tn(a, b2, tiles=pat),
              (a.T @ b2) * pat.mask())
    print("kernel contracts: OK (80 randomized cases)")


# ---------------------------------------------------------------------------
# MLP train-step parity: masked-dense (reference) vs skipping (sparse)
# ---------------------------------------------------------------------------
# Mirrors rust/src/runtime/step/mod.rs::mlp_train, including which Skip
# goes where (the `ask`/`sk` distinction for the tdp path).


def softmax_xent_grad(logits, y):
    rows = logits.shape[0]
    mx = logits.max(axis=1, keepdims=True)
    lse = np.log(np.exp(logits - mx).sum(axis=1, keepdims=True)) + mx
    loss = float(np.mean(lse[:, 0] - logits[np.arange(rows), y]))
    p = np.exp(logits - lse)
    p[np.arange(rows), y] -= 1.0
    return loss, (p / rows).astype(np.float32)


def mlp_step(params, momenta, x, y, variant, cfg, lr, mu, sparse,
             dyn=False):
    """One train step; `sparse=False` is the masked-dense reference.

    ``dyn=True`` models the sparse backend's dynamic backward sparsity
    (plan ``DynMask`` nodes): the backward GEMMs restrict the shared
    dimension to columns of the post-ReLU activations that are actually
    nonzero (live = static kept set minus runtime-dead units), paying the
    one-pass column scan the runtime pays. Value-preserving by the same
    argument as the Rust kernels: a dead unit contributes only zeros.
    """
    w1, b1, w2, b2, w3, b3 = params
    B = x.shape[0]
    h1, h2 = w1.shape[1], w2.shape[1]

    def gemm(a, b, kept_k=None, kept_n=None, tiles=None):
        if not sparse:
            return a @ b
        return k_gemm(a, b, kept_k, kept_n, tiles)

    def nt(a, b, kept_j=None, tiles=None):
        if not sparse:
            return a @ b.T
        return k_nt(a, b, kept_j, tiles)

    def tn(a, b, kept_p=None, kept_n=None, tiles=None):
        if not sparse:
            return a.T @ b
        return k_tn(a, b, kept_p, kept_n, tiles)

    if variant == "tdp":
        pat1, pat2, s1, s2 = cfg
        w1u = w1 if sparse else w1 * pat1.mask()
        w2u = w2 if sparse else w2 * pat2.mask()
        t1 = pat1 if sparse else None
        t2 = pat2 if sparse else None
        z1 = np.maximum(gemm(x, w1u, tiles=t1) * s1 + b1, 0.0)
        z2 = np.maximum(gemm(z1, w2u, tiles=t2) * s2 + b2, 0.0)
        out0, out1 = z1, z2
        logits = gemm(out1, w3) + b3
        loss, dlogits = softmax_xent_grad(logits, y)
        dw3 = tn(out1, dlogits)
        db3 = dlogits.sum(axis=0)
        dout1 = nt(dlogits, w3)
        dz2 = np.where(out1 > 0, dout1, 0.0).astype(np.float32)
        db2 = dz2.sum(axis=0)
        du2 = dz2 * s2
        dw2 = tn(out0, du2, tiles=t2) if sparse else (out0.T @ du2) \
            * pat2.mask()
        dout0 = nt(du2, w2u, tiles=t2) if sparse else du2 @ w2u.T
        dz1 = np.where(out0 > 0, dout0, 0.0).astype(np.float32)
        db1 = dz1.sum(axis=0)
        du1 = dz1 * s1
        dw1 = tn(x, du1, tiles=t1) if sparse else (x.T @ du1) \
            * pat1.mask()
    else:
        if variant == "conv":
            m0, m1, s0, s1 = cfg
            kk0 = kk1 = None
        else:  # rdp
            (dp0, b00), (dp1, b01), s0, s1 = cfg
            m0 = np.tile(row_mask(h1, dp0, b00), (B, 1))
            m1 = np.tile(row_mask(h2, dp1, b01), (B, 1))
            kk0 = row_kept(h1, dp0, b00)
            kk1 = row_kept(h2, dp1, b01)
        z1 = np.maximum(gemm(x, w1, kept_n=kk0) + b1, 0.0)
        o0 = (z1 * m0 * s0).astype(np.float32)
        z2 = np.maximum(gemm(o0, w2, kept_k=kk0, kept_n=kk1) + b2, 0.0)
        o1 = (z2 * m1 * s1).astype(np.float32)
        out0, out1 = o0, o1
        logits = gemm(out1, w3, kept_k=kk1) + b3
        loss, dlogits = softmax_xent_grad(logits, y)
        # Dynamic masks: live = static kept ∩ {columns with any nonzero
        # activation}. The scan itself is part of the modeled cost.
        kd0, kd1 = kk0, kk1
        if dyn and sparse:
            live1 = np.flatnonzero(np.any(out1 != 0.0, axis=0))
            kd1 = live1 if kk1 is None else np.intersect1d(kk1, live1)
            live0 = np.flatnonzero(np.any(out0 != 0.0, axis=0))
            kd0 = live0 if kk0 is None else np.intersect1d(kk0, live0)
        dw3 = tn(out1, dlogits, kept_p=kd1)
        db3 = dlogits.sum(axis=0)
        dout1 = nt(dlogits, w3, kept_j=kd1)
        da1 = (dout1 * m1 * s1).astype(np.float32)
        dz2 = np.where(out1 > 0, da1, 0.0).astype(np.float32)
        db2 = dz2.sum(axis=0)
        dw2 = tn(out0, dz2, kept_p=kd0, kept_n=kk1)
        dout0 = nt(dz2, w2, kept_j=kd0)
        da0 = (dout0 * m0 * s0).astype(np.float32)
        dz1 = np.where(out0 > 0, da0, 0.0).astype(np.float32)
        db1 = dz1.sum(axis=0)
        dw1 = tn(x, dz1, kept_n=kk0)

    grads = [dw1, db1, dw2, db2, dw3, db3]
    new_m = [mu * m + g for m, g in zip(momenta, grads)]
    new_p = [p - lr * nm for p, nm in zip(params, new_m)]
    return loss, new_p, new_m


def validate_mlp_step(seed=1):
    rng = np.random.default_rng(seed)
    n_in, h1, h2, n_out, B = 784, 64, 64, 10, 16
    dims = [(n_in, h1), (h1,), (h1, h2), (h2,), (h2, n_out), (n_out,)]
    params = [
        (rng.uniform(-1, 1, d) * np.sqrt(6 / sum(d if len(d) == 2
                                                 else (d[0], d[0]))))
        .astype(np.float32) if len(d) == 2
        else np.zeros(d, np.float32) for d in dims]
    momenta = [rng.standard_normal(d).astype(np.float32) * 0.01
               for d in dims]
    x = rng.random((B, n_in)).astype(np.float32)
    y = rng.integers(0, n_out, B)
    cases = [
        ("conv", ((rng.random((B, h1)) < 0.5).astype(np.float32),
                  (rng.random((B, h2)) < 0.5).astype(np.float32),
                  2.0, 2.0)),
        ("rdp", ((2, 1), (4, 3), 2.0, 2.0)),
        ("tdp", (TilePat(n_in, h1, 2, 1, 16), TilePat(h1, h2, 4, 2, 16),
                 2.0, 2.0)),
    ]
    for variant, cfg in cases:
        ref = mlp_step(params, momenta, x, y, variant, cfg, 0.05, 0.9,
                       sparse=False)
        spa = mlp_step(params, momenta, x, y, variant, cfg, 0.05, 0.9,
                       sparse=True)
        check(f"mlp step loss ({variant})", spa[0], ref[0])
        for i, (a, b) in enumerate(zip(ref[1] + ref[2],
                                       spa[1] + spa[2])):
            check(f"mlp step {variant} tensor {i}", b, a)
        # rdp/tdp: dropped rows/tiles of the guarded grads must be zero
        # in the *sparse* gradients (bit-freeze invariant) — momenta paths
        # carry prior momentum, so compare the param delta structure via
        # the reference instead (already equal above).
        if variant != "tdp":
            # Dynamic backward sparsity (AD_DYN_BWD model): restricting
            # the backward GEMMs to runtime-live columns must not move
            # the result at all — same masked-dense reference. Tiles
            # skips never carry dynamic masks (no flat column view).
            dyn = mlp_step(params, momenta, x, y, variant, cfg, 0.05,
                           0.9, sparse=True, dyn=True)
            check(f"mlp step loss ({variant}, dyn)", dyn[0], ref[0])
            for i, (a, b) in enumerate(zip(ref[1] + ref[2],
                                           dyn[1] + dyn[2])):
                check(f"mlp step {variant} dyn tensor {i}", b, a)
    print("mlp train-step parity (conv/rdp/tdp + dyn-bwd): OK")


def validate_windowed_step(seed=3):
    """Exercise the windowed lstm timing model across the bench's W grid
    (every variant x W path must execute; run accounting must tile the
    sequence exactly)."""
    rng = np.random.default_rng(seed)
    h, vocab, B, seq = 32, 64, 8, 8
    bufs = {
        "inp": rng.random((B, h)).astype(np.float32),
        "h": rng.random((B, h)).astype(np.float32),
        "wx": (rng.standard_normal((h, 4 * h)) * 0.05).astype(np.float32),
        "wh": (rng.standard_normal((h, 4 * h)) * 0.05).astype(np.float32),
        "wsoft": (rng.standard_normal((h, vocab)) * 0.05).astype(
            np.float32),
        "flat": rng.random((seq * B, h)).astype(np.float32),
        "da": rng.random((B, 4 * h)).astype(np.float32),
    }
    for w in [None, 1, 4, 16]:
        wps = max(1, seq // (seq if w is None else w))
        assert wps * (seq // wps) == seq, (w, wps)
        for variant in ["conv", "rdp", "tdp"]:
            for dp in [1, 2, 4]:
                lstmsyn_step(variant, dp, rng, bufs, window=w)
    print("windowed lstm timing model: OK "
          "(conv/rdp/tdp x dp {1,2,4} x W {1,4,16,seq})")


# ---------------------------------------------------------------------------
# Bench: dense vs row-skip vs tile-skip on mlpsyn / lstmsyn shapes
# ---------------------------------------------------------------------------


def dp_sequence(rate, steps, rng):
    """Per-step dp draws whose long-run drop rate is `rate` over support
    {1,2,4} (two-point mixture; the Rust harness uses the searched K)."""
    if rate <= 0.5:
        k2 = rate / 0.5
        probs = {1: 1 - k2, 2: k2, 4: 0.0}
    else:
        k4 = (rate - 0.5) / 0.25
        probs = {1: 0.0, 2: 1 - k4, 4: k4}
    support = [1, 2, 4]
    p = np.array([probs[d] for d in support])
    return [int(rng.choice(support, p=p)) for _ in range(steps)]


def mlpsyn_step(variant, dp, rng, bufs, dyn_bwd=False):
    """One mlpsyn train step through the scale-model kernels."""
    x, w1, w2, w3 = bufs["x"], bufs["w1"], bufs["w2"], bufs["w3"]
    B, n_in = x.shape
    h1, h2 = w1.shape[1], w2.shape[1]
    y = bufs["y"]
    if variant == "conv":
        cfg = ((rng.random((B, h1)) < 0.5).astype(np.float32),
               (rng.random((B, h2)) < 0.5).astype(np.float32), 2.0, 2.0)
        v = "conv"
    elif variant == "rdp":
        if dp == 1:
            cfg = ((1, 0), (1, 0), 1.0, 1.0)
        else:
            cfg = ((dp, int(rng.integers(0, dp))),
                   (dp, int(rng.integers(0, dp))), 2.0, 2.0)
        v = "rdp"
    else:
        b0a, b0b = int(rng.integers(0, dp)), int(rng.integers(0, dp))
        cfg = (TilePat(n_in, h1, dp, b0a, 16),
               TilePat(h1, h2, dp, b0b, 16), 2.0, 2.0)
        v = "tdp"
    return mlp_step([w1, bufs["b1"], w2, bufs["b2"], w3, bufs["b3"]],
                    bufs["mom"], x, y, v, cfg, 0.01, 0.9, sparse=True,
                    dyn=dyn_bwd and v != "tdp")


def pack_panel(w, kept):
    """Model of SparseKernels::prep packing kept rows into a contiguous
    panel, charged once per (site, window) exactly where the runtime
    preps. A pack is a kept_rows x n memcpy — an order of magnitude
    cheaper than the gemms it feeds (16 x 128 floats vs 16 x 128 x m
    MACs), so it is modeled as one gather per pack, not at per-MAC
    granularity."""
    return w[kept].copy()


def lstmsyn_step(variant, dp, rng, bufs, window=None, dyn_bwd=False):
    """Timing model of one lstmsyn BPTT step: the exact GEMM call list of
    runtime/step's LSTM forward + backward (shapes and skips), with the
    gate nonlinearities included; recurrence values are stand-ins (timing
    only — numerical parity is covered by the kernel-contract and MLP
    checks, which exercise the same skip identities).

    `window` is the time-window size W (timesteps per pattern draw,
    `AD_TIME_WINDOW`): None or W >= seq is the per-step default (one
    window per step — W > seq only holds the draw across steps, which
    changes RNG traffic, not per-step kernel work, since the runtime
    preps per step); W < seq re-draws the bias every W timesteps, so a
    step carries seq/W windows, each paying its own panel prep and its
    own softmax-projection run, mirroring runtime/step's `FeedRun`
    grouping.

    `dyn_bwd` models the plan's zero-initial-state mask: at t == 0 the
    previous hidden state is architecturally zero, so the dwh
    accumulation (`k_tn(hs, da)`) is skipped outright for every layer —
    exactly what the sparse backend's `TnNode::dyn_rows` path does with
    `DynMask::zero_state` (an empty live set walks nothing)."""
    h, vocab, B, seq, layers = 32, 64, 8, 8, 2
    inp, hs, wx, wh, wsoft = (bufs["inp"], bufs["h"], bufs["wx"],
                              bufs["wh"], bufs["wsoft"])
    w = seq if window is None else window
    wps = max(1, seq // w)       # windows (feed runs) per step
    run_len = seq // wps
    kept_runs = t0_runs = t1_runs = None
    if variant == "rdp" and dp > 1:
        kept_runs = [row_kept(h, dp, int(rng.integers(0, dp)))
                     for _ in range(wps)]
        # Panel prep hoisted out of the timestep loop: once per
        # (site, window), reused by forward, backward, and softmax.
        for kept in kept_runs:
            pack_panel(wx, kept)
            pack_panel(wsoft, kept)
    if variant == "tdp" and dp > 1:
        # Sparse tile gemms skip off the raw buffer (prep is a no-op),
        # so windows only change the per-run draw, not packing cost.
        t0_runs = [TilePat(h, 4 * h, dp, int(rng.integers(0, dp)), 16)
                   for _ in range(wps)]
        t1_runs = [TilePat(h, vocab, dp, int(rng.integers(0, dp)), 16)
                   for _ in range(wps)]
    conv_mask = None
    if variant == "conv":
        conv_mask = (rng.random((B, h)) < 0.5).astype(np.float32)
    # Forward.
    for t in range(seq):
        ri = t // run_len
        for l in range(layers):
            guarded = l > 0  # site l-1 guards layer l's input
            if guarded and kept_runs is not None:
                gates = k_gemm(inp, wx, kept_k=kept_runs[ri])
            elif guarded and t0_runs is not None:
                gates = k_gemm(inp, wx, tiles=t0_runs[ri])
            else:
                a = inp * conv_mask if (guarded and conv_mask is not None) \
                    else inp
                gates = k_gemm(a, wx)
            gates = gates + k_gemm(hs, wh)
            gates = 1.0 / (1.0 + np.exp(-np.clip(gates, -30, 30)))
    # Softmax projection, one gemm per feed run (W >= seq: one flat
    # call over all seq*B rows, exactly the pre-window behavior).
    rows = bufs["flat"]
    for ri in range(wps):
        seg = rows[ri * run_len * B:(ri + 1) * run_len * B]
        if t1_runs is not None:
            logits = k_gemm(seg, wsoft, tiles=t1_runs[ri])
        else:
            logits = k_gemm(
                seg, wsoft,
                kept_k=kept_runs[ri] if kept_runs is not None else None)
        dlog = (logits - logits.mean(axis=1, keepdims=True)).astype(
            np.float32) / seg.shape[0]
        # Backward: softmax projection for the same run.
        if t1_runs is not None:
            k_tn(seg, dlog, tiles=t1_runs[ri])
            k_nt(dlog, wsoft, tiles=t1_runs[ri])
        else:
            kp = kept_runs[ri] if kept_runs is not None else None
            k_tn(seg, dlog, kept_p=kp)
            k_nt(dlog, wsoft, kept_j=kp)
    # Backward: cells.
    da = bufs["da"]
    for t in reversed(range(seq)):
        ri = t // run_len
        for l in reversed(range(layers)):
            if not (dyn_bwd and t == 0):
                # dwh; under dyn the t==0 accumulation is skipped
                # outright (h_prev is the zero initial state).
                k_tn(hs, da)
            k_nt(da, wh)           # dh_prev
            guarded = l > 0
            if guarded and kept_runs is not None:
                k_tn(inp, da, kept_p=kept_runs[ri])  # dwx (rows restr.)
                k_nt(da, wx, kept_j=kept_runs[ri])   # dinp (cols restr.)
            elif guarded and t0_runs is not None:
                k_tn(inp, da, tiles=t0_runs[ri])
                k_nt(da, wx, tiles=t0_runs[ri])
            else:
                k_tn(inp, da)
                k_nt(da, wx)                 # demb / dinp
    return None


def bench(out_path, steps, warm, seed=7):
    rng = np.random.default_rng(seed)
    report = {
        "bench": "sparse_speedup",
        "version": 1,
        "provenance": (
            "tools/bench_sparse_port.py — numpy scale-model port of "
            "rust/benches/sparse_speedup.rs (loop iterations proportional "
            "to touched MACs, modeling the SCALAR microkernels; no cargo "
            "toolchain in this container). dyn-bwd rows model dynamic "
            "backward sparsity (AD_DYN_BWD): lstmsyn skips the t==0 dwh "
            "accumulation (zero initial state); mlpsyn restricts backward "
            "GEMMs to runtime-live ReLU columns, but at batch 16 a fully "
            "dead column is vanishingly rare, so its dyn_vs_static "
            "collapses to ~1.00 — the honest result; the LSTM warmup "
            "skip is the genuine dynamic win at this scale. dyn_vs_static "
            "is the median per-rep ratio of interleaved paired static/dyn "
            "runs at matched dp draws (alternating order within each "
            "pair), rounded to 2 decimals (the model's noise floor). "
            "Regenerate natively with: "
            "cargo run --release --bin sparse_speedup, then install via "
            "tools/check_bench_regression.py --refresh-baseline"),
        "backend": "sparse",
        "threads": 1,
        "microkernel": "scalar",
        "smoke": False,
        "reps": steps,
        "support": [1, 2, 4],
        "windows": [1, 4, 16],
        "lstm_seq": 8,
        "rows": [],
    }

    # mlpsyn buffers.
    n_in, h1, h2, n_out, B = 784, 64, 64, 10, 16
    mlp_bufs = {
        "x": rng.random((B, n_in)).astype(np.float32),
        "y": rng.integers(0, n_out, B),
        "w1": (rng.standard_normal((n_in, h1)) * 0.05).astype(np.float32),
        "b1": np.zeros(h1, np.float32),
        "w2": (rng.standard_normal((h1, h2)) * 0.05).astype(np.float32),
        "b2": np.zeros(h2, np.float32),
        "w3": (rng.standard_normal((h2, n_out)) * 0.05).astype(np.float32),
        "b3": np.zeros(n_out, np.float32),
    }
    dims = [(n_in, h1), (h1,), (h1, h2), (h2,), (h2, n_out), (n_out,)]
    mlp_bufs["mom"] = [np.zeros(d, np.float32) for d in dims]

    # lstmsyn buffers.
    h, vocab, B2, seq = 32, 64, 8, 8
    lstm_bufs = {
        "inp": rng.random((B2, h)).astype(np.float32),
        "h": rng.random((B2, h)).astype(np.float32),
        "wx": (rng.standard_normal((h, 4 * h)) * 0.05).astype(np.float32),
        "wh": (rng.standard_normal((h, 4 * h)) * 0.05).astype(np.float32),
        "wsoft": (rng.standard_normal((h, vocab)) * 0.05).astype(
            np.float32),
        "flat": rng.random((seq * B2, h)).astype(np.float32),
        "da": rng.random((B2, 4 * h)).astype(np.float32),
    }

    def run(arch, variant, rate, window=None):
        dps = dp_sequence(rate if variant != "conv" else 0.0,
                          warm + steps, rng)
        times = []
        for i, dp in enumerate(dps):
            t0 = time.perf_counter()
            if arch == "mlpsyn":
                mlpsyn_step(variant, dp, rng, mlp_bufs)
            else:
                lstmsyn_step(variant, dp, rng, lstm_bufs, window=window)
            dt = time.perf_counter() - t0
            if i >= warm:
                times.append(dt)
        times = np.array(times)
        med = float(np.median(times))
        return {
            "median_step_s": med,
            "mad_s": float(np.median(np.abs(times - med))),
            "mean_step_s": float(times.mean()),
        }

    BURST = 3  # steps per timed sample: amortizes timer + transients

    def run_pair(arch, rate, window=None):
        """Interleaved static/dyn row-skip runs at matched dp draws:
        each rep times one BURST of static steps and one of dyn steps
        back to back (alternating order), and dyn_vs_static is the
        median of the per-rep ratios — the paired estimator, so machine
        drift between reps cancels instead of polluting two independent
        medians. Times are per step (burst / BURST)."""
        draws = dp_sequence(rate, (warm + steps) * BURST, rng)
        bursts = [draws[i * BURST:(i + 1) * BURST]
                  for i in range(warm + steps)]
        ts, td = [], []
        for i, dps in enumerate(bursts):
            order = (False, True) if i % 2 == 0 else (True, False)
            rep = {}
            for dyn in order:
                t0 = time.perf_counter()
                for dp in dps:
                    if arch == "mlpsyn":
                        mlpsyn_step("rdp", dp, rng, mlp_bufs,
                                    dyn_bwd=dyn)
                    else:
                        lstmsyn_step("rdp", dp, rng, lstm_bufs,
                                     window=window, dyn_bwd=dyn)
                rep[dyn] = (time.perf_counter() - t0) / BURST
            if i >= warm:
                ts.append(rep[False])
                td.append(rep[True])
        ts, td = np.array(ts), np.array(td)
        med = float(np.median(td))
        ratio = float(np.median(ts / td))
        return ratio, {
            "median_step_s": med,
            "mad_s": float(np.median(np.abs(td - med))),
            "mean_step_s": float(td.mean()),
        }

    def push_row(arch, rate, label, variant, r, dense, window=None,
                 dyn_vs_static=None):
        speedup = dense / r["median_step_s"]
        row = {
            "arch": arch,
            "rate": rate,
            "config": label,
            "variant": variant,
            "microkernel": "scalar",
            "reps": steps,
            "speedup_vs_dense": round(speedup, 4),
        }
        if window is not None:
            row["window"] = window
        if dyn_vs_static is not None:
            row["dyn_vs_static"] = dyn_vs_static
        row.update({k: round(v, 8) for k, v in r.items()})
        report["rows"].append(row)
        table.append((arch, rate, label, r["median_step_s"], speedup))

    table = []
    dense_med = {}
    for arch in ["mlpsyn", "lstmsyn"]:
        for rate in [0.3, 0.5, 0.7]:
            dense = None
            for label, variant in [("dense", "conv"),
                                   ("row-skip", "rdp"),
                                   ("tile-skip", "tdp")]:
                r = run(arch, variant, rate)
                if label == "dense":
                    dense = r["median_step_s"]
                    dense_med[(arch, rate)] = dense
                push_row(arch, rate, label, variant, r, dense,
                         window=8 if arch == "lstmsyn" else None)

    # Windowed lstmsyn rows (config `<label>@wN`): pattern re-drawn
    # every N timesteps, panel prep and softmax runs charged per
    # window, compared against the same per-rate dense baseline. The
    # scale model sees the per-window *work* (extra preps and split
    # softmax runs at small W) but not the panel-locality gains of the
    # packed Rust kernels, so it understates large-W speedups; the
    # native harness is the authoritative measurement (and the gate's
    # absolute windowed floor only arms on native baselines).
    for rate in [0.3, 0.5, 0.7]:
        for w in [1, 4, 16]:
            for label, variant in [("row-skip", "rdp"),
                                   ("tile-skip", "tdp")]:
                r = run("lstmsyn", variant, rate, window=w)
                push_row("lstmsyn", rate, f"{label}@w{w}", variant, r,
                         dense_med[("lstmsyn", rate)], window=w)

    # dyn-bwd rows: row-skip with dynamic backward sparsity ON, paired
    # against static-only runs of the identical configuration (see the
    # provenance note — the LSTM t==0 warmup skip is the real win at
    # this scale; mlpsyn's batch-16 dyn gain rounds to ~1.00). The dense
    # baseline is RE-measured adjacently so the row's speedup_vs_dense
    # is not polluted by machine drift since the first section ran.
    for arch in ["mlpsyn", "lstmsyn"]:
        for rate in [0.3, 0.5, 0.7]:
            window = 8 if arch == "lstmsyn" else None
            dense_adj = run(arch, "conv", rate,
                            window=window)["median_step_s"]
            ratio, r = run_pair(arch, rate, window=window)
            push_row(arch, rate, "dyn-bwd", "rdp", r, dense_adj,
                     window=window, dyn_vs_static=round(ratio, 2))

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path} ({len(report['rows'])} rows)")
    print(f"{'arch':8} {'rate':>5} {'config':>14} {'median':>10} "
          f"{'speedup':>8}")
    ok = True
    for arch, rate, label, med, sp in table:
        print(f"{arch:8} {rate:5.1f} {label:>14} {med * 1e3:9.3f}ms "
              f"{sp:7.2f}x")
        if label != "dense" and "@w" not in label and rate >= 0.5 \
                and sp <= 1.0:
            ok = False
            print(f"  ^^ NOT faster than dense at rate {rate}")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--bench", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--warm", type=int, default=3)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..",
        "BENCH_sparse.json"))
    args = ap.parse_args()
    do_all = not (args.validate or args.bench)
    ok = True
    if args.validate or do_all:
        validate_kernels()
        validate_mlp_step()
        validate_windowed_step()
    if args.bench or do_all:
        ok = bench(os.path.normpath(args.out), args.steps, args.warm)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
