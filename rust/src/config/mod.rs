//! Typed experiment configuration loaded from `configs/*.toml` (or built
//! from CLI flags). One config fully determines an experiment: model tag,
//! dropout variant + rates, data sizes, optimization hyper-parameters.

use std::path::Path;

use anyhow::{bail, Result};

use crate::coordinator::schedule::Variant;
use crate::util::toml::{self, TomlDoc};

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Artifact tag, e.g. "mlp2048x2048" or "lstm2x256v2048b20".
    pub tag: String,
    pub variant: Variant,
    /// Target dropout rate per site.
    pub rates: Vec<f64>,
    /// Divisor support set for the pattern search.
    pub support: Vec<usize>,
    pub shared_dp: bool,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    /// Dataset sizes (images or tokens).
    pub n_train: usize,
    pub n_test: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            tag: "mlp2048x2048".into(),
            variant: Variant::Rdp,
            rates: vec![0.5, 0.5],
            support: vec![1, 2, 4, 8],
            shared_dp: false,
            steps: 200,
            lr: 0.01,
            seed: 42,
            n_train: 10_000,
            n_test: 2_000,
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> Result<()> {
        if self.rates.is_empty() {
            bail!("config: rates must be non-empty");
        }
        if self.rates.iter().any(|&r| !(0.0..1.0).contains(&r)) {
            bail!("config: rates must be in [0, 1), got {:?}", self.rates);
        }
        if self.support.is_empty() || self.support[0] == 0 {
            bail!("config: bad divisor support {:?}", self.support);
        }
        if self.lr <= 0.0 {
            bail!("config: lr must be positive");
        }
        if self.steps == 0 {
            bail!("config: steps must be positive");
        }
        Ok(())
    }

    /// Load from a TOML file; missing keys fall back to defaults.
    pub fn from_toml(path: &Path) -> Result<TrainConfig> {
        let doc = toml::parse_file(path)?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let cfg = TrainConfig {
            tag: doc.str_or("model.tag", &d.tag).to_string(),
            variant: Variant::parse(
                doc.str_or("dropout.variant", "rdp"))?,
            rates: doc
                .get("dropout.rates")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or(d.rates),
            support: doc
                .get("dropout.support")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_i64().map(|i| i as usize))
                        .collect()
                })
                .unwrap_or(d.support),
            shared_dp: doc.bool_or("dropout.shared_dp", d.shared_dp),
            steps: doc.i64_or("train.steps", d.steps as i64) as usize,
            lr: doc.f64_or("train.lr", d.lr),
            seed: doc.i64_or("train.seed", d.seed as i64) as u64,
            n_train: doc.i64_or("data.n_train", d.n_train as i64) as usize,
            n_test: doc.i64_or("data.n_test", d.n_test as i64) as usize,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_doc() {
        let doc = toml::parse(
            "[model]\ntag = \"mlp1024x1024\"\n[dropout]\n\
             variant = \"tile\"\nrates = [0.7, 0.7]\nshared_dp = true\n\
             support = [1, 2, 4, 8]\n[train]\nsteps = 500\nlr = 0.05\n\
             seed = 7\n[data]\nn_train = 60000\nn_test = 10000\n",
        )
        .unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.tag, "mlp1024x1024");
        assert_eq!(cfg.variant, Variant::Tdp);
        assert_eq!(cfg.rates, vec![0.7, 0.7]);
        assert!(cfg.shared_dp);
        assert_eq!(cfg.steps, 500);
        assert_eq!(cfg.n_train, 60_000);
    }

    #[test]
    fn rejects_bad_rates() {
        let doc = toml::parse("[dropout]\nrates = [1.5]\n").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn rejects_bad_variant() {
        let doc = toml::parse("[dropout]\nvariant = \"nope\"\n").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
    }
}
