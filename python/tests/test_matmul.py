"""L1 dense tiled matmul vs the pure-jnp oracle (values and gradients)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, pick_block, ref

DIMS = st.sampled_from([1, 2, 4, 8, 16, 20, 28, 32, 33, 64, 96, 100, 128])


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))
    out = matmul(a, b)
    np.testing.assert_allclose(out, ref.matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([4, 20, 32]), k=st.sampled_from([8, 96]),
       n=st.sampled_from([8, 64]), seed=st.integers(0, 2**16))
def test_matmul_gradients(m, k, n, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))

    def f_kernel(a, b):
        return jnp.sum(jnp.tanh(matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.tanh(jnp.dot(a, b)))

    gk = jax.grad(f_kernel, argnums=(0, 1))(a, b)
    gr = jax.grad(f_ref, argnums=(0, 1))(a, b)
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(x, y, rtol=1e-3, atol=1e-4)


def test_pick_block_divides_and_caps():
    for dim in [1, 7, 28, 64, 256, 784, 1500, 2048, 8800]:
        b = pick_block(dim)
        assert dim % b == 0
        assert b <= 256
    assert pick_block(784) == 196  # largest divisor <= 256
    assert pick_block(2048) == 256


def test_matmul_under_jit_and_vmap_composition():
    a = rand(3, (16, 32))
    b = rand(4, (32, 8))
    jitted = jax.jit(lambda a, b: matmul(a, b))
    np.testing.assert_allclose(jitted(a, b), a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_float_stability_large_k():
    # Accumulation across many k-blocks must stay accurate.
    a = jnp.ones((8, 1024), jnp.float32) * 0.01
    b = jnp.ones((1024, 8), jnp.float32) * 0.01
    out = matmul(a, b)
    np.testing.assert_allclose(out, jnp.full((8, 8), 1024 * 1e-4),
                               rtol=1e-4)
