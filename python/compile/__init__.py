"""Build-time Python package: L1 Pallas kernels + L2 JAX model graphs + AOT.

Never imported at runtime — ``make artifacts`` runs :mod:`compile.aot` once,
after which the Rust binary is self-contained (see DESIGN.md section 3).
"""
