//! Summary statistics for the bench harness and metrics (criterion is
//! unavailable offline; this provides the estimators our tables need).

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
///
/// NaN samples are dropped before ranking: one bad measurement (a failed
/// timer read, a 0/0 rate) must not kill a whole bench run — this used
/// to sort with `partial_cmp(..).unwrap()`, which panics on the first
/// NaN comparison. All-NaN input returns NaN (the honest answer); empty
/// input stays 0.0 for backwards compatibility.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> =
        xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if s.is_empty() {
        return f64::NAN;
    }
    s.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread), scaled for normal
/// consistency (x1.4826). NaN samples are ignored, like [`percentile`].
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    1.4826 * median(&dev)
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Exponential moving average (loss-curve smoothing).
#[derive(Clone, Debug)]
pub struct Ema {
    pub alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-9);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [0.5, 1.5, -3.0, 8.0, 2.25];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - stddev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let clean = [1.0, 1.1, 0.9, 1.05, 0.95];
        let dirty = [1.0, 1.1, 0.9, 1.05, 100.0];
        assert!(mad(&dirty) < 0.5, "mad {} should ignore outlier",
                mad(&dirty));
        assert!(stddev(&dirty) > 10.0);
        assert!((mad(&clean) - mad(&dirty)).abs() < 0.2);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..32 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic_and_are_ignored() {
        // Regression: the sort used `partial_cmp(..).unwrap()`, so a
        // single NaN timing sample panicked the whole bench report.
        let xs = [3.0, f64::NAN, 1.0, 2.0, f64::NAN, 4.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        // MAD over the finite samples, NaNs dropped at both levels.
        let clean = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(mad(&xs), mad(&clean));
        // All-NaN input yields NaN, not a panic (and not a silent 0).
        assert!(median(&[f64::NAN, f64::NAN]).is_nan());
        // Infinities still rank (total order), no panic.
        let inf = [1.0, f64::INFINITY, f64::NEG_INFINITY, 2.0];
        assert_eq!(median(&inf), 1.5);
    }
}
