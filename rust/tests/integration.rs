//! Integration tests over the full stack: manifest -> PJRT compile ->
//! train/eval execution -> state update. Uses the tiny `mlptest`/`lstmtest`
//! artifacts built by `make artifacts` (aot.py --set test is a subset of
//! the default set).

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::state::{lit_f32, lit_i32, lit_scalar_f32,
                                     lit_scalar_i32};
use approx_dropout::runtime::{Engine, Manifest, TrainState};
use approx_dropout::util::rng::Rng;

fn setup() -> ExecutorCache {
    let dir = approx_dropout::artifacts_dir();
    let manifest = Manifest::load(&dir).expect("manifest (run make artifacts)");
    let engine = Engine::cpu().expect("pjrt cpu");
    ExecutorCache::new(engine, manifest)
}

/// Host-side forward pass of the tiny MLP (32 -> 64 -> 64 -> 10) used to
/// cross-check the eval graph's numerics end-to-end.
fn host_mlp_eval(params: &[Vec<f32>], x: &[f32], y: &[i32], batch: usize)
                 -> (f64, f64) {
    let dims = [(32usize, 64usize), (64, 64), (64, 10)];
    let mut act: Vec<f32> = x.to_vec();
    let mut width = 32;
    for (li, &(k, n)) in dims.iter().enumerate() {
        let w = &params[2 * li];
        let b = &params[2 * li + 1];
        let mut next = vec![0f32; batch * n];
        for bi in 0..batch {
            for j in 0..n {
                let mut acc = b[j];
                for i in 0..k {
                    acc += act[bi * width + i] * w[i * n + j];
                }
                // ReLU on hidden layers only.
                next[bi * n + j] = if li < 2 { acc.max(0.0) } else { acc };
            }
        }
        act = next;
        width = n;
    }
    // Softmax CE + correct count.
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    for bi in 0..batch {
        let logits = &act[bi * 10..(bi + 1) * 10];
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 =
            logits.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
        loss -= (logits[y[bi] as usize] - lse) as f64;
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == y[bi] as usize {
            correct += 1.0;
        }
    }
    (loss / batch as f64, correct)
}

#[test]
fn eval_graph_matches_host_forward() {
    let cache = setup();
    let exe = cache.get("mlptest_eval").unwrap();
    let mut rng = Rng::new(7);
    let meta = cache.manifest().get("mlptest_conv").unwrap();
    let state = TrainState::init(meta, &mut rng);

    let batch = 8;
    let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.next_usize(10) as i32).collect();

    let x_l = lit_f32(&[batch, 32], &x).unwrap();
    let y_l = lit_i32(&[batch], &y).unwrap();
    let mut refs = state.param_refs();
    refs.push(&x_l);
    refs.push(&y_l);
    let out = exe.run_raw(&refs).unwrap();
    let loss_dev = out[0].get_first_element::<f32>().unwrap() as f64;
    let correct_dev = out[1].get_first_element::<f32>().unwrap() as f64;

    let host_params: Vec<Vec<f32>> =
        (0..6).map(|i| state.param_f32(i).unwrap()).collect();
    let (loss_host, correct_host) = host_mlp_eval(&host_params, &x, &y,
                                                  batch);
    assert!((loss_dev - loss_host).abs() < 1e-4,
            "device {loss_dev} vs host {loss_host}");
    assert_eq!(correct_dev, correct_host);
}

#[test]
fn trainer_constructs_and_names_executables() {
    let cache = setup();
    let schedule =
        Schedule::new(Variant::Conv, &[0.5, 0.5], &[1, 2], false).unwrap();
    let tr = MlpTrainer::new(&cache, "mlptest", schedule, 64, 0.05, 11)
        .unwrap();
    assert_eq!(tr.executable_names(), vec!["mlptest_conv".to_string()]);
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
    let tr = MlpTrainer::new(&cache, "mlptest", schedule, 64, 0.05, 11)
        .unwrap();
    assert_eq!(tr.executable_names(), vec!["mlptest_rdp_2_2".to_string()]);
}

fn run_step(state: &mut TrainState,
            exe: &approx_dropout::runtime::Executable, rng: &mut Rng,
            b0: (i32, i32), lr: f32) -> (f64, f64) {
    let batch = 8;
    let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.next_usize(10) as i32).collect();
    let tail = vec![
        lit_f32(&[batch, 32], &x).unwrap(),
        lit_i32(&[batch], &y).unwrap(),
        lit_scalar_i32(b0.0),
        lit_scalar_i32(b0.1),
        lit_scalar_f32(2.0), // inverted-dropout scale, site 1
        lit_scalar_f32(2.0), // inverted-dropout scale, site 2
        lit_scalar_f32(lr),
    ];
    state.step(exe, &tail).unwrap()
}

#[test]
fn rdp_step_loss_finite_and_state_changes() {
    let cache = setup();
    let exe = cache.get("mlptest_rdp_2_2").unwrap();
    let mut rng = Rng::new(21);
    let meta = cache.manifest().get("mlptest_rdp_2_2").unwrap();
    let mut state = TrainState::init(meta, &mut rng);
    let before = state.param_f32(0).unwrap();
    let (loss, correct) = run_step(&mut state, &exe, &mut rng, (1, 0), 0.1);
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert!((0.0..=8.0).contains(&correct));
    let after = state.param_f32(0).unwrap();
    assert_ne!(before, after, "params must change after one step");
    assert_eq!(state.step, 1);
}

#[test]
fn rdp_only_kept_rows_update_in_w3() {
    // RDP drops entire rows of the next layer's weight matrix: the
    // gradient (hence the update) of dropped rows of w3 must be zero.
    let cache = setup();
    let exe = cache.get("mlptest_rdp_2_2").unwrap();
    let mut rng = Rng::new(33);
    let meta = cache.manifest().get("mlptest_rdp_2_2").unwrap();
    let mut state = TrainState::init(meta, &mut rng);
    let w3_before = state.param_f32(4).unwrap();

    let b0_1 = 1; // site-2 pattern: keep rows {1, 3, 5, ...}
    run_step(&mut state, &exe, &mut rng, (0, b0_1), 0.1);
    let w3_after = state.param_f32(4).unwrap();

    // w3 shape [64, 10]; rows with i % 2 == b0_1 kept, others frozen.
    let mut kept_changed = 0;
    for i in 0..64 {
        let row_changed = (0..10)
            .any(|j| w3_before[i * 10 + j] != w3_after[i * 10 + j]);
        if i % 2 == b0_1 as usize {
            kept_changed += usize::from(row_changed);
        } else {
            // The exact claim of the pattern: dropped rows receive NO
            // gradient and are bit-identical after the step.
            assert!(!row_changed, "dropped row {i} must be frozen");
        }
    }
    // Kept rows update unless their ReLU unit is dead for the whole batch;
    // with random init most must move.
    assert!(kept_changed >= 16,
            "only {kept_changed}/32 kept rows updated");
}

#[test]
fn tdp_step_runs() {
    let cache = setup();
    let exe = cache.get("mlptest_tdp_2_2").unwrap();
    let mut rng = Rng::new(5);
    let meta = cache.manifest().get("mlptest_tdp_2_2").unwrap();
    let mut state = TrainState::init(meta, &mut rng);
    let (loss, _) = run_step(&mut state, &exe, &mut rng, (1, 0), 0.1);
    assert!(loss.is_finite());
}

#[test]
fn lstm_trainer_end_to_end_tiny() {
    let cache = setup();
    let corpus = Corpus::generate(64, 4000, 400, 400, 9);
    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        let shared = variant != Variant::Conv;
        let schedule =
            Schedule::new(variant, &[0.5, 0.5], &[2], shared).unwrap();
        let mut tr = LstmTrainer::new(&cache, "lstmtest", schedule,
                                      &corpus.train, 0.5, 13)
            .unwrap();
        tr.warmup().unwrap();
        let first = tr.step().unwrap().0;
        for _ in 0..10 {
            tr.step().unwrap();
        }
        let last = tr.metrics.last_loss();
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first + 0.5,
                "{variant:?}: loss diverged {first} -> {last}");
        let (xent, ppl, acc) = tr.evaluate(&corpus.valid).unwrap();
        assert!(xent.is_finite() && ppl > 1.0 && (0.0..=1.0).contains(&acc));
    }
}

#[test]
fn mlp_trainer_learns_real_digits() {
    // Short but real training on the synthetic MNIST through the tiny
    // arch... mlptest takes 32-dim inputs, so use the real 784-dim arch
    // only if present; otherwise validate the loss trend on random data
    // via the tiny RDP artifact (covered above). Here: LSTM-free check
    // that a conv schedule trainer improves batch accuracy on digits with
    // the 2048 arch when available.
    let cache = setup();
    if cache.manifest().get("mlp1024x64_conv").is_err() {
        return; // artifact subset build; skip
    }
    let data = MnistSyn::generate(512, 3);
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2], true).unwrap();
    let mut tr = MlpTrainer::new(&cache, "mlp1024x64", schedule, data.n,
                                 0.01, 7).unwrap();
    tr.warmup().unwrap();
    let mut first_loss = 0.0;
    let mut last_loss = 0.0;
    let steps = 60;
    for s in 0..steps {
        let (loss, _) = tr.step(&data).unwrap();
        if s < 10 {
            first_loss += loss / 10.0;
        }
        if s >= steps - 10 {
            last_loss += loss / 10.0;
        }
    }
    assert!(last_loss < first_loss,
            "no learning: loss {first_loss:.3} -> {last_loss:.3}");
}

#[test]
fn deterministic_given_seed() {
    let cache = setup();
    let corpus = Corpus::generate(64, 3000, 300, 300, 17);
    let run = |seed: u64| -> Vec<f64> {
        let schedule =
            Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
        let mut tr = LstmTrainer::new(&cache, "lstmtest", schedule,
                                      &corpus.train, 0.5, seed)
            .unwrap();
        (0..5).map(|_| tr.step().unwrap().0).collect()
    };
    assert_eq!(run(99), run(99));
    assert_ne!(run(99), run(100));
}
