//! MLP front (paper sections IV-A/B): arch-specific input assembly for
//! the generic [`Trainer`] driver.
//!
//! Per iteration the front samples the dropout pattern for each hidden
//! layer from the schedule, resolves the matching AOT executable
//! (`<tag>_rdp_<dp1>_<dp2>` ...), and lays out the input tail per the
//! manifest calling convention. The conventional baseline follows the
//! identical path but generates Bernoulli masks instead of bias scalars —
//! wall-clock comparisons therefore measure exactly the paper's quantity.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::driver::{push_bias_scalars, push_scale_scalars,
                                 ModelFront, StepInput, Trainer};
use crate::coordinator::pool::ExecutorCache;
use crate::coordinator::schedule::{Schedule, Variant};
use crate::data::{MnistBatcher, MnistSyn};
use crate::runtime::{ArchMeta, HostTensor, Manifest, TrainState};
use crate::service::checkpoint::{rng_state_from_json, rng_state_to_json};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The MLP trainer is the generic driver over [`MlpFront`].
pub type MlpTrainer = Trainer<MlpFront>;

pub struct MlpFront {
    pub tag: String,
    pub schedule: Schedule,
    batcher: MnistBatcher,
    hidden: Vec<usize>,
    batch: usize,
    n_in: usize,
    /// Construction seed — part of the checkpoint config hash because
    /// callers (CLI, serve) regenerate the *dataset* from it; resuming
    /// under a different seed would silently train on different data.
    seed: u64,
    rng: Rng,
}

impl ModelFront for MlpFront {
    type Data = MnistSyn;
    type EvalData = MnistSyn;

    fn tag(&self) -> &str {
        &self.tag
    }

    fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    fn artifact_for(&self, dp: &[usize]) -> String {
        Manifest::artifact_name(&self.tag, self.schedule.variant.as_str(), dp)
    }

    fn assemble(&mut self, data: &MnistSyn) -> Result<StepInput> {
        let choices = {
            let _sp = crate::obs::trace::span("sample");
            self.schedule.sample(&mut self.rng)
        };
        let prev_epoch = self.batcher.epoch;
        // Tail tensors own their buffers (the pipelined path ships them
        // across a thread), so the batcher/masks fill owned Vecs directly
        // — same copy count as building literals from borrowed slices.
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.batcher.next_batch_into(data, &mut self.rng, &mut x, &mut y);

        let mut tail = Vec::with_capacity(2 + 2 * self.schedule.sites());
        let n_in = x.len() / self.batch;
        tail.push(HostTensor::f32(&[self.batch, n_in], x));
        tail.push(HostTensor::i32(&[self.batch], y));

        let name = match self.schedule.variant {
            Variant::Conv => {
                // Bernoulli masks + inverted-dropout scales per site.
                for site in 0..self.schedule.sites() {
                    let keep = 1.0 - self.schedule.rates[site];
                    let w = self.hidden[site];
                    let m = self.rng.mask_vec(keep, self.batch * w);
                    tail.push(HostTensor::f32(&[self.batch, w], m));
                }
                push_scale_scalars(&mut tail, &self.schedule.rates);
                format!("{}_conv", self.tag)
            }
            _ => {
                push_bias_scalars(&mut tail, &choices);
                push_scale_scalars(&mut tail, &self.schedule.rates);
                let dp: Vec<usize> = choices.iter().map(|c| c.dp).collect();
                self.artifact_for(&dp)
            }
        };

        // MnistBatcher counts the epoch it is starting (the first batch
        // reports epoch 1); a *completed* epoch is any later bump.
        let epoch_boundary =
            self.batcher.epoch != prev_epoch && self.batcher.epoch > 1;
        Ok(StepInput { name, tail, examples: self.batch, epoch_boundary })
    }

    fn eval_num_batches(&self, test: &MnistSyn) -> usize {
        test.n / self.batch
    }

    fn eval_batch(&self, test: &MnistSyn, bi: usize)
                  -> Result<Vec<HostTensor>> {
        let mut x = Vec::with_capacity(self.batch * self.n_in);
        let mut y = Vec::with_capacity(self.batch);
        for i in bi * self.batch..(bi + 1) * self.batch {
            x.extend_from_slice(test.image(i));
            y.push(test.labels[i] as i32);
        }
        Ok(vec![
            HostTensor::f32(&[self.batch, self.n_in], x),
            HostTensor::i32(&[self.batch], y),
        ])
    }

    fn eval_examples_per_batch(&self) -> usize {
        self.batch
    }

    fn config_line(&self) -> String {
        format!("mlp tag={} variant={} rates={:?} shared_dp={} \
                 combos={:?} batch={} hidden={:?} n_in={} seed={}",
                self.tag, self.schedule.variant.as_str(),
                self.schedule.rates, self.schedule.shared_dp,
                self.schedule.dp_combos(), self.batch, self.hidden,
                self.n_in, self.seed)
    }

    fn snapshot(&self) -> Json {
        let (order, cursor, epoch) = self.batcher.snapshot();
        Json::obj(vec![
            ("kind", Json::str("mlp")),
            ("rng", rng_state_to_json(self.rng.state())),
            ("order", Json::Arr(
                order.iter().map(|&i| Json::num(i as f64)).collect())),
            // usize::MAX (the first-call sentinel) exceeds f64's exact
            // integer range, so the cursor travels as hex.
            ("cursor", Json::str(
                &crate::service::checkpoint::hex_u64(cursor as u64))),
            ("epoch", Json::num(epoch as f64)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<()> {
        if snap.get("kind").and_then(Json::as_str) != Some("mlp") {
            bail!("front snapshot is not an MLP state");
        }
        let rng = Rng::from_state(rng_state_from_json(
            snap.get("rng").ok_or_else(|| anyhow!("snapshot: no rng"))?)?)
            .ok_or_else(|| anyhow!("snapshot: dead rng state"))?;
        let order: Vec<usize> = snap
            .get("order")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("snapshot: no batcher order"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(
                || anyhow!("snapshot: bad order entry")))
            .collect::<Result<_>>()?;
        let cursor = crate::service::checkpoint::parse_hex_u64(
            snap.get("cursor").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("snapshot: no cursor"))?)?
            as usize;
        let epoch = snap.get("epoch").and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("snapshot: no epoch"))?;
        self.batcher.restore(order, cursor, epoch)?;
        self.rng = rng;
        Ok(())
    }
}

impl Trainer<MlpFront> {
    pub fn new(cache: &ExecutorCache, tag: &str, schedule: Schedule,
               n_train: usize, lr: f32, seed: u64) -> Result<MlpTrainer> {
        let conv = cache.manifest().get(&format!("{tag}_conv"))?;
        let (n_in, hidden, batch) = match &conv.arch {
            ArchMeta::Mlp { n_in, hidden, batch, .. } =>
                (*n_in, hidden.clone(), *batch),
            _ => bail!("artifact {tag} is not an MLP"),
        };
        if schedule.sites() != hidden.len() {
            bail!("schedule has {} sites, MLP has {} hidden layers",
                  schedule.sites(), hidden.len());
        }
        let mut rng = Rng::new(seed);
        let state = TrainState::init(conv, &mut rng,
                                     cache.backend().as_ref())?;
        let front = MlpFront {
            tag: tag.to_string(),
            schedule,
            batcher: MnistBatcher::new(n_train, batch)?,
            hidden,
            batch,
            n_in,
            seed,
            rng,
        };
        Ok(Trainer::from_parts(cache, front, state, lr))
    }

    /// One full training iteration; returns (loss, batch accuracy).
    pub fn step(&mut self, data: &MnistSyn) -> Result<(f64, f64)> {
        self.step_with(data)
    }

    /// Run `n` steps; returns mean loss over the window.
    pub fn train(&mut self, data: &MnistSyn, n: usize) -> Result<f64> {
        self.train_with(data, n)
    }

    /// Evaluate on a test set through the dropout-free eval graph; returns
    /// (mean loss, accuracy).
    pub fn evaluate(&mut self, test: &MnistSyn) -> Result<(f64, f64)> {
        self.evaluate_with(test)
    }
}
