//! Observability contract tests.
//!
//! The load-bearing guarantee of the `obs` layer is that it *observes*:
//! enabling phase tracing must never draw RNG, reorder dispatches, or
//! change a single trajectory bit — otherwise every "measured" run is a
//! different experiment from the un-measured one. These tests pin that
//! on both hermetic backends (reference and sparse), including the
//! windowed-LSTM configuration whose per-(site, window) prep work the
//! phase breakdown exists to attribute.
//!
//! Hermetic: built-in synthetic manifest, no artifacts, never skips.

use std::path::PathBuf;

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::obs::{self, trace};
use approx_dropout::runtime::Manifest;
use approx_dropout::util::json::Json;

/// Everything observable about one short training run, bit-comparable.
#[derive(Debug, PartialEq)]
struct Traj {
    curve: Vec<(u64, u64, u64)>,
    dispatched: Vec<String>,
    ckpt_bytes: Vec<u8>,
}

fn tmp_ckpt(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("obs_{}_{}.ckpt", std::process::id(), name))
}

/// Short MLP run; the curve is captured as raw f64 bits so equality is
/// bit-identity, not approximate.
fn run_mlp(cache: &ExecutorCache, name: &str) -> Traj {
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2], false).unwrap();
    let (train, _) = MnistSyn::train_test(256, 64, 42);
    let mut tr =
        MlpTrainer::new(cache, "mlpsyn", schedule, train.n, 0.01, 7)
            .unwrap();
    tr.warmup().unwrap();
    for _ in 0..6 {
        tr.step(&train).unwrap();
    }
    let path = tmp_ckpt(name);
    tr.save_checkpoint(&path).unwrap();
    let ckpt_bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    Traj {
        curve: tr.metrics.curve.iter()
            .map(|p| (p.step, p.loss.to_bits(), p.acc.to_bits()))
            .collect(),
        dispatched: tr.metrics.dispatched.clone(),
        ckpt_bytes,
    }
}

/// Short windowed LSTM run (W=10 holds one pattern draw across two
/// steps of the seq-5 arch — the configuration with a real `prep`
/// phase to attribute).
fn run_lstm_windowed(cache: &ExecutorCache, name: &str) -> Traj {
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
    let corpus = Corpus::generate(64, 3000, 300, 300, 9);
    let mut tr = LstmTrainer::new_with_window(cache, "lstmtest", schedule,
                                              &corpus.train, 0.5, 13,
                                              Some(10))
        .unwrap();
    tr.warmup().unwrap();
    for _ in 0..4 {
        tr.step().unwrap();
    }
    let path = tmp_ckpt(name);
    tr.save_checkpoint(&path).unwrap();
    let ckpt_bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    Traj {
        curve: tr.metrics.curve.iter()
            .map(|p| (p.step, p.loss.to_bits(), p.acc.to_bits()))
            .collect(),
        dispatched: tr.metrics.dispatched.clone(),
        ckpt_bytes,
    }
}

/// The pinned acceptance invariant: AD_TRACE on vs off is bit-identical
/// — loss/accuracy curves, dispatch sequences, and final parameter
/// bytes — on the reference interpreter, the sparse engine, and the
/// windowed-LSTM sparse configuration. All toggling lives in this one
/// test so parallel test threads never race the global flag.
#[test]
fn trace_on_is_bit_identical_to_trace_off() {
    let ref_cache = ExecutorCache::reference(Manifest::builtin_test());
    let sparse_cache = ExecutorCache::sparse(Manifest::builtin_test());

    trace::force_enabled(false);
    let mlp_ref_off = run_mlp(&ref_cache, "mro");
    let mlp_sp_off = run_mlp(&sparse_cache, "mso");
    let lstm_sp_off = run_lstm_windowed(&sparse_cache, "lso");

    trace::force_enabled(true);
    let _ = trace::take_phases(); // start the on-runs from a clean slate
    let mlp_ref_on = run_mlp(&ref_cache, "mrn");
    let mlp_sp_on = run_mlp(&sparse_cache, "msn");
    let lstm_sp_on = run_lstm_windowed(&sparse_cache, "lsn");
    let phases = trace::take_phases();
    trace::force_enabled(false);

    assert_eq!(mlp_ref_off, mlp_ref_on,
               "reference backend diverged under AD_TRACE");
    assert_eq!(mlp_sp_off, mlp_sp_on,
               "sparse backend diverged under AD_TRACE");
    assert_eq!(lstm_sp_off, lstm_sp_on,
               "windowed LSTM diverged under AD_TRACE");

    // The spans did fire on the real path: every interpreter phase is
    // present and scoped to the front that ran it.
    let have: Vec<(&str, &str)> = phases.iter()
        .map(|r| (r.scope.as_str(), r.phase))
        .collect();
    for phase in ["sample", "assemble", "marshal", "execute", "fwd",
                  "bptt", "sgd"] {
        assert!(have.iter().any(|&(s, p)| p == phase
                                && s.starts_with("mlpsyn/rdp")),
                "phase '{phase}' missing for mlpsyn/rdp: {have:?}");
    }
    for phase in ["prep", "softmax"] {
        assert!(have.iter().any(|&(s, p)| p == phase
                                && s.starts_with("lstmtest/rdp")),
                "phase '{phase}' missing for lstmtest/rdp: {have:?}");
    }
    for r in &phases {
        assert!(r.agg.count > 0 && r.agg.total_s >= 0.0
                && r.agg.max_s <= r.agg.total_s + 1e-12,
                "inconsistent aggregate: {r:?}");
    }
}

/// The always-on registry reflects real work after a sparse run, and the
/// export document keeps the checker's invariants (instruments present,
/// histogram counts sum to total) with live counters behind it.
#[test]
fn metrics_export_reflects_sparse_training() {
    let cache = ExecutorCache::sparse(Manifest::builtin_test());
    let _ = run_mlp(&cache, "mex");
    let doc = obs::metrics_report("test").to_json();
    let rows = doc.get("rows").and_then(Json::as_arr).unwrap();
    let find = |name: &str| -> &Json {
        rows.iter()
            .find(|r| r.get("instrument").and_then(Json::as_str)
                      == Some(name)
                  && r.get("label").is_none())
            .unwrap_or_else(|| panic!("instrument {name} missing"))
    };
    // Row-skip training touched and skipped real rows; every dispatch
    // was counted under a sparse/<artifact> label.
    assert!(find("sparse_rows_kept").get("value").unwrap().as_f64()
                .unwrap() > 0.0);
    assert!(find("sparse_rows_dropped").get("value").unwrap().as_f64()
                .unwrap() > 0.0);
    let dispatch = find("dispatch_total");
    assert!(dispatch.get("value").unwrap().as_f64().unwrap() >= 6.0);
    assert!(rows.iter().any(|r| {
        r.get("instrument").and_then(Json::as_str)
            == Some("dispatch_total")
            && r.get("label").and_then(Json::as_str)
                .is_some_and(|l| l.starts_with("sparse/"))
    }), "no per-label dispatch row");
    // Histogram rows stay internally consistent while counters are hot.
    for r in rows.iter().filter(
        |r| r.get("kind").and_then(Json::as_str) == Some("histogram"))
    {
        let counts: f64 = r.get("counts").and_then(Json::as_arr).unwrap()
            .iter().map(|c| c.as_f64().unwrap()).sum();
        assert_eq!(Some(counts), r.get("total").unwrap().as_f64());
    }
}
