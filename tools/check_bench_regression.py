#!/usr/bin/env python3
"""Gate native sparse-speedup numbers against the checked-in baseline.

Usage:
    check_bench_regression.py NATIVE.json CHECKED_IN.json [--tolerance T]
    check_bench_regression.py --refresh-baseline NATIVE.json CHECKED_IN.json
    check_bench_regression.py --infer-advisory BENCH_infer.json
    check_bench_regression.py --self-test

Gate mode (default) fails (exit 1) if any gated row's native
`speedup_vs_dense` falls more than `tolerance` (fraction) below the
checked-in value. Gated rows are the paper-relevant operating points:
rate in {0.5, 0.7} for the row-skip and tile-skip configs — including
their time-windowed `<config>@wN` variants — on every arch present in
the baseline. Dense rows (speedup 1.0 by construction), low-rate smoke
points, `<config>@scalar` rows, and `dyn-bwd` rows are reported but
not gated against the baseline. `dyn-bwd` rows (dynamic backward
sparsity from the SparsityPlan's masks) do get a *structural*
check: present rows must carry finite positive `dyn_vs_static` and
`speedup_vs_dense` fields — their magnitudes stay advisory because the
dyn-vs-static delta is within shared-runner noise, but a malformed row
means the paired measurement path regressed and fails.

The windowed LSTM rows additionally carry an *absolute* floor: the
time-window feature exists to close the paper's LSTM speedup gap, so
`lstmsyn` row-skip at rate 0.5 with a 16-timestep window must beat
dense by at least 1.6x. The floor is a ratchet — advisory until a
reviewed native baseline demonstrating the bar is landed via
`--refresh-baseline`, a hard gate on native candidates afterwards.
Smoke runs and reports predating the windowed rows skip it.

Additionally, when the native report was produced by a SIMD microkernel
(meta `microkernel` != "scalar") and carries `@scalar` comparison rows,
the gate requires the SIMD path to beat the scalar sparse path on the
GEMM-dominated mlpsyn row/tile-skip configs (median step time strictly
lower) — the microkernel layer must actually pay for itself.

`--infer-advisory` validates and prints an inference-serving latency
report (`BENCH_infer.json` from `approx-dropout infer`). Latency on a
shared CI runner is too noisy to gate on an absolute threshold, so the
numbers are advisory rows in the job log — but a *malformed* report
(wrong bench name, no rows, NaN/missing qps or percentile fields) is a
broken measurement path and fails with exit 1, so the serving bench
cannot silently rot.

Tolerance calibration: when --tolerance is not given it is derived from
the baseline's provenance — 0.25 against a *native* baseline (same
harness, same math; a >25% drop is a real regression), 0.40 against a
synthetic scale-model baseline (ratios model scalar MAC counts only;
printed with a loud calibration warning). Re-baselining is
`--refresh-baseline`: it atomically replaces CHECKED_IN.json with
NATIVE.json, so a baseline update is a reviewed one-line command plus a
diff, never hand-edited JSON.
"""

import argparse
import json
import math
import os
import sys
import tempfile

GATED_RATES = (0.5, 0.7)
GATED_CONFIGS = ("row-skip", "tile-skip")
NATIVE_TOLERANCE = 0.25
SCALE_MODEL_TOLERANCE = 0.40
# Absolute floor on the windowed LSTM operating point (the acceptance
# bar for the time-window feature), independent of any baseline.
WINDOWED_FLOOR_KEY = ("lstmsyn", 0.5, "row-skip@w16")
WINDOWED_FLOOR = 1.6


def is_gated_config(config):
    """Gated: row/tile-skip, including their `@wN` windowed variants.

    `@scalar` rows (and any other suffix) stay ungated — they exist as
    intra-report comparisons, not baseline-tracked operating points.
    """
    if config in GATED_CONFIGS:
        return True
    base, sep, suffix = config.partition("@w")
    return bool(sep) and base in GATED_CONFIGS and suffix.isdigit()


def load_doc(path):
    with open(path) as f:
        return json.load(f)


def rows_by_key(doc):
    return {(r["arch"], r["rate"], r["config"]): r for r in doc["rows"]}


def is_native(doc):
    return str(doc.get("provenance", "")).startswith("native")


def pick_tolerance(args_tolerance, checked_doc):
    if args_tolerance is not None:
        return args_tolerance, "explicit --tolerance"
    if is_native(checked_doc):
        return NATIVE_TOLERANCE, "native baseline"
    return SCALE_MODEL_TOLERANCE, (
        "synthetic baseline (scale model) — WIDENED tolerance; refresh "
        "the baseline from a native run to tighten the gate "
        "(--refresh-baseline)")


def check_baseline_floor(native, checked, tolerance):
    """Speedup floor per gated row; returns (failures, printed lines)."""
    failures, lines = [], []
    for key in sorted(checked, key=str):
        arch, rate, config = key
        base = checked[key]["speedup_vs_dense"]
        nat = native.get(key)
        gated = rate in GATED_RATES and is_gated_config(config)
        if nat is None:
            verdict = "MISSING" if gated else "missing (ungated)"
            if gated:
                failures.append(f"{key}: missing from native report")
            lines.append(f"{arch:8} {rate:5} {config:>16} {'-':>8} "
                         f"{base:9.2f} {'-':>7}  {verdict}")
            continue
        nat_speedup = nat["speedup_vs_dense"]
        floor = (1.0 - tolerance) * base
        if gated:
            ok = nat_speedup >= floor
            verdict = "ok" if ok else "REGRESSION"
            if not ok:
                failures.append(
                    f"{key}: native {nat_speedup:.2f} < floor "
                    f"{floor:.2f} (baseline {base:.2f})")
        else:
            verdict = "info"
        lines.append(f"{arch:8} {rate:5} {config:>16} {nat_speedup:8.2f} "
                     f"{base:9.2f} {floor:7.2f}  {verdict}")
    return failures, lines


def check_simd_beats_scalar(native_doc, native):
    """SIMD vs scalar on the mlpsyn GEMM-dominated configs.

    Only applies when the native run used a SIMD microkernel AND emitted
    the @scalar comparison rows; returns (failures, printed lines).
    """
    failures, lines = [], []
    mk = native_doc.get("microkernel", "scalar")
    if mk == "scalar":
        lines.append("(native run used scalar microkernels; "
                     "SIMD-vs-scalar gate skipped)")
        return failures, lines
    compared = 0
    for rate in GATED_RATES:
        for config in GATED_CONFIGS:
            simd = native.get(("mlpsyn", rate, config))
            scalar = native.get(("mlpsyn", rate, f"{config}@scalar"))
            if simd is None or scalar is None:
                continue
            compared += 1
            s, c = simd["median_step_s"], scalar["median_step_s"]
            ratio = c / s if s > 0 else float("nan")
            # 2% noise margin: a tie or timer-quantum wobble on a shared
            # runner is not a regression; a genuinely slower SIMD path is.
            ok = s <= c * 1.02
            verdict = "ok" if ok else "SIMD SLOWER THAN SCALAR"
            if not ok:
                failures.append(
                    f"mlpsyn rate={rate} {config}: {mk} median {s:.6f}s "
                    f">= scalar median {c:.6f}s")
            lines.append(f"mlpsyn   {rate:5} {config:>16} {mk}={s:.6f}s "
                         f"scalar={c:.6f}s  x{ratio:.2f}  {verdict}")
    if compared == 0:
        lines.append(f"(microkernel={mk} but no @scalar rows present; "
                     "SIMD-vs-scalar gate skipped)")
    return failures, lines


def check_windowed_floor(native_doc, native, checked_doc, checked):
    """Absolute speedup floor for the windowed LSTM operating point.

    The time-window feature's acceptance bar is >= 1.6x on lstmsyn
    row-skip at rate 0.5 with a 16-timestep window, measured natively.
    The floor is a *ratchet*: it arms once a reviewed native baseline
    demonstrates the bar (so landing that baseline is what turns the
    bar into a hard gate), and from then on a native candidate may not
    fall below the absolute bar even if the relative tolerance would
    let it. Until a native windowed baseline is landed — or against
    scale-model candidates, which model scalar MAC ratios and cannot
    see the panel-locality win the floor measures — the line is
    advisory. Smoke runs are skipped outright (rep counts too small to
    time honestly).
    """
    failures, lines = [], []
    arch, rate, config = WINDOWED_FLOOR_KEY
    if not any("@w" in key[2] for key in native):
        lines.append("(no @wN rows in candidate report; windowed floor "
                     "skipped — report predates time-window support)")
        return failures, lines
    if native_doc.get("smoke"):
        lines.append("(smoke run; absolute windowed floor skipped)")
        return failures, lines
    base_row = checked.get(WINDOWED_FLOOR_KEY)
    armed = (is_native(native_doc) and is_native(checked_doc)
             and base_row is not None
             and base_row["speedup_vs_dense"] >= WINDOWED_FLOOR)
    row = native.get(WINDOWED_FLOOR_KEY)
    if row is None:
        msg = (f"{WINDOWED_FLOOR_KEY}: windowed rows present but the "
               f"floor's operating point is missing")
        if armed:
            failures.append(msg)
        lines.append(f"  {msg}")
        return failures, lines
    speedup = row["speedup_vs_dense"]
    ok = speedup >= WINDOWED_FLOOR
    if armed:
        verdict = "ok" if ok else "BELOW WINDOWED FLOOR"
        if not ok:
            failures.append(
                f"{WINDOWED_FLOOR_KEY}: native {speedup:.2f} < armed "
                f"absolute floor {WINDOWED_FLOOR:.2f}")
    else:
        status = "meets bar" if ok else "below bar"
        verdict = (f"advisory ({status}; arms when a native baseline "
                   f">= {WINDOWED_FLOOR} is landed)")
    lines.append(f"{arch:8} {rate:5} {config:>16} {speedup:8.2f}  "
                 f"floor {WINDOWED_FLOOR:.2f}  {verdict}")
    return failures, lines


def check_dyn_bwd_rows(native):
    """Advisory structural validation of the dynamic-backward rows.

    `dyn-bwd` rows (row-skip with the plan's dynamic backward masks ON)
    are never baseline-gated: the dyn-vs-static delta is small enough
    that a shared runner's noise would make a relative gate flap. But a
    *malformed* row — missing or non-finite `dyn_vs_static` or
    `speedup_vs_dense` — means the paired measurement path itself
    regressed, and that fails. A report with no dyn-bwd rows at all
    gets an advisory note only, so reports predating dynamic backward
    sparsity stay green.
    """
    failures, lines = [], []
    rows = [(k, v) for k, v in sorted(native.items(), key=lambda kv:
            str(kv[0])) if k[2] == "dyn-bwd"]
    if not rows:
        lines.append("(no dyn-bwd rows in candidate report; advisory — "
                     "report predates dynamic backward sparsity)")
        return failures, lines
    for (arch, rate, _), row in rows:
        bad = []
        for field in ("dyn_vs_static", "speedup_vs_dense"):
            v = row.get(field)
            if (not isinstance(v, (int, float)) or not math.isfinite(v)
                    or v <= 0):
                bad.append(f"{field} is {v!r}")
        if bad:
            failures.append(f"('{arch}', {rate}, 'dyn-bwd'): "
                            + "; ".join(bad))
            verdict = "MALFORMED"
            dvs = "-"
        else:
            dvs = f"{row['dyn_vs_static']:.2f}"
            verdict = ("advisory ok" if row["dyn_vs_static"] >= 1.0
                       else "advisory: dyn slower than static")
        lines.append(f"{arch:8} {rate:5} {'dyn-bwd':>16} "
                     f"dyn_vs_static={dvs:>5}  {verdict}")
    return failures, lines


def run_gate(native_path, checked_path, tolerance):
    native_doc = load_doc(native_path)
    checked_doc = load_doc(checked_path)
    native = rows_by_key(native_doc)
    checked = rows_by_key(checked_doc)
    tol, why = pick_tolerance(tolerance, checked_doc)
    print(f"baseline provenance: {checked_doc['provenance']}")
    print(f"native   provenance: {native_doc['provenance']}")
    print(f"native   microkernel: {native_doc.get('microkernel', '?')} "
          f"threads: {native_doc.get('threads', '?')}")
    print(f"tolerance: native >= (1 - {tol}) * baseline  [{why}]\n")
    print(f"{'arch':8} {'rate':>5} {'config':>16} {'native':>8} "
          f"{'baseline':>9} {'floor':>7}  verdict")

    failures, lines = check_baseline_floor(native, checked, tol)
    for ln in lines:
        print(ln)
    print("\nSIMD-vs-scalar (native report only):")
    simd_failures, lines = check_simd_beats_scalar(native_doc, native)
    for ln in lines:
        print(ln)
    failures += simd_failures
    print("\nwindowed LSTM absolute floor (ratchet):")
    win_failures, lines = check_windowed_floor(native_doc, native,
                                               checked_doc, checked)
    for ln in lines:
        print(ln)
    failures += win_failures
    print("\ndyn-bwd rows (structural, advisory):")
    dyn_failures, lines = check_dyn_bwd_rows(native)
    for ln in lines:
        print(ln)
    failures += dyn_failures

    if failures:
        print(f"\nFAIL: {len(failures)} gated check(s) failed:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: all gated speedups within tolerance of the baseline")
    return 0


INFER_ROW_FIELDS = ("qps", "p50_ms", "p99_ms")


def infer_advisory(path):
    """Validate + print a BENCH_infer.json latency report, advisory-only.

    Serving latency on a shared runner is too noisy for an absolute
    gate, so healthy numbers always exit 0 — but a structurally broken
    report (missing file, wrong bench name, zero rows, NaN or missing
    latency fields) means the measurement path itself regressed, and
    that exits 1.
    """
    try:
        doc = load_doc(path)
    except (OSError, ValueError) as e:
        print(f"infer advisory: cannot read {path}: {e}")
        return 1
    failures = []
    if doc.get("bench") != "infer":
        failures.append(f"bench is {doc.get('bench')!r}, expected 'infer'")
    rows = doc.get("rows") or []
    if not rows:
        failures.append("no rows — the serving bench measured nothing")
    print(f"infer advisory: backend={doc.get('backend', '?')} "
          f"tag={doc.get('tag', '?')} slots={doc.get('slots', '?')} "
          f"config_hash={doc.get('config_hash', '?')}")
    print(f"{'model':10} {'reqs':>6} {'clients':>7} {'qps':>9} "
          f"{'p50_ms':>8} {'p99_ms':>8} {'max_batch':>9}")
    for i, row in enumerate(rows):
        for field in INFER_ROW_FIELDS:
            v = row.get(field)
            if (not isinstance(v, (int, float))
                    or not math.isfinite(v) or v < 0):
                failures.append(f"row {i} ({row.get('model', '?')}): "
                                f"{field} is {v!r}, expected a finite "
                                f"non-negative number")
        print(f"{str(row.get('model', '?')):10} "
              f"{row.get('requests', '-'):>6} "
              f"{row.get('clients', '-'):>7} "
              f"{_num(row.get('qps')):>9} "
              f"{_num(row.get('p50_ms')):>8} "
              f"{_num(row.get('p99_ms')):>8} "
              f"{row.get('max_batch_observed', '-'):>9}")
    if failures:
        print(f"\nFAIL: BENCH_infer.json is malformed "
              f"({len(failures)} problem(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: infer report well-formed "
          "(latency numbers are advisory, not gated)")
    return 0


def _num(v):
    return f"{v:.2f}" if isinstance(v, (int, float)) else "-"


def refresh_baseline(native_path, checked_path):
    """Replace the checked-in baseline with the native report, atomically."""
    doc = load_doc(native_path)  # parse first: never install junk
    if not is_native(doc):
        print(f"REFUSING refresh: {native_path} provenance is not native "
              f"({doc.get('provenance', '?')!r}) — the baseline refresh "
              f"exists precisely to install measured numbers")
        return 1
    if doc.get("smoke"):
        print(f"REFUSING refresh: {native_path} is a smoke run "
              f"(AD_BENCH_SMOKE=1); rerun with full reps first")
        return 1
    directory = os.path.dirname(os.path.abspath(checked_path)) or "."
    with open(native_path) as f:
        text = f.read()
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, checked_path)
    except BaseException:
        os.unlink(tmp)
        raise
    print(f"baseline refreshed: {checked_path} <- {native_path} "
          f"(provenance: {doc['provenance']})")
    print("review + commit the diff to land the new baseline")
    return 0


# ---------------------------------------------------------------------------
# Self-test: the gate's own behavior, runnable with no bench artifacts.
# ---------------------------------------------------------------------------


def _doc(provenance, rows, microkernel="avx2", smoke=False):
    return {
        "bench": "sparse_speedup",
        "version": 1,
        "provenance": provenance,
        "microkernel": microkernel,
        "threads": 4,
        "smoke": smoke,
        "rows": rows,
    }


def _row(arch, rate, config, speedup, median=0.01):
    return {
        "arch": arch, "rate": rate, "config": config,
        "speedup_vs_dense": speedup, "median_step_s": median,
    }


def self_test():
    import contextlib
    import io

    def gate_with(native_doc, checked_doc, tolerance=None):
        with tempfile.TemporaryDirectory() as d:
            np, cp = os.path.join(d, "n.json"), os.path.join(d, "c.json")
            with open(np, "w") as f:
                json.dump(native_doc, f)
            with open(cp, "w") as f:
                json.dump(checked_doc, f)
            out = io.StringIO()
            with contextlib.redirect_stdout(out):
                rc = run_gate(np, cp, tolerance)
            return rc, out.getvalue()

    base_rows = [_row("mlpsyn", r, c, s)
                 for r, c, s in [(0.5, "row-skip", 2.0),
                                 (0.5, "tile-skip", 1.7),
                                 (0.7, "row-skip", 3.4),
                                 (0.7, "tile-skip", 2.7)]]
    native_doc = _doc("native: bench", list(base_rows))
    checked_doc = _doc("native: bench", list(base_rows))

    # 1. Identical reports pass.
    rc, _ = gate_with(native_doc, checked_doc)
    assert rc == 0, "identical reports must pass"

    # 2. A >25% drop on a gated row fails against a native baseline.
    dropped = _doc("native: bench",
                   [dict(r) for r in base_rows])
    dropped["rows"][0] = _row("mlpsyn", 0.5, "row-skip", 1.0)
    rc, out = gate_with(dropped, checked_doc)
    assert rc == 1 and "REGRESSION" in out, "drop must fail"

    # 3. The same drop passes under the widened scale-model tolerance…
    scale_doc = _doc("tools/bench_sparse_port.py scale model",
                     list(base_rows), microkernel="scalar")
    smaller = _doc("native: bench", [dict(r) for r in base_rows])
    smaller["rows"][0] = _row("mlpsyn", 0.5, "row-skip", 1.3)
    rc, out = gate_with(smaller, scale_doc)
    assert rc == 0 and "WIDENED" in out, "calibrated tolerance"
    # …but a catastrophic drop still fails.
    smaller["rows"][0] = _row("mlpsyn", 0.5, "row-skip", 0.9)
    rc, _ = gate_with(smaller, scale_doc)
    assert rc == 1, "catastrophic drop must fail even when widened"

    # 4. A gated row missing from the native report fails.
    partial = _doc("native: bench", base_rows[1:])
    rc, out = gate_with(partial, checked_doc)
    assert rc == 1 and "missing" in out.lower(), "missing row must fail"

    # 5. SIMD-vs-scalar gate: simd slower than scalar fails; faster
    #    passes; scalar-microkernel runs skip the check.
    simd_rows = list(base_rows) + [
        _row("mlpsyn", 0.5, "row-skip@scalar", 1.9, median=0.02),
    ]
    fast = _doc("native: bench", [dict(r) for r in simd_rows])
    rc, _ = gate_with(fast, checked_doc)
    assert rc == 0, "simd faster than scalar must pass"
    slow = _doc("native: bench", [dict(r) for r in simd_rows])
    slow["rows"][0] = _row("mlpsyn", 0.5, "row-skip", 2.0, median=0.05)
    rc, out = gate_with(slow, checked_doc)
    assert rc == 1 and "SLOWER" in out, "simd slower must fail"
    scalar_run = _doc("native: bench", [dict(r) for r in simd_rows],
                      microkernel="scalar")
    scalar_run["rows"][0] = _row("mlpsyn", 0.5, "row-skip", 2.0,
                                 median=0.05)
    rc, _ = gate_with(scalar_run, checked_doc)
    assert rc == 0, "scalar-microkernel run skips the simd gate"

    # 6. Windowed rows: baseline-tracked like their base configs, plus
    #    the absolute lstmsyn row-skip@w16 floor ratchet at rate 0.5.
    win_rows = list(base_rows) + [
        _row("lstmsyn", 0.5, "row-skip", 1.3),
        _row("lstmsyn", 0.5, "row-skip@w1", 1.2),
        _row("lstmsyn", 0.5, "row-skip@w16", 2.5),
    ]
    win_native = _doc("native: bench", [dict(r) for r in win_rows])
    win_checked = _doc("native: bench", [dict(r) for r in win_rows])
    rc, _ = gate_with(win_native, win_checked)
    assert rc == 0, "healthy windowed rows must pass"
    # A >25% drop on a @wN row regresses like any gated config (1.7 still
    # clears the 1.6 absolute floor, so this isolates the relative gate).
    degraded = _doc("native: bench", [dict(r) for r in win_rows])
    degraded["rows"][-1] = _row("lstmsyn", 0.5, "row-skip@w16", 1.7)
    rc, out = gate_with(degraded, win_checked)
    assert rc == 1 and "REGRESSION" in out, "@w16 relative drop must fail"
    # Armed floor (native baseline >= 1.6): a candidate below the bar
    # fails absolutely even if the baseline itself had regressed…
    low = [dict(r) for r in win_rows]
    low[-1] = _row("lstmsyn", 0.5, "row-skip@w16", 1.4)
    rc, out = gate_with(_doc("native: bench", low), win_checked)
    assert rc == 1 and "BELOW WINDOWED FLOOR" in out, \
        "sub-1.6x w16 vs an armed native baseline must fail the floor"
    # …but the same candidate against a baseline that never demonstrated
    # the bar (here: both sides at 1.4) is advisory, not fatal — the
    # ratchet only arms once a reviewed native baseline meets the bar.
    rc, out = gate_with(_doc("native: bench", [dict(r) for r in low]),
                        _doc("native: bench", [dict(r) for r in low]))
    assert rc == 0 and "advisory" in out, "unarmed floor is advisory"
    # Scale-model baselines never arm the floor either.
    rc, out = gate_with(win_native,
                        _doc("tools/bench_sparse_port.py scale model",
                             [dict(r) for r in win_rows]))
    assert rc == 0 and "advisory" in out, \
        "scale-model baseline leaves the floor advisory"
    # Smoke runs skip the floor entirely (still gate relatively); a
    # report with no @wN rows at all skips it too.
    smoke_low = _doc("native: bench", [dict(r) for r in low], smoke=True)
    rc, out = gate_with(smoke_low, win_checked)
    assert rc == 1 and "smoke run" in out and "REGRESSION" in out, \
        "smoke skips the floor but still gates relatively"
    rc, out = gate_with(native_doc, checked_doc)
    assert rc == 0 and "predates time-window" in out, \
        "pre-window reports skip the floor"
    # @scalar rows must never be swept into the gated set.
    assert is_gated_config("row-skip@w4")
    assert not is_gated_config("row-skip@scalar")
    assert not is_gated_config("dense")

    # 7. dyn-bwd rows: never gated, but structurally validated. A report
    #    missing them entirely is advisory-green (predates the feature);
    #    a malformed row fails.
    rc, out = gate_with(native_doc, checked_doc)
    assert rc == 0 and "predates dynamic backward" in out, \
        "report with no dyn-bwd rows stays green with an advisory note"
    dyn_rows = list(base_rows) + [
        dict(_row("mlpsyn", 0.5, "dyn-bwd", 2.0), dyn_vs_static=1.01),
        dict(_row("lstmsyn", 0.5, "dyn-bwd", 1.2), dyn_vs_static=1.03),
    ]
    dyn_native = _doc("native: bench", [dict(r) for r in dyn_rows])
    rc, out = gate_with(dyn_native, checked_doc)
    assert rc == 0 and "advisory ok" in out, "healthy dyn-bwd rows pass"
    # A sub-1.0 dyn_vs_static is advisory, not fatal…
    slow_dyn = _doc("native: bench", [dict(r) for r in dyn_rows])
    slow_dyn["rows"][-1] = dict(_row("lstmsyn", 0.5, "dyn-bwd", 1.2),
                                dyn_vs_static=0.97)
    rc, out = gate_with(slow_dyn, checked_doc)
    assert rc == 0 and "dyn slower than static" in out, \
        "slow dyn-bwd is advisory"
    # …but a missing/NaN dyn_vs_static field is a broken measurement
    # path and fails.
    broken_dyn = _doc("native: bench", [dict(r) for r in dyn_rows])
    del broken_dyn["rows"][-1]["dyn_vs_static"]
    rc, out = gate_with(broken_dyn, checked_doc)
    assert rc == 1 and "MALFORMED" in out, "missing dyn_vs_static fails"
    broken_dyn["rows"][-1]["dyn_vs_static"] = float("nan")
    rc, _ = gate_with(broken_dyn, checked_doc)
    assert rc == 1, "NaN dyn_vs_static fails"

    # 8. --infer-advisory: well-formed reports pass (numbers advisory),
    #    structural damage fails.
    def advisory_with(doc):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "BENCH_infer.json")
            with open(p, "w") as f:
                json.dump(doc, f)
            out = io.StringIO()
            with contextlib.redirect_stdout(out):
                rc = infer_advisory(p)
            return rc, out.getvalue()

    infer_row = {"model": "m", "requests": 64, "clients": 8,
                 "qps": 410.5, "p50_ms": 1.2, "p99_ms": 9.8,
                 "mean_ms": 2.0, "max_batch_observed": 6}
    infer_doc = {"bench": "infer", "version": 1,
                 "provenance": "approx-dropout infer",
                 "backend": "sparse", "tag": "mlpsyn", "slots": 2,
                 "config_hash": "00000000deadbeef",
                 "rows": [dict(infer_row)]}
    rc, out = advisory_with(infer_doc)
    assert rc == 0 and "advisory" in out, "healthy infer report passes"
    # Even absurdly slow numbers stay advisory: exit 0.
    slow_infer = dict(infer_doc)
    slow_infer["rows"] = [dict(infer_row, qps=0.01, p99_ms=9000.0)]
    assert advisory_with(slow_infer)[0] == 0, "latency is never gated"
    # Structural damage fails: wrong bench name, empty rows, NaN/null
    # latency fields, missing file.
    wrong = dict(infer_doc, bench="sparse_speedup")
    assert advisory_with(wrong)[0] == 1, "wrong bench name fails"
    empty = dict(infer_doc, rows=[])
    rc, out = advisory_with(empty)
    assert rc == 1 and "no rows" in out, "empty rows fail"
    nan_doc = dict(infer_doc)
    nan_doc["rows"] = [dict(infer_row, p99_ms=None)]
    rc, out = advisory_with(nan_doc)
    assert rc == 1 and "p99_ms" in out, "null latency field fails"
    nan_doc["rows"] = [dict(infer_row, qps=float("nan"))]
    assert advisory_with(nan_doc)[0] == 1, "NaN qps fails"
    out = io.StringIO()
    with contextlib.redirect_stdout(out):
        rc = infer_advisory(os.path.join(
            tempfile.gettempdir(), "ad-no-such-report.json"))
    assert rc == 1, "missing report file fails"

    # 9. refresh-baseline installs native reports and refuses junk.
    with tempfile.TemporaryDirectory() as d:
        np, cp = os.path.join(d, "n.json"), os.path.join(d, "c.json")
        with open(cp, "w") as f:
            json.dump(scale_doc, f)
        with open(np, "w") as f:
            json.dump(native_doc, f)
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            assert refresh_baseline(np, cp) == 0
        assert is_native(load_doc(cp)), "refresh must install the native doc"
        # Non-native refresh candidate is refused.
        with open(np, "w") as f:
            json.dump(scale_doc, f)
        with contextlib.redirect_stdout(out):
            assert refresh_baseline(np, cp) == 1
        # Smoke-run refresh candidate is refused.
        with open(np, "w") as f:
            json.dump(_doc("native: bench", base_rows, smoke=True), f)
        with contextlib.redirect_stdout(out):
            assert refresh_baseline(np, cp) == 1

    print("self-test OK (9 scenarios)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("native", nargs="?")
    ap.add_argument("checked_in", nargs="?")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="allowed fractional drop below baseline "
                         "(default: 0.25 vs a native baseline, 0.40 vs "
                         "a synthetic scale-model baseline)")
    ap.add_argument("--refresh-baseline", action="store_true",
                    help="replace CHECKED_IN.json with NATIVE.json "
                         "(atomic; refuses non-native or smoke reports)")
    ap.add_argument("--infer-advisory", metavar="BENCH_infer.json",
                    help="validate + print an inference-serving latency "
                         "report; numbers are advisory, structural "
                         "damage exits 1")
    ap.add_argument("--self-test", action="store_true",
                    help="run the checker's own scenario tests and exit")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if args.infer_advisory:
        return infer_advisory(args.infer_advisory)
    if not args.native or not args.checked_in:
        ap.error("NATIVE.json and CHECKED_IN.json are required "
                 "(or use --self-test)")
    if args.refresh_baseline:
        return refresh_baseline(args.native, args.checked_in)
    return run_gate(args.native, args.checked_in, args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
