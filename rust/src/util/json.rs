//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! Supports the full JSON grammar we emit (objects, arrays, strings with
//! escapes, numbers, bools, null); serde is unavailable offline. Not a
//! general-purpose library — errors carry byte offsets for debugging.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (we never emit them).
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#)
            .unwrap();
        assert_eq!(v.path("c.d"), Some(&Json::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn manifest_shape() {
        let v = parse(
            r#"{"version":1,"artifacts":[{"name":"m","inputs":
               [{"name":"w1","shape":[784,2048],"dtype":"f32",
                 "kind":"param"}]}]}"#,
        )
        .unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let inp = &a.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
                   Some(2048));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
