"""L2: JAX training-step graphs for the paper's two workloads (MLP, LSTM).

Every function here is lowered ONCE by :mod:`compile.aot` into an HLO-text
artifact that the Rust coordinator loads and drives; nothing in this module
runs on the request path.

Graph conventions (mirrored by ``rust/src/runtime/`` via manifest.json):

* inputs  = [*params, *momenta, x, y, *variant_extras, lr]
* outputs = (*new_params, *new_momenta, loss, correct)
* the SGD-with-momentum update (Caffe semantics: ``m' = mu*m + g``,
  ``p' = p - lr*m'``) is *inside* the graph, so one PJRT call performs the
  full training iteration and params stay device-resident.

Variant extras:

* ``conv`` — per-dropout-site Bernoulli 0/1 masks (generated host-side by
  the Rust coordinator, exactly like Caffe's cuRAND masks) followed by
  their 1/keep scales (f32 scalars).
* ``rdp``  — int32 bias ``b0`` per dropout site; the divisor ``dp`` is
  baked into the graph (it determines the compact shapes, which is the
  whole point: a *regular* pattern makes the smaller static graph legal —
  see DESIGN.md section 2). MLP sites take a scalar; LSTM sites take a
  ``[seq]`` track (one bias per timestep) so the coordinator can re-draw
  the bias every ``AD_TIME_WINDOW`` timesteps. A constant track reproduces
  the per-step behaviour bit-for-bit.
* ``tdp``  — int32 bias per dropped weight matrix, same scalar-vs-track
  split as ``rdp``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from . import patterns
from .kernels import masked_matmul, matmul, tile_sparse_matmul

MOMENTUM = 0.9
FORGET_BIAS = 1.0
# Default tile edge for the Tile-based Dropout Pattern. The paper uses
# 32x32 (matching the GPU's 32 shared-memory banks); on this backend the
# analogous hardware unit is the 128-lane MXU tile, and 128x128 tiles also
# keep the AOT'd sparse-accumulation grid short (DESIGN.md section
# Hardware-Adaptation). Architectures can override (tiny test archs use 16).
TILE = 128


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, y: jax.Array):
    """Mean cross-entropy + correct-prediction count (y: int32 labels)."""
    ls = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(ls, y[:, None], axis=-1).mean()
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return nll, correct


def sgd_momentum(params, momenta, grads, lr):
    new_m = [MOMENTUM * m + g for m, g in zip(momenta, grads)]
    new_p = [p - lr * m for p, m in zip(params, new_m)]
    return new_p, new_m


def row_scale(h: int, dp: int) -> float:
    """Inverted-dropout correction for the row pattern: 1 / keep-ratio."""
    return float(h) / float(h // dp)


def tile_scale(k: int, n: int, dp: int, tile: int = TILE) -> float:
    tr, tc = patterns.tile_dims(k, n, tile)
    total = (k // tr) * (n // tc)
    return float(total) / float(
        patterns.tile_kept_count(k, n, dp, tile))


def _train_step(logits_or_loss_fn, n_params, is_loss=False):
    """Wrap a logits/loss function into the full (loss, grads, update) step.

    Argument layout matches the module docstring. ``logits_or_loss_fn``
    receives ``(params, x, y, *extras)`` and returns either logits (the
    softmax CE is added here) or ``(loss, correct)`` when ``is_loss``.
    """

    def step(*args):
        params = list(args[:n_params])
        momenta = list(args[n_params:2 * n_params])
        x, y = args[2 * n_params], args[2 * n_params + 1]
        extras = args[2 * n_params + 2:-1]
        lr = args[-1]

        def loss_fn(ps):
            if is_loss:
                return logits_or_loss_fn(ps, x, y, *extras)
            logits = logits_or_loss_fn(ps, x, *extras)
            return softmax_xent(logits, y)

        (loss, correct), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_m = sgd_momentum(params, momenta, grads, lr)
        return (*new_p, *new_m, loss, correct)

    return step


# ---------------------------------------------------------------------------
# MLP (paper sections IV-A/B): 784 -> H1 -> H2 -> 10, ReLU, softmax CE.
# Dropout sites: the two hidden layers, rates (r1, r2).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MlpArch:
    hidden: tuple[int, int]
    n_in: int = 784
    n_out: int = 10
    batch: int = 128
    tile: int = TILE

    @property
    def name(self) -> str:
        return f"mlp{self.hidden[0]}x{self.hidden[1]}"


def mlp_param_specs(arch: MlpArch):
    h1, h2 = arch.hidden
    return [
        ("w1", (arch.n_in, h1)),
        ("b1", (h1,)),
        ("w2", (h1, h2)),
        ("b2", (h2,)),
        ("w3", (h2, arch.n_out)),
        ("b3", (arch.n_out,)),
    ]


def _mlp_logits_dense(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h1 = jax.nn.relu(matmul(x, w1) + b1)
    h2 = jax.nn.relu(matmul(h1, w2) + b2)
    return matmul(h2, w3) + b3


def _mlp_logits_conv(params, x, m1, m2, s1, s2):
    """Conventional dropout (paper Fig. 1a): the full-size matmuls always
    run; the Bernoulli mask is fused into the *consuming* matmul (the
    strongest fair baseline — saves the masked-copy materialization but
    cannot shrink the computation)."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = jax.nn.relu(matmul(x, w1) + b1)
    h2 = jax.nn.relu(masked_matmul(h1, m1, w2, s1) + b2)
    return masked_matmul(h2, m2, w3, s2) + b3


def _mlp_logits_rdp(params, x, b01, b02, s1, s2, *, dp1: int, dp2: int,
                    h1: int, h2: int):
    """Row-based pattern: compact every matmul (paper Fig. 3a).

    Kept neuron sets: hidden1 {b01 + dp1*j}, hidden2 {b02 + dp2*j}. All
    three weight matrices are sliced to kept rows/cols *before* the matmul —
    dropped data is never fetched — and activations stay compact end-to-end.

    ``s1``/``s2`` are the inverted-dropout corrections. They are runtime
    inputs holding 1/(1-p) of the site's long-run target rate (Caffe
    semantics, which the paper inherits) — NOT the per-iteration 1/dp
    ratio: a constant scale keeps the estimator unbiased across the
    sampled patterns with far lower gradient variance than per-pattern
    scaling (dp=8 would otherwise amplify that iteration's gradients 8x).
    """
    w1, b1, w2, b2, w3, b3 = params
    w1c = patterns.gather_cols(w1, dp1, b01)           # [784, h1/dp1]
    b1c = patterns.gather_vec(b1, dp1, b01)
    h1c = jax.nn.relu(matmul(x, w1c) + b1c) * s1       # [B, h1/dp1]
    w2c = patterns.gather_cols(
        patterns.gather_rows(w2, dp1, b01), dp2, b02)  # [h1/dp1, h2/dp2]
    b2c = patterns.gather_vec(b2, dp2, b02)
    h2c = jax.nn.relu(matmul(h1c, w2c) + b2c) * s2     # [B, h2/dp2]
    w3c = patterns.gather_rows(w3, dp2, b02)           # [h2/dp2, 10]
    return matmul(h2c, w3c) + b3


def _mlp_logits_tdp(params, x, b01, b02, s1, s2, *, dp1: int, dp2: int,
                    n_in: int, h1: int, h2: int, tile: int = TILE):
    """Tile-based pattern (paper Fig. 3b): DropConnect at tile
    granularity on W1 and W2; only kept tiles are fetched/multiplied.
    ``s1``/``s2``: runtime 1/(1-p) scales (see _mlp_logits_rdp)."""
    w1, b1, w2, b2, w3, b3 = params
    h1a = jax.nn.relu(patterns.tdp_matmul(x, w1, dp1, b01, tile) * s1 + b1)
    h2a = jax.nn.relu(patterns.tdp_matmul(h1a, w2, dp2, b02, tile) * s2
                      + b2)
    return matmul(h2a, w3) + b3


def mlp_train_step_conv(arch: MlpArch):
    return _train_step(_mlp_logits_conv, 6)


def mlp_train_step_rdp(arch: MlpArch, dp1: int, dp2: int):
    h1, h2 = arch.hidden
    fn = functools.partial(_mlp_logits_rdp, dp1=dp1, dp2=dp2, h1=h1, h2=h2)
    return _train_step(fn, 6)


def mlp_train_step_tdp(arch: MlpArch, dp1: int, dp2: int):
    h1, h2 = arch.hidden
    fn = functools.partial(_mlp_logits_tdp, dp1=dp1, dp2=dp2,
                           n_in=arch.n_in, h1=h1, h2=h2, tile=arch.tile)
    return _train_step(fn, 6)


def mlp_eval(arch: MlpArch):
    """Inference graph: no dropout (inverted scaling keeps weights as-is)."""

    def fn(*args):
        params = list(args[:6])
        x, y = args[6], args[7]
        return softmax_xent(_mlp_logits_dense(params, x), y)

    return fn


# ---------------------------------------------------------------------------
# LSTM (paper section IV-C): word-level LM. Dropout on the non-recurrent
# connections — layer_l -> layer_{l+1} and top layer -> softmax (Zaremba
# style), one site per layer, rates (r_1..r_L). One dropout pattern per
# training iteration, shared across timesteps (the paper applies a single
# pattern per iteration to the whole batch).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LstmArch:
    vocab: int
    hidden: int
    layers: int = 2
    seq: int = 35
    batch: int = 20
    tile: int = TILE

    @property
    def name(self) -> str:
        return f"lstm{self.layers}x{self.hidden}v{self.vocab}"


def lstm_param_specs(arch: LstmArch):
    specs = [("emb", (arch.vocab, arch.hidden))]
    for l in range(arch.layers):
        specs += [
            (f"wx{l}", (arch.hidden, 4 * arch.hidden)),
            (f"wh{l}", (arch.hidden, 4 * arch.hidden)),
            (f"bg{l}", (4 * arch.hidden,)),
        ]
    specs += [("wsoft", (arch.hidden, arch.vocab)), ("bsoft", (arch.vocab,))]
    return specs


def _unpack_lstm(params, layers):
    emb = params[0]
    cells = [tuple(params[1 + 3 * l: 4 + 3 * l]) for l in range(layers)]
    wsoft, bsoft = params[-2], params[-1]
    return emb, cells, wsoft, bsoft


def _lstm_loss(arch: LstmArch, params, x, y, input_mms, soft_fn):
    """Shared scan skeleton.

    input_mms[l](inp, t) -> [B, 4H]: the layer-l *input* contribution to
    the gates (this is where each dropout variant plugs in its transform of
    the previous layer's output — masked, row-compacted, or tile-sparse).
    ``t`` is the traced timestep index, so variants with per-timestep
    pattern tracks (rdp/tdp time windows) can index their ``[seq]`` bias
    inside the scan; variants with per-step state ignore it.
    soft_fn(flat, wsoft) -> logits for the top-layer outputs.
    """
    emb, cells, wsoft, bsoft = _unpack_lstm(params, arch.layers)
    b, t = x.shape
    e = jnp.transpose(jnp.take(emb, x, axis=0), (1, 0, 2))  # [T, B, H]

    h0 = jnp.zeros((arch.layers, b, arch.hidden), e.dtype)
    c0 = jnp.zeros((arch.layers, b, arch.hidden), e.dtype)

    def step(carry, xs_t):
        x_t, t_idx = xs_t
        hs, cs = carry
        new_h, new_c = [], []
        inp = x_t
        for l, (wx, wh, bg) in enumerate(cells):
            gates = input_mms[l](inp, t_idx) + matmul(hs[l], wh) + bg
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c2 = (jax.nn.sigmoid(f + FORGET_BIAS) * cs[l]
                  + jax.nn.sigmoid(i) * jnp.tanh(g))
            h2 = jax.nn.sigmoid(o) * jnp.tanh(c2)
            new_h.append(h2)
            new_c.append(c2)
            inp = h2
        return (jnp.stack(new_h), jnp.stack(new_c)), new_h[-1]

    (_, _), tops = lax.scan(
        step, (h0, c0), (e, jnp.arange(t, dtype=jnp.int32)))  # [T, B, H]
    flat = tops.reshape(t * b, arch.hidden)
    logits = soft_fn(flat, wsoft) + bsoft        # [T*B, V]
    targets = jnp.transpose(y, (1, 0)).reshape(t * b)
    return softmax_xent(logits, targets)


def _lstm_step_factory(arch: LstmArch, build_fns):
    """Common train-step wrapper: ``build_fns(params, extras)`` returns
    (input_mms, soft_fn) for this variant."""
    n_params = len(lstm_param_specs(arch))

    def step(*args):
        params = list(args[:n_params])
        momenta = list(args[n_params:2 * n_params])
        x, y = args[2 * n_params], args[2 * n_params + 1]
        extras = list(args[2 * n_params + 2:-1])
        lr = args[-1]

        def loss_fn(ps):
            input_mms, soft_fn = build_fns(ps, extras)
            return _lstm_loss(arch, ps, x, y, input_mms, soft_fn)

        (loss, correct), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_m = sgd_momentum(params, momenta, grads, lr)
        return (*new_p, *new_m, loss, correct)

    return step


def lstm_train_step_conv(arch: LstmArch):
    L = arch.layers

    def build(ps, extras):
        _, cells, _, _ = _unpack_lstm(ps, L)
        masks, scales = extras[:L], extras[L:2 * L]
        mms = [lambda inp, t, wx=cells[0][0]: matmul(inp, wx)]
        for l in range(1, L):
            mms.append(
                lambda inp, t, wx=cells[l][0], m=masks[l - 1],
                s=scales[l - 1]: masked_matmul(inp, m, wx, s))

        def soft(f, w, m=masks[L - 1], s=scales[L - 1]):
            mm = jnp.tile(m, (f.shape[0] // m.shape[0], 1))
            return masked_matmul(f, mm, w, s)

        return mms, soft

    return _lstm_step_factory(arch, build)


def lstm_train_step_rdp(arch: LstmArch, dp: int):
    L, H = arch.layers, arch.hidden

    def build(ps, extras):
        _, cells, _, _ = _unpack_lstm(ps, L)
        b0s = extras[:L]        # one int32 [seq] bias track per site
        scales = extras[L:2 * L]  # runtime 1/(1-p) per site
        mms = [lambda inp, t, wx=cells[0][0]: matmul(inp, wx)]
        for l in range(1, L):
            # The kept set may change every timestep (time-windowed
            # draws), so the weight-row gather lives inside the scan,
            # keyed by the site's bias track at t. XLA hoists it when
            # the track is constant across the window.
            mms.append(
                lambda inp, t, wx=cells[l][0], tr=b0s[l - 1],
                s=scales[l - 1]:
                matmul(patterns.gather_cols(inp, dp, jnp.take(tr, t)) * s,
                       patterns.gather_rows(wx, dp, jnp.take(tr, t))))

        def soft(f, w, tr=b0s[L - 1], s=scales[L - 1]):
            # f is the flattened [T*B, H] top-layer output; each
            # timestep's rows project through its own bias, so map the
            # gathers over the leading (time) axis.
            ft = f.reshape(arch.seq, f.shape[0] // arch.seq, H)

            def per_t(f_t, b0):
                fc = patterns.gather_cols(f_t, dp, b0) * s
                return matmul(fc, patterns.gather_rows(w, dp, b0))

            return jax.vmap(per_t)(ft, tr).reshape(f.shape[0], -1)

        return mms, soft

    return _lstm_step_factory(arch, build)


def lstm_train_step_tdp(arch: LstmArch, dp: int):
    L, H, V = arch.layers, arch.hidden, arch.vocab
    tile = arch.tile

    def build(ps, extras):
        _, cells, wsoft, _ = _unpack_lstm(ps, L)
        b0s = extras[:L]        # one int32 [seq] bias track per site
        scales = extras[L:2 * L]  # runtime 1/(1-p) per site
        mms = [lambda inp, t, wx=cells[0][0]: matmul(inp, wx)]
        for l in range(1, L):
            mms.append(
                lambda inp, t, wx=cells[l][0], tr=b0s[l - 1],
                s=scales[l - 1]:
                patterns.tdp_matmul(inp, wx, dp, jnp.take(tr, t), tile) * s)

        def soft(f, w, tr=b0s[L - 1], s=scales[L - 1]):
            ft = f.reshape(arch.seq, f.shape[0] // arch.seq, H)
            return jax.vmap(
                lambda f_t, b0:
                patterns.tdp_matmul(f_t, w, dp, b0, tile) * s
            )(ft, tr).reshape(f.shape[0], -1)

        return mms, soft

    return _lstm_step_factory(arch, build)


def lstm_eval(arch: LstmArch):
    n_params = len(lstm_param_specs(arch))
    L = arch.layers

    def fn(*args):
        params = list(args[:n_params])
        x, y = args[n_params], args[n_params + 1]
        _, cells, _, _ = _unpack_lstm(params, L)
        mms = [lambda inp, t, wx=cells[l][0]: matmul(inp, wx)
               for l in range(L)]
        return _lstm_loss(arch, params, x, y, mms, matmul)

    return fn
