//! Fig. 5 — "The training process of RDP and traditional dropout": fix the
//! dropout rate at 0.5 and trace the accuracy/loss of RDP vs the
//! conventional baseline over training iterations.
//!
//! Paper shape to reproduce: RDP converges at least as early and as
//! smoothly as the baseline (the regular patterns do not hurt training
//! dynamics).
//!
//! Uses the reduced-scale LSTM (H=256) so the curve is traced in minutes;
//! AD_BENCH_TRAIN_STEPS scales the curve length (default 120).

use approx_dropout::bench::drivers::{env_usize, trace_lstm_curve, BenchCtx};
use approx_dropout::bench::Table;
use approx_dropout::coordinator::Variant;
use approx_dropout::data::Corpus;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new()?;
    let steps = env_usize("AD_BENCH_TRAIN_STEPS", 0).max(120);
    let every = (steps / 12).max(1);
    let tag = "lstm2x256v2048b20";
    println!("== Fig 5: training curve, {tag}, rate 0.5, {steps} steps ==");
    let corpus = Corpus::generate(2048, 150_000, 15_000, 15_000, 11);

    let conv = trace_lstm_curve(&ctx, tag, Variant::Conv, 0.5, 2, &corpus,
                                steps, every, 42)?;
    let rdp = trace_lstm_curve(&ctx, tag, Variant::Rdp, 0.5, 2, &corpus,
                               steps, every, 42)?;

    let mut table = Table::new(&["iteration", "conv loss", "conv acc",
                                 "RDP loss", "RDP acc"]);
    for (c, r) in conv.iter().zip(&rdp) {
        table.row(&[format!("{}", c.0), format!("{:.4}", c.1),
                    format!("{:.3}", c.2), format!("{:.4}", r.1),
                    format!("{:.3}", r.2)]);
    }
    table.print();

    // Smoothness proxy: mean |delta loss| between consecutive trace points.
    let rough = |pts: &[(u64, f64, f64)]| -> f64 {
        pts.windows(2).map(|w| (w[1].1 - w[0].1).abs()).sum::<f64>()
            / (pts.len() - 1).max(1) as f64
    };
    println!("\nmean |delta loss| — conv {:.4}, RDP {:.4} (paper: RDP \
              curve is smoother)", rough(&conv), rough(&rdp));
    println!("final loss — conv {:.4}, RDP {:.4} (paper: RDP converges \
              no slower)", conv.last().unwrap().1, rdp.last().unwrap().1);
    Ok(())
}
