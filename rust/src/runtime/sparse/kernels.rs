//! Structured-sparse f32 kernel library: cache-blocked GEMM/GEMV with
//! **row-skip** and **tile-skip** variants. This is the compute engine the
//! paper's speedup claim rests on — where the reference backend evaluates
//! masked-*dense* math, these kernels never touch a dropped coordinate:
//! dropped rows of the shared dimension are never loaded or multiplied,
//! dropped output rows/columns are never written (they stay exactly
//! zero), and dropped weight tiles are never read (the *raw* weight is
//! passed in; see [`Kernels::prep_weight`]).
//!
//! ## Microkernels
//!
//! Every inner loop runs through the [`simd::Microkernel`] primitives
//! (`axpy` / `axpy2` / `dot_acc`): runtime-detected AVX2+FMA or NEON
//! vector code when the CPU has it, a portable unrolled scalar fallback
//! otherwise, `AD_SIMD=off` to force scalar (see `sparse::simd`). The
//! scalar microkernels are bit-compatible with the dense loops; the SIMD
//! ones differ in float rounding only (FMA + fixed-order lane
//! reductions) and stay inside the 1e-5 relative contract the parity
//! suites enforce. [`SparseKernels::auto`] picks up the process-wide
//! selection; [`SparseKernels::scalar`] pins the portable path.
//!
//! ## Blocking and parallelism
//!
//! Every kernel partitions its **output** into disjoint ranges — row
//! chunks of [`CHUNK_ROWS`] rows (GEMM/NT), kept-gradient-row chunks or
//! tile-rows (TN) — and runs the chunks on the process-wide worker pool
//! (`sparse::pool`, sized by `AD_THREADS`). Each output element is
//! computed entirely within one chunk with the shared dimension streamed
//! in ascending index order ([`KBLOCK`]-sized panels keep the B operand
//! L1/L2-resident), so results are bit-identical across thread counts.
//! With scalar microkernels they are additionally bit-compatible with
//! the dense kernels: skipping an exactly-zero contribution is an IEEE
//! no-op, and the surviving contributions are accumulated in the same
//! order the dense loops use. Calls below [`MIN_PAR_WORK`]
//! multiply-accumulates run inline on the caller — the pool round-trip
//! costs more than the math at tiny sizes.
//!
//! Contract details (which operand a [`Skip`] describes per method) live
//! on the [`Kernels`] trait; the property suite
//! (`rust/tests/sparse_kernels.rs`) pins sparse == dense-under-mask for
//! randomized shapes, skips, and tilings, plus SIMD-vs-scalar agreement.

use crate::obs::registry;
use crate::patterns::{RowPattern, TilePattern};
use crate::runtime::plan::{DynMask, Kept, NtNode, TnNode};
use crate::runtime::sparse::pool::{self, ThreadPool};
use crate::runtime::sparse::simd::{self, Microkernel};
use crate::runtime::step::kernels::{Kernels, PreppedWeight, Skip};

/// Output rows per parallel chunk. Fixed (not derived from the thread
/// count) so the partition is reproducible; correctness never depends on
/// it — see the determinism contract in `sparse::pool`.
const CHUNK_ROWS: usize = 8;

/// Shared-dimension panel size: KBLOCK rows of B (<= KBLOCK * n floats)
/// stay cache-resident while a chunk's A rows stream over them.
const KBLOCK: usize = 64;

/// Minimum multiply-accumulate count before a call is worth fanning out
/// to the worker pool.
const MIN_PAR_WORK: usize = 32 * 1024;

/// The structure-exploiting kernel set over one pinned microkernel
/// implementation; dispatches through the process-wide `AD_THREADS`
/// pool.
#[derive(Clone, Copy)]
pub struct SparseKernels {
    mk: &'static Microkernel,
    /// Honor dynamic masks on plan nodes (`AD_DYN_BWD`, default on).
    /// When off, every node entry point delegates to the static path —
    /// bit- and dispatch-identical to pre-dynamic behavior.
    dyn_bwd: bool,
}

/// Process-wide `AD_DYN_BWD` default, pinned at first use like the
/// `AD_SIMD` selection: `off`/`0`/`false` disables dynamic backward
/// sparsity, anything else (including unset) enables it.
fn dyn_bwd_default() -> bool {
    static DYN: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *DYN.get_or_init(|| {
        !matches!(std::env::var("AD_DYN_BWD").ok().as_deref(),
                  Some("off") | Some("0") | Some("false"))
    })
}

impl SparseKernels {
    /// The process-wide microkernel selection (`AD_SIMD` + CPU feature
    /// detection) — what `SparseBackend::new` uses.
    pub fn auto() -> Self {
        SparseKernels { mk: simd::active(), dyn_bwd: dyn_bwd_default() }
    }

    /// Force the portable scalar microkernels: the `AD_SIMD=off`
    /// configuration, bit-compatible with `DenseKernels` accumulation.
    pub fn scalar() -> Self {
        SparseKernels { mk: simd::scalar(), dyn_bwd: dyn_bwd_default() }
    }

    /// The detected SIMD microkernels, if this CPU has any — `None`
    /// otherwise (callers print a loud skip, never a silent pass).
    pub fn simd() -> Option<Self> {
        simd::detected()
            .map(|mk| SparseKernels { mk, dyn_bwd: dyn_bwd_default() })
    }

    /// Pin dynamic backward sparsity on or off for this kernel set,
    /// overriding the `AD_DYN_BWD` process default (benches compare the
    /// two configurations side by side).
    pub fn with_dyn(mut self, on: bool) -> Self {
        self.dyn_bwd = on;
        self
    }

    /// Name of the pinned microkernel ("avx2" | "neon" | "scalar").
    pub fn microkernel(&self) -> &'static str {
        self.mk.name
    }
}

impl Default for SparseKernels {
    fn default() -> Self {
        Self::auto()
    }
}

impl std::fmt::Debug for SparseKernels {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SparseKernels")
            .field("microkernel", &self.mk.name)
            .field("dyn_bwd", &self.dyn_bwd)
            .finish()
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: every task writes through the pointer only inside the disjoint
// output range its chunk index selects.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

fn all_indices(dim: usize) -> Vec<usize> {
    (0..dim).collect()
}

/// Registry notes for the shared-dimension structure a GEMM is about to
/// exploit. Pure observers on the always-on process registry (relaxed
/// atomic adds): they never branch the compute path and never read
/// pattern state the kernel doesn't already use, so enabling export can
/// not perturb results.
#[inline]
fn note_rows(skip: &Skip) {
    if let Skip::Rows(p) = skip {
        let kept = p.kept_count() as u64;
        registry::SPARSE_ROWS_KEPT.add(kept);
        registry::SPARSE_ROWS_DROPPED.add(p.m as u64 - kept);
    }
}

#[inline]
fn note_tiles(pat: &TilePattern) {
    let (tk, tn) = pat.grid();
    let kept = pat.kept_count() as u64;
    registry::SPARSE_TILES_KEPT.add(kept);
    registry::SPARSE_TILES_DROPPED.add((tk * tn) as u64 - kept);
}

/// Registry note for a dynamic mask at the moment a kernel honors it:
/// `kept` counts the rows/columns actually walked, `dropped` the
/// runtime-discovered dead ones the walk skipped.
#[inline]
fn note_dyn(mask: &DynMask) {
    registry::SPARSE_DYN_ROWS_KEPT.add(mask.live.len() as u64);
    registry::SPARSE_DYN_ROWS_DROPPED.add(mask.dropped() as u64);
}

/// Flat kept-index list of a non-`Tiles` skip (`Tiles` never reaches
/// the row-kernel paths — the tile walks handle it upstream).
fn kept_or_all(skip: &Skip, dim: usize) -> Vec<usize> {
    match skip.kept(dim) {
        Kept::Rows(v) => v,
        _ => all_indices(dim),
    }
}

/// Run `task` over `n_chunks` chunks, inline when the call is too small
/// to amortize the pool handshake.
fn run_chunks(p: &ThreadPool, work: usize, n_chunks: usize,
              task: &(dyn Fn(usize) + Sync)) {
    if work < MIN_PAR_WORK || n_chunks <= 1 || p.n_threads() == 1 {
        for c in 0..n_chunks {
            task(c);
        }
    } else {
        p.run(n_chunks, task);
    }
}

/// `y += Σ a_i * x_i` over a panel of (coefficient, row) pairs: zero
/// coefficients are skipped (an IEEE no-op on these exact-zero
/// activations, and dropped/poisoned rows are never loaded), and nonzero
/// terms are paired into rank-2 `axpy2` calls — which every microkernel
/// implements as the exact result of two sequential `axpy` passes, so
/// the pairing can never change a result bit.
fn axpy_panel<'a, I>(mk: &Microkernel, rows: I, y: &mut [f32])
where
    I: Iterator<Item = (f32, &'a [f32])>,
{
    let mut pending: Option<(f32, &[f32])> = None;
    for (a, x) in rows {
        if a == 0.0 {
            continue;
        }
        match pending.take() {
            None => pending = Some((a, x)),
            Some((a0, x0)) => mk.axpy2(a0, x0, a, x, y),
        }
    }
    if let Some((a, x)) = pending {
        mk.axpy(a, x, y);
    }
}

impl Kernels for SparseKernels {
    fn name(&self) -> &'static str {
        "sparse"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
            k_skip: &Skip, out_skip: &Skip) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        let p = pool::global();
        let mut out = vec![0f32; m * n];
        match k_skip {
            Skip::Tiles(pat) => {
                note_tiles(pat);
                gemm_tiles(p, self.mk, a, b, m, k, n, pat, &mut out);
            }
            _ => {
                note_rows(k_skip);
                let kidx = kept_or_all(k_skip, k);
                match out_skip {
                    // Only worth packing when columns are actually
                    // dropped; a keep-everything pattern (dp=1 draws)
                    // would pay a full copy of B for zero skipped work.
                    Skip::Rows(q) if q.kept_count() < q.m => {
                        gemm_rows_cols(p, self.mk, a, b, m, k, n, &kidx,
                                       q, &mut out);
                    }
                    _ => gemm_rows(p, self.mk, a, b, m, k, n, &kidx,
                                   &mut out),
                }
            }
        }
        out
    }

    fn gemm_nt(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize,
               skip: &Skip) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
        let p = pool::global();
        let mut out = vec![0f32; m * k];
        match skip {
            Skip::Tiles(pat) => {
                note_tiles(pat);
                nt_tiles(p, self.mk, a, b, m, n, k, pat, &mut out);
            }
            _ => {
                note_rows(skip);
                let jidx = kept_or_all(skip, k);
                nt_rows(p, self.mk, a, b, m, n, k, &jidx, &mut out);
            }
        }
        out
    }

    fn gemm_tn_acc(&self, a: &[f32], b: &[f32], m: usize, k: usize,
                   n: usize, row_skip: &Skip, col_skip: &Skip,
                   out: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        debug_assert_eq!(out.len(), k * n);
        let p = pool::global();
        match row_skip {
            Skip::Tiles(pat) => {
                note_tiles(pat);
                tn_tiles(p, self.mk, a, b, m, k, n, pat, out)
            }
            _ => {
                note_rows(row_skip);
                let pidx = kept_or_all(row_skip, k);
                let cidx = match col_skip {
                    Skip::Rows(q) => Some(q.kept_indices()),
                    _ => None,
                };
                tn_rows(p, self.mk, a, b, m, k, n, &pidx, cidx.as_deref(),
                        out);
            }
        }
    }

    fn prep_weight(&self, _w: &[f32], _k: usize, _n: usize, _skip: &Skip)
                   -> Option<Vec<f32>> {
        // Never materialize a masked weight: the GEMM loops skip dropped
        // tiles themselves, off the raw buffer.
        None
    }

    fn prep(&self, w: &[f32], k: usize, n: usize, skip: &Skip)
            -> PreppedWeight {
        match skip {
            // Row skips: cache the kept-index set and pack the kept rows
            // of `w` into a contiguous `[kk, n]` panel, paid once per
            // (site, window) instead of once per GEMM. Dropped rows are
            // never read (the poison test below pins that).
            Skip::Rows(pat) if pat.kept_count() < pat.m => {
                debug_assert_eq!(pat.m, k, "Rows skip width mismatch");
                debug_assert_eq!(w.len(), k * n);
                let kept = pat.kept_indices();
                let mut panel = vec![0f32; kept.len() * n];
                for (pi, &ki) in kept.iter().enumerate() {
                    panel[pi * n..(pi + 1) * n]
                        .copy_from_slice(&w[ki * n..(ki + 1) * n]);
                }
                registry::SPARSE_PANEL_BYTES
                    .add((panel.len() * std::mem::size_of::<f32>()) as u64);
                PreppedWeight::packed(kept, panel)
            }
            // Tiles: the tile walks skip off the raw buffer already;
            // Dense (and keep-everything Rows): no-op by contract.
            _ => PreppedWeight::dense(),
        }
    }

    fn gemm_pw(&self, a: &[f32], w: &[f32], pw: &PreppedWeight, m: usize,
               k: usize, n: usize, k_skip: &Skip, out_skip: &Skip)
               -> Vec<f32> {
        if let (Some(kept), Some(panel)) = (&pw.kept, &pw.panel) {
            // The panel fast path covers exactly the gemm_rows shape
            // (k restricted, output dense). Column-restricted outputs
            // keep the gemm_rows_cols packing, which also compacts the
            // n axis.
            if matches!(k_skip, Skip::Rows(_)) && out_skip.is_dense() {
                note_rows(k_skip);
                debug_assert_eq!(panel.len(), kept.len() * n);
                debug_assert_eq!(a.len(), m * k);
                let mut out = vec![0f32; m * n];
                gemm_rows_packed(pool::global(), self.mk, a, panel, kept,
                                 m, k, n, &mut out);
                return out;
            }
        }
        self.gemm(a, pw.weight(w), m, k, n, k_skip, out_skip)
    }

    fn gemm_nt_pw(&self, a: &[f32], w: &[f32], pw: &PreppedWeight,
                  m: usize, n: usize, k: usize, skip: &Skip) -> Vec<f32> {
        if let (Some(kept), Some(panel)) = (&pw.kept, &pw.panel) {
            if matches!(skip, Skip::Rows(_)) {
                note_rows(skip);
                debug_assert_eq!(panel.len(), kept.len() * n);
                debug_assert_eq!(a.len(), m * n);
                let mut out = vec![0f32; m * k];
                nt_rows_packed(pool::global(), self.mk, a, panel, kept,
                               m, n, k, &mut out);
                return out;
            }
        }
        self.gemm_nt(a, pw.weight(w), m, n, k, skip)
    }

    fn dyn_backward(&self) -> bool {
        self.dyn_bwd
    }

    fn gemm_tn_acc_node(&self, a: &[f32], b: &[f32], node: &TnNode,
                        m: usize, k: usize, n: usize, out: &mut [f32]) {
        // Dynamic row restriction: the plan marked runtime-dead units
        // (ReLU-zero columns, zero LSTM initial state) on the shared
        // dimension. Walking only `mask.live` is bitwise exact — a dead
        // unit contributes 0.0 coefficients everywhere, and the static
        // paths skip exact zeros elementwise anyway. Tiles row skips
        // have no flat index view, so they stay on the tile walk.
        if self.dyn_bwd && !matches!(node.row_skip, Skip::Tiles(_)) {
            if let Some(mask) = node.dyn_rows {
                debug_assert_eq!(a.len(), m * k);
                debug_assert_eq!(b.len(), m * n);
                debug_assert_eq!(out.len(), k * n);
                // `total` is the static kept count of the axis, not k.
                debug_assert!(mask.live.len() <= mask.total
                              && mask.total <= k);
                note_rows(&node.row_skip);
                note_dyn(mask);
                let cidx = match &node.col_skip {
                    Skip::Rows(q) => Some(q.kept_indices()),
                    _ => None,
                };
                tn_rows(pool::global(), self.mk, a, b, m, k, n,
                        &mask.live, cidx.as_deref(), out);
                return;
            }
        }
        self.gemm_tn_acc(a, b, m, k, n, &node.row_skip, &node.col_skip,
                         out);
    }

    fn gemm_nt_node(&self, a: &[f32], w: &[f32], node: &NtNode,
                    m: usize, n: usize, k: usize) -> Vec<f32> {
        // Dynamic column restriction: dead output columns stay zero, a
        // value the downstream ReLU-derivative gate multiplies by zero
        // anyway (the plan only attaches masks where that gate exists).
        // The unpacked walk against raw `w` is bit-identical to the
        // packed panel path (`nt_rows_packed` docs), so `mask.live` —
        // a subset of the panel's kept rows — needs no repacking.
        if self.dyn_bwd && !matches!(node.skip, Skip::Tiles(_)) {
            if let Some(mask) = node.dyn_cols {
                debug_assert_eq!(a.len(), m * n);
                debug_assert_eq!(w.len(), k * n);
                debug_assert!(mask.live.len() <= mask.total
                              && mask.total <= k);
                note_rows(&node.skip);
                note_dyn(mask);
                let mut out = vec![0f32; m * k];
                nt_rows(pool::global(), self.mk, a, w, m, n, k,
                        &mask.live, &mut out);
                return out;
            }
        }
        match node.pw {
            Some(pw) => self.gemm_nt_pw(a, w, pw, m, n, k, &node.skip),
            None => self.gemm_nt(a, w, m, n, k, &node.skip),
        }
    }
}

// ---------------------------------------------------------------------------
// GEMM: C[m,n] = A[m,k] @ B[k,n]
// ---------------------------------------------------------------------------

/// Row-skip GEMM: only the shared-dimension indices in `kidx` are
/// touched. Chunks over output rows; KBLOCK-panel over `kidx`.
fn gemm_rows(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
             b: &[f32], m: usize, k: usize, n: usize, kidx: &[usize],
             out: &mut [f32]) {
    let n_chunks = m.div_ceil(CHUNK_ROWS);
    let ptr = SendPtr(out.as_mut_ptr());
    let task = move |c: usize| {
        let r0 = c * CHUNK_ROWS;
        let r1 = (r0 + CHUNK_ROWS).min(m);
        // SAFETY: rows r0..r1 belong to this chunk alone.
        let seg = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(r0 * n),
                                           (r1 - r0) * n)
        };
        for kb in kidx.chunks(KBLOCK) {
            for (ri, i) in (r0..r1).enumerate() {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut seg[ri * n..(ri + 1) * n];
                axpy_panel(
                    mk,
                    kb.iter().map(|&pi| {
                        (arow[pi], &b[pi * n..(pi + 1) * n])
                    }),
                    orow,
                );
            }
        }
    };
    run_chunks(p, m * kidx.len() * n, n_chunks, &task);
}

/// Row-skip GEMM against a prepacked kept-row panel (`panel[pi] ==
/// b[kidx[pi]]`): the per-call kept-set derivation and the strided walks
/// over B disappear, which is the per-window amortization the
/// time-window work buys. **Bit-identical to [`gemm_rows`]**: panel
/// positions are chunked by [`KBLOCK`] exactly like `kidx.chunks`, the
/// coefficient stream `arow[kidx[pi]]` matches `gemm_rows`' `arow[pi]`
/// pair for pair, and `axpy_panel` sees the same (coefficient, row)
/// sequence — only the row storage is contiguous now.
fn gemm_rows_packed(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
                    panel: &[f32], kidx: &[usize], m: usize, k: usize,
                    n: usize, out: &mut [f32]) {
    let kk = kidx.len();
    let n_chunks = m.div_ceil(CHUNK_ROWS);
    let ptr = SendPtr(out.as_mut_ptr());
    let task = move |c: usize| {
        let r0 = c * CHUNK_ROWS;
        let r1 = (r0 + CHUNK_ROWS).min(m);
        // SAFETY: rows r0..r1 belong to this chunk alone.
        let seg = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(r0 * n),
                                           (r1 - r0) * n)
        };
        let mut p0 = 0;
        while p0 < kk {
            let p1 = (p0 + KBLOCK).min(kk);
            for (ri, i) in (r0..r1).enumerate() {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut seg[ri * n..(ri + 1) * n];
                axpy_panel(
                    mk,
                    (p0..p1).map(|pi| {
                        (arow[kidx[pi]],
                         &panel[pi * n..(pi + 1) * n])
                    }),
                    orow,
                );
            }
            p0 = p1;
        }
    };
    run_chunks(p, m * kk * n, n_chunks, &task);
}

/// Row-skip + column-restricted GEMM: the kept columns of the kept rows
/// of B are packed into a compact `[kk, nc]` panel (dropped coordinates
/// are never read), the product is computed compactly, and the result is
/// scattered to the kept output columns — the paper's "smaller dense
/// matmul" in one call.
fn gemm_rows_cols(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
                  b: &[f32], m: usize, k: usize, n: usize,
                  kidx: &[usize], cols: &RowPattern, out: &mut [f32]) {
    debug_assert_eq!(cols.m, n);
    let cidx = cols.kept_indices();
    let (kk, nc) = (kidx.len(), cidx.len());
    if nc == 0 || kk == 0 {
        return;
    }
    let mut bp = vec![0f32; kk * nc];
    for (pi, &pr) in kidx.iter().enumerate() {
        let brow = &b[pr * n..(pr + 1) * n];
        let prow = &mut bp[pi * nc..(pi + 1) * nc];
        for (dst, &j) in prow.iter_mut().zip(&cidx) {
            *dst = brow[j];
        }
    }
    let mut cp = vec![0f32; m * nc];
    {
        let n_chunks = m.div_ceil(CHUNK_ROWS);
        let ptr = SendPtr(cp.as_mut_ptr());
        let bp_ref: &[f32] = &bp;
        let task = move |c: usize| {
            let r0 = c * CHUNK_ROWS;
            let r1 = (r0 + CHUNK_ROWS).min(m);
            let seg = unsafe {
                std::slice::from_raw_parts_mut(ptr.0.add(r0 * nc),
                                               (r1 - r0) * nc)
            };
            let mut p0 = 0;
            while p0 < kk {
                let p1 = (p0 + KBLOCK).min(kk);
                for (ri, i) in (r0..r1).enumerate() {
                    let arow = &a[i * k..(i + 1) * k];
                    let orow = &mut seg[ri * nc..(ri + 1) * nc];
                    axpy_panel(
                        mk,
                        (p0..p1).map(|pi| {
                            (arow[kidx[pi]],
                             &bp_ref[pi * nc..(pi + 1) * nc])
                        }),
                        orow,
                    );
                }
                p0 = p1;
            }
        };
        run_chunks(p, m * kk * nc, n_chunks, &task);
    }
    for i in 0..m {
        let crow = &cp[i * nc..(i + 1) * nc];
        let orow = &mut out[i * n..(i + 1) * n];
        for (ci, &j) in cidx.iter().enumerate() {
            orow[j] = crow[ci];
        }
    }
}

/// Tile-skip GEMM: B is a `[k, n]` weight under a tile pattern; only
/// kept tiles are loaded. Kept tiles are visited in row-major grid order,
/// so each output element accumulates its k-contributions ascending.
fn gemm_tiles(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
              b: &[f32], m: usize, k: usize, n: usize, pat: &TilePattern,
              out: &mut [f32]) {
    debug_assert_eq!((pat.k, pat.n), (k, n));
    let (tr, tc) = (pat.tr, pat.tc);
    let kept = pat.kept_tiles();
    let n_chunks = m.div_ceil(CHUNK_ROWS);
    let ptr = SendPtr(out.as_mut_ptr());
    let kept_ref: &[(usize, usize)] = &kept;
    let task = move |c: usize| {
        let r0 = c * CHUNK_ROWS;
        let r1 = (r0 + CHUNK_ROWS).min(m);
        let seg = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(r0 * n),
                                           (r1 - r0) * n)
        };
        for &(gr, gc) in kept_ref {
            let k0 = gr * tr;
            let j0 = gc * tc;
            for (ri, i) in (r0..r1).enumerate() {
                let arow = &a[i * k + k0..i * k + k0 + tr];
                let orow = &mut seg[ri * n + j0..ri * n + j0 + tc];
                axpy_panel(
                    mk,
                    arow.iter().enumerate().map(|(p0, &av)| {
                        (av, &b[(k0 + p0) * n + j0..][..tc])
                    }),
                    orow,
                );
            }
        }
    };
    run_chunks(p, m * kept.len() * tr * tc, n_chunks, &task);
}

// ---------------------------------------------------------------------------
// NT: C[m,k] = A[m,n] @ B[k,n]^T
// ---------------------------------------------------------------------------

/// Output-column-restricted NT: only output columns in `jidx` are
/// computed (B rows outside it are never loaded); the rest stay zero.
fn nt_rows(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
           b: &[f32], m: usize, n: usize, k: usize, jidx: &[usize],
           out: &mut [f32]) {
    let n_chunks = m.div_ceil(CHUNK_ROWS);
    let ptr = SendPtr(out.as_mut_ptr());
    let task = move |c: usize| {
        let r0 = c * CHUNK_ROWS;
        let r1 = (r0 + CHUNK_ROWS).min(m);
        let seg = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(r0 * k),
                                           (r1 - r0) * k)
        };
        for (ri, i) in (r0..r1).enumerate() {
            let arow = &a[i * n..(i + 1) * n];
            let orow = &mut seg[ri * k..(ri + 1) * k];
            for &j in jidx {
                let brow = &b[j * n..(j + 1) * n];
                orow[j] = mk.dot_acc(0.0, arow, brow);
            }
        }
    };
    run_chunks(p, m * jidx.len() * n, n_chunks, &task);
}

/// Output-column-restricted NT against a prepacked kept-row panel
/// (`panel[pi] == b[jidx[pi]]`). **Bit-identical to [`nt_rows`]**: each
/// kept output column is one `dot_acc` over the same values in the same
/// order — the B row just comes from the contiguous panel.
fn nt_rows_packed(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
                  panel: &[f32], jidx: &[usize], m: usize, n: usize,
                  k: usize, out: &mut [f32]) {
    let n_chunks = m.div_ceil(CHUNK_ROWS);
    let ptr = SendPtr(out.as_mut_ptr());
    let task = move |c: usize| {
        let r0 = c * CHUNK_ROWS;
        let r1 = (r0 + CHUNK_ROWS).min(m);
        let seg = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(r0 * k),
                                           (r1 - r0) * k)
        };
        for (ri, i) in (r0..r1).enumerate() {
            let arow = &a[i * n..(i + 1) * n];
            let orow = &mut seg[ri * k..(ri + 1) * k];
            for (pi, &j) in jidx.iter().enumerate() {
                let brow = &panel[pi * n..(pi + 1) * n];
                orow[j] = mk.dot_acc(0.0, arow, brow);
            }
        }
    };
    run_chunks(p, m * jidx.len() * n, n_chunks, &task);
}

/// Tile-masked NT: B is a `[k, n]` weight under a tile pattern; each
/// output column j (a B row) sums only over that row's kept tiles, in
/// ascending column order (value-equal to the dense dot against the
/// masked weight).
fn nt_tiles(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
            b: &[f32], m: usize, n: usize, k: usize, pat: &TilePattern,
            out: &mut [f32]) {
    debug_assert_eq!((pat.k, pat.n), (k, n));
    let (tr, tc) = (pat.tr, pat.tc);
    let (tk, tn) = pat.grid();
    let kept = pat.kept_count();
    let n_chunks = m.div_ceil(CHUNK_ROWS);
    let ptr = SendPtr(out.as_mut_ptr());
    let task = move |c: usize| {
        let r0 = c * CHUNK_ROWS;
        let r1 = (r0 + CHUNK_ROWS).min(m);
        let seg = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(r0 * k),
                                           (r1 - r0) * k)
        };
        for (ri, i) in (r0..r1).enumerate() {
            let arow = &a[i * n..(i + 1) * n];
            let orow = &mut seg[ri * k..(ri + 1) * k];
            for gr in 0..tk {
                for j0 in 0..tr {
                    let j = gr * tr + j0;
                    let brow = &b[j * n..(j + 1) * n];
                    let mut acc = 0f32;
                    for gc in 0..tn {
                        if !pat.keeps_tile(gr, gc) {
                            continue;
                        }
                        let c0 = gc * tc;
                        acc = mk.dot_acc(acc, &arow[c0..c0 + tc],
                                         &brow[c0..c0 + tc]);
                    }
                    orow[j] = acc;
                }
            }
        }
    };
    run_chunks(p, m * kept * tr * tc, n_chunks, &task);
}

// ---------------------------------------------------------------------------
// TN: C[k,n] += A[m,k]^T @ B[m,n]  (gradient accumulation)
// ---------------------------------------------------------------------------

/// Kept output rows per parallel chunk in the TN kernels.
const CHUNK_GROWS: usize = 8;

/// Row/column-restricted TN accumulation: only output rows in `pidx`
/// (and, when `cidx` is given, columns in it) receive updates; A's
/// dropped columns and B's dropped columns are never loaded. The
/// column-restricted arm stays on scalar gathers — the kept columns are
/// strided, not contiguous, so there is no microkernel run to hand off.
fn tn_rows(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
           b: &[f32], m: usize, k: usize, n: usize, pidx: &[usize],
           cidx: Option<&[usize]>, out: &mut [f32]) {
    let n_chunks = pidx.len().div_ceil(CHUNK_GROWS);
    let ptr = SendPtr(out.as_mut_ptr());
    let task = move |c: usize| {
        let g0 = c * CHUNK_GROWS;
        let g1 = (g0 + CHUNK_GROWS).min(pidx.len());
        for &pr in &pidx[g0..g1] {
            // SAFETY: kept rows are unique; each belongs to one chunk.
            let orow = unsafe {
                std::slice::from_raw_parts_mut(ptr.0.add(pr * n), n)
            };
            match cidx {
                None => {
                    axpy_panel(
                        mk,
                        (0..m).map(|i| {
                            (a[i * k + pr], &b[i * n..(i + 1) * n])
                        }),
                        orow,
                    );
                }
                Some(cs) => {
                    for i in 0..m {
                        let av = a[i * k + pr];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[i * n..(i + 1) * n];
                        for &j in cs {
                            orow[j] += av * brow[j];
                        }
                    }
                }
            }
        }
    };
    let width = cidx.map_or(n, <[usize]>::len);
    run_chunks(p, pidx.len() * m * width, n_chunks, &task);
}

/// Tile-restricted TN accumulation: only C's kept tiles receive updates.
/// Chunks over tile-rows (disjoint output row ranges).
fn tn_tiles(p: &ThreadPool, mk: &'static Microkernel, a: &[f32],
            b: &[f32], m: usize, k: usize, n: usize, pat: &TilePattern,
            out: &mut [f32]) {
    debug_assert_eq!((pat.k, pat.n), (k, n));
    let (tr, tc) = (pat.tr, pat.tc);
    let (tk, tn) = pat.grid();
    let ptr = SendPtr(out.as_mut_ptr());
    let task = move |gr: usize| {
        for gc in 0..tn {
            if !pat.keeps_tile(gr, gc) {
                continue;
            }
            let c0 = gc * tc;
            for p0 in 0..tr {
                let pr = gr * tr + p0;
                // SAFETY: tile-row `gr` owns output rows gr*tr..(gr+1)*tr.
                let oseg = unsafe {
                    std::slice::from_raw_parts_mut(
                        ptr.0.add(pr * n + c0), tc)
                };
                axpy_panel(
                    mk,
                    (0..m).map(|i| {
                        (a[i * k + pr], &b[i * n + c0..][..tc])
                    }),
                    oseg,
                );
            }
        }
    };
    run_chunks(p, pat.kept_count() * tr * tc * m, tk, &task);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::step::kernels::DenseKernels;
    use crate::util::rng::Rng;
    use crate::util::testkit::{self, gen_choice, gen_vec_f32};

    const D: Skip = Skip::Dense;

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= 1e-5 * x.abs().max(y.abs()).max(1.0),
                    "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dense_skip_matches_dense_kernels_exactly() {
        testkit::quickcheck("sparse dense-path parity", |rng| {
            let (m, k, n) = (testkit::gen_range(rng, 1, 20),
                             testkit::gen_range(rng, 1, 40),
                             testkit::gen_range(rng, 1, 40));
            let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
            let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
            // Scalar microkernels: bit-compatible with the dense loops.
            let s = SparseKernels::scalar();
            let d = DenseKernels;
            assert_eq!(s.gemm(&a, &b, m, k, n, &D, &D),
                       d.gemm(&a, &b, m, k, n, &D, &D));
            let bt = gen_vec_f32(rng, n * k, -1.0, 1.0);
            let a2 = gen_vec_f32(rng, m * n, -1.0, 1.0);
            assert_eq!(s.gemm_nt(&a2, &bt, m, n, k, &D),
                       d.gemm_nt(&a2, &bt, m, n, k, &D));
            let b2 = gen_vec_f32(rng, m * n, -1.0, 1.0);
            close(&s.gemm_tn(&a, &b2, m, k, n, &D, &D),
                  &d.gemm_tn(&a, &b2, m, k, n, &D, &D));
        });
    }

    #[test]
    fn row_skip_never_needs_dropped_rows() {
        // Poison the dropped rows of B with NaN: a correct row-skip GEMM
        // never loads them. Run under BOTH microkernel modes — the SIMD
        // panels must also never touch a dropped row.
        let mut rng = Rng::new(11);
        let (m, k, n) = (6, 32, 24);
        let pat = RowPattern::new(k, 4, 1);
        // a's dropped columns are structurally zero (masked activations).
        let mut a = gen_vec_f32(&mut rng, m * k, -1.0, 1.0);
        for i in 0..m {
            for p2 in 0..k {
                if !pat.keeps(p2) {
                    a[i * k + p2] = 0.0;
                }
            }
        }
        let mut b = gen_vec_f32(&mut rng, k * n, -1.0, 1.0);
        let clean = b.clone();
        for p2 in 0..k {
            if !pat.keeps(p2) {
                for j in 0..n {
                    b[p2 * n + j] = f32::NAN;
                }
            }
        }
        let want = DenseKernels.gemm(&a, &clean, m, k, n, &D, &D);
        let got = SparseKernels::scalar()
            .gemm(&a, &b, m, k, n, &Skip::Rows(pat), &D);
        assert_eq!(got, want);
        assert!(got.iter().all(|v| v.is_finite()));
        if let Some(s) = SparseKernels::simd() {
            let got = s.gemm(&a, &b, m, k, n, &Skip::Rows(pat), &D);
            close(&got, &want);
            assert!(got.iter().all(|v| v.is_finite()),
                    "SIMD panel loaded a poisoned dropped row");
        }
    }

    #[test]
    fn tile_skip_never_needs_dropped_tiles() {
        let mut rng = Rng::new(12);
        let (m, k, n) = (5, 32, 32);
        let pat = TilePattern::new(k, n, 2, 1, 16);
        let a = gen_vec_f32(&mut rng, m * k, -1.0, 1.0);
        let mut w = gen_vec_f32(&mut rng, k * n, -1.0, 1.0);
        let masked: Vec<f32> =
            w.iter().zip(pat.mask()).map(|(&x, mk)| x * mk).collect();
        // Poison dropped tiles in the raw weight.
        for (v, mk) in w.iter_mut().zip(pat.mask()) {
            if mk == 0.0 {
                *v = f32::NAN;
            }
        }
        let skip = Skip::Tiles(pat);
        let want = DenseKernels.gemm(&a, &masked, m, k, n, &D, &D);
        let want_nt;
        let a2 = gen_vec_f32(&mut rng, m * n, -1.0, 1.0);
        {
            let s = SparseKernels::scalar();
            let got = s.gemm(&a, &w, m, k, n, &skip, &D);
            assert_eq!(got, want);
            // NT against the same tiled weight.
            want_nt = DenseKernels.gemm_nt(&a2, &masked, m, n, k, &D);
            let got = s.gemm_nt(&a2, &w, m, n, k, &skip);
            close(&got, &want_nt);
            assert!(got.iter().all(|v| v.is_finite()));
        }
        if let Some(s) = SparseKernels::simd() {
            let got = s.gemm(&a, &w, m, k, n, &skip, &D);
            close(&got, &want);
            assert!(got.iter().all(|v| v.is_finite()),
                    "SIMD tile walk loaded a poisoned dropped tile");
            let got = s.gemm_nt(&a2, &w, m, n, k, &skip);
            close(&got, &want_nt);
            assert!(got.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn results_bit_stable_across_thread_counts() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (24, 96, 80);
        let a = gen_vec_f32(&mut rng, m * k, -1.0, 1.0);
        let b = gen_vec_f32(&mut rng, k * n, -1.0, 1.0);
        let kidx: Vec<usize> = (0..k).step_by(2).collect();
        let pools = [ThreadPool::new(1), ThreadPool::new(2),
                     ThreadPool::new(5)];
        // Whatever microkernel is active: thread-count bit-stability is
        // a property of the disjoint-output partition, not of the math.
        let mk = simd::active();
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for p in &pools {
            let mut out = vec![0f32; m * n];
            gemm_rows(p, mk, &a, &b, m, k, n, &kidx, &mut out);
            outs.push(out);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[0], outs[2]);
        // Same for the TN accumulator.
        let b2 = gen_vec_f32(&mut rng, m * n, -1.0, 1.0);
        let pidx: Vec<usize> = (1..k).step_by(2).collect();
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for p in &pools {
            let mut out = vec![0.5f32; k * n];
            tn_rows(p, mk, &a, &b2, m, k, n, &pidx, None, &mut out);
            outs.push(out);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[0], outs[2]);
    }

    #[test]
    fn out_col_restriction_leaves_dropped_cols_zero() {
        testkit::quickcheck("gemm out-col restriction", |rng| {
            let m = testkit::gen_range(rng, 1, 10);
            let k = 8 * testkit::gen_range(rng, 1, 6);
            let n = 8 * testkit::gen_range(rng, 1, 6);
            let dp = *gen_choice(rng, &[2usize, 4]);
            let b0 = testkit::gen_range(rng, 0, dp);
            let q = RowPattern::new(n, dp, b0);
            let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
            let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
            let s = SparseKernels::auto();
            let got = s.gemm(&a, &b, m, k, n, &D, &Skip::Rows(q));
            let full = DenseKernels.gemm(&a, &b, m, k, n, &D, &D);
            for i in 0..m {
                for j in 0..n {
                    if q.keeps(j) {
                        let (x, y) = (got[i * n + j], full[i * n + j]);
                        assert!((x - y).abs() <= 1e-5, "{x} vs {y}");
                    } else {
                        assert_eq!(got[i * n + j], 0.0);
                    }
                }
            }
        });
    }

    #[test]
    fn packed_panel_paths_bit_match_unpacked() {
        // The per-window PreppedWeight fast paths must be bit-identical
        // to the per-call gemm/gemm_nt they replace, under every
        // microkernel — same pairing, same accumulation order, only the
        // row storage differs.
        testkit::quickcheck("packed panel parity", |rng| {
            let m = testkit::gen_range(rng, 1, 12);
            let k = 8 * testkit::gen_range(rng, 1, 8);
            let n = 8 * testkit::gen_range(rng, 1, 8);
            let dp = *gen_choice(rng, &[2usize, 4]);
            let b0 = testkit::gen_range(rng, 0, dp);
            let skip = Skip::Rows(RowPattern::new(k, dp, b0));
            let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
            let w = gen_vec_f32(rng, k * n, -1.0, 1.0);
            for s in [SparseKernels::scalar(), SparseKernels::auto()] {
                let pw = s.prep(&w, k, n, &skip);
                assert!(pw.has_panel());
                assert_eq!(s.gemm_pw(&a, &w, &pw, m, k, n, &skip, &D),
                           s.gemm(&a, &w, m, k, n, &skip, &D));
                let a2 = gen_vec_f32(rng, m * n, -1.0, 1.0);
                assert_eq!(s.gemm_nt_pw(&a2, &w, &pw, m, n, k, &skip),
                           s.gemm_nt(&a2, &w, m, n, k, &skip));
            }
        });
    }

    #[test]
    fn prep_never_reads_dropped_rows_and_dense_is_noop() {
        let (k, n) = (32, 24);
        let pat = RowPattern::new(k, 4, 1);
        let mut w = gen_vec_f32(&mut Rng::new(21), k * n, -1.0, 1.0);
        for r in 0..k {
            if !pat.keeps(r) {
                for v in &mut w[r * n..(r + 1) * n] {
                    *v = f32::NAN;
                }
            }
        }
        let s = SparseKernels::scalar();
        let pw = s.prep(&w, k, n, &Skip::Rows(pat));
        assert!(pw.panel.as_ref().unwrap().iter().all(|v| v.is_finite()),
                "panel packing loaded a poisoned dropped row");
        assert_eq!(pw.kept.as_ref().unwrap().len(), pat.kept_count());
        // Dense and keep-everything skips prepare nothing.
        assert!(!s.prep(&w, k, n, &D).has_panel());
        let keep_all = Skip::Rows(RowPattern::new(k, 1, 0));
        assert!(!s.prep(&w, k, n, &keep_all).has_panel());
        // Tiles: the tile walks run off the raw buffer — no handle state.
        let tiles = Skip::Tiles(TilePattern::new(32, 24, 2, 0, 8));
        assert!(!s.prep(&w, 32, 24, &tiles).has_panel());
    }

    #[test]
    fn gemv_matches_gemm_row() {
        let mut rng = Rng::new(14);
        let (k, n) = (48, 36);
        let x = gen_vec_f32(&mut rng, k, -1.0, 1.0);
        let b = gen_vec_f32(&mut rng, k * n, -1.0, 1.0);
        let pat = RowPattern::new(k, 4, 2);
        let s = SparseKernels::scalar();
        let y = s.gemv(&x, &b, k, n, &Skip::Rows(pat), &D);
        // Equals the masked-dense product.
        let xm: Vec<f32> = x.iter().enumerate()
            .map(|(i, &v)| if pat.keeps(i) { v } else { 0.0 })
            .collect();
        let want = DenseKernels.gemm(&xm, &b, 1, k, n, &D, &D);
        assert_eq!(y, want);
    }

    // SIMD-vs-scalar kernel agreement lives in the integration property
    // suite (rust/tests/sparse_kernels.rs:
    // simd_matches_scalar_on_randomized_shapes_skips_tilings) — one
    // copy, all four entry points, all skip families.
}
