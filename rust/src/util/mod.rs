//! In-tree infrastructure: PRNG, JSON/TOML parsing, CLI args, statistics,
//! logging, and a property-testing mini-framework. All hand-built because
//! the offline registry only carries the `xla` crate's dependency closure
//! (see DESIGN.md section 9).

pub mod argparse;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod testkit;
pub mod toml;

/// Wall-clock stopwatch used by trainers and the bench harness.
#[derive(Debug)]
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: std::time::Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = std::time::Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
        assert!(t.elapsed_s() < 1.0);
    }
}
