//! Training state: parameters + momenta held as **XLA literals** end-to-end.
//!
//! Perf-critical design (EXPERIMENTS.md section Perf): a train step's
//! outputs come back as one tuple literal; `decompose_tuple` is zero-copy,
//! and feeding the same literals back as the next step's inputs avoids any
//! host-side reshuffling of the (possibly hundreds of MB) parameter state.
//! The only per-step copies left are PJRT's own host->device transfers.

use anyhow::{anyhow, bail, Result};

use crate::runtime::engine::Executable;
use crate::runtime::manifest::{ArtifactMeta, Kind, TensorMeta};
use crate::util::rng::Rng;

pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub momenta: Vec<xla::Literal>,
    /// Manifest metadata of the params (name/shape), same order.
    pub metas: Vec<TensorMeta>,
    /// Cumulative training iterations applied.
    pub step: u64,
}

fn f32_bytes(data: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    }
}

/// Build an f32 literal from host data in one copy.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32, shape, f32_bytes(data))
        .map_err(|e| anyhow!("literal f32 {shape:?}: {e:?}"))
}

/// Build an i32 literal from host data in one copy.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("literal i32 {shape:?}: {e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

impl TrainState {
    /// Initialize from an artifact's param metas:
    /// * 2-D weights: Glorot-uniform  U(+-sqrt(6 / (fan_in + fan_out)))
    /// * embeddings (name "emb"): U(-0.1, 0.1) (Zaremba-style)
    /// * 1-D biases: zeros; momenta: zeros.
    pub fn init(meta: &ArtifactMeta, rng: &mut Rng) -> TrainState {
        let mut params = Vec::new();
        let mut metas = Vec::new();
        for t in meta.inputs.iter().filter(|t| t.kind == Kind::Param) {
            let n = t.elements();
            let data: Vec<f32> = if t.shape.len() == 2 {
                if t.name == "emb" {
                    (0..n).map(|_| rng.uniform(-0.1, 0.1) as f32).collect()
                } else {
                    let limit =
                        (6.0 / (t.shape[0] + t.shape[1]) as f64).sqrt();
                    (0..n).map(|_| rng.uniform(-limit, limit) as f32)
                        .collect()
                }
            } else {
                vec![0.0; n]
            };
            params.push(lit_f32(&t.shape, &data).expect("init literal"));
            metas.push(t.clone());
        }
        let momenta = metas
            .iter()
            .map(|t| lit_f32(&t.shape, &vec![0.0; t.elements()]).unwrap())
            .collect();
        TrainState { params, momenta, metas, step: 0 }
    }

    /// Run one train step: inputs are `params ++ momenta ++ tail` (tail =
    /// x, y, variant extras, lr in manifest order). The output literals
    /// replace the state in place. Returns (loss, correct).
    pub fn step(&mut self, exe: &Executable, tail: &[xla::Literal])
                -> Result<(f64, f64)> {
        let n = self.params.len();
        let refs: Vec<&xla::Literal> = self
            .params
            .iter()
            .chain(self.momenta.iter())
            .chain(tail.iter())
            .collect();
        let mut outputs = exe.run_raw(&refs)?;
        if outputs.len() != 2 * n + 2 {
            bail!("expected {} outputs, got {}", 2 * n + 2, outputs.len());
        }
        let correct = outputs.pop().unwrap().get_first_element::<f32>()
            .map_err(|e| anyhow!("correct scalar: {e:?}"))? as f64;
        let loss = outputs.pop().unwrap().get_first_element::<f32>()
            .map_err(|e| anyhow!("loss scalar: {e:?}"))? as f64;
        let mut it = outputs.into_iter();
        for p in self.params.iter_mut() {
            *p = it.next().unwrap();
        }
        for m in self.momenta.iter_mut() {
            *m = it.next().unwrap();
        }
        self.step += 1;
        Ok((loss, correct))
    }

    /// Run one eval-graph batch against a borrowed executable: inputs are
    /// `params ++ extra` (extra = x, y in manifest order), outputs are the
    /// (loss, correct) scalars. State is untouched — eval graphs are
    /// dropout-free forward passes.
    pub fn eval_step(&self, exe: &Executable, extra: &[xla::Literal])
                     -> Result<(f64, f64)> {
        let mut refs = self.param_refs();
        for l in extra {
            refs.push(l);
        }
        let out = exe.run_raw(&refs)?;
        let loss = out[0].get_first_element::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))? as f64;
        let correct = out[1].get_first_element::<f32>()
            .map_err(|e| anyhow!("correct: {e:?}"))? as f64;
        Ok((loss, correct))
    }

    /// References to the parameter literals (eval-graph inputs).
    pub fn param_refs(&self) -> Vec<&xla::Literal> {
        self.params.iter().collect()
    }

    /// Copy one parameter back to host (tests / inspection).
    pub fn param_f32(&self, i: usize) -> Result<Vec<f32>> {
        self.params[i]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("param {i} to_vec: {e:?}"))
    }

    /// Total parameter count (diagnostics).
    pub fn n_elements(&self) -> usize {
        self.metas.iter().map(|t| t.elements()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn init_shapes_match_manifest() {
        let m = manifest();
        let meta = m.get("mlptest_conv").unwrap();
        let mut rng = Rng::new(0);
        let st = TrainState::init(meta, &mut rng);
        assert_eq!(st.params.len(), 6);
        assert_eq!(st.metas[0].shape, vec![32, 64]);
        assert_eq!(st.metas[1].shape, vec![64]);
        // biases zero, weights nonzero
        assert!(st.param_f32(1).unwrap().iter().all(|&v| v == 0.0));
        assert!(st.param_f32(0).unwrap().iter().any(|&v| v != 0.0));
        assert_eq!(st.n_elements(), 32 * 64 + 64 + 64 * 64 + 64 + 64 * 10
                   + 10);
    }

    #[test]
    fn glorot_bounds() {
        let m = manifest();
        let meta = m.get("mlptest_conv").unwrap();
        let mut rng = Rng::new(1);
        let st = TrainState::init(meta, &mut rng);
        let limit = (6.0 / (32 + 64) as f64).sqrt() as f32;
        let w1 = st.param_f32(0).unwrap();
        assert!(w1.iter().all(|&v| v.abs() <= limit));
        let max = w1.iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(max > 0.8 * limit);
    }

    #[test]
    fn lit_roundtrip() {
        let l = lit_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        let i = lit_i32(&[4], &[7, 8, 9, 10]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8, 9, 10]);
        assert_eq!(lit_scalar_f32(2.5).get_first_element::<f32>().unwrap(),
                   2.5);
    }
}
