"""LSTM train-step graphs vs pure-jnp mask-based references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, patterns

ARCH = model.LstmArch(vocab=64, hidden=32, layers=2, seq=5, batch=4,
                      tile=16)


@pytest.fixture(scope="module")
def setup():
    specs = model.lstm_param_specs(ARCH)
    params = [jax.random.normal(jax.random.PRNGKey(i), s) * 0.1
              for i, (n, s) in enumerate(specs)]
    moms = [jnp.zeros(s) for _, s in specs]
    x = jax.random.randint(jax.random.PRNGKey(50), (4, 5), 0, 64, jnp.int32)
    y = jax.random.randint(jax.random.PRNGKey(51), (4, 5), 0, 64, jnp.int32)
    return params, moms, x, y


def trk(b0):
    """Constant [seq] bias track (the legacy per-step case)."""
    return jnp.full((ARCH.seq,), b0, jnp.int32)


def ref_loss(ps, x, y, variant, dp=2, b0s=None, masks=None, scales=None):
    """Mask-based reference. ``b0s`` are [seq] int32 bias *tracks* (one
    bias per timestep, matching the time-window manifest schema); masks
    are rebuilt per timestep so windowed tracks are covered too."""
    emb, cells, wsoft, bsoft = model._unpack_lstm(ps, 2)
    H = ARCH.hidden
    e = jnp.transpose(jnp.take(emb, x, axis=0), (1, 0, 2))
    hs = [jnp.zeros((4, H))] * 2
    cs = [jnp.zeros((4, H))] * 2
    tops = []
    if variant in ("rdp", "tdp"):
        trks = [np.asarray(b).reshape(-1) for b in b0s]
    for t in range(ARCH.seq):
        inp = e[t]
        for l, (wx, wh, bg) in enumerate(cells):
            win = inp
            wx_eff = wx
            s_extra = 1.0
            if l > 0:
                if variant == "rdp":
                    rm0 = patterns.row_mask(H, dp, int(trks[0][t])) * 2.0
                    win = inp * rm0
                elif variant == "conv":
                    win = inp * masks[0] * scales[0]
                elif variant == "tdp":
                    wx_eff = wx * patterns.tile_mask(
                        H, 4 * H, dp, int(trks[0][t]), ARCH.tile)
                    s_extra = 2.0
            gates = (win @ wx_eff) * s_extra + hs[l] @ wh + bg
            i_, f_, g_, o_ = jnp.split(gates, 4, -1)
            c2 = (jax.nn.sigmoid(f_ + 1.0) * cs[l]
                  + jax.nn.sigmoid(i_) * jnp.tanh(g_))
            h2 = jax.nn.sigmoid(o_) * jnp.tanh(c2)
            hs[l], cs[l] = h2, c2
            inp = h2
        tops.append(hs[1])
    flat = jnp.stack(tops).reshape(ARCH.seq * 4, H)
    if variant == "rdp":
        rm1 = jnp.concatenate(
            [jnp.broadcast_to(patterns.row_mask(H, dp, int(trks[1][t]))
                              * 2.0, (4, H)) for t in range(ARCH.seq)], 0)
        logits = (flat * rm1) @ wsoft + bsoft
    elif variant == "conv":
        mm = jnp.tile(masks[1], (ARCH.seq, 1))
        logits = (flat * mm * scales[1]) @ wsoft + bsoft
    elif variant == "tdp":
        ss = 2.0
        logits = jnp.concatenate(
            [(flat[4 * t: 4 * (t + 1)]
              @ (wsoft * patterns.tile_mask(H, ARCH.vocab, dp,
                                            int(trks[1][t]), ARCH.tile)))
             * ss for t in range(ARCH.seq)], 0) + bsoft
    else:
        logits = flat @ wsoft + bsoft
    targets = jnp.transpose(y, (1, 0)).reshape(ARCH.seq * 4)
    return model.softmax_xent(logits, targets)


@pytest.mark.parametrize("b0s", [(0, 1), (1, 0)])
def test_rdp_matches_masked_reference(setup, b0s):
    params, moms, x, y = setup
    n = len(params)
    lr = jnp.float32(0.1)
    b0s_j = [trk(b) for b in b0s]
    sc = [jnp.float32(2.0)] * 2
    out = model.lstm_train_step_rdp(ARCH, 2)(*params, *moms, x, y, *b0s_j,
                                             *sc, lr)
    (loss_r, corr_r), grads = jax.value_and_grad(
        lambda ps: ref_loss(ps, x, y, "rdp", 2, b0s_j), has_aux=True)(params)
    new_p, _ = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[2 * n], loss_r, rtol=1e-5, atol=1e-6)
    assert float(out[2 * n + 1]) == float(corr_r)
    for a, b in zip(out[:n], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_tdp_matches_masked_reference(setup):
    params, moms, x, y = setup
    n = len(params)
    lr = jnp.float32(0.1)
    b0s = [trk(1), trk(0)]
    sc = [jnp.float32(2.0)] * 2
    out = model.lstm_train_step_tdp(ARCH, 2)(*params, *moms, x, y, *b0s,
                                             *sc, lr)
    (loss_r, _), grads = jax.value_and_grad(
        lambda ps: ref_loss(ps, x, y, "tdp", 2, b0s), has_aux=True)(params)
    new_p, _ = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[2 * n], loss_r, rtol=1e-5, atol=1e-6)
    for a, b in zip(out[:n], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_rdp_windowed_track_matches_per_timestep_reference(setup):
    # Time-windowed draw: the bias changes mid-sequence (AD_TIME_WINDOW <
    # seq). The graph must apply each timestep's own kept-set in forward
    # AND backward — compared against a per-timestep masked reference.
    params, moms, x, y = setup
    n = len(params)
    lr = jnp.float32(0.1)
    trks = [jnp.array([0, 0, 1, 1, 0], jnp.int32),
            jnp.array([1, 0, 0, 1, 1], jnp.int32)]
    sc = [jnp.float32(2.0)] * 2
    out = model.lstm_train_step_rdp(ARCH, 2)(*params, *moms, x, y, *trks,
                                             *sc, lr)
    (loss_r, corr_r), grads = jax.value_and_grad(
        lambda ps: ref_loss(ps, x, y, "rdp", 2, trks), has_aux=True)(params)
    new_p, _ = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[2 * n], loss_r, rtol=1e-5, atol=1e-6)
    assert float(out[2 * n + 1]) == float(corr_r)
    for a, b in zip(out[:n], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_tdp_windowed_track_matches_per_timestep_reference(setup):
    params, moms, x, y = setup
    n = len(params)
    lr = jnp.float32(0.1)
    trks = [jnp.array([1, 1, 0, 0, 1], jnp.int32),
            jnp.array([0, 1, 1, 0, 0], jnp.int32)]
    sc = [jnp.float32(2.0)] * 2
    out = model.lstm_train_step_tdp(ARCH, 2)(*params, *moms, x, y, *trks,
                                             *sc, lr)
    (loss_r, _), grads = jax.value_and_grad(
        lambda ps: ref_loss(ps, x, y, "tdp", 2, trks), has_aux=True)(params)
    new_p, _ = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[2 * n], loss_r, rtol=1e-5, atol=1e-6)
    for a, b in zip(out[:n], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_conv_matches_reference(setup):
    params, moms, x, y = setup
    n = len(params)
    lr = jnp.float32(0.1)
    masks = [(jax.random.uniform(jax.random.PRNGKey(7 + i), (4, 32))
              > 0.5).astype(jnp.float32) for i in range(2)]
    scales = [jnp.float32(2.0)] * 2
    out = model.lstm_train_step_conv(ARCH)(*params, *moms, x, y, *masks,
                                           *scales, lr)
    (loss_r, _), grads = jax.value_and_grad(
        lambda ps: ref_loss(ps, x, y, "conv", masks=masks, scales=scales),
        has_aux=True)(params)
    new_p, _ = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[2 * n], loss_r, rtol=1e-5, atol=1e-6)
    for a, b in zip(out[:n], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_eval_matches_reference(setup):
    params, _, x, y = setup
    n = len(params)
    loss_e, corr_e = model.lstm_eval(ARCH)(*params, x, y)
    loss_r, corr_r = ref_loss(params, x, y, "eval")
    np.testing.assert_allclose(loss_e, loss_r, rtol=1e-5)
    assert float(corr_e) == float(corr_r)


def test_recurrent_weights_fully_trained_under_rdp(setup):
    # RDP drops only non-recurrent connections: the recurrent kernels wh
    # must receive gradient through every unit.
    params, moms, x, y = setup
    n = len(params)
    out = model.lstm_train_step_rdp(ARCH, 2)(
        *params, *moms, x, y, trk(0), trk(0), jnp.float32(2.0),
        jnp.float32(2.0), jnp.float32(0.1))
    wh0_before = params[2]  # wx0, wh0 order: emb, wx0, wh0, bg0, ...
    wh0_after = out[2]
    changed = np.mean(np.asarray(wh0_before) != np.asarray(wh0_after))
    assert changed > 0.95, f"only {changed:.0%} of wh0 updated"


def test_three_layer_arch_builds_and_steps():
    arch3 = model.LstmArch(vocab=64, hidden=32, layers=3, seq=4, batch=2,
                           tile=16)
    specs = model.lstm_param_specs(arch3)
    assert len(specs) == 1 + 3 * 3 + 2
    params = [jax.random.normal(jax.random.PRNGKey(i), s) * 0.1
              for i, (_, s) in enumerate(specs)]
    moms = [jnp.zeros(s) for _, s in specs]
    x = jnp.zeros((2, 4), jnp.int32)
    y = jnp.ones((2, 4), jnp.int32)
    t4 = lambda b: jnp.full((4,), b, jnp.int32)
    out = model.lstm_train_step_rdp(arch3, 2)(
        *params, *moms, x, y, t4(0), t4(1), t4(0),
        jnp.float32(2.0), jnp.float32(2.0), jnp.float32(2.0),
        jnp.float32(0.1))
    assert np.isfinite(float(out[2 * len(params)]))
