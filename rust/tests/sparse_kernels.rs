//! Property tests of the structured-sparse kernel library: for randomized
//! shapes, skip-lists, and tilings, every sparse kernel equals the dense
//! kernel applied to the correspondingly *masked* operands — the contract
//! that lets one step program (`runtime::step`) run on either backend.
//!
//! Tolerances: the sparse kernels accumulate the shared dimension in the
//! same ascending order as the dense loops and only skip exactly-zero
//! contributions, so most comparisons here are `assert_eq` (bitwise), not
//! epsilon checks.

use approx_dropout::patterns::{RowPattern, TilePattern};
use approx_dropout::runtime::{DenseKernels, Kernels, Skip, SparseKernels};
use approx_dropout::util::rng::Rng;
use approx_dropout::util::testkit::{self, gen_choice, gen_range,
                                    gen_vec_f32};

const D: Skip = Skip::Dense;

/// Zero the columns of `a [m,k]` that `pat` drops (the structural
/// precondition the step program guarantees for masked activations).
fn mask_cols(a: &mut [f32], m: usize, k: usize, pat: &RowPattern) {
    for i in 0..m {
        for p in 0..k {
            if !pat.keeps(p) {
                a[i * k + p] = 0.0;
            }
        }
    }
}

/// `w ∘ mask` for a tile pattern.
fn mask_tiles(w: &[f32], pat: &TilePattern) -> Vec<f32> {
    w.iter().zip(pat.mask()).map(|(&x, m)| x * m).collect()
}

/// Random tile-pattern weight dims valid for dp in {2, 4} at tile 16.
fn gen_tile_dims(rng: &mut Rng) -> (usize, usize) {
    *gen_choice(rng, &[(32usize, 64usize), (64, 32), (64, 64), (32, 128),
                       (128, 32)])
}

#[test]
fn gemm_row_skip_equals_dense_on_masked_activations() {
    testkit::quickcheck("gemm row-skip", |rng| {
        let m = gen_range(rng, 1, 12);
        let dp = *gen_choice(rng, &[1usize, 2, 3, 4]);
        let k = dp * gen_range(rng, 1, 20);
        let n = gen_range(rng, 1, 40);
        let b0 = gen_range(rng, 0, dp);
        let pat = RowPattern::new(k, dp, b0);
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        mask_cols(&mut a, m, k, &pat);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let got = SparseKernels.gemm(&a, &b, m, k, n, &Skip::Rows(pat),
                                     &D);
        let want = DenseKernels.gemm(&a, &b, m, k, n, &D, &D);
        assert_eq!(got, want, "m={m} k={k} n={n} dp={dp} b0={b0}");
    });
}

#[test]
fn gemm_tile_skip_equals_dense_on_masked_weight() {
    testkit::quickcheck("gemm tile-skip", |rng| {
        let m = gen_range(rng, 1, 10);
        let (k, n) = gen_tile_dims(rng);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let b0 = gen_range(rng, 0, dp);
        let pat = TilePattern::new(k, n, dp, b0, 16);
        let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let w = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let skip = Skip::Tiles(pat);
        // Dense kernels require the prepared (masked) weight; sparse
        // kernels take the raw one — that asymmetry IS the contract.
        let wm = DenseKernels.prep_weight(&w, k, n, &skip).unwrap();
        assert_eq!(wm, mask_tiles(&w, &pat));
        assert!(SparseKernels.prep_weight(&w, k, n, &skip).is_none());
        let got = SparseKernels.gemm(&a, &w, m, k, n, &skip, &D);
        let want = DenseKernels.gemm(&a, &wm, m, k, n, &skip, &D);
        assert_eq!(got, want, "k={k} n={n} dp={dp} b0={b0}");
    });
}

#[test]
fn gemm_out_skip_computes_kept_columns_only() {
    testkit::quickcheck("gemm out-skip", |rng| {
        let m = gen_range(rng, 1, 10);
        let k = gen_range(rng, 1, 30);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let n = dp * gen_range(rng, 1, 12);
        let b0 = gen_range(rng, 0, dp);
        let q = RowPattern::new(n, dp, b0);
        let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let got = SparseKernels.gemm(&a, &b, m, k, n, &D, &Skip::Rows(q));
        let full = DenseKernels.gemm(&a, &b, m, k, n, &D, &D);
        for i in 0..m {
            for j in 0..n {
                if q.keeps(j) {
                    assert_eq!(got[i * n + j], full[i * n + j],
                               "kept ({i},{j})");
                } else {
                    assert_eq!(got[i * n + j], 0.0, "dropped ({i},{j})");
                }
            }
        }
    });
}

#[test]
fn gemm_nt_row_and_tile_skips_match_dense() {
    testkit::quickcheck("gemm_nt skips", |rng| {
        // Rows: output columns restricted.
        let m = gen_range(rng, 1, 10);
        let n = gen_range(rng, 1, 30);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let k = dp * gen_range(rng, 1, 10);
        let b0 = gen_range(rng, 0, dp);
        let q = RowPattern::new(k, dp, b0);
        let a = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let got = SparseKernels.gemm_nt(&a, &b, m, n, k, &Skip::Rows(q));
        let full = DenseKernels.gemm_nt(&a, &b, m, n, k, &D);
        for i in 0..m {
            for j in 0..k {
                if q.keeps(j) {
                    assert_eq!(got[i * k + j], full[i * k + j]);
                } else {
                    assert_eq!(got[i * k + j], 0.0);
                }
            }
        }

        // Tiles: B tile-masked.
        let (tk2, tn2) = gen_tile_dims(rng);
        let pat = TilePattern::new(tk2, tn2, dp, b0, 16);
        let a2 = gen_vec_f32(rng, m * tn2, -1.0, 1.0);
        let w = gen_vec_f32(rng, tk2 * tn2, -1.0, 1.0);
        let got = SparseKernels.gemm_nt(&a2, &w, m, tn2, tk2,
                                        &Skip::Tiles(pat));
        let want = DenseKernels.gemm_nt(&a2, &mask_tiles(&w, &pat), m,
                                        tn2, tk2, &D);
        for (i, (&x, &y)) in got.iter().zip(&want).enumerate() {
            assert!((x - y).abs() <= 1e-6 * x.abs().max(y.abs()).max(1.0),
                    "nt tiles elem {i}: {x} vs {y}");
        }
    });
}

#[test]
fn gemm_tn_acc_freezes_dropped_rows_cols_and_tiles() {
    testkit::quickcheck("gemm_tn_acc skips", |rng| {
        let m = gen_range(rng, 1, 10);
        let dpr = *gen_choice(rng, &[2usize, 4]);
        let dpc = *gen_choice(rng, &[1usize, 2]);
        let k = dpr * gen_range(rng, 1, 10);
        let n = dpc * gen_range(rng, 1, 15);
        let pr = RowPattern::new(k, dpr, gen_range(rng, 0, dpr));
        let qc = RowPattern::new(n, dpc, gen_range(rng, 0, dpc));
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        mask_cols(&mut a, m, k, &pr);
        let mut b = gen_vec_f32(rng, m * n, -1.0, 1.0);
        mask_cols(&mut b, m, n, &qc);
        let prior = 0.25f32;
        let mut got = vec![prior; k * n];
        SparseKernels.gemm_tn_acc(&a, &b, m, k, n, &Skip::Rows(pr),
                                  &Skip::Rows(qc), &mut got);
        let mut want = vec![prior; k * n];
        DenseKernels.gemm_tn_acc(&a, &b, m, k, n, &D, &D, &mut want);
        assert_eq!(got, want);
        // Dropped gradient rows keep their prior value bit-for-bit (the
        // momentum/param freeze invariant).
        for p in 0..k {
            if !pr.keeps(p) {
                for j in 0..n {
                    assert_eq!(got[p * n + j], prior);
                }
            }
        }
    });
}

#[test]
fn gemm_tn_acc_tiles_matches_dense_masked_accumulation() {
    testkit::quickcheck("gemm_tn_acc tiles", |rng| {
        let m = gen_range(rng, 1, 8);
        let (k, n) = gen_tile_dims(rng);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let b0 = gen_range(rng, 0, dp);
        let pat = TilePattern::new(k, n, dp, b0, 16);
        let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let b = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let skip = Skip::Tiles(pat);
        let mut got = vec![1.5f32; k * n];
        SparseKernels.gemm_tn_acc(&a, &b, m, k, n, &skip, &D, &mut got);
        let mut want = vec![1.5f32; k * n];
        DenseKernels.gemm_tn_acc(&a, &b, m, k, n, &skip, &D, &mut want);
        assert_eq!(got, want);
        let (gk, gn) = pat.grid();
        for r in 0..gk {
            for c in 0..gn {
                if !pat.keeps_tile(r, c) {
                    let v = got[(r * pat.tr) * n + c * pat.tc];
                    assert_eq!(v, 1.5, "dropped tile ({r},{c})");
                }
            }
        }
    });
}

#[test]
fn gemv_is_the_single_row_gemm() {
    testkit::quickcheck("gemv", |rng| {
        let dp = *gen_choice(rng, &[1usize, 2, 4]);
        let k = dp * gen_range(rng, 1, 16);
        let n = gen_range(rng, 1, 40);
        let pat = RowPattern::new(k, dp, gen_range(rng, 0, dp));
        let mut x = gen_vec_f32(rng, k, -1.0, 1.0);
        mask_cols(&mut x, 1, k, &pat);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let skip = Skip::Rows(pat);
        let got = SparseKernels.gemv(&x, &b, k, n, &skip, &D);
        let want = DenseKernels.gemm(&x, &b, 1, k, n, &D, &D);
        assert_eq!(got, want);
    });
}

/// Large-enough shapes to actually cross the kernels' parallel threshold
/// (the quickcheck shapes above mostly run inline): exercises the worker
/// pool path end-to-end and re-checks dense parity there.
#[test]
fn parallel_path_matches_dense() {
    let mut rng = Rng::new(1234);
    let (m, k, n) = (64, 256, 192);
    let pat = RowPattern::new(k, 2, 1);
    let mut a = gen_vec_f32(&mut rng, m * k, -1.0, 1.0);
    mask_cols(&mut a, m, k, &pat);
    let b = gen_vec_f32(&mut rng, k * n, -1.0, 1.0);
    let got = SparseKernels.gemm(&a, &b, m, k, n, &Skip::Rows(pat), &D);
    let want = DenseKernels.gemm(&a, &b, m, k, n, &D, &D);
    assert_eq!(got, want);

    let b2 = gen_vec_f32(&mut rng, m * n, -1.0, 1.0);
    let mut got = vec![0f32; k * n];
    SparseKernels.gemm_tn_acc(&a, &b2, m, k, n, &Skip::Rows(pat), &D,
                              &mut got);
    let mut want = vec![0f32; k * n];
    DenseKernels.gemm_tn_acc(&a, &b2, m, k, n, &D, &D, &mut want);
    assert_eq!(got, want);
}
