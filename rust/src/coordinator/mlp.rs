//! MLP training coordinator (paper sections IV-A/B).
//!
//! Per iteration: sample the dropout pattern for each hidden layer from the
//! schedule, pick the matching AOT executable (`<tag>_rdp_<dp1>_<dp2>` ...),
//! assemble the input list per the manifest calling convention, execute,
//! and absorb the updated state. The conventional baseline follows the
//! identical loop but generates Bernoulli masks instead of bias scalars —
//! wall-clock comparisons therefore measure exactly the paper's quantity.

use anyhow::{bail, Result};

use crate::coordinator::metrics::TrainMetrics;
use crate::coordinator::pool::ExecutorPool;
use crate::coordinator::schedule::{Schedule, Variant};
use crate::data::{MnistBatcher, MnistSyn};
use crate::patterns::MaskGen;
use crate::runtime::state::{lit_f32, lit_i32, lit_scalar_f32,
                            lit_scalar_i32};
use crate::runtime::{ArchMeta, Engine, Manifest, TrainState};
use crate::util::rng::Rng;
use crate::util::Timer;

pub struct MlpTrainer<'e> {
    pool: ExecutorPool<'e>,
    pub tag: String,
    pub schedule: Schedule,
    pub state: TrainState,
    pub metrics: TrainMetrics,
    pub lr: f32,
    batcher: MnistBatcher,
    hidden: Vec<usize>,
    batch: usize,
    rng: Rng,
    maskgen: Vec<MaskGen>,
}

impl<'e> MlpTrainer<'e> {
    pub fn new(engine: &'e Engine, manifest: &'e Manifest, tag: &str,
               schedule: Schedule, n_train: usize, lr: f32, seed: u64)
               -> Result<MlpTrainer<'e>> {
        let conv = manifest.get(&format!("{tag}_conv"))?;
        let (hidden, batch) = match &conv.arch {
            ArchMeta::Mlp { hidden, batch, .. } =>
                (hidden.clone(), *batch),
            _ => bail!("artifact {tag} is not an MLP"),
        };
        if schedule.sites() != hidden.len() {
            bail!("schedule has {} sites, MLP has {} hidden layers",
                  schedule.sites(), hidden.len());
        }
        let mut rng = Rng::new(seed);
        let state = TrainState::init(conv, &mut rng);
        let maskgen = (0..hidden.len()).map(|_| MaskGen::new()).collect();
        Ok(MlpTrainer {
            pool: ExecutorPool::new(engine, manifest),
            tag: tag.to_string(),
            schedule,
            state,
            metrics: TrainMetrics::default(),
            lr,
            batcher: MnistBatcher::new(n_train, batch),
            hidden,
            batch,
            rng,
            maskgen,
        })
    }

    /// Pre-compile every executable the schedule can dispatch to, so the
    /// timed loop measures steady-state iteration cost only.
    pub fn warmup(&mut self) -> Result<()> {
        let names = self.executable_names();
        self.pool.warm(&names)
    }

    pub fn executable_names(&self) -> Vec<String> {
        match self.schedule.variant {
            Variant::Conv => vec![format!("{}_conv", self.tag)],
            v => self
                .schedule
                .dp_combos()
                .iter()
                .map(|dp| Manifest::artifact_name(&self.tag, v.as_str(), dp))
                .collect(),
        }
    }

    /// One full training iteration; returns (loss, batch accuracy).
    /// Hot path: all inputs are assembled as XLA literals directly and the
    /// parameter state stays literal-resident (see runtime::state).
    pub fn step(&mut self, data: &MnistSyn) -> Result<(f64, f64)> {
        let t = Timer::start();
        let choices = self.schedule.sample(&mut self.rng);
        let (x, y) = self.batcher.next_batch(data, &mut self.rng);

        let mut tail: Vec<xla::Literal> = Vec::with_capacity(8);
        tail.push(lit_f32(&[self.batch, x.len() / self.batch], x)?);
        tail.push(lit_i32(&[self.batch], y)?);

        let name = match self.schedule.variant {
            Variant::Conv => {
                // Bernoulli masks + inverted-dropout scales per site.
                for (site, rate) in
                    self.schedule.rates.clone().iter().enumerate()
                {
                    let keep = 1.0 - rate;
                    let w = self.hidden[site];
                    let m = self.maskgen[site]
                        .fill(&mut self.rng, keep, self.batch * w);
                    tail.push(lit_f32(&[self.batch, w], m)?);
                }
                for rate in &self.schedule.rates {
                    tail.push(lit_scalar_f32((1.0 / (1.0 - rate)) as f32));
                }
                format!("{}_conv", self.tag)
            }
            v => {
                for c in &choices {
                    tail.push(lit_scalar_i32(c.b0 as i32));
                }
                // Inverted-dropout correction: constant 1/(1-p) of the
                // site's long-run rate (Caffe semantics), NOT the
                // per-iteration 1/dp — see model.py _mlp_logits_rdp.
                for rate in &self.schedule.rates {
                    tail.push(lit_scalar_f32((1.0 / (1.0 - rate)) as f32));
                }
                let dp: Vec<usize> = choices.iter().map(|c| c.dp).collect();
                Manifest::artifact_name(&self.tag, v.as_str(), &dp)
            }
        };
        tail.push(lit_scalar_f32(self.lr));

        let exe = self.pool.get(&name)?;
        let (loss, correct) = self.state.step(exe, &tail)?;
        self.metrics.record(self.state.step, loss, correct, self.batch,
                            t.elapsed_s());
        Ok((loss, correct / self.batch as f64))
    }

    /// Run `n` steps; returns mean loss over the window.
    pub fn train(&mut self, data: &MnistSyn, n: usize) -> Result<f64> {
        let mut sum = 0.0;
        for _ in 0..n {
            sum += self.step(data)?.0;
        }
        Ok(sum / n.max(1) as f64)
    }

    /// Evaluate on a test set through the dropout-free eval graph; returns
    /// (mean loss, accuracy).
    pub fn evaluate(&mut self, test: &MnistSyn) -> Result<(f64, f64)> {
        let name = format!("{}_eval", self.tag);
        let n_in: usize = {
            let exe = self.pool.get(&name)?;
            match &exe.meta.arch {
                ArchMeta::Mlp { n_in, .. } => *n_in,
                _ => bail!("not an mlp eval graph"),
            }
        };
        let mut total_loss = 0.0;
        let mut total_correct = 0.0;
        let mut batches = 0.0;
        let full = test.n / self.batch;
        for bi in 0..full {
            let mut x = Vec::with_capacity(self.batch * n_in);
            let mut y = Vec::with_capacity(self.batch);
            for i in bi * self.batch..(bi + 1) * self.batch {
                x.extend_from_slice(test.image(i));
                y.push(test.labels[i] as i32);
            }
            let x_l = lit_f32(&[self.batch, n_in], &x)?;
            let y_l = lit_i32(&[self.batch], &y)?;
            let mut refs = self.state.param_refs();
            refs.push(&x_l);
            refs.push(&y_l);
            let exe = self.pool.get(&name)?;
            let out = exe.run_raw(&refs)?;
            total_loss += out[0].get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("loss: {e:?}"))? as f64;
            total_correct += out[1].get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("correct: {e:?}"))? as f64;
            batches += 1.0;
        }
        Ok((total_loss / batches,
            total_correct / (batches * self.batch as f64)))
    }
}
