//! Fig. 6(a) — Row approximate dropout on the 3-layer LSTM over the
//! PTB-like corpus: speedup and perplexity across dropout rates.
//!
//! Paper shape to reproduce: speedup rises 1.24 -> 1.85 as the rate goes
//! 0.3 -> 0.7 while test perplexity stays within ~0.05 of the baseline.

use approx_dropout::bench::drivers::{fmt_opt_ppl, run_lstm, BenchCtx};
use approx_dropout::bench::{fmt_time, Table};
use approx_dropout::coordinator::{speedup, Variant};
use approx_dropout::data::Corpus;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new()?;
    let tag = "lstm3x512v10240b20";
    println!("== Fig 6a: {tag} (PTB-syn), RDP rate sweep, {} timed \
              steps/config ==", ctx.timed_steps);
    let corpus = Corpus::generate(10_240, 200_000, 20_000, 20_000, 13);

    let mut table = Table::new(&["rate", "conv step", "RDP step", "speedup",
                                 "conv ppl", "RDP ppl"]);
    for &r in &[0.3, 0.5, 0.7] {
        let (t_conv, q_conv) = run_lstm(&ctx, tag, Variant::Conv, r, 3,
                                        &corpus, 0.1, 42)?;
        let (t_rdp, q_rdp) = run_lstm(&ctx, tag, Variant::Rdp, r, 3,
                                      &corpus, 0.1, 42)?;
        table.row(&[format!("{r}"), fmt_time(t_conv), fmt_time(t_rdp),
                    format!("{:.2}x", speedup(t_conv, t_rdp)),
                    fmt_opt_ppl(q_conv), fmt_opt_ppl(q_rdp)]);
        println!("  rate {r}: {:.2}x", speedup(t_conv, t_rdp));
    }
    println!();
    table.print();
    println!("\npaper: speedup 1.24/~1.5/1.85 at rates 0.3/0.5/0.7; test \
              perplexity +0.04 at rate 0.7 (AD_BENCH_TRAIN_STEPS>0 adds \
              perplexity columns)");
    Ok(())
}
