"""Tile-sparse (TDP) kernel vs oracle and vs the dense tile-mask model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import patterns
from compile.kernels import ref, tile_sparse_matmul


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


CASES = [
    # (K, N, dp) — covers dp | tn, dp | tk, and adapted tile edges (784).
    (128, 64, 2),
    (128, 64, 4),
    (256, 64, 8),
    (96, 128, 4),
    (784, 64, 4),
]


@pytest.mark.parametrize("k,n,dp", CASES)
def test_forward_matches_oracle_and_dense_mask(k, n, dp):
    x = rand(0, (8, k))
    w = rand(1, (k, n))
    for b0v in range(dp):
        b0 = jnp.int32(b0v)
        rows, cols = patterns.tile_kept_rc(k, n, dp, b0)
        wt = patterns.gather_tiles(w, rows, cols)
        out = tile_sparse_matmul(x, wt, rows, cols, n)
        np.testing.assert_allclose(
            out, ref.tile_sparse_matmul_ref(x, wt, rows, cols, n),
            rtol=1e-4, atol=1e-4)
        dense = w * patterns.tile_mask(k, n, dp, b0)
        np.testing.assert_allclose(out, x @ dense, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("k,n,dp", [(128, 64, 2), (256, 64, 8),
                                    (784, 64, 4)])
def test_gradients_match_oracle(k, n, dp):
    x = rand(2, (4, k))
    w = rand(3, (k, n))
    b0 = jnp.int32(dp - 1)
    rows, cols = patterns.tile_kept_rc(k, n, dp, b0)
    wt = patterns.gather_tiles(w, rows, cols)

    def f_k(x, wt):
        return jnp.sum(jnp.tanh(tile_sparse_matmul(x, wt, rows, cols, n)))

    def f_r(x, wt):
        return jnp.sum(jnp.tanh(
            ref.tile_sparse_matmul_ref(x, wt, rows, cols, n)))

    gk = jax.grad(f_k, argnums=(0, 1))(x, wt)
    gr = jax.grad(f_r, argnums=(0, 1))(x, wt)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_dp1_is_full_matmul():
    x = rand(4, (8, 64))
    w = rand(5, (64, 64))
    rows, cols = patterns.tile_kept_rc(64, 64, 1, jnp.int32(0))
    wt = patterns.gather_tiles(w, rows, cols)
    out = tile_sparse_matmul(x, wt, rows, cols, 64)
    np.testing.assert_allclose(out, x @ w, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(dp=st.sampled_from([2, 4]), b0v=st.integers(0, 3),
       seed=st.integers(0, 2**12))
def test_property_output_energy_scales_down(dp, b0v, seed):
    # Dropping (dp-1)/dp of tiles must cut output Frobenius mass vs the
    # full matmul (statistically; random gaussian weights).
    if b0v >= dp:
        b0v %= dp
    k = n = 128
    x = rand(seed, (8, k))
    w = rand(seed + 1, (k, n))
    rows, cols = patterns.tile_kept_rc(k, n, dp, jnp.int32(b0v))
    wt = patterns.gather_tiles(w, rows, cols)
    out = tile_sparse_matmul(x, wt, rows, cols, n)
    full = x @ w
    assert jnp.linalg.norm(out) < jnp.linalg.norm(full) * 1.05


def test_unsupported_dp_raises():
    with pytest.raises(ValueError):
        patterns.tile_kept_count(96, 64, 8)  # grid 3x2, 8 divides neither
