//! The element-math contract of the step interpreter: a [`Kernels`]
//! implementation supplies every heavy matrix operation a train/eval step
//! performs, together with the *structural sparsity* ([`Skip`]) of each
//! operand, so one shared step program (`runtime::step`) can run as
//! masked-dense math (reference backend) or as row-/tile-skipping compact
//! math (sparse backend) without duplicating the model semantics.
//!
//! ## The Skip contract
//!
//! A [`Skip`] describes zeros that are *known before the kernel runs*
//! because they come from a regular dropout pattern (paper section III),
//! not from data. Implementations may exploit the structure (never load or
//! multiply the dropped coordinates) or ignore it (compute masked-dense) —
//! both must produce the same value on every coordinate a caller can
//! observe:
//!
//! * `Skip::Dense` — no structure; plain dense math.
//! * `Skip::Rows(p)` — a [`crate::patterns::RowPattern`] over one index
//!   axis. The meaning
//!   per position is documented on each method; in every case coordinates
//!   outside the kept set `{b0 + dp*j}` are exactly zero in the operand
//!   (inputs) or may be left exactly zero (outputs, which callers mask or
//!   never read downstream).
//! * `Skip::Tiles(t)` — a [`crate::patterns::TilePattern`] over a
//!   `[k, n]` weight matrix:
//!   the weight is tile-masked. Kernels that exploit the structure receive
//!   the **raw** weight and must not touch dropped tiles; kernels that
//!   don't are given the pre-masked weight by [`Kernels::prep_weight`].
//!
//! Exact-zero skipping is value-preserving: the dense path accumulates the
//! dropped coordinates as `acc += x * 0.0`, an exact no-op in IEEE f32 (up
//! to the sign of a zero total). With scalar microkernels
//! (`AD_SIMD=off`) the sparse implementation accumulates the shared
//! dimension in the same ascending order as the dense loops, so
//! reference and sparse agree far tighter than the 1e-5 relative
//! tolerance the parity suite (`rust/tests/hermetic.rs`) enforces; the
//! SIMD microkernels (fused multiply-add, fixed-order lane reductions —
//! see `runtime::sparse::simd`) stay within that same 1e-5 contract.

// `Skip` (and its structured kept-set view `Kept`) moved to the
// sparsity-plan IR — the one module that decides structure. Re-exported
// here so the kernel contract's long-standing import path keeps working.
pub use crate::runtime::plan::{Kept, Skip};
use crate::runtime::plan::{GemmNode, NtNode, TnNode};

/// The element math of one execution backend. All matrices are row-major
/// f32; shapes are trusted (`debug_assert`ed, validated upstream by the
/// manifest `check`).
pub trait Kernels: Send + Sync + std::fmt::Debug {
    /// Short name for logs/diagnostics ("dense" | "sparse").
    fn name(&self) -> &'static str;

    /// `C[m,n] = A[m,k] @ B[k,n]`.
    ///
    /// * `k_skip` — structure along the shared dim: `Rows(p)` means A's
    ///   columns outside `p` are exactly zero (masked activations);
    ///   `Tiles(t)` means B is tile-masked (pass B through
    ///   [`Self::prep_weight`] first).
    /// * `out_skip` — `Rows(q)`: output columns outside `q` may be left
    ///   exactly zero (the caller masks them before any further use).
    ///   Never `Tiles`.
    fn gemm(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
            k_skip: &Skip, out_skip: &Skip) -> Vec<f32>;

    /// `C[m,k] = A[m,n] @ B[k,n]^T`.
    ///
    /// * `skip` — `Rows(q)`: output columns (the k axis) outside `q` may
    ///   be left exactly zero; `Tiles(t)`: B is tile-masked over `[k,n]`
    ///   (prepared weight for non-exploiting kernels).
    fn gemm_nt(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize,
               skip: &Skip) -> Vec<f32>;

    /// `C[k,n] += A[m,k]^T @ B[m,n]` (gradient accumulation).
    ///
    /// * `row_skip` — `Rows(p)`: A's columns (C's rows) outside `p` are
    ///   exactly zero — dropped gradient rows receive no accumulation,
    ///   the bit-freeze invariant the hermetic suite pins. `Tiles(t)`:
    ///   only C's kept tiles receive accumulation.
    /// * `col_skip` — `Rows(q)`: B's columns (C's columns) outside `q`
    ///   are exactly zero. Never `Tiles`.
    fn gemm_tn_acc(&self, a: &[f32], b: &[f32], m: usize, k: usize,
                   n: usize, row_skip: &Skip, col_skip: &Skip,
                   out: &mut [f32]);

    /// Allocating wrapper over [`Self::gemm_tn_acc`].
    fn gemm_tn(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
               row_skip: &Skip, col_skip: &Skip) -> Vec<f32> {
        let mut out = vec![0f32; k * n];
        self.gemm_tn_acc(a, b, m, k, n, row_skip, col_skip, &mut out);
        out
    }

    /// `y[n] = x[k] @ B[k,n]` — the GEMV (single-row) entry point; same
    /// skip contract as [`Self::gemm`] with `m == 1`.
    fn gemv(&self, x: &[f32], b: &[f32], k: usize, n: usize,
            k_skip: &Skip, out_skip: &Skip) -> Vec<f32> {
        self.gemm(x, b, 1, k, n, k_skip, out_skip)
    }

    /// Prepare a `[k, n]` weight for repeated GEMMs under `skip`:
    /// implementations that compute masked-dense return the materialized
    /// `w ∘ mask` (`Some`), structure-exploiting implementations return
    /// `None` (use the raw weight; their loops never read dropped tiles).
    /// `Dense`/`Rows` skips never need preparation.
    fn prep_weight(&self, w: &[f32], k: usize, n: usize, skip: &Skip)
                   -> Option<Vec<f32>>;

    /// Prepare a reusable [`PreppedWeight`] handle for a `[k, n]` weight
    /// that will serve *many* GEMMs under the same `skip` (one time
    /// window of an unrolled sequence: forward, backward, and the softmax
    /// projection all hit the same preparation). The handle is valid only
    /// while the weight bits are unchanged — SGD invalidates it, so it
    /// never outlives one step.
    ///
    /// Contract: `Skip::Dense` MUST be an allocation-free no-op
    /// ([`PreppedWeight::dense`]), so callers can prep unconditionally.
    /// The default covers masked-dense implementations by delegating to
    /// [`Self::prep_weight`]; structure-exploiting implementations
    /// override it to cache kept sets / packed panels.
    fn prep(&self, w: &[f32], k: usize, n: usize, skip: &Skip)
            -> PreppedWeight {
        match skip {
            Skip::Dense => PreppedWeight::dense(),
            _ => PreppedWeight::masked(self.prep_weight(w, k, n, skip)),
        }
    }

    /// [`Self::gemm`] against a prepared weight. `w` is the raw weight
    /// the handle was prepared from (handles don't carry it — passing it
    /// explicitly keeps the borrow story trivial). Implementations may
    /// hit packed panels when the skip shape allows; the result must be
    /// bit-identical to `gemm` over the same skips.
    fn gemm_pw(&self, a: &[f32], w: &[f32], pw: &PreppedWeight, m: usize,
               k: usize, n: usize, k_skip: &Skip, out_skip: &Skip)
               -> Vec<f32> {
        self.gemm(a, pw.weight(w), m, k, n, k_skip, out_skip)
    }

    /// [`Self::gemm_nt`] against a prepared weight (same contract as
    /// [`Self::gemm_pw`]).
    fn gemm_nt_pw(&self, a: &[f32], w: &[f32], pw: &PreppedWeight,
                  m: usize, n: usize, k: usize, skip: &Skip) -> Vec<f32> {
        self.gemm_nt(a, pw.weight(w), m, n, k, skip)
    }

    // -- Plan-node entry points -------------------------------------------
    //
    // The step interpreter routes every GEMM through these; the node
    // carries the full static structure plus any dynamic mask. The
    // defaults dispatch to the raw/prepped methods above and IGNORE the
    // dynamic fields, so masked-dense implementations (DenseKernels, and
    // any future backend that opts out) are bit- and dispatch-identical
    // to the pre-plan code by construction. Structure-exploiting
    // implementations override these to honor the dynamic masks under
    // the exactness contract documented on `plan::DynMask`.

    /// Whether this implementation honors dynamic masks on plan nodes.
    /// When `false` the step interpreter skips building them entirely
    /// (no scans), keeping the dense/reference path untouched.
    fn dyn_backward(&self) -> bool {
        false
    }

    /// Forward GEMM of a plan node: [`Self::gemm_pw`] when the node
    /// carries a prepared weight, [`Self::gemm`] otherwise.
    fn gemm_node(&self, a: &[f32], w: &[f32], node: &GemmNode, m: usize,
                 k: usize, n: usize) -> Vec<f32> {
        match node.pw {
            Some(pw) => self.gemm_pw(a, w, pw, m, k, n, &node.k_skip,
                                     &node.out_skip),
            None => self.gemm(a, w, m, k, n, &node.k_skip,
                              &node.out_skip),
        }
    }

    /// Backward input-gradient GEMM of a plan node (`dyn_cols` ignored
    /// by default).
    fn gemm_nt_node(&self, a: &[f32], w: &[f32], node: &NtNode, m: usize,
                    n: usize, k: usize) -> Vec<f32> {
        match node.pw {
            Some(pw) => self.gemm_nt_pw(a, w, pw, m, n, k, &node.skip),
            None => self.gemm_nt(a, w, m, n, k, &node.skip),
        }
    }

    /// Weight-gradient accumulation of a plan node (`dyn_rows` ignored
    /// by default).
    fn gemm_tn_acc_node(&self, a: &[f32], b: &[f32], node: &TnNode,
                        m: usize, k: usize, n: usize, out: &mut [f32]) {
        self.gemm_tn_acc(a, b, m, k, n, &node.row_skip, &node.col_skip,
                         out);
    }

    /// Allocating wrapper over [`Self::gemm_tn_acc_node`].
    fn gemm_tn_node(&self, a: &[f32], b: &[f32], node: &TnNode, m: usize,
                    k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0f32; k * n];
        self.gemm_tn_acc_node(a, b, node, m, k, n, &mut out);
        out
    }
}

/// A weight prepared once per (site, window) and reused across every GEMM
/// in the window (tentpole (c) of the time-window work). What it holds
/// depends on the backend and skip:
///
/// * masked-dense backends under `Tiles` → `masked` (`w ∘ mask`);
/// * structure-exploiting backends under `Rows` → `kept` + `panel`
///   (kept-row indices and the packed `[kept.len(), n]` row panel);
/// * everything else → empty (use the raw weight), and `Skip::Dense`
///   preparation is an allocation-free no-op by contract.
#[derive(Clone, Debug, Default)]
pub struct PreppedWeight {
    masked: Option<Vec<f32>>,
    /// Kept indices along the k axis, ascending.
    pub kept: Option<Vec<usize>>,
    /// Packed kept rows of the weight, `[kept.len(), n]`, aligned with
    /// `kept` (row `pi` of the panel is weight row `kept[pi]`).
    pub panel: Option<Vec<f32>>,
}

impl PreppedWeight {
    /// The no-op preparation: every accessor falls through to the raw
    /// weight. No allocation.
    pub fn dense() -> PreppedWeight {
        PreppedWeight::default()
    }

    /// Wrap a [`Kernels::prep_weight`] result (masked-dense backends).
    pub fn masked(masked: Option<Vec<f32>>) -> PreppedWeight {
        PreppedWeight { masked, kept: None, panel: None }
    }

    /// A packed kept-row panel (structure-exploiting backends under
    /// `Rows` skips): `panel` must hold `kept.len()` rows of `n` floats,
    /// row `pi` being weight row `kept[pi]`.
    pub fn packed(kept: Vec<usize>, panel: Vec<f32>) -> PreppedWeight {
        PreppedWeight { masked: None, kept: Some(kept),
                        panel: Some(panel) }
    }

    /// The weight view plain `gemm`/`gemm_nt` should run against: the
    /// masked copy when one was materialized, else the raw weight.
    pub fn weight<'a>(&'a self, raw: &'a [f32]) -> &'a [f32] {
        self.masked.as_deref().unwrap_or(raw)
    }

    /// True when this handle carries a packed kept-row panel.
    pub fn has_panel(&self) -> bool {
        self.kept.is_some() && self.panel.is_some()
    }
}

// ---------------------------------------------------------------------------
// DenseKernels: the reference backend's masked-dense loops
// ---------------------------------------------------------------------------

/// The reference element math: exactly the scalar loops the pure-Rust
/// interpreter has always used. `Rows` skips are ignored (the structural
/// zeros in the operands already produce the right result — and the inner
/// loops skip zero activations elementwise, like the compact graphs'
/// cost model's *dense* baseline); `Tiles` skips run against the
/// pre-masked weight from [`Kernels::prep_weight`].
#[derive(Clone, Copy, Debug, Default)]
pub struct DenseKernels;

impl Kernels for DenseKernels {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
            _k_skip: &Skip, _out_skip: &Skip) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue; // masked activations make this sparse
                }
                let brow = &b[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    fn gemm_nt(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize,
               _skip: &Skip) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
        let mut out = vec![0f32; m * k];
        for i in 0..m {
            let arow = &a[i * n..(i + 1) * n];
            for j in 0..k {
                let brow = &b[j * n..(j + 1) * n];
                let mut acc = 0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                out[i * k + j] = acc;
            }
        }
        out
    }

    fn gemm_tn_acc(&self, a: &[f32], b: &[f32], m: usize, k: usize,
                   n: usize, row_skip: &Skip, _col_skip: &Skip,
                   out: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        debug_assert_eq!(out.len(), k * n);
        if let Skip::Tiles(pat) = row_skip {
            // Compute the full gradient into a scratch buffer, mask, then
            // accumulate — dropped tiles of `out` receive no update even
            // when `out` carries prior accumulation (LSTM BPTT).
            let mut tmp = vec![0f32; k * n];
            dense_tn(a, b, m, k, n, &mut tmp);
            let mask = pat.mask();
            for ((o, &t), &mk) in out.iter_mut().zip(&tmp).zip(&mask) {
                *o += t * mk;
            }
            return;
        }
        dense_tn(a, b, m, k, n, out);
    }

    fn prep_weight(&self, w: &[f32], k: usize, n: usize, skip: &Skip)
                   -> Option<Vec<f32>> {
        match skip {
            Skip::Tiles(pat) => {
                debug_assert_eq!(w.len(), k * n);
                debug_assert_eq!((pat.k, pat.n), (k, n));
                let mask = pat.mask();
                Some(w.iter().zip(&mask).map(|(&x, &m)| x * m).collect())
            }
            _ => None,
        }
    }
}

/// `out[k,n] += a[m,k]^T @ b[m,n]` — the shared dense accumulation loop.
fn dense_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
            out: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::{RowPattern, TilePattern};

    const D: Skip = Skip::Dense;

    #[test]
    fn dense_gemm_shapes_and_values() {
        let kern = DenseKernels;
        // [2,3] @ [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let c = kern.gemm(&a, &b, 2, 3, 2, &D, &D);
        assert_eq!(c, vec![58., 64., 139., 154.]);
        // a @ (b^T)^T == a @ b via gemm_nt with b stored transposed.
        let bt = [7., 9., 11., 8., 10., 12.]; // [2,3] = b^T
        let c2 = kern.gemm_nt(&a, &bt, 2, 3, 2, &D);
        assert_eq!(c2, c);
        // a^T @ a: [3,3] symmetric.
        let g = kern.gemm_tn(&a, &a, 2, 3, 3, &D, &D);
        assert_eq!(g[1], g[3]);
        assert_eq!(g[0], 1. * 1. + 4. * 4.);
        // gemv == gemm with m = 1.
        let y = kern.gemv(&a[..3], &b, 3, 2, &D, &D);
        assert_eq!(y, c[..2].to_vec());
    }

    #[test]
    fn dense_prep_weight_masks_tiles() {
        let kern = DenseKernels;
        let pat = TilePattern::new(32, 64, 2, 0, 16);
        let w = vec![1f32; 32 * 64];
        let wm = kern.prep_weight(&w, 32, 64, &Skip::Tiles(pat)).unwrap();
        assert_eq!(wm, pat.mask());
        assert!(kern.prep_weight(&w, 32, 64, &D).is_none());
        let rows = Skip::Rows(RowPattern::new(64, 2, 0));
        assert!(kern.prep_weight(&w, 32, 64, &rows).is_none());
    }

    #[test]
    fn dense_tn_tiles_freezes_dropped_tiles_under_accumulation() {
        let kern = DenseKernels;
        let pat = TilePattern::new(32, 32, 2, 1, 16);
        let a = vec![1f32; 4 * 32];
        let b = vec![1f32; 4 * 32];
        let mut out = vec![5f32; 32 * 32];
        kern.gemm_tn_acc(&a, &b, 4, 32, 32, &Skip::Tiles(pat), &D,
                         &mut out);
        for r in 0..2 {
            for c in 0..2 {
                let v = out[(r * 16) * 32 + c * 16];
                if pat.keeps_tile(r, c) {
                    assert_eq!(v, 5.0 + 4.0, "kept tile ({r},{c})");
                } else {
                    assert_eq!(v, 5.0, "dropped tile ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn prep_dense_is_noop_and_pw_gemms_match_plain() {
        let kern = DenseKernels;
        let w: Vec<f32> = (0..32 * 64).map(|i| i as f32 * 0.01).collect();
        let a: Vec<f32> = (0..4 * 32).map(|i| (i % 7) as f32).collect();
        // Dense prep carries nothing and falls through to the raw weight.
        let pw = kern.prep(&w, 32, 64, &D);
        assert!(pw.weight(&w).as_ptr() == w.as_ptr());
        assert!(!pw.has_panel());
        assert_eq!(kern.gemm_pw(&a, &w, &pw, 4, 32, 64, &D, &D),
                   kern.gemm(&a, &w, 4, 32, 64, &D, &D));
        // Tile prep materializes the mask, exactly like prep_weight.
        let tiles = Skip::Tiles(TilePattern::new(32, 64, 2, 0, 16));
        let pw = kern.prep(&w, 32, 64, &tiles);
        assert_eq!(pw.weight(&w),
                   kern.prep_weight(&w, 32, 64, &tiles).unwrap());
        assert_eq!(kern.gemm_pw(&a, &w, &pw, 4, 32, 64, &tiles, &D),
                   kern.gemm(&a, pw.weight(&w), 4, 32, 64, &tiles, &D));
        // Row skips need no masked copy on the dense backend (the zeroed
        // activations already produce the right result).
        let rows = Skip::Rows(RowPattern::new(32, 2, 1));
        let pw = kern.prep(&w, 32, 64, &rows);
        assert!(pw.weight(&w).as_ptr() == w.as_ptr());
        // gemm_nt_pw: b is [k, n] = [32, 64], a is [m, n].
        let an: Vec<f32> = (0..4 * 64).map(|i| (i % 5) as f32).collect();
        assert_eq!(kern.gemm_nt_pw(&an, &w, &pw, 4, 64, 32, &rows),
                   kern.gemm_nt(&an, &w, 4, 64, 32, &rows));
    }

    #[test]
    fn node_defaults_match_raw_dispatch() {
        let kern = DenseKernels;
        let a: Vec<f32> = (0..4 * 32).map(|i| (i % 7) as f32).collect();
        let w: Vec<f32> = (0..32 * 64).map(|i| i as f32 * 0.01).collect();
        let rows = Skip::Rows(RowPattern::new(32, 2, 1));
        // gemm_node without pw == gemm; with pw == gemm_pw.
        let node = GemmNode::new(rows, D);
        assert_eq!(kern.gemm_node(&a, &w, &node, 4, 32, 64),
                   kern.gemm(&a, &w, 4, 32, 64, &rows, &D));
        let tiles = Skip::Tiles(TilePattern::new(32, 64, 2, 0, 16));
        let pw = kern.prep(&w, 32, 64, &tiles);
        let node = GemmNode::new(tiles, D).with_pw(&pw);
        assert_eq!(kern.gemm_node(&a, &w, &node, 4, 32, 64),
                   kern.gemm_pw(&a, &w, &pw, 4, 32, 64, &tiles, &D));
        // nt/tn node defaults ignore dynamic masks entirely.
        let an: Vec<f32> = (0..4 * 64).map(|i| (i % 5) as f32).collect();
        let mask = crate::runtime::plan::DynMask::zero_state(32);
        let nt = NtNode::new(rows).with_dyn(Some(&mask));
        assert_eq!(kern.gemm_nt_node(&an, &w, &nt, 4, 64, 32),
                   kern.gemm_nt(&an, &w, 4, 64, 32, &rows));
        let tn = TnNode::new(rows, D).with_dyn(Some(&mask));
        assert_eq!(kern.gemm_tn_node(&a, &an, &tn, 4, 32, 64),
                   kern.gemm_tn(&a, &an, 4, 32, 64, &rows, &D));
        assert!(!kern.dyn_backward());
    }
}
