//! Data substrates: synthetic MNIST-like digits, a synthetic PTB-like
//! corpus, and batch iterators (see DESIGN.md sections 5-6 for the
//! substitution rationale).

pub mod batcher;
pub mod mnist;
pub mod ptb;

pub use batcher::{BpttBatcher, MnistBatcher};
pub use mnist::{MnistSyn, IMG_PIXELS};
pub use ptb::Corpus;
