#!/usr/bin/env python3
"""Checkpoint/resume smoke check: interrupted == uninterrupted.

Usage:
    check_resume_smoke.py FIRST.json RESUMED.json FULL.json

FIRST   — curve of a run that trained N steps and wrote a checkpoint
RESUMED — curve of a run that resumed that checkpoint and trained M more
FULL    — curve of an uninterrupted N+M-step run (same config/seed)

Asserts the concatenation FIRST + RESUMED equals FULL *exactly* — step
numbers, losses and accuracies — i.e. resume reproduces the trajectory
bit-for-bit (curve JSON carries shortest-round-trip f64 decimals, so
float equality after json.load is bit equality).
"""

import json
import sys


def rows(path):
    with open(path) as f:
        return [(r["step"], r["loss"], r["acc"])
                for r in json.load(f)["rows"]]


def main():
    if len(sys.argv) != 4:
        print(__doc__)
        return 2
    first, resumed, full = map(rows, sys.argv[1:4])
    stitched = first + resumed
    print(f"first: {len(first)} steps, resumed: {len(resumed)} steps, "
          f"full: {len(full)} steps")
    if len(stitched) != len(full):
        print(f"FAIL: stitched has {len(stitched)} steps, full has "
              f"{len(full)}")
        return 1
    bad = [(a, b) for a, b in zip(stitched, full) if a != b]
    if bad:
        print(f"FAIL: {len(bad)} step(s) diverge; first: "
              f"stitched={bad[0][0]} full={bad[0][1]}")
        return 1
    print("OK: resumed trajectory is identical to the uninterrupted run")
    return 0


if __name__ == "__main__":
    sys.exit(main())
