//! Algorithm 1 walkthrough: run the SGD-based search across the paper's
//! rate grid on both the paper's {1..N} support and the artifact support,
//! then validate the statistical-equivalence claim by Monte-Carlo sampling
//! patterns and measuring the empirical per-neuron drop rate.
//!
//! ```sh
//! cargo run --release --example pattern_search
//! ```

use approx_dropout::bench::Table;
use approx_dropout::patterns::RowPattern;
use approx_dropout::search::{self, SearchConfig};
use approx_dropout::util::rng::Rng;

fn main() {
    let cfg = SearchConfig::default();
    let mut table = Table::new(&["target p", "support", "achieved",
                                 "entropy", "iters"]);
    for &p in &[0.3, 0.4, 0.5, 0.6, 0.7] {
        let paper = search::search_paper(p, 10, &cfg);
        table.row(&[format!("{p}"), "{1..10}".into(),
                    format!("{:.4}", paper.achieved_rate),
                    format!("{:.3}", paper.distribution.entropy()),
                    format!("{}", paper.iters)]);
        let ours = search::search(p, &[1, 2, 4, 8], &cfg);
        table.row(&[format!("{p}"), "{1,2,4,8}".into(),
                    format!("{:.4}", ours.achieved_rate),
                    format!("{:.3}", ours.distribution.entropy()),
                    format!("{}", ours.iters)]);
    }
    println!("Algorithm 1 across the paper's rate grid:");
    table.print();

    // Monte-Carlo check of Eq. 2/3: per-neuron empirical drop frequency.
    println!("\nStatistical equivalence (paper Eq. 2-3), layer width 128, \
              30k sampled iterations:");
    let mut t2 = Table::new(&["target p", "E[rate] (Eq.3)",
                              "per-neuron min", "per-neuron max"]);
    for &p in &[0.3, 0.5, 0.7] {
        let dist = search::search(p, &[1, 2, 4, 8], &cfg).distribution;
        let mut rng = Rng::new(p.to_bits());
        let m = 128;
        let iters = 30_000;
        let mut dropped = vec![0u32; m];
        for _ in 0..iters {
            let c = dist.sample(&mut rng);
            let pat = RowPattern::new(m, c.dp, c.b0);
            for (i, d) in dropped.iter_mut().enumerate() {
                if !pat.keeps(i) {
                    *d += 1;
                }
            }
        }
        let freqs: Vec<f64> =
            dropped.iter().map(|&c| c as f64 / iters as f64).collect();
        let min = freqs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = freqs.iter().cloned().fold(0.0f64, f64::max);
        t2.row(&[format!("{p}"), format!("{:.4}", dist.expected_rate()),
                 format!("{min:.4}"), format!("{max:.4}")]);
    }
    t2.print();
    println!("\nEvery neuron's empirical drop rate matches the Bernoulli \
              target — the approximation is statistically equivalent.");
}
