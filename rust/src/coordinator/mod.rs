//! L3 coordinator — the paper's system layer in Rust.
//!
//! Responsibilities per training iteration (paper Fig. 2):
//! 1. sample a dropout pattern `(dp, b0)` per site from the searched
//!    distribution K ([`schedule`]),
//! 2. dispatch to the AOT executable whose static shapes match the sampled
//!    divisors ([`pool`]; the regularity -> static-shape mapping is the
//!    core hardware adaptation, DESIGN.md section 2),
//! 3. assemble inputs (params, momenta, batch, masks or bias scalars) and
//!    execute through PJRT ([`crate::runtime`]),
//! 4. absorb updated state and record metrics ([`metrics`]).
//!
//! The iteration loop itself lives once, in the generic [`driver`]
//! (DESIGN.md section 4): each architecture contributes a
//! [`driver::ModelFront`] that assembles its inputs ([`mlp`], [`lstm`]),
//! and every trainer dispatches through the process-wide shared
//! [`pool::ExecutorCache`] so concurrent baseline/variant runs compile
//! each artifact exactly once.

pub mod driver;
pub mod lstm;
pub mod metrics;
pub mod mlp;
pub mod pool;
pub mod reduce;
pub mod schedule;

pub use driver::{eval_state_from_checkpoint, ModelFront, ShardedTrainer,
                 StepInput, Trainer};
pub use lstm::{LstmFront, LstmTrainer};
pub use metrics::{perplexity, speedup, TrainMetrics};
pub use mlp::{MlpFront, MlpTrainer};
pub use pool::ExecutorCache;
pub use reduce::{reduce_grad_pair, tree_reduce};
pub use schedule::{Schedule, Variant};
