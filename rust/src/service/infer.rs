//! Inference serving: a checkpoint-backed model registry, an mpsc
//! request front, and dynamic micro-batching over the shared executor
//! fleet.
//!
//! Model of operation:
//! * **Registry.** [`InferServer::start`] loads one `*.ckpt` per
//!   [`ModelSpec`], validates it — format version, parameter schema
//!   against the manifest tag, and (when pinned) the checkpoint's config
//!   hash — and hands the restored parameters to a dedicated worker
//!   thread as an eval-only `TrainState`
//!   (`coordinator::eval_state_from_checkpoint`). A mismatch is rejected
//!   at load, never discovered as a kernel shape panic mid-request.
//! * **Request front.** [`InferServer::submit`] routes one [`Example`]
//!   to its model's worker over an mpsc channel and returns a [`Ticket`]
//!   (a oneshot-style receiver) for the [`InferResponse`]. HTTP can sit
//!   on top of this later; the channel API is the contract.
//! * **Dynamic micro-batching.** A worker that receives a request first
//!   acquires a fleet slot ([`SlotGate`] — the same gate type the
//!   training scheduler uses, shareable via
//!   [`InferServer::start_with_gate`] so inference and training jobs
//!   queue fairly against each other), and only *then* drains its queue:
//!   every request that arrived while the worker waited in the FIFO
//!   coalesces into one padded batched eval dispatch. Padding replicates
//!   the last real example; because the eval forward pass is
//!   row-independent (see `runtime::step::softmax_xent_rows`), each
//!   request's per-example result is bit-identical to what a solo
//!   dispatch would produce — `tests/infer.rs` pins this on both
//!   hermetic backends.
//!
//! The per-example outputs only exist on the hermetic backends (the AOT
//! PJRT eval graphs return batch aggregates), so `start` fails fast on
//! PJRT instead of failing the first request.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{eval_state_from_checkpoint, ExecutorCache};
use crate::obs::registry;
use crate::runtime::{ArchMeta, Executor, HostTensor, InferOut, Kind,
                     TrainState, Value};
use crate::service::checkpoint::{hex_u64, Checkpoint, CKPT_VERSION};
use crate::service::scheduler::SlotGate;
use crate::util::Timer;
use crate::{info, warn_};

// ---------------------------------------------------------------------------
// Registry specs

/// One model the registry serves: a name, the manifest tag whose eval
/// graph runs it, and the checkpoint holding its weights.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub tag: String,
    pub ckpt: PathBuf,
    /// When set, the checkpoint's `config_hash` must equal this value —
    /// pins the served weights to one exact training configuration
    /// (tag/variant/rates/seed/lr-policy), same fingerprint
    /// `Trainer::restore` enforces on resume.
    pub expect_hash: Option<u64>,
}

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct InferConfig {
    /// Backend slots shared by all model workers (ignored by
    /// [`InferServer::start_with_gate`], which inherits the gate).
    pub slots: usize,
    /// Cap on requests coalesced per dispatch; 0 = the model's graph
    /// batch (the natural maximum — a dispatch can never carry more
    /// examples than the compiled eval graph's fixed batch dimension).
    pub max_batch: usize,
}

impl Default for InferConfig {
    fn default() -> Self {
        InferConfig { slots: 2, max_batch: 0 }
    }
}

// ---------------------------------------------------------------------------
// Requests and responses

/// One inference example — the unit a request carries.
#[derive(Clone, Debug)]
pub enum Example {
    /// One image: `x` is `[n_in]` pixels, `y` the label.
    Mlp { x: Vec<f32>, y: i32 },
    /// One token track: `x` is `[seq]` tokens, `y` the `[seq]` shifted
    /// targets.
    Lstm { x: Vec<i32>, y: Vec<i32> },
}

#[derive(Clone, Debug)]
pub struct InferRequest {
    pub model: String,
    pub example: Example,
}

#[derive(Clone, Debug)]
pub struct InferResponse {
    pub model: String,
    /// Per-example loss (MLP: the image's nll; LSTM: mean nll over the
    /// track's targets).
    pub loss: f64,
    /// Per-example correct count (MLP: 0/1; LSTM: correct tokens).
    pub correct: f64,
    /// Requests coalesced into the dispatch that served this one.
    pub batch: usize,
    /// Submit-to-response wall time (queueing + slot wait + dispatch).
    pub latency_s: f64,
}

/// Response handle: blocks on `recv()` until the worker answers. The
/// error arm carries a rendered message (a failed dispatch answers every
/// coalesced request with the same cause).
pub type Ticket = mpsc::Receiver<std::result::Result<InferResponse,
                                                     String>>;

// ---------------------------------------------------------------------------
// Internals

/// Geometry of a served model, extracted from the manifest tag.
#[derive(Clone, Copy, Debug)]
enum Geometry {
    Mlp { n_in: usize, n_out: usize, batch: usize },
    Lstm { seq: usize, vocab: usize, batch: usize },
}

impl Geometry {
    fn batch(&self) -> usize {
        match self {
            Geometry::Mlp { batch, .. } | Geometry::Lstm { batch, .. } =>
                *batch,
        }
    }

    /// Reject a malformed example at submit time, so one bad request can
    /// never fail the dispatch it would have coalesced into.
    fn validate(&self, ex: &Example) -> Result<()> {
        match (self, ex) {
            (Geometry::Mlp { n_in, n_out, .. }, Example::Mlp { x, y }) => {
                if x.len() != *n_in {
                    bail!("mlp example has {} pixels, model takes {n_in}",
                          x.len());
                }
                if *y < 0 || *y as usize >= *n_out {
                    bail!("label {y} out of range [0, {n_out})");
                }
            }
            (Geometry::Lstm { seq, vocab, .. }, Example::Lstm { x, y }) => {
                if x.len() != *seq || y.len() != *seq {
                    bail!("lstm example has {}/{} tokens/targets, model \
                           takes {seq}", x.len(), y.len());
                }
                if let Some(&t) = x.iter().chain(y.iter())
                    .find(|&&t| t < 0 || t as usize >= *vocab)
                {
                    bail!("token {t} out of range [0, {vocab})");
                }
            }
            (Geometry::Mlp { .. }, Example::Lstm { .. }) =>
                bail!("lstm example submitted to an mlp model"),
            (Geometry::Lstm { .. }, Example::Mlp { .. }) =>
                bail!("mlp example submitted to an lstm model"),
        }
        Ok(())
    }
}

/// One in-flight request inside a worker queue.
struct Queued {
    example: Example,
    tx: mpsc::Sender<std::result::Result<InferResponse, String>>,
    t0: Timer,
}

struct ModelHandle {
    /// Mutex rather than a bare sender: clients submit through `&self`
    /// from many threads, and `mpsc::Sender` is not `Sync` on older
    /// toolchains. The hold is a single `send` — contention-free next to
    /// a dispatch.
    tx: Mutex<mpsc::Sender<Queued>>,
    geometry: Geometry,
    tag: String,
    step: u64,
    config_hash: u64,
    served: Arc<AtomicUsize>,
    max_batch_observed: Arc<AtomicUsize>,
}

/// Per-model serving counters (observability + the coalescing tests).
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub name: String,
    pub tag: String,
    /// Training step the served checkpoint captured.
    pub step: u64,
    pub config_hash: u64,
    pub served: usize,
    pub max_batch_observed: usize,
}

// ---------------------------------------------------------------------------
// The server

/// Registry + request front + per-model micro-batching workers. Dropping
/// the server closes the submit channels and joins every worker.
pub struct InferServer {
    handles: HashMap<String, ModelHandle>,
    workers: Vec<JoinHandle<()>>,
    gate: Arc<SlotGate>,
}

impl InferServer {
    /// Load every model and start its worker; fails fast (no server, no
    /// threads left behind) if any checkpoint is missing, malformed,
    /// hash-pinned to a different config, or schema-incompatible with
    /// its tag.
    pub fn start(cache: &ExecutorCache, specs: &[ModelSpec],
                 cfg: &InferConfig) -> Result<InferServer> {
        let gate = Arc::new(SlotGate::new(cfg.slots.max(1)));
        Self::start_with_gate(cache, specs, cfg, gate)
    }

    /// Like [`InferServer::start`] but over a caller-provided gate —
    /// pass the training fleet's gate to make inference dispatches and
    /// training ticks queue FIFO against each other on the same slots.
    pub fn start_with_gate(cache: &ExecutorCache, specs: &[ModelSpec],
                           cfg: &InferConfig, gate: Arc<SlotGate>)
                           -> Result<InferServer> {
        if specs.is_empty() {
            bail!("inference registry: no models to serve");
        }
        if cache.backend().name() == "pjrt" {
            bail!("inference serving requires per-example eval outputs, \
                   which the AOT PJRT eval graphs do not expose (batch \
                   aggregates only) — run with \
                   AD_BACKEND=reference|sparse");
        }
        let mut server = InferServer {
            handles: HashMap::new(),
            workers: Vec::new(),
            gate,
        };
        for spec in specs {
            if server.handles.contains_key(&spec.name) {
                bail!("inference registry: duplicate model name '{}'",
                      spec.name);
            }
            // Validate on the caller thread so start() is the fail-fast
            // boundary; the worker re-ingests (values stay pinned to the
            // thread that serves them).
            let ckpt = Checkpoint::load(&spec.ckpt)
                .with_context(|| format!("model '{}'", spec.name))?;
            validate_registry_entry(cache, spec, &ckpt)?;
            let geometry = geometry_of(cache, &spec.tag)?;
            let max_batch = match cfg.max_batch {
                0 => geometry.batch(),
                m => m.min(geometry.batch()),
            };
            let (tx, rx) = mpsc::channel::<Queued>();
            let (ready_tx, ready_rx) = mpsc::channel();
            let served = Arc::new(AtomicUsize::new(0));
            let observed = Arc::new(AtomicUsize::new(0));
            let worker = WorkerCtx {
                cache: cache.clone(),
                gate: Arc::clone(&server.gate),
                name: spec.name.clone(),
                tag: spec.tag.clone(),
                geometry,
                max_batch,
                served: Arc::clone(&served),
                observed: Arc::clone(&observed),
            };
            let handle = std::thread::Builder::new()
                .name(format!("infer-{}", spec.name))
                .spawn(move || worker.run(ckpt, rx, ready_tx))
                .context("spawning inference worker")?;
            server.workers.push(handle);
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => bail!("model '{}': {msg}", spec.name),
                Err(_) => bail!("model '{}': worker died during setup",
                                spec.name),
            }
            info!("infer: serving '{}' (tag {}, step {}, config \
                   {}, max batch {max_batch})", spec.name, spec.tag,
                  ckpt.step, hex_u64(ckpt.config_hash));
            server.handles.insert(spec.name.clone(), ModelHandle {
                tx: Mutex::new(tx),
                geometry,
                tag: spec.tag.clone(),
                step: ckpt.step,
                config_hash: ckpt.config_hash,
                served,
                max_batch_observed: observed,
            });
        }
        Ok(server)
    }

    /// Enqueue one request; returns immediately with a [`Ticket`].
    /// Errors here are *caller* errors (unknown model, malformed
    /// example) — dispatch errors arrive through the ticket.
    pub fn submit(&self, req: InferRequest) -> Result<Ticket> {
        let h = self.handles.get(&req.model).ok_or_else(|| {
            let mut known: Vec<&str> =
                self.handles.keys().map(String::as_str).collect();
            known.sort_unstable();
            anyhow!("no model '{}' in the registry (serving: {})",
                    req.model, known.join(", "))
        })?;
        h.geometry.validate(&req.example)
            .with_context(|| format!("model '{}'", req.model))?;
        let (tx, rx) = mpsc::channel();
        h.tx.lock().unwrap_or_else(|p| p.into_inner())
            .send(Queued { example: req.example, tx, t0: Timer::start() })
            .map_err(|_| anyhow!("model '{}': worker is gone",
                                 req.model))?;
        Ok(rx)
    }

    /// The slot gate inference dispatches queue on (shared with training
    /// when started via [`InferServer::start_with_gate`]).
    pub fn gate(&self) -> &Arc<SlotGate> {
        &self.gate
    }

    /// Per-model counters, sorted by model name.
    pub fn stats(&self) -> Vec<ModelStats> {
        let mut out: Vec<ModelStats> = self.handles.iter()
            .map(|(name, h)| ModelStats {
                name: name.clone(),
                tag: h.tag.clone(),
                step: h.step,
                config_hash: h.config_hash,
                served: h.served.load(Ordering::Relaxed),
                max_batch_observed:
                    h.max_batch_observed.load(Ordering::Relaxed),
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }
}

impl Drop for InferServer {
    fn drop(&mut self) {
        // Closing the submit channels ends every worker loop; join so no
        // worker outlives the server (tests rely on this for determinism).
        self.handles.clear();
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// Registry-load validation: format version, optional pinned config
/// hash, and the parameter schema (names + shapes against the tag).
fn validate_registry_entry(cache: &ExecutorCache, spec: &ModelSpec,
                           ckpt: &Checkpoint) -> Result<()> {
    if ckpt.version != CKPT_VERSION {
        bail!("model '{}': checkpoint version {} unsupported (expected \
               {CKPT_VERSION})", spec.name, ckpt.version);
    }
    if let Some(want) = spec.expect_hash {
        if ckpt.config_hash != want {
            bail!("model '{}': checkpoint config hash {} does not match \
                   the pinned hash {} — refusing to serve a different \
                   experiment's weights", spec.name,
                  hex_u64(ckpt.config_hash), hex_u64(want));
        }
    }
    let meta = cache.manifest().get(&format!("{}_conv", spec.tag))
        .with_context(|| format!("model '{}': tag {} not in the \
                                  manifest", spec.name, spec.tag))?;
    let param_metas: Vec<_> = meta.inputs.iter()
        .filter(|t| t.kind == Kind::Param)
        .collect();
    if ckpt.params.len() != param_metas.len() {
        bail!("model '{}': checkpoint has {} param tensors, tag {} \
               declares {}", spec.name, ckpt.params.len(), spec.tag,
              param_metas.len());
    }
    for (t, m) in ckpt.params.iter().zip(&param_metas) {
        if t.name != m.name || t.shape != m.shape {
            bail!("model '{}': checkpoint tensor {}:{:?} does not match \
                   tag {}'s parameter {}:{:?}", spec.name, t.name,
                  t.shape, spec.tag, m.name, m.shape);
        }
    }
    Ok(())
}

fn geometry_of(cache: &ExecutorCache, tag: &str) -> Result<Geometry> {
    let meta = cache.manifest().get(&format!("{tag}_conv"))?;
    Ok(match &meta.arch {
        ArchMeta::Mlp { n_in, n_out, batch, .. } =>
            Geometry::Mlp { n_in: *n_in, n_out: *n_out, batch: *batch },
        ArchMeta::Lstm { seq, vocab, batch, .. } =>
            Geometry::Lstm { seq: *seq, vocab: *vocab, batch: *batch },
    })
}

// ---------------------------------------------------------------------------
// Worker

struct WorkerCtx {
    cache: ExecutorCache,
    gate: Arc<SlotGate>,
    name: String,
    tag: String,
    geometry: Geometry,
    max_batch: usize,
    served: Arc<AtomicUsize>,
    observed: Arc<AtomicUsize>,
}

impl WorkerCtx {
    fn run(self, ckpt: Checkpoint,
           rx: mpsc::Receiver<Queued>,
           ready: mpsc::Sender<std::result::Result<(), String>>) {
        // Setup under a slot: checkpoint ingest and eval-graph compile
        // are backend work like any training tick.
        let hold = self.gate.acquire();
        let built = catch_unwind(AssertUnwindSafe(|| -> Result<_> {
            let state =
                eval_state_from_checkpoint(&self.cache, &self.tag, &ckpt)?;
            let exe = self.cache.get(&format!("{}_eval", self.tag))?;
            Ok((state, exe))
        }));
        drop(hold);
        let (state, exe) = match built {
            Ok(Ok(v)) => {
                ready.send(Ok(())).ok();
                v
            }
            Ok(Err(e)) => {
                ready.send(Err(format!("{e:#}"))).ok();
                return;
            }
            Err(p) => {
                ready.send(Err(format!("panic: {}", panic_msg(&p)))).ok();
                return;
            }
        };

        while let Ok(first) = rx.recv() {
            // Acquire the slot *before* draining: everything that queues
            // while this worker waits its FIFO turn coalesces into the
            // same dispatch. This is the dynamic part of the batching —
            // idle fleets serve singles at minimum latency, saturated
            // fleets batch up to the graph's batch dimension.
            let hold = self.gate.acquire();
            let mut batch = vec![first];
            while batch.len() < self.max_batch {
                match rx.try_recv() {
                    Ok(q) => batch.push(q),
                    Err(_) => break,
                }
            }
            let n = batch.len();
            self.observed.fetch_max(n, Ordering::Relaxed);
            registry::INFER_BATCHES.inc();
            registry::INFER_BATCH_OCCUPANCY.observe(n as f64);
            let r = catch_unwind(AssertUnwindSafe(
                || self.dispatch(&state, exe.as_ref(), &batch)));
            drop(hold);
            match r {
                Ok(Ok(out)) => {
                    for (i, q) in batch.into_iter().enumerate() {
                        self.served.fetch_add(1, Ordering::Relaxed);
                        let latency_s = q.t0.elapsed_s();
                        registry::INFER_REQUESTS.inc();
                        registry::INFER_LATENCY_S.observe(latency_s);
                        q.tx.send(Ok(InferResponse {
                            model: self.name.clone(),
                            loss: f64::from(out.ex_loss[i]),
                            correct: f64::from(out.ex_correct[i]),
                            batch: n,
                            latency_s,
                        })).ok();
                    }
                }
                Ok(Err(e)) => self.fail_batch(batch, format!("{e:#}")),
                Err(p) => self.fail_batch(
                    batch, format!("panic: {}", panic_msg(&p))),
            }
        }
    }

    /// Pack up to `max_batch` queued examples into the eval graph's
    /// fixed-batch tensors, padding the tail with copies of the last
    /// real example (valid inputs whose results are simply dropped), and
    /// dispatch through the per-example eval entry.
    fn dispatch(&self, state: &TrainState, exe: &dyn Executor,
                batch: &[Queued]) -> Result<InferOut> {
        let backend = self.cache.backend();
        let extra: Vec<Value> = match self.geometry {
            Geometry::Mlp { n_in, batch: b, .. } => {
                let mut x = Vec::with_capacity(b * n_in);
                let mut y = Vec::with_capacity(b);
                for q in batch {
                    match &q.example {
                        Example::Mlp { x: xi, y: yi } => {
                            x.extend_from_slice(xi);
                            y.push(*yi);
                        }
                        Example::Lstm { .. } =>
                            bail!("lstm example in an mlp worker queue"),
                    }
                }
                let (px, py) = (x[x.len() - n_in..].to_vec(),
                                y[y.len() - 1]);
                while y.len() < b {
                    x.extend_from_slice(&px);
                    y.push(py);
                }
                vec![
                    backend.ingest(HostTensor::f32(&[b, n_in], x))?,
                    backend.ingest(HostTensor::i32(&[b], y))?,
                ]
            }
            Geometry::Lstm { seq, batch: b, .. } => {
                let mut x = Vec::with_capacity(b * seq);
                let mut y = Vec::with_capacity(b * seq);
                for q in batch {
                    match &q.example {
                        Example::Lstm { x: xi, y: yi } => {
                            x.extend_from_slice(xi);
                            y.extend_from_slice(yi);
                        }
                        Example::Mlp { .. } =>
                            bail!("mlp example in an lstm worker queue"),
                    }
                }
                let (px, py) = (x[x.len() - seq..].to_vec(),
                                y[y.len() - seq..].to_vec());
                while y.len() < b * seq {
                    x.extend_from_slice(&px);
                    y.extend_from_slice(&py);
                }
                vec![
                    backend.ingest(HostTensor::i32(&[b, seq], x))?,
                    backend.ingest(HostTensor::i32(&[b, seq], y))?,
                ]
            }
        };
        state.infer_step(exe, &extra)
    }

    fn fail_batch(&self, batch: Vec<Queued>, msg: String) {
        warn_!("infer: model '{}' dispatch of {} request(s) failed: \
                {msg}", self.name, batch.len());
        for q in batch {
            q.tx.send(Err(msg.clone())).ok();
        }
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}
