//! Dropout patterns (the paper's section III): row-based (RDP) and
//! tile-based (TDP) regular patterns, the Bernoulli mask generator used by
//! the conventional-dropout baseline, and the sampled pattern distribution
//! K produced by the SGD-based search (section III-C).
//!
//! Index math here MUST mirror `python/compile/patterns.py` — the Rust side
//! samples `(dp, b0)` and passes `b0` into the AOT graph, so both sides
//! must agree on what "kept" means. The cross-language agreement is pinned
//! by integration tests (`rust/tests/`) that run the AOT graphs against
//! host-side reconstructions.

pub mod distribution;
pub mod mask;
pub mod row;
pub mod tile;
pub mod window;

pub use distribution::PatternDistribution;
pub use mask::MaskGen;
pub use row::RowPattern;
pub use tile::TilePattern;
pub use window::TimeWindow;

/// Largest divisor of `dim` that is <= cap (mirrors python `pick_block`).
pub fn pick_block(dim: usize, cap: usize) -> usize {
    if dim <= cap {
        return dim;
    }
    for b in (1..=cap).rev() {
        if dim % b == 0 {
            return b;
        }
    }
    1
}

/// A sampled per-iteration pattern choice for one dropout site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Choice {
    /// Divisor: 1 of every `dp` units is kept (dp = 1 means no dropout).
    pub dp: usize,
    /// Bias in [0, dp): which residue class is kept.
    pub b0: usize,
}

impl Choice {
    pub fn none() -> Self {
        Choice { dp: 1, b0: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_block_matches_python() {
        assert_eq!(pick_block(2048, 256), 256);
        assert_eq!(pick_block(784, 32), 28);
        assert_eq!(pick_block(10, 32), 10);
        assert_eq!(pick_block(1500, 256), 250);
        assert_eq!(pick_block(64, 256), 64);
        assert_eq!(pick_block(8800, 256), 220);
    }
}
