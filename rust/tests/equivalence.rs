//! Statistical-equivalence tests (the paper's section III-D claim): over
//! many training iterations, the per-neuron/per-synapse drop frequency of
//! the approximate patterns converges to the target Bernoulli rate, and the
//! number of distinct sub-models matches the theory.

use std::collections::BTreeSet;

use approx_dropout::patterns::{Choice, PatternDistribution, RowPattern,
                               TilePattern, TimeWindow};
use approx_dropout::search::{self, SearchConfig};
use approx_dropout::util::rng::Rng;

#[test]
fn searched_distribution_drop_rate_matches_bernoulli_target() {
    // End-to-end: run Algorithm 1 for each paper rate, sample patterns,
    // measure empirical per-neuron drop frequency on a realistic layer.
    let cfg = SearchConfig::default();
    let m = 128;
    let iters = 30_000;
    for &p in &[0.3, 0.5, 0.7] {
        let dist = search::search(p, &[1, 2, 4, 8], &cfg).distribution;
        let mut rng = Rng::new(p.to_bits());
        let mut dropped = vec![0u32; m];
        for _ in 0..iters {
            let c = dist.sample(&mut rng);
            let pat = RowPattern::new(m, c.dp, c.b0);
            for (i, d) in dropped.iter_mut().enumerate() {
                if !pat.keeps(i) {
                    *d += 1;
                }
            }
        }
        for (i, &cnt) in dropped.iter().enumerate() {
            let f = cnt as f64 / iters as f64;
            assert!((f - p).abs() < 0.02,
                    "rate {p}, neuron {i}: empirical {f}");
        }
    }
}

/// Time-windowed extension of the convergence claim above: re-drawing the
/// pattern *bias* every W timesteps (instead of once per step) must leave
/// the long-run per-neuron drop frequency at the Bernoulli target for
/// every window size the bench grid exercises. The dp divisor is fixed
/// per step (the artifact-name constraint), exactly as the coordinator
/// holds it; W=1 is fresh-per-timestep, W=4 is two windows per seq=8
/// step, and W=16 holds one (dp, b0) across two consecutive steps — the
/// same carry the trainer checkpoints.
#[test]
fn windowed_drop_frequency_converges_across_window_grid() {
    let cfg = SearchConfig::default();
    let m = 128;
    let seq = 8;
    let steps = 4_000; // 32k timestep samples per (rate, window) cell
    for &p in &[0.3, 0.5, 0.7] {
        let dist = search::search(p, &[1, 2, 4, 8], &cfg).distribution;
        let target = dist.expected_rate();
        for &w in &[1usize, 4, 16] {
            let tw = TimeWindow::resolve(Some(w), seq);
            let hold = tw.steps_per_draw();
            let mut rng = Rng::new(p.to_bits() ^ ((w as u64) << 32));
            let mut dropped = vec![0u64; m];
            let mut held: Option<Choice> = None;
            let mut held_left = 0usize;
            for _ in 0..steps {
                let c = if hold > 1 && held_left > 0 {
                    held_left -= 1;
                    held.unwrap()
                } else {
                    let c = dist.sample(&mut rng);
                    if hold > 1 {
                        held = Some(c);
                        held_left = hold - 1;
                    }
                    c
                };
                let tracks = tw.expand_b0_tracks(&[c], &mut rng);
                for t in 0..seq {
                    let pat = RowPattern::new(m, c.dp,
                                              tracks[0][t] as usize);
                    for (i, d) in dropped.iter_mut().enumerate() {
                        if !pat.keeps(i) {
                            *d += 1;
                        }
                    }
                }
            }
            let samples = (steps * seq) as f64;
            for (i, &cnt) in dropped.iter().enumerate() {
                let f = cnt as f64 / samples;
                // Windowed draws are correlated within a hold (W=16
                // halves, W=4 only adds within-step draws), so the
                // effective sample count is >= 16k everywhere: sigma
                // <= 0.5/sqrt(16k) ~ 0.004; 0.02 is a ~5 sigma band
                // on top of the search's |achieved - p| < 5e-3 slack.
                assert!((f - target).abs() < 0.02,
                        "rate {p} W={w} neuron {i}: {f} vs {target}");
                assert!((f - p).abs() < 0.025,
                        "rate {p} W={w} neuron {i}: {f} vs nominal {p}");
            }
        }
    }
}

/// TDP analogue of the RowPattern convergence test above: over many
/// sampled iterations, EVERY tile's empirical drop frequency converges to
/// the Bernoulli target, across the paper's rate grid. (Previously only
/// RowPattern was measured against the target at multiple rates; tiles
/// were spot-checked at 0.5 with 16 probes.)
#[test]
fn tile_pattern_drop_frequency_converges_at_every_tile() {
    let cfg = SearchConfig::default();
    let (k, n) = (128, 128);
    let iters = 30_000;
    for &p in &[0.3, 0.5, 0.7] {
        let dist = search::search(p, &[1, 2, 4], &cfg).distribution;
        // Feasibility: max rate of {1,2,4} is 0.75 >= 0.7.
        let probe = TilePattern::new(k, n, 1, 0, 32);
        let (tk, tn) = probe.grid();
        let mut rng = Rng::new(p.to_bits() ^ 0x7113_7113);
        let mut dropped = vec![0u32; tk * tn];
        for _ in 0..iters {
            let c = dist.sample(&mut rng);
            let pat = TilePattern::new(k, n, c.dp, c.b0, 32);
            for r in 0..tk {
                for cc in 0..tn {
                    if !pat.keeps_tile(r, cc) {
                        dropped[r * tn + cc] += 1;
                    }
                }
            }
        }
        let target = dist.expected_rate();
        for (i, &cnt) in dropped.iter().enumerate() {
            let f = cnt as f64 / iters as f64;
            // ~5 sigma at sigma <= 0.5/sqrt(30k) ~ 0.0029, plus the
            // search's |achieved - p| < 5e-3 slack.
            assert!((f - target).abs() < 0.02,
                    "rate {p}, tile {i}: empirical {f} vs {target}");
            assert!((f - p).abs() < 0.025,
                    "rate {p}, tile {i}: empirical {f} vs nominal {p}");
        }
    }
}

#[test]
fn tile_pattern_synapse_drop_rate_matches_target() {
    let cfg = SearchConfig::default();
    let (k, n) = (128, 128);
    let iters = 4_000;
    let p = 0.5;
    let dist = search::search(p, &[1, 2, 4], &cfg).distribution;
    let mut rng = Rng::new(4242);
    let mut dropped = vec![0u32; 16]; // sample 16 probe synapses
    let probes: Vec<(usize, usize)> =
        (0..16).map(|i| (i * 7 % k, i * 13 % n)).collect();
    for _ in 0..iters {
        let c = dist.sample(&mut rng);
        let pat = TilePattern::new(k, n, c.dp, c.b0, 32);
        for (pi, &(r, cc)) in probes.iter().enumerate() {
            if !pat.keeps_tile(r / pat.tr, cc / pat.tc) {
                dropped[pi] += 1;
            }
        }
    }
    for (pi, &cnt) in dropped.iter().enumerate() {
        let f = cnt as f64 / iters as f64;
        assert!((f - p).abs() < 0.04, "probe {pi}: empirical {f} vs {p}");
    }
}

#[test]
fn submodel_count_row_pattern() {
    // Paper: number of sub-models for RDP with dp up to N is sum_i i.
    // Enumerate distinct kept-sets across (dp, b0) for a small layer.
    let m = 24;
    let mut seen = BTreeSet::new();
    let support = [1usize, 2, 3, 4];
    for &dp in &support {
        for b0 in 0..dp {
            seen.insert(RowPattern::new(m, dp, b0).kept_indices());
        }
    }
    let expected: usize = support.iter().sum();
    assert_eq!(seen.len(), expected,
               "each (dp, b0) must induce a distinct sub-model");
}

#[test]
fn expected_rate_equals_per_unit_probability_identity() {
    // Eq. 2 == Eq. 3 algebraically for any distribution.
    let mut rng = Rng::new(7);
    for _ in 0..100 {
        let raw: Vec<f64> = (0..4).map(|_| rng.uniform(0.01, 1.0)).collect();
        let s: f64 = raw.iter().sum();
        let probs: Vec<f64> = raw.iter().map(|x| x / s).collect();
        let d = PatternDistribution::new(vec![1, 2, 4, 8], probs);
        assert!((d.expected_rate() - d.per_unit_drop_probability()).abs()
                < 1e-12);
    }
}

#[test]
fn search_matches_paper_rate_grid() {
    // Reproduce the paper's target grid 0.3..0.7 on the paper's {1..N}
    // support and our artifact support; both must hit within 1%.
    let cfg = SearchConfig::default();
    for &p in &[0.3, 0.4, 0.5, 0.6, 0.7] {
        let a = search::search_paper(p, 10, &cfg);
        assert!((a.achieved_rate - p).abs() < 1e-2,
                "paper support target {p}: {}", a.achieved_rate);
        let b = search::search(p, &[1, 2, 4, 8], &cfg);
        assert!((b.achieved_rate - p).abs() < 1e-2,
                "artifact support target {p}: {}", b.achieved_rate);
    }
}
