//! Session subsystem: checkpointable training sessions and the
//! concurrent multi-job service layer behind the `serve` CLI.
//!
//! * [`checkpoint`] — the versioned `*.ckpt` format: full `TrainState`
//!   (f32 bit patterns), RNG cursor, batcher position, lr/epoch driver
//!   state, config hash, dispatch-log tail. `Trainer::checkpoint` /
//!   `Trainer::resume_from` (in `coordinator::driver`) produce and
//!   consume these; a resumed run reproduces the uninterrupted
//!   trajectory bit for bit on the hermetic backends.
//! * [`jobs`] — the TOML jobs manifest (`[service]` + `[jobs.<name>]`
//!   tables) mapping to [`jobs::JobSpec`]/[`jobs::ServiceConfig`].
//! * [`scheduler`] — the fleet loop: FIFO backend-slot gate, per-job
//!   runner threads, `catch_unwind` crash quarantine, periodic
//!   checkpoint ticks, per-job JSON reports via `bench::report`.
//! * [`infer`] — inference serving over the same fleet: a
//!   checkpoint-backed model registry, an mpsc request front, and
//!   per-model workers that coalesce concurrent requests into dynamic
//!   micro-batches (one padded eval dispatch per slot acquisition),
//!   with per-request results bit-identical to solo dispatches.
//!
//! DESIGN.md sections 10-11 document the formats and the scheduling /
//! serving models.

pub mod checkpoint;
pub mod infer;
pub mod jobs;
pub mod scheduler;

pub use checkpoint::{Checkpoint, CKPT_VERSION};
pub use infer::{Example, InferConfig, InferRequest, InferResponse,
                InferServer, ModelSpec, ModelStats, Ticket};
pub use jobs::{jobs_from_doc, load_jobs_manifest, JobSpec, ModelKind,
               ServiceConfig};
pub use scheduler::{run_jobs, run_jobs_with_gate, summarize,
                    ensure_all_ok, JobOutcome, JobStatus, ServiceReport,
                    SlotGate};
