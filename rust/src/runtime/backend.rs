//! Execution-backend abstraction: the [`Backend`]/[`Executor`] trait pair
//! plus the [`Value`] currency that moves between steps.
//!
//! The coordinator never talks to PJRT (or any other engine) directly: it
//! uploads [`HostTensor`]s through a [`Backend`], dispatches them to an
//! [`Executor`] obtained by compile-by-name from the manifest, and keeps
//! the returned [`Value`]s resident for the next step. Three backends
//! ship:
//!
//! * **PJRT** (`runtime::engine`, behind the `pjrt` cargo feature) — loads
//!   AOT HLO-text artifacts and keeps state as XLA literals end-to-end.
//! * **Reference** (`runtime::reference`, always available) — the shared
//!   step interpreter (`runtime::step`) over masked-dense element math.
//!   No artifacts, no Python, no PJRT: the whole
//!   sample→dispatch→step→metrics loop is testable hermetically.
//! * **Sparse** (`runtime::sparse`, always available) — the same step
//!   interpreter over the multithreaded row-/tile-skipping kernel
//!   library; dropped coordinates are never loaded or multiplied.
//!
//! Contract shared by all backends (pinned by `rust/tests/hermetic.rs`):
//! identical manifest calling convention (inputs `params ++ momenta ++ x,
//! y, extras, lr`; outputs `params' ++ momenta' ++ loss, correct`),
//! identical artifact-name dispatch (the coordinator's RNG never sees the
//! backend), and deterministic results for a fixed seed. Numerics may
//! differ in float rounding only (summation order is backend-specific).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactMeta, Dtype, Manifest, TensorMeta};

/// Host-side tensor: shape + dtype-tagged storage. The unit the
/// coordinator assembles and hands to [`Backend::upload`].
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } =>
                shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn scalar(&self) -> Result<f64> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 =>
                Ok(data[0] as f64),
            HostTensor::I32 { data, .. } if data.len() == 1 =>
                Ok(data[0] as f64),
            _ => bail!("tensor is not a scalar"),
        }
    }

    /// Validate against a manifest tensor description.
    pub fn check(&self, meta: &TensorMeta) -> Result<()> {
        if self.shape() != meta.shape.as_slice() {
            bail!("tensor {}: shape {:?} != manifest {:?}", meta.name,
                  self.shape(), meta.shape);
        }
        let ok = matches!(
            (self, meta.dtype),
            (HostTensor::F32 { .. }, Dtype::F32)
                | (HostTensor::I32 { .. }, Dtype::I32)
        );
        if !ok {
            bail!("tensor {}: dtype mismatch", meta.name);
        }
        Ok(())
    }
}

/// A backend-resident tensor value — the currency [`crate::runtime::TrainState`]
/// and the dispatch path move between steps. The reference backend keeps
/// values in host memory; the PJRT backend keeps XLA literals resident so
/// a step's outputs feed the next step without host round-trips.
pub enum Value {
    Host(HostTensor),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::Literal),
}

impl Value {
    /// Borrow the host tensor; errors on device-resident values (the
    /// reference executor calls this on its inputs).
    pub fn as_host(&self) -> Result<&HostTensor> {
        match self {
            Value::Host(t) => Ok(t),
            #[cfg(feature = "pjrt")]
            Value::Pjrt(_) =>
                bail!("value is a PJRT literal, not a host tensor"),
        }
    }

    /// First element as f64 (loss/correct scalars).
    pub fn scalar_f64(&self) -> Result<f64> {
        match self {
            Value::Host(t) => t.scalar(),
            #[cfg(feature = "pjrt")]
            Value::Pjrt(l) => l
                .get_first_element::<f32>()
                .map(|v| v as f64)
                .map_err(|e| anyhow::anyhow!("scalar from literal: {e:?}")),
        }
    }

    /// Copy the value's f32 data back to host (tests / inspection).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        match self {
            Value::Host(t) => Ok(t.as_f32()?.to_vec()),
            #[cfg(feature = "pjrt")]
            Value::Pjrt(l) => l
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("literal to_vec f32: {e:?}")),
        }
    }
}

/// One contiguous shard of a training batch, in batch-row units: rows
/// `lo .. lo+rows` of a `global_rows`-row batch. The data-parallel driver
/// cuts each global batch into a *worker-count-independent* list of these
/// (see `ModelFront::shard_leaves`), so the gradient reduction tree has
/// the same leaves — and therefore the same f32 association order — at
/// any worker count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeafSpec {
    /// First batch row this leaf covers.
    pub lo: usize,
    /// Rows in this leaf.
    pub rows: usize,
    /// Rows in the whole global batch (the loss/gradient denominator:
    /// per-leaf gradients are scaled by the *global* mean so summing
    /// leaves reproduces the full-batch gradient).
    pub global_rows: usize,
}

/// One leaf's gradient contribution: per-parameter gradient buffers in
/// manifest parameter order (already scaled by the global-batch mean),
/// plus the raw f64 loss sum and correct count over the leaf's rows.
#[derive(Clone, Debug)]
pub struct GradOut {
    pub grads: Vec<Vec<f32>>,
    /// Sum of per-row nll over the leaf (divide by the global example
    /// count after reduction).
    pub loss_sum: f64,
    pub correct: f32,
}

/// One compiled (or interpreted) artifact: executes steps with inputs in
/// manifest order and returns outputs in manifest order.
///
/// `Send + Sync` is part of the contract: executors live in the
/// process-wide `ExecutorCache` map, which the multi-job service layer
/// shares across concurrent session threads.
pub trait Executor: Send + Sync {
    fn meta(&self) -> &ArtifactMeta;

    /// Execute one step. This is the hot path: inputs are whatever
    /// [`Value`] form the backend keeps resident, outputs likewise.
    fn run_raw(&self, inputs: &[&Value]) -> Result<Vec<Value>>;

    /// Forward/backward over one batch shard, *without* the optimizer
    /// apply: inputs are the full global-batch list in manifest order
    /// (`params ++ momenta ++ x, y, extras, lr` — momenta and lr are
    /// ignored), slicing to `leaf` happens inside. Host tensors only: the
    /// data-parallel driver fans these out across worker threads, and
    /// host buffers are the only `Value` form that is `Sync`.
    ///
    /// Backends that cannot decompose a step into grad shards keep this
    /// default and the sharded trainer fails loudly up front.
    fn run_grads(&self, inputs: &[&HostTensor], leaf: &LeafSpec)
                 -> Result<GradOut> {
        let _ = (inputs, leaf);
        bail!("{}: this backend cannot run gradient shards — \
               data-parallel training needs a hermetic backend \
               (AD_BACKEND=reference|sparse)", self.meta().name)
    }
}

/// An execution engine: compile-by-name from the manifest plus tensor
/// upload/download. One per process; cheap handles are shared through
/// [`crate::coordinator::ExecutorCache`] — including across the service
/// layer's concurrent job threads, hence `Send + Sync`. (Backend-resident
/// [`Value`]s carry no such bound: each training session stays pinned to
/// the thread that runs it.)
pub trait Backend: Send + Sync {
    /// Short name for logs/diagnostics ("pjrt" | "reference").
    fn name(&self) -> &'static str;

    /// Compile (or build the interpreter for) one manifest artifact.
    fn compile(&self, manifest: &Manifest, name: &str)
               -> Result<Arc<dyn Executor>>;

    /// Move a host tensor into the backend's resident value form.
    fn upload(&self, t: &HostTensor) -> Result<Value>;

    /// Owned-buffer upload: backends that keep values host-side override
    /// this to take the buffer without a copy.
    fn ingest(&self, t: HostTensor) -> Result<Value> {
        self.upload(&t)
    }

    /// Copy a value back into host form.
    fn download(&self, v: &Value, meta: &TensorMeta) -> Result<HostTensor> {
        let _ = meta; // used by the pjrt arm only
        match v {
            Value::Host(t) => Ok(t.clone()),
            #[cfg(feature = "pjrt")]
            Value::Pjrt(l) => crate::runtime::engine::host_from_literal(
                l, meta),
        }
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    Ok(Arc::new(crate::runtime::engine::PjrtBackend::cpu()?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    bail!("AD_BACKEND=pjrt, but this build was compiled without the \
           `pjrt` cargo feature (cargo build --features pjrt)")
}

/// Which backend the `AD_BACKEND` env var selects — the single source of
/// truth for the env convention, shared by [`backend_from_env`] and
/// `crate::manifest_or_builtin` (which must decide *before* constructing
/// anything). Errors on unknown values so typos surface as themselves,
/// not as a downstream missing-artifacts message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Reference,
    Sparse,
    Pjrt,
}

pub fn backend_kind_from_env() -> Result<BackendKind> {
    match std::env::var("AD_BACKEND").as_deref() {
        Ok("reference") | Ok("ref") => Ok(BackendKind::Reference),
        Ok("sparse") => Ok(BackendKind::Sparse),
        Ok("pjrt") => Ok(BackendKind::Pjrt),
        Ok(other) => bail!("unknown AD_BACKEND '{other}' \
                            (expected reference|sparse|pjrt)"),
        Err(_) => Ok(if cfg!(feature = "pjrt") {
            BackendKind::Pjrt
        } else {
            BackendKind::Reference
        }),
    }
}

/// Whether the `AD_BACKEND` selection resolves to a hermetic host
/// backend (reference or sparse) — those execute the built-in synthetic
/// manifest with no artifacts on disk.
pub fn env_selects_hermetic() -> Result<bool> {
    Ok(backend_kind_from_env()? != BackendKind::Pjrt)
}

/// Select the backend from the `AD_BACKEND` env var: `reference` forces
/// the pure-Rust masked-dense interpreter, `sparse` the structured-sparse
/// compute engine, `pjrt` the PJRT client (error when the feature is
/// compiled out); unset picks PJRT when available, else reference.
pub fn backend_from_env() -> Result<Arc<dyn Backend>> {
    match backend_kind_from_env()? {
        BackendKind::Reference =>
            Ok(Arc::new(crate::runtime::reference::ReferenceBackend::new())),
        BackendKind::Sparse =>
            Ok(Arc::new(crate::runtime::sparse::SparseBackend::new())),
        BackendKind::Pjrt => pjrt_backend(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes_and_scalars() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(!t.is_empty());
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        assert!(t.scalar().is_err());
        let s = HostTensor::scalar_f32(2.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.scalar().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_i32(-3).scalar().unwrap(), -3.0);
    }

    #[test]
    fn check_validates_shape_and_dtype() {
        use crate::runtime::manifest::Kind;
        let meta = TensorMeta {
            name: "w".into(),
            shape: vec![4],
            dtype: Dtype::F32,
            kind: Kind::Param,
        };
        assert!(HostTensor::f32(&[4], vec![0.0; 4]).check(&meta).is_ok());
        assert!(HostTensor::f32(&[5], vec![0.0; 5]).check(&meta).is_err());
        assert!(HostTensor::i32(&[4], vec![0; 4]).check(&meta).is_err());
    }

    #[test]
    fn value_scalar_and_download_roundtrip() {
        let v = Value::Host(HostTensor::scalar_f32(1.5));
        assert_eq!(v.scalar_f64().unwrap(), 1.5);
        let v = Value::Host(HostTensor::f32(&[3], vec![1.0, 2.0, 3.0]));
        assert_eq!(v.to_f32().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(v.as_host().is_ok());
    }

    #[test]
    fn env_selection_reference() {
        // Not a full env test (env vars are process-global); just pin that
        // the explicit constructor path works.
        let b = crate::runtime::reference::ReferenceBackend::new();
        assert_eq!(b.name(), "reference");
    }
}
