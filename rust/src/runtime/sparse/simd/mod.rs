//! SIMD microkernel layer under the structured-sparse kernel library.
//!
//! The sparse kernels (`sparse::kernels`) express every inner loop
//! through three primitive operations on contiguous f32 runs — the
//! [`Microkernel`] contract:
//!
//! * `axpy`      — `y[i] += a * x[i]` (rank-1 panel update),
//! * `axpy2`     — `y[i] += a0 * x0[i] + a1 * x1[i]` (rank-2 fusion:
//!   one load/store of `y` per two panel rows),
//! * `dot_acc`   — `init + Σ x[i] * y[i]` (inner product with a carried
//!   accumulator, so tile-segment walks keep one running sum).
//!
//! Three implementations ship, selected **once per process**:
//!
//! * **avx2** (`x86.rs`) — 8-lane AVX2 + FMA, 2x unrolled (16 floats per
//!   iteration), runtime-detected via `is_x86_feature_detected!`.
//! * **neon** (`neon.rs`) — 4-lane NEON FMA, 2x unrolled (8 floats per
//!   iteration), on aarch64.
//! * **scalar** (`scalar.rs`) — portable unrolled loops whose
//!   accumulation order is **bit-compatible with `DenseKernels`**: plain
//!   mul-then-add, strictly ascending index order, single accumulator.
//!
//! ## Determinism contract
//!
//! Selection happens once (env + CPUID) and never changes within a
//! process, every implementation uses a fixed lane/unroll/reduction
//! order, and the sparse kernels partition outputs disjointly — so
//! results are bit-stable across repetitions, across `AD_THREADS`
//! values, and across calls. Across *implementations* results differ in
//! float rounding only (FMA fuses the multiply-add; vector dot products
//! reduce lanes in a fixed but different association): the SIMD-vs-scalar
//! property suite (`rust/tests/sparse_kernels.rs`) bounds the difference
//! at 1e-5 relative, the same contractual tolerance the hermetic
//! cross-backend parity tests enforce.
//!
//! ## The `AD_SIMD` knob
//!
//! * unset / `on` / `auto` / `1` — use the best microkernel this CPU
//!   supports (AVX2+FMA on x86_64, NEON on aarch64), scalar otherwise.
//! * `off` / `scalar` / `0` — force the portable scalar microkernels
//!   (the escape hatch; also the bit-exact-vs-reference configuration).
//! * anything else — loud warning, then the same default as unset.

use std::sync::OnceLock;

#[cfg(target_arch = "aarch64")]
mod neon;
pub mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

type AxpyFn = unsafe fn(a: f32, x: *const f32, y: *mut f32, n: usize);
type Axpy2Fn = unsafe fn(a0: f32, x0: *const f32, a1: f32,
                         x1: *const f32, y: *mut f32, n: usize);
type DotAccFn = unsafe fn(init: f32, x: *const f32, y: *const f32,
                          n: usize) -> f32;

/// One microkernel implementation: raw-pointer primitives plus the name
/// reports/logs carry. Constructed only by this module, and only for
/// implementations whose CPU features were verified first — that check
/// is what makes the safe wrapper methods sound.
pub struct Microkernel {
    pub name: &'static str,
    axpy: AxpyFn,
    axpy2: Axpy2Fn,
    dot_acc: DotAccFn,
}

impl std::fmt::Debug for Microkernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Microkernel").field("name", &self.name).finish()
    }
}

impl Microkernel {
    /// `y[i] += a * x[i]` over `min(x.len(), y.len())` elements.
    #[inline]
    pub fn axpy(&self, a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: n is within both slices; the implementation's CPU
        // features were runtime-verified before this value was built.
        unsafe { (self.axpy)(a, x.as_ptr(), y.as_mut_ptr(), n) }
    }

    /// `y[i] += a0 * x0[i] + a1 * x1[i]` — bit-identical to
    /// `axpy(a0, x0, y); axpy(a1, x1, y)` in every implementation (the
    /// fusion only saves the intermediate load/store of `y`).
    #[inline]
    pub fn axpy2(&self, a0: f32, x0: &[f32], a1: f32, x1: &[f32],
                 y: &mut [f32]) {
        let n = x0.len().min(x1.len()).min(y.len());
        debug_assert_eq!(x0.len(), y.len());
        debug_assert_eq!(x1.len(), y.len());
        // SAFETY: as in `axpy`.
        unsafe {
            (self.axpy2)(a0, x0.as_ptr(), a1, x1.as_ptr(),
                         y.as_mut_ptr(), n)
        }
    }

    /// `init + Σ x[i] * y[i]` over `min(x.len(), y.len())` elements.
    #[inline]
    pub fn dot_acc(&self, init: f32, x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: as in `axpy`.
        unsafe { (self.dot_acc)(init, x.as_ptr(), y.as_ptr(), n) }
    }
}

/// The portable scalar microkernels (always available; accumulation
/// order bit-compatible with `DenseKernels`).
pub fn scalar() -> &'static Microkernel {
    &scalar::SCALAR
}

/// The best SIMD microkernel this CPU supports, if any. Runtime feature
/// detection — a binary built for generic x86_64 still uses AVX2+FMA on
/// CPUs that have them, and falls back to scalar on CPUs that don't.
#[cfg(target_arch = "x86_64")]
pub fn detected() -> Option<&'static Microkernel> {
    if std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma")
    {
        Some(&x86::AVX2)
    } else {
        None
    }
}

/// The best SIMD microkernel this CPU supports, if any.
#[cfg(target_arch = "aarch64")]
pub fn detected() -> Option<&'static Microkernel> {
    if std::arch::is_aarch64_feature_detected!("neon") {
        Some(&neon::NEON)
    } else {
        None
    }
}

/// No SIMD microkernels on other architectures.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn detected() -> Option<&'static Microkernel> {
    None
}

/// What an `AD_SIMD` value asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Use `detected()`, falling back to scalar.
    Auto,
    /// Force the scalar microkernels.
    Off,
}

/// Parse one `AD_SIMD` value (`None` = unset). Unknown values warn
/// loudly and behave like unset — a typo must not silently change which
/// math runs.
pub fn parse_mode(v: Option<&str>) -> SimdMode {
    match v.map(str::trim) {
        None | Some("") => SimdMode::Auto,
        Some(s) => match s.to_ascii_lowercase().as_str() {
            "on" | "auto" | "1" | "true" => SimdMode::Auto,
            "off" | "scalar" | "0" | "false" => SimdMode::Off,
            other => {
                crate::warn_!("AD_SIMD='{other}' is not one of \
                               on|auto|off|scalar; using auto-detection \
                               (same as unset)");
                SimdMode::Auto
            }
        },
    }
}

/// The process-wide microkernel selection: `AD_SIMD` + CPU detection,
/// resolved once on first use and cached — a process never mixes
/// microkernels behind one backend, which is what keeps repeated steps
/// bit-stable.
pub fn active() -> &'static Microkernel {
    static ACTIVE: OnceLock<&'static Microkernel> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let mk = match parse_mode(std::env::var("AD_SIMD").ok().as_deref())
        {
            SimdMode::Off => scalar(),
            SimdMode::Auto => detected().unwrap_or_else(scalar),
        };
        crate::debug!("sparse microkernel: {}", mk.name);
        mk
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(parse_mode(None), SimdMode::Auto);
        assert_eq!(parse_mode(Some("")), SimdMode::Auto);
        assert_eq!(parse_mode(Some("  ")), SimdMode::Auto);
        assert_eq!(parse_mode(Some("on")), SimdMode::Auto);
        assert_eq!(parse_mode(Some("AUTO")), SimdMode::Auto);
        assert_eq!(parse_mode(Some("1")), SimdMode::Auto);
        assert_eq!(parse_mode(Some("off")), SimdMode::Off);
        assert_eq!(parse_mode(Some("Scalar")), SimdMode::Off);
        assert_eq!(parse_mode(Some("0")), SimdMode::Off);
        // Unknown values fall back to auto (with a warning).
        assert_eq!(parse_mode(Some("fast")), SimdMode::Auto);
    }

    #[test]
    fn scalar_always_available_and_active_is_stable() {
        assert_eq!(scalar().name, "scalar");
        // Whatever `active()` resolves to, it resolves to the same
        // implementation every time (process-wide pin).
        let a = active();
        let b = active();
        assert!(std::ptr::eq(a, b));
    }

    #[test]
    fn scalar_ops_basics() {
        let mk = scalar();
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        mk.axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
        let x1 = [1.0f32, 1.0, 1.0];
        mk.axpy2(1.0, &x, -1.0, &x1, &mut y);
        assert_eq!(y, [12.0, 25.0, 38.0]);
        assert_eq!(mk.dot_acc(0.5, &x, &x1), 0.5 + 6.0);
        // Empty runs are no-ops.
        mk.axpy(3.0, &[], &mut []);
        assert_eq!(mk.dot_acc(1.25, &[], &[]), 1.25);
    }

    #[test]
    fn detected_simd_matches_scalar_on_small_cases() {
        let Some(simd) = detected() else {
            eprintln!("SKIP: no SIMD microkernel on this CPU");
            return;
        };
        assert_ne!(simd.name, "scalar");
        let n = 37; // crosses the vector width + leaves a scalar tail
        let x: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let z: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let mut y0: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
        let mut y1 = y0.clone();
        scalar::SCALAR.axpy(1.5, &x, &mut y0);
        simd.axpy(1.5, &x, &mut y1);
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0),
                    "{a} vs {b}");
        }
        let d0 = scalar::SCALAR.dot_acc(0.25, &x, &z);
        let d1 = simd.dot_acc(0.25, &x, &z);
        assert!((d0 - d1).abs() <= 1e-5 * d0.abs().max(1.0),
                "{d0} vs {d1}");
        // axpy2 == two axpys, bit-identical, in every implementation.
        let mut via_two = y1.clone();
        simd.axpy(0.5, &x, &mut via_two);
        simd.axpy(-0.25, &z, &mut via_two);
        let mut fused = y1.clone();
        simd.axpy2(0.5, &x, -0.25, &z, &mut fused);
        assert_eq!(via_two, fused);
    }

    #[test]
    fn simd_results_bit_stable_across_reps() {
        let mk = active();
        let n = 133;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
        let z: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).cos()).collect();
        let runs: Vec<u32> = (0..3)
            .map(|_| mk.dot_acc(1.0, &x, &z).to_bits())
            .collect();
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[0], runs[2]);
        let mut y0 = vec![0.5f32; n];
        let mut y1 = vec![0.5f32; n];
        mk.axpy2(0.3, &x, 0.9, &z, &mut y0);
        mk.axpy2(0.3, &x, 0.9, &z, &mut y1);
        assert_eq!(y0, y1);
    }
}
