//! Synthetic MNIST substitute (DESIGN.md section 5/6).
//!
//! The image ships no datasets, so we synthesize a 10-class 28x28
//! grayscale digit task: each class has a hand-authored 7x5 glyph bitmap
//! that is rendered with a random affine transform (translation, scale,
//! shear, rotation), stroke smoothing, and pixel noise. The task is
//! learnable to >=97% by the paper's MLPs while leaving headroom for
//! dropout-variant differences — which is all the experiments compare.

use crate::util::rng::Rng;

pub const IMG_SIDE: usize = 28;
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
pub const N_CLASSES: usize = 10;

/// 7x5 glyph bitmaps, row-major, '#' = ink. Classic 5x7 font digits.
const GLYPHS: [[&str; 7]; 10] = [
    [" ### ", "#   #", "#  ##", "# # #", "##  #", "#   #", " ### "], // 0
    ["  #  ", " ##  ", "  #  ", "  #  ", "  #  ", "  #  ", " ### "], // 1
    [" ### ", "#   #", "    #", "   # ", "  #  ", " #   ", "#####"], // 2
    [" ### ", "#   #", "    #", "  ## ", "    #", "#   #", " ### "], // 3
    ["   # ", "  ## ", " # # ", "#  # ", "#####", "   # ", "   # "], // 4
    ["#####", "#    ", "#### ", "    #", "    #", "#   #", " ### "], // 5
    [" ### ", "#    ", "#    ", "#### ", "#   #", "#   #", " ### "], // 6
    ["#####", "    #", "   # ", "  #  ", "  #  ", "  #  ", "  #  "], // 7
    [" ### ", "#   #", "#   #", " ### ", "#   #", "#   #", " ### "], // 8
    [" ### ", "#   #", "#   #", " ####", "    #", "    #", " ### "], // 9
];

/// A generated dataset: row-major images in [0,1], one label per image.
#[derive(Clone, Debug)]
pub struct MnistSyn {
    pub images: Vec<f32>, // n * IMG_PIXELS
    pub labels: Vec<u8>,
    pub n: usize,
}

impl MnistSyn {
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }

    /// Generate `n` samples, classes uniform, fully determined by `seed`.
    pub fn generate(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut images = Vec::with_capacity(n * IMG_PIXELS);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.next_usize(N_CLASSES);
            labels.push(class as u8);
            render_digit(class, &mut rng, &mut images);
        }
        MnistSyn { images, labels, n }
    }

    /// Standard train/test pair with disjoint seeds.
    pub fn train_test(n_train: usize, n_test: usize, seed: u64)
                      -> (Self, Self) {
        (Self::generate(n_train, seed),
         Self::generate(n_test, seed ^ 0xDEAD_BEEF_0BAD_F00D))
    }
}

/// Render one jittered glyph into `out` (appends IMG_PIXELS values).
fn render_digit(class: usize, rng: &mut Rng, out: &mut Vec<f32>) {
    let glyph = &GLYPHS[class];
    // Random affine: output pixel -> glyph coordinates (inverse mapping).
    let scale = rng.uniform(0.85, 1.15);
    let angle = rng.uniform(-0.18, 0.18);
    let shear = rng.uniform(-0.15, 0.15);
    let dx = rng.uniform(-3.0, 3.0);
    let dy = rng.uniform(-3.0, 3.0);
    let noise = 0.08;
    let (sin, cos) = angle.sin_cos();

    // Glyph cell size in output pixels (glyph spans ~20x21 px box).
    let cell_w = 4.0 * scale;
    let cell_h = 3.0 * scale;
    let cx = IMG_SIDE as f64 / 2.0 + dx;
    let cy = IMG_SIDE as f64 / 2.0 + dy;

    let start = out.len();
    for py in 0..IMG_SIDE {
        for px in 0..IMG_SIDE {
            // Map output pixel to glyph-space coordinates.
            let ox = px as f64 - cx;
            let oy = py as f64 - cy;
            let rx = cos * ox + sin * oy + shear * oy;
            let ry = -sin * ox + cos * oy;
            let gx = rx / cell_h + 2.5; // glyph is 5 wide
            let gy = ry / cell_w + 3.5; // and 7 tall
            let ink = sample_glyph(glyph, gx, gy);
            let v = ink + noise * rng.normal() as f64;
            out.push(v.clamp(0.0, 1.0) as f32);
        }
    }
    debug_assert_eq!(out.len() - start, IMG_PIXELS);
}

/// Bilinear sample of the glyph bitmap with soft edges.
fn sample_glyph(glyph: &[&str; 7], gx: f64, gy: f64) -> f64 {
    let at = |x: i64, y: i64| -> f64 {
        if !(0..5).contains(&x) || !(0..7).contains(&y) {
            return 0.0;
        }
        if glyph[y as usize].as_bytes()[x as usize] == b'#' {
            1.0
        } else {
            0.0
        }
    };
    let x0 = gx.floor();
    let y0 = gy.floor();
    let fx = gx - x0;
    let fy = gy - y0;
    let (x0, y0) = (x0 as i64, y0 as i64);
    let v = at(x0, y0) * (1.0 - fx) * (1.0 - fy)
        + at(x0 + 1, y0) * fx * (1.0 - fy)
        + at(x0, y0 + 1) * (1.0 - fx) * fy
        + at(x0 + 1, y0 + 1) * fx * fy;
    // Soften into a stroke-like intensity.
    (v * 1.4).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = MnistSyn::generate(32, 99);
        let b = MnistSyn::generate(32, 99);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
    }

    #[test]
    fn shapes_and_ranges() {
        let d = MnistSyn::generate(100, 7);
        assert_eq!(d.images.len(), 100 * IMG_PIXELS);
        assert_eq!(d.labels.len(), 100);
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.labels.iter().all(|&l| (l as usize) < N_CLASSES));
    }

    #[test]
    fn classes_roughly_uniform() {
        let d = MnistSyn::generate(10_000, 3);
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "counts {counts:?}");
        }
    }

    #[test]
    fn images_have_ink_and_background() {
        let d = MnistSyn::generate(64, 11);
        for i in 0..d.n {
            let img = d.image(i);
            let ink = img.iter().filter(|&&v| v > 0.5).count();
            assert!(ink > 20, "sample {i}: too little ink ({ink} px)");
            assert!(ink < IMG_PIXELS / 2,
                    "sample {i}: too much ink ({ink} px)");
        }
    }

    #[test]
    fn same_class_varies_between_samples() {
        // Jitter must actually vary renders, otherwise the task is a
        // 10-template lookup and dropout comparisons are meaningless.
        let d = MnistSyn::generate(200, 13);
        let mut by_class: std::collections::BTreeMap<u8, Vec<usize>> =
            Default::default();
        for i in 0..d.n {
            by_class.entry(d.labels[i]).or_default().push(i);
        }
        for (c, idxs) in by_class {
            if idxs.len() < 2 {
                continue;
            }
            let a = d.image(idxs[0]);
            let b = d.image(idxs[1]);
            let diff: f32 =
                a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum();
            assert!(diff > 1.0, "class {c}: renders nearly identical");
        }
    }

    #[test]
    fn train_test_disjoint_streams() {
        let (tr, te) = MnistSyn::train_test(50, 50, 42);
        assert_ne!(tr.images[..IMG_PIXELS], te.images[..IMG_PIXELS]);
    }
}
