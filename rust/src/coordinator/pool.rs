//! Process-wide executor cache: one compiled executor per
//! (model, variant, dp) artifact, compiled lazily on first use and shared
//! by every trainer in the process. This mirrors the paper's setup where
//! the pattern distribution (and hence the set of matrix shapes) is fixed
//! before training starts — compilation is a one-time cost off the
//! steady-state hot path, and a baseline-vs-variant comparison (the
//! paper's headline measurement) compiles each artifact exactly once no
//! matter how many trainers run.
//!
//! The cache is generic over the execution [`Backend`]: PJRT compiles HLO
//! artifacts, the reference backend builds interpreters from the manifest
//! alone. The handle is cheap to clone (`Arc` all the way down); clones
//! share the underlying map. Lookups take a read lock on the hit path and
//! upgrade to a write lock only to compile, using the `HashMap` entry API
//! so a miss costs a single hash probe under the write lock.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::Result;

use crate::runtime::{backend_from_env, Backend, Executor, Manifest,
                     ReferenceBackend};
use crate::util::Timer;

#[derive(Clone)]
pub struct ExecutorCache {
    backend: Arc<dyn Backend>,
    manifest: Arc<Manifest>,
    exes: Arc<RwLock<HashMap<String, Arc<dyn Executor>>>>,
    /// Compile wall-clock per artifact (diagnostics / EXPERIMENTS Perf).
    compile_log: Arc<Mutex<Vec<(String, f64)>>>,
}

impl ExecutorCache {
    pub fn new(backend: Arc<dyn Backend>, manifest: Manifest) -> Self {
        ExecutorCache {
            backend,
            manifest: Arc::new(manifest),
            exes: Arc::new(RwLock::new(HashMap::new())),
            compile_log: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Cache over the pure-Rust reference backend (hermetic: no
    /// artifacts, no PJRT).
    pub fn reference(manifest: Manifest) -> Self {
        Self::new(Arc::new(ReferenceBackend::new()), manifest)
    }

    /// Cache over the PJRT CPU client.
    #[cfg(feature = "pjrt")]
    pub fn pjrt_cpu(manifest: Manifest) -> Result<Self> {
        Ok(Self::new(Arc::new(crate::runtime::PjrtBackend::cpu()?),
                     manifest))
    }

    /// Backend selected by `AD_BACKEND` (reference|pjrt); defaults to
    /// PJRT when compiled in, reference otherwise.
    pub fn from_env(manifest: Manifest) -> Result<Self> {
        Ok(Self::new(backend_from_env()?, manifest))
    }

    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (compiling if needed) the executor for `name`. The returned
    /// `Arc` is independent of the cache's locks, so callers hold no borrow
    /// across the subsequent execute.
    pub fn get(&self, name: &str) -> Result<Arc<dyn Executor>> {
        if let Some(exe) = self.exes.read().expect("cache lock").get(name) {
            return Ok(Arc::clone(exe));
        }
        // Compilation runs under the write lock on purpose: it guarantees
        // each artifact compiles exactly once process-wide (the invariant
        // the benches and tests assert via `compile_times_s`). Readers
        // briefly queue behind a first-time compile; steady-state hits
        // never touch the write lock.
        let mut map = self.exes.write().expect("cache lock");
        match map.entry(name.to_string()) {
            // Another trainer may have compiled it between the locks.
            Entry::Occupied(e) => Ok(Arc::clone(e.get())),
            Entry::Vacant(slot) => {
                let t = Timer::start();
                let exe = self.backend.compile(&self.manifest, name)?;
                let dt = t.elapsed_s();
                crate::debug!("compiled {name} in {dt:.2}s \
                               ({})", self.backend.name());
                self.compile_log
                    .lock()
                    .expect("compile log lock")
                    .push((name.to_string(), dt));
                Ok(Arc::clone(slot.insert(exe)))
            }
        }
    }

    /// Pre-compile a list of artifacts (e.g. every dp combo a schedule can
    /// sample) so training loops never stall on compilation.
    pub fn warm(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Number of compiled executors currently cached.
    pub fn len(&self) -> usize {
        self.exes.read().expect("cache lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of (artifact name, compile seconds), one entry per compile
    /// actually performed — a shared cache therefore lists each artifact
    /// at most once.
    pub fn compile_times_s(&self) -> Vec<(String, f64)> {
        self.compile_log.lock().expect("compile log lock").clone()
    }

    /// Total compilation wall-clock absorbed by this cache.
    pub fn total_compile_s(&self) -> f64 {
        self.compile_log
            .lock()
            .expect("compile log lock")
            .iter()
            .map(|(_, s)| s)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_cache_compiles_once_and_counts() {
        let cache = ExecutorCache::reference(Manifest::builtin_test());
        assert!(cache.is_empty());
        let a = cache.get("mlptest_rdp_2_2").unwrap();
        let b = cache.get("mlptest_rdp_2_2").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hit must return the same executor");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.compile_times_s().len(), 1);
        assert!(cache.total_compile_s() >= 0.0);
        assert!(cache.get("nonexistent").is_err());
        // Clones share the map.
        let clone = cache.clone();
        clone.get("mlptest_eval").unwrap();
        assert_eq!(cache.len(), 2);
    }
}
