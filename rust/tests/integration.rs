//! Integration tests over the full PJRT stack: manifest -> PJRT compile
//! -> train/eval execution -> state update. Uses the tiny
//! `mlptest`/`lstmtest` artifacts built by `make artifacts` (aot.py
//! --set test is a subset of the default set).
//!
//! This suite is artifact-dependent by nature (it exists to validate the
//! AOT path), so it compiles only with the `pjrt` feature and — when the
//! artifacts or the PJRT client are unavailable — prints ONE loud skip
//! line and returns instead of panicking mid-suite. The hermetic
//! equivalents of these behaviors live in `rust/tests/hermetic.rs` and
//! `rust/tests/driver.rs`, which never skip.
#![cfg(feature = "pjrt")]

mod common;

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::{Executor, HostTensor, Manifest, TrainState,
                              Value};
use approx_dropout::util::rng::Rng;

use common::host_mlp_eval;

/// PJRT cache over the artifacts directory, or None with one loud
/// explanation on the first call.
fn setup() -> Option<ExecutorCache> {
    static WARN: std::sync::Once = std::sync::Once::new();
    let dir = approx_dropout::artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            WARN.call_once(|| eprintln!(
                "SKIP (pjrt integration suite): no artifacts manifest at \
                 {} — run `make artifacts` to enable these tests ({e:#})",
                dir.display()));
            return None;
        }
    };
    match ExecutorCache::pjrt_cpu(manifest) {
        Ok(c) => Some(c),
        Err(e) => {
            WARN.call_once(|| eprintln!(
                "SKIP (pjrt integration suite): PJRT CPU client \
                 unavailable: {e:#}"));
            None
        }
    }
}

#[test]
fn eval_graph_matches_host_forward() {
    let Some(cache) = setup() else { return };
    let exe = cache.get("mlptest_eval").unwrap();
    let backend = cache.backend().clone();
    let mut rng = Rng::new(7);
    let meta = cache.manifest().get("mlptest_conv").unwrap();
    let state = TrainState::init(meta, &mut rng, backend.as_ref()).unwrap();

    let batch = 8;
    let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.next_usize(10) as i32).collect();

    let x_v = backend
        .upload(&HostTensor::f32(&[batch, 32], x.clone()))
        .unwrap();
    let y_v = backend
        .upload(&HostTensor::i32(&[batch], y.clone()))
        .unwrap();
    let mut refs = state.param_refs();
    refs.push(&x_v);
    refs.push(&y_v);
    let out = exe.run_raw(&refs).unwrap();
    let loss_dev = out[0].scalar_f64().unwrap();
    let correct_dev = out[1].scalar_f64().unwrap();

    let host_params: Vec<Vec<f32>> =
        (0..6).map(|i| state.param_f32(i).unwrap()).collect();
    let (loss_host, correct_host) = host_mlp_eval(&host_params, &x, &y,
                                                  batch);
    assert!((loss_dev - loss_host).abs() < 1e-4,
            "device {loss_dev} vs host {loss_host}");
    assert_eq!(correct_dev, correct_host);
}

#[test]
fn trainer_constructs_and_names_executables() {
    let Some(cache) = setup() else { return };
    let schedule =
        Schedule::new(Variant::Conv, &[0.5, 0.5], &[1, 2], false).unwrap();
    let tr = MlpTrainer::new(&cache, "mlptest", schedule, 64, 0.05, 11)
        .unwrap();
    assert_eq!(tr.executable_names(), vec!["mlptest_conv".to_string()]);
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
    let tr = MlpTrainer::new(&cache, "mlptest", schedule, 64, 0.05, 11)
        .unwrap();
    assert_eq!(tr.executable_names(), vec!["mlptest_rdp_2_2".to_string()]);
}

fn run_step(cache: &ExecutorCache, state: &mut TrainState,
            exe: &dyn Executor, rng: &mut Rng, b0: (i32, i32), lr: f32)
            -> (f64, f64) {
    let backend = cache.backend();
    let batch = 8;
    let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.next_usize(10) as i32).collect();
    let tail: Vec<Value> = vec![
        backend.upload(&HostTensor::f32(&[batch, 32], x)).unwrap(),
        backend.upload(&HostTensor::i32(&[batch], y)).unwrap(),
        backend.upload(&HostTensor::scalar_i32(b0.0)).unwrap(),
        backend.upload(&HostTensor::scalar_i32(b0.1)).unwrap(),
        // inverted-dropout scales, sites 1 and 2
        backend.upload(&HostTensor::scalar_f32(2.0)).unwrap(),
        backend.upload(&HostTensor::scalar_f32(2.0)).unwrap(),
        backend.upload(&HostTensor::scalar_f32(lr)).unwrap(),
    ];
    state.step(exe, &tail).unwrap()
}

#[test]
fn rdp_step_loss_finite_and_state_changes() {
    let Some(cache) = setup() else { return };
    let exe = cache.get("mlptest_rdp_2_2").unwrap();
    let mut rng = Rng::new(21);
    let meta = cache.manifest().get("mlptest_rdp_2_2").unwrap();
    let mut state =
        TrainState::init(meta, &mut rng, cache.backend().as_ref())
            .unwrap();
    let before = state.param_f32(0).unwrap();
    let (loss, correct) = run_step(&cache, &mut state, exe.as_ref(),
                                   &mut rng, (1, 0), 0.1);
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert!((0.0..=8.0).contains(&correct));
    let after = state.param_f32(0).unwrap();
    assert_ne!(before, after, "params must change after one step");
    assert_eq!(state.step, 1);
}

#[test]
fn rdp_only_kept_rows_update_in_w3() {
    // RDP drops entire rows of the next layer's weight matrix: the
    // gradient (hence the update) of dropped rows of w3 must be zero.
    let Some(cache) = setup() else { return };
    let exe = cache.get("mlptest_rdp_2_2").unwrap();
    let mut rng = Rng::new(33);
    let meta = cache.manifest().get("mlptest_rdp_2_2").unwrap();
    let mut state =
        TrainState::init(meta, &mut rng, cache.backend().as_ref())
            .unwrap();
    let w3_before = state.param_f32(4).unwrap();

    let b0_1 = 1; // site-2 pattern: keep rows {1, 3, 5, ...}
    run_step(&cache, &mut state, exe.as_ref(), &mut rng, (0, b0_1), 0.1);
    let w3_after = state.param_f32(4).unwrap();

    // w3 shape [64, 10]; rows with i % 2 == b0_1 kept, others frozen.
    let mut kept_changed = 0;
    for i in 0..64 {
        let row_changed = (0..10)
            .any(|j| w3_before[i * 10 + j] != w3_after[i * 10 + j]);
        if i % 2 == b0_1 as usize {
            kept_changed += usize::from(row_changed);
        } else {
            // The exact claim of the pattern: dropped rows receive NO
            // gradient and are bit-identical after the step.
            assert!(!row_changed, "dropped row {i} must be frozen");
        }
    }
    // Kept rows update unless their ReLU unit is dead for the whole batch;
    // with random init most must move.
    assert!(kept_changed >= 16,
            "only {kept_changed}/32 kept rows updated");
}

#[test]
fn tdp_step_runs() {
    let Some(cache) = setup() else { return };
    let exe = cache.get("mlptest_tdp_2_2").unwrap();
    let mut rng = Rng::new(5);
    let meta = cache.manifest().get("mlptest_tdp_2_2").unwrap();
    let mut state =
        TrainState::init(meta, &mut rng, cache.backend().as_ref())
            .unwrap();
    let (loss, _) = run_step(&cache, &mut state, exe.as_ref(), &mut rng,
                             (1, 0), 0.1);
    assert!(loss.is_finite());
}

#[test]
fn lstm_trainer_end_to_end_tiny() {
    let Some(cache) = setup() else { return };
    let corpus = Corpus::generate(64, 4000, 400, 400, 9);
    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        let shared = variant != Variant::Conv;
        let schedule =
            Schedule::new(variant, &[0.5, 0.5], &[2], shared).unwrap();
        let mut tr = LstmTrainer::new(&cache, "lstmtest", schedule,
                                      &corpus.train, 0.5, 13)
            .unwrap();
        tr.warmup().unwrap();
        let first = tr.step().unwrap().0;
        for _ in 0..10 {
            tr.step().unwrap();
        }
        let last = tr.metrics.last_loss();
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first + 0.5,
                "{variant:?}: loss diverged {first} -> {last}");
        let (xent, ppl, acc) = tr.evaluate(&corpus.valid).unwrap();
        assert!(xent.is_finite() && ppl > 1.0 && (0.0..=1.0).contains(&acc));
    }
}

#[test]
fn mlp_trainer_learns_real_digits() {
    // Short but real training on the synthetic MNIST through the 784-dim
    // arch when the full artifact set is present.
    let Some(cache) = setup() else { return };
    if cache.manifest().get("mlp1024x64_conv").is_err() {
        eprintln!("SKIP mlp_trainer_learns_real_digits: artifact subset \
                   build (no mlp1024x64)");
        return;
    }
    let data = MnistSyn::generate(512, 3);
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2], true).unwrap();
    let mut tr = MlpTrainer::new(&cache, "mlp1024x64", schedule, data.n,
                                 0.01, 7).unwrap();
    tr.warmup().unwrap();
    let mut first_loss = 0.0;
    let mut last_loss = 0.0;
    let steps = 60;
    for s in 0..steps {
        let (loss, _) = tr.step(&data).unwrap();
        if s < 10 {
            first_loss += loss / 10.0;
        }
        if s >= steps - 10 {
            last_loss += loss / 10.0;
        }
    }
    assert!(last_loss < first_loss,
            "no learning: loss {first_loss:.3} -> {last_loss:.3}");
}

#[test]
fn deterministic_given_seed() {
    let Some(cache) = setup() else { return };
    let corpus = Corpus::generate(64, 3000, 300, 300, 17);
    let run = |seed: u64| -> Vec<f64> {
        let schedule =
            Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
        let mut tr = LstmTrainer::new(&cache, "lstmtest", schedule,
                                      &corpus.train, 0.5, seed)
            .unwrap();
        (0..5).map(|_| tr.step().unwrap().0).collect()
    };
    assert_eq!(run(99), run(99));
    assert_ne!(run(99), run(100));
}
