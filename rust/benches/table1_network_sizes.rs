//! Table I — "Comparing different network with specific dropout rate":
//! hidden sizes 1024x64 / 1024x1024 / 2048x2048 / 4096x4096 at rate
//! (0.7, 0.7), ROW and TILE patterns.
//!
//! Paper shape to reproduce: speedup grows with network size — ROW 1.27 ->
//! 2.16, TILE 1.19 -> 1.95; accuracy within 0.5% of baseline.

use approx_dropout::bench::drivers::{fmt_opt_pct, run_mlp, BenchCtx};
use approx_dropout::bench::{fmt_time, Table};
use approx_dropout::coordinator::{speedup, Variant};
use approx_dropout::data::MnistSyn;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new()?;
    let (train, test) = MnistSyn::train_test(8_192, 2_048, 7);
    println!("== Table I: network-size sweep @ rate (0.7, 0.7), {} timed \
              steps/config ==", ctx.timed_steps);

    // Table I archs use shared-dp sampling (diagonal artifact set).
    let archs = ["mlp1024x64", "mlp1024x1024", "mlp2048x2048",
                 "mlp4096x4096"];
    let rr = [0.7, 0.7];
    let mut table = Table::new(&["network", "pattern", "step", "speedup",
                                 "accuracy"]);
    for tag in archs {
        let (t_conv, _) = run_mlp(&ctx, tag, Variant::Conv, &rr, false,
                                  &train, &test, 42)?;
        for (label, variant) in [("ROW", Variant::Rdp),
                                 ("TILE", Variant::Tdp)] {
            let (t, acc) = run_mlp(&ctx, tag, variant, &rr, true, &train,
                                   &test, 42)?;
            table.row(&[
                tag.trim_start_matches("mlp").to_string(),
                label.to_string(),
                fmt_time(t),
                format!("{:.2}x", speedup(t_conv, t)),
                fmt_opt_pct(acc),
            ]);
            println!("  {tag} {label}: {:.2}x", speedup(t_conv, t));
        }
    }
    println!();
    table.print();
    println!("\npaper: ROW 1.27/1.45/1.77/2.16, TILE 1.19/1.41/1.60/1.95 \
              — speedup must GROW with network size");
    Ok(())
}
