"""L1 Pallas kernel: tiled dense matmul.

This is the compute hot-spot of the whole stack: every dropout variant
ultimately funnels into a dense matmul over *compacted* operands (the paper's
"compact matrices" built in GPU shared memory; here the HBM->VMEM tiling is
expressed with BlockSpec). The kernel is differentiable via a custom VJP that
reuses itself for both operand gradients, so the exported train-step graphs
contain only this kernel plus cheap gather/scatter glue.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is traced to plain HLO (see DESIGN.md
section "Hardware-Adaptation").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Largest block edge we allow. 256 keeps the VMEM footprint of one grid step
# at (256*256*3)*4B = 768 KiB << 16 MiB while giving the MXU large tiles.
_BLOCK_CAP = 256


def pick_block(dim: int, cap: int = _BLOCK_CAP) -> int:
    """Largest divisor of ``dim`` that is <= cap.

    Shapes in this project are chosen so this is large (powers of two, or
    1500-style composites); the worst case degrades to small blocks but stays
    correct.
    """
    if dim <= cap:
        return dim
    for b in range(cap, 0, -1):
        if dim % b == 0:
            return b
    return 1


def _mm_kernel(a_ref, b_ref, o_ref):
    """One (i, j, h) grid step: accumulate a (bm x bk) @ (bk x bn) product."""
    h = pl.program_id(2)

    @pl.when(h == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _matmul_fwd_impl(a: jax.Array, b: jax.Array) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm, bn, bk = pick_block(m), pick_block(n), pick_block(k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """``a @ b`` through the Pallas tiled kernel (differentiable)."""
    return _matmul_fwd_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_fwd_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # dA = g @ B^T, dB = A^T @ g — both through the same Pallas kernel so the
    # backward pass exercises the identical HBM->VMEM schedule.
    da = _matmul_fwd_impl(g, b.T)
    db = _matmul_fwd_impl(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
