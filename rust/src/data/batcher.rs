//! Batch iterators: shuffled epochs for image classification, contiguous
//! BPTT windows for language modeling (the standard PTB protocol).
//!
//! Both batchers fill caller-owned buffers (`*_into`): the coordinator's
//! step assembly owns its tail tensors (the pipelined path ships them
//! across a thread), and reusing the caller's Vec capacity keeps the
//! steady state down to the one unavoidable copy out of the dataset.

use anyhow::{bail, Result};

use crate::data::mnist::{MnistSyn, IMG_PIXELS};
use crate::util::rng::Rng;

/// Shuffled mini-batch iterator over an image dataset.
#[derive(Debug)]
pub struct MnistBatcher {
    order: Vec<usize>,
    cursor: usize,
    pub batch: usize,
    pub epoch: usize,
}

impl MnistBatcher {
    /// A batcher over `n` samples. `batch` must satisfy
    /// `1 <= batch <= n`: the reshuffle branch in
    /// [`Self::next_batch_into`] resets `cursor = 0` and then slices
    /// `order[0..batch]`, so a batch larger than the dataset would
    /// surface later as an out-of-range slice panic mid-training —
    /// reject it here, loudly, as the config error it is.
    pub fn new(n: usize, batch: usize) -> Result<Self> {
        if batch == 0 || batch > n {
            bail!("batch size {batch} is invalid for a {n}-sample \
                   dataset (need 1 <= batch <= n; shrink --batch or \
                   raise --n-train)");
        }
        Ok(MnistBatcher {
            order: (0..n).collect(),
            cursor: usize::MAX, // force shuffle on first call
            batch,
            epoch: 0,
        })
    }

    /// Fill the next batch from `data` into `x` ([batch * 784]) and `y`
    /// ([batch]); buffers are cleared first and their capacity is reused
    /// across calls. Reshuffles at epoch boundaries (drops the ragged
    /// tail batch, as Caffe does).
    pub fn next_batch_into(&mut self, data: &MnistSyn, rng: &mut Rng,
                           x: &mut Vec<f32>, y: &mut Vec<i32>) {
        if self.cursor == usize::MAX
            || self.cursor + self.batch > self.order.len()
        {
            rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        x.clear();
        y.clear();
        x.reserve(self.batch * IMG_PIXELS);
        y.reserve(self.batch);
        for &i in &self.order[self.cursor..self.cursor + self.batch] {
            x.extend_from_slice(data.image(i));
            y.push(data.labels[i] as i32);
        }
        self.cursor += self.batch;
    }

    /// Checkpoint view: (shuffled order, cursor, epoch). `cursor ==
    /// usize::MAX` is the "first call pending" sentinel — a resumed run
    /// must reproduce the mid-epoch shuffle exactly, so the order vector
    /// is part of the state, not re-derivable.
    pub fn snapshot(&self) -> (Vec<usize>, usize, usize) {
        (self.order.clone(), self.cursor, self.epoch)
    }

    /// Restore a [`MnistBatcher::snapshot`]. Rejects snapshots that are
    /// not a permutation of this batcher's index range or whose cursor is
    /// out of bounds — a corrupt checkpoint must not surface later as a
    /// silent out-of-range panic mid-training.
    pub fn restore(&mut self, order: Vec<usize>, cursor: usize,
                   epoch: usize) -> Result<()> {
        if order.len() != self.order.len() {
            bail!("batcher restore: order has {} entries, dataset has {}",
                  order.len(), self.order.len());
        }
        let mut seen = vec![false; order.len()];
        for &i in &order {
            if i >= seen.len() || seen[i] {
                bail!("batcher restore: order is not a permutation of \
                       0..{}", seen.len());
            }
            seen[i] = true;
        }
        if cursor != usize::MAX && cursor > order.len() {
            bail!("batcher restore: cursor {cursor} out of range (n = {})",
                  order.len());
        }
        self.order = order;
        self.cursor = cursor;
        self.epoch = epoch;
        Ok(())
    }
}

/// Contiguous BPTT batcher: the token stream is laid out as `batch`
/// parallel contiguous tracks; each call yields the next `seq`-token
/// window with targets shifted by one. x/y layout: [batch, seq] row-major.
#[derive(Debug)]
pub struct BpttBatcher {
    tracks: Vec<i32>, // batch x track_len, row-major
    track_len: usize,
    pub batch: usize,
    pub seq: usize,
    pos: usize,
    pub epoch: usize,
}

impl BpttBatcher {
    /// A BPTT batcher over a token stream. Same construction-time
    /// validation policy as [`MnistBatcher::new`]: an undersized corpus
    /// is a loud config error here, not a slice panic in the first
    /// `next_window_into` call.
    pub fn new(tokens: &[i32], batch: usize, seq: usize) -> Result<Self> {
        if batch == 0 || seq == 0 {
            bail!("bptt batcher needs batch >= 1 and seq >= 1 \
                   (got batch={batch}, seq={seq})");
        }
        let track_len = tokens.len() / batch;
        if track_len <= seq {
            bail!("corpus of {} tokens is too small for batch={batch} x \
                   seq={seq} (each of the {batch} parallel tracks holds \
                   {track_len} tokens; need > seq — shrink --batch/--seq \
                   or raise --tokens)", tokens.len());
        }
        let mut tracks = vec![0i32; batch * track_len];
        for b in 0..batch {
            tracks[b * track_len..(b + 1) * track_len]
                .copy_from_slice(&tokens[b * track_len..(b + 1) * track_len]);
        }
        Ok(BpttBatcher { tracks, track_len, batch, seq, pos: 0, epoch: 0 })
    }

    /// Number of windows per epoch.
    pub fn windows_per_epoch(&self) -> usize {
        (self.track_len - 1) / self.seq
    }

    /// Fill the next BPTT window into caller-owned buffers (cleared
    /// first; capacity is reused across calls).
    pub fn next_window_into(&mut self, x: &mut Vec<i32>, y: &mut Vec<i32>) {
        if self.pos + self.seq + 1 > self.track_len {
            self.pos = 0;
            self.epoch += 1;
        }
        x.clear();
        y.clear();
        x.reserve(self.batch * self.seq);
        y.reserve(self.batch * self.seq);
        for b in 0..self.batch {
            let base = b * self.track_len + self.pos;
            x.extend_from_slice(&self.tracks[base..base + self.seq]);
            y.extend_from_slice(&self.tracks[base + 1..base + self.seq + 1]);
        }
        self.pos += self.seq;
    }

    /// Tokens per parallel track (checkpoint validation: a resumed
    /// batcher must be built over an identically-sized corpus).
    pub fn track_len(&self) -> usize {
        self.track_len
    }

    /// Checkpoint view: (pos, epoch). The tracks themselves are rebuilt
    /// deterministically from the corpus at reconstruction time.
    pub fn snapshot(&self) -> (usize, usize) {
        (self.pos, self.epoch)
    }

    /// Restore a [`BpttBatcher::snapshot`]; rejects an out-of-range
    /// position (corrupt checkpoint) up front.
    pub fn restore(&mut self, pos: usize, epoch: usize) -> Result<()> {
        if pos > self.track_len {
            bail!("bptt restore: pos {pos} beyond track length {}",
                  self.track_len);
        }
        self.pos = pos;
        self.epoch = epoch;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist::MnistSyn;

    fn mnist_next(b: &mut MnistBatcher, data: &MnistSyn, rng: &mut Rng)
                  -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        b.next_batch_into(data, rng, &mut x, &mut y);
        (x, y)
    }

    fn bptt_next(b: &mut BpttBatcher) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        b.next_window_into(&mut x, &mut y);
        (x, y)
    }

    #[test]
    fn mnist_batches_cover_epoch_without_repeats() {
        let data = MnistSyn::generate(64, 1);
        let mut b = MnistBatcher::new(64, 16).unwrap();
        let mut rng = Rng::new(2);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let (_, y) = mnist_next(&mut b, &data, &mut rng);
            assert_eq!(y.len(), 16);
            // Track coverage via the shuffled order indices instead of
            // labels (labels repeat); recover by comparing x rows.
            seen.extend(y.iter().cloned().map(|v| v as i64));
        }
        assert_eq!(b.epoch, 1);
        // After one epoch a new shuffle starts.
        mnist_next(&mut b, &data, &mut rng);
        assert_eq!(b.epoch, 2);
        assert!(!seen.is_empty());
    }

    #[test]
    fn mnist_batch_contents_match_dataset() {
        let data = MnistSyn::generate(32, 3);
        let mut b = MnistBatcher::new(32, 8).unwrap();
        let mut rng = Rng::new(4);
        let (x, y) = mnist_next(&mut b, &data, &mut rng);
        // Every batch row must be an exact dataset image with its label.
        for bi in 0..8 {
            let row = &x[bi * IMG_PIXELS..(bi + 1) * IMG_PIXELS];
            let found = (0..data.n).any(|i| {
                data.image(i) == row && data.labels[i] as i32 == y[bi]
            });
            assert!(found, "batch row {bi} not found in dataset");
        }
    }

    #[test]
    fn mnist_buffer_capacity_is_reused() {
        let data = MnistSyn::generate(32, 5);
        let mut b = MnistBatcher::new(32, 8).unwrap();
        let mut rng = Rng::new(6);
        let mut x = Vec::new();
        let mut y = Vec::new();
        b.next_batch_into(&data, &mut rng, &mut x, &mut y);
        let (cx, cy) = (x.capacity(), y.capacity());
        let px = x.as_ptr();
        b.next_batch_into(&data, &mut rng, &mut x, &mut y);
        assert_eq!(x.len(), 8 * IMG_PIXELS);
        assert_eq!((x.capacity(), y.capacity()), (cx, cy));
        assert_eq!(x.as_ptr(), px, "no reallocation in steady state");
    }

    #[test]
    fn mnist_snapshot_restore_resumes_identically() {
        let data = MnistSyn::generate(48, 9);
        let mut a = MnistBatcher::new(48, 8).unwrap();
        let mut rng_a = Rng::new(21);
        for _ in 0..3 {
            mnist_next(&mut a, &data, &mut rng_a);
        }
        let (order, cursor, epoch) = a.snapshot();
        let rng_snap = rng_a.state();
        let ahead: Vec<_> =
            (0..5).map(|_| mnist_next(&mut a, &data, &mut rng_a)).collect();
        let mut b = MnistBatcher::new(48, 8).unwrap();
        b.restore(order, cursor, epoch).unwrap();
        let mut rng_b = Rng::from_state(rng_snap).unwrap();
        let resumed: Vec<_> =
            (0..5).map(|_| mnist_next(&mut b, &data, &mut rng_b)).collect();
        assert_eq!(ahead, resumed, "restored batcher must replay exactly");
        assert_eq!(a.epoch, b.epoch);
    }

    #[test]
    fn mnist_restore_rejects_corrupt_state() {
        let mut b = MnistBatcher::new(16, 4).unwrap();
        assert!(b.restore(vec![0; 16], 0, 1).is_err(), "not a permutation");
        assert!(b.restore((0..8).collect(), 0, 1).is_err(), "wrong length");
        assert!(b.restore((0..16).collect(), 17, 1).is_err(), "bad cursor");
        assert!(b.restore((0..16).collect(), usize::MAX, 0).is_ok(),
                "the first-call sentinel round-trips");
    }

    #[test]
    fn bptt_snapshot_restore_resumes_identically() {
        let tokens: Vec<i32> = (0..217).collect();
        let mut a = BpttBatcher::new(&tokens, 3, 7).unwrap();
        for _ in 0..4 {
            bptt_next(&mut a);
        }
        let (pos, epoch) = a.snapshot();
        let ahead: Vec<_> = (0..9).map(|_| bptt_next(&mut a)).collect();
        let mut b = BpttBatcher::new(&tokens, 3, 7).unwrap();
        b.restore(pos, epoch).unwrap();
        let resumed: Vec<_> = (0..9).map(|_| bptt_next(&mut b)).collect();
        assert_eq!(ahead, resumed);
        assert!(b.restore(10_000, 0).is_err(), "out-of-range pos rejected");
    }

    #[test]
    fn bptt_windows_are_contiguous_and_shifted() {
        let tokens: Vec<i32> = (0..103).collect();
        let mut b = BpttBatcher::new(&tokens, 2, 5).unwrap();
        let (x, y) = bptt_next(&mut b);
        // Track 0 starts at 0, track 1 at track_len = 51.
        assert_eq!(&x[..5], &[0, 1, 2, 3, 4]);
        assert_eq!(&y[..5], &[1, 2, 3, 4, 5]);
        assert_eq!(&x[5..10], &[51, 52, 53, 54, 55]);
        let (x2, _) = bptt_next(&mut b);
        assert_eq!(&x2[..5], &[5, 6, 7, 8, 9]);
    }

    #[test]
    fn construction_rejects_oversized_batch_loudly() {
        // Regression: batch > n used to pass an `assert!` panic (or, in
        // its absence, surface as an out-of-range slice in the reshuffle
        // branch of next_batch_into). It is a config error and must say
        // so.
        let err = MnistBatcher::new(16, 32).unwrap_err();
        assert!(err.to_string().contains("batch size 32"),
                "unhelpful error: {err}");
        assert!(MnistBatcher::new(16, 0).is_err());
        assert!(MnistBatcher::new(16, 16).is_ok(), "batch == n is legal");

        let tokens: Vec<i32> = (0..64).collect();
        // 64 tokens / batch 8 = 8 per track: too short for seq 8.
        let err = BpttBatcher::new(&tokens, 8, 8).unwrap_err();
        assert!(err.to_string().contains("too small"),
                "unhelpful error: {err}");
        assert!(BpttBatcher::new(&tokens, 0, 4).is_err());
        assert!(BpttBatcher::new(&tokens, 4, 0).is_err());
        assert!(BpttBatcher::new(&tokens, 8, 7).is_ok());
    }

    #[test]
    fn bptt_epoch_wraps() {
        let tokens: Vec<i32> = (0..40).collect();
        let mut b = BpttBatcher::new(&tokens, 2, 6).unwrap();
        let per_epoch = b.windows_per_epoch();
        assert_eq!(per_epoch, (20 - 1) / 6);
        for _ in 0..per_epoch {
            bptt_next(&mut b);
        }
        assert_eq!(b.epoch, 0);
        bptt_next(&mut b);
        assert_eq!(b.epoch, 1);
    }
}
