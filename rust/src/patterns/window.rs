//! Time-windowed pattern draws (ROADMAP item 4; cf. *Structured in Space,
//! Randomized in Time*, arXiv 2106.12089): instead of one structured
//! pattern per dropout site per *training step* (today's behavior), draw
//! one pattern per site per **window of `W` timesteps** and hold it fixed
//! inside the window. Randomization moves across time instead of within
//! it, which keeps the per-unit long-run drop rate at `p` (each window is
//! an i.i.d. draw from the same searched distribution K) while making the
//! sparsity exploitable: kept-row sets — and therefore packed weight
//! panels — stay valid for a whole window of GEMMs.
//!
//! Window semantics. `W` counts timesteps of the unrolled sequence:
//!
//! * `W == seq` (the **default**): one draw per step, bit-exact with the
//!   pre-windowing behavior — the RNG stream is identical because no extra
//!   draws are made.
//! * `W < seq` (requires `seq % W == 0`): the step's `dp` is fixed (it is
//!   baked into the dispatched artifact name), but `b0` is re-drawn per
//!   window *within* the step. `W = 1` is true per-timestep
//!   randomization.
//! * `W > seq` (requires `W % seq == 0`): the step's `(dp, b0)` choices
//!   are held for `W / seq` consecutive steps. The coordinator front owns
//!   that carry (and checkpoints it); this module only reports
//!   `steps_per_draw`.
//!
//! Incompatible requests (neither divisibility holds, or `W == 0`) fall
//! back **loudly** to `W = seq` — the `AD_TIME_WINDOW` env knob is global,
//! and a mismatch against one arch's `seq` must not break unrelated archs.
//!
//! RNG contract (checkpoint bit-exactness): the window schedule is folded
//! into the front's existing `Rng` stream, not a side generator. Order per
//! step: `Schedule::sample` first (unchanged — dp draw(s) plus one `b0`
//! per site), then extra-window draws with **sites outer, windows inner**,
//! one `rng.next_usize(dp)` per (site, extra window) — including `dp = 1`
//! sites, where the draw is consumed and trivially returns 0, so the
//! stream shape never depends on the sampled dp. With one window per step
//! there are no extra draws, which is what makes the default bit-exact.

use crate::patterns::Choice;
use crate::util::rng::Rng;

/// Resolved time-window policy for one arch (a `(seq, W)` pair that
/// already satisfies the divisibility rule). Construct via
/// [`TimeWindow::resolve`] or [`TimeWindow::from_env`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimeWindow {
    seq: usize,
    w: usize,
}

impl TimeWindow {
    /// The default policy: one draw per step (`W = seq`), bit-exact with
    /// the pre-windowing behavior.
    pub fn per_step(seq: usize) -> TimeWindow {
        TimeWindow { seq, w: seq.max(1) }
    }

    /// Resolve an explicit request against this arch's `seq`. `None`
    /// means default. Invalid or incompatible requests warn on stderr and
    /// fall back to the default rather than erroring — the knob is
    /// process-global and must not take down archs it cannot divide.
    pub fn resolve(requested: Option<usize>, seq: usize) -> TimeWindow {
        let seq = seq.max(1);
        match requested {
            None => TimeWindow::per_step(seq),
            Some(w) if w == seq => TimeWindow::per_step(seq),
            Some(w) if w >= 1 && (seq % w == 0 || w % seq == 0) => {
                TimeWindow { seq, w }
            }
            Some(w) => {
                eprintln!(
                    "[patterns::window] AD_TIME_WINDOW={w} is incompatible \
                     with seq={seq} (need seq % W == 0 or W % seq == 0); \
                     falling back to the per-step default W={seq}");
                TimeWindow::per_step(seq)
            }
        }
    }

    /// Resolve from the `AD_TIME_WINDOW` env knob. Unset, empty, or the
    /// literal `"seq"` select the default; anything unparsable warns and
    /// falls back. Read once at front construction — the runtime itself
    /// never consults the environment (it derives windows from the data).
    pub fn from_env(seq: usize) -> TimeWindow {
        match std::env::var("AD_TIME_WINDOW") {
            Err(_) => TimeWindow::per_step(seq),
            Ok(v) => {
                let v = v.trim();
                if v.is_empty() || v.eq_ignore_ascii_case("seq") {
                    return TimeWindow::per_step(seq);
                }
                match v.parse::<usize>() {
                    Ok(w) if w >= 1 => TimeWindow::resolve(Some(w), seq),
                    _ => {
                        eprintln!(
                            "[patterns::window] AD_TIME_WINDOW={v:?} is not \
                             a positive integer or \"seq\"; using the \
                             per-step default W={seq}");
                        TimeWindow::per_step(seq)
                    }
                }
            }
        }
    }

    /// Window length in timesteps (clamped into `[1, ..]`, `seq`-aligned).
    pub fn w(&self) -> usize {
        self.w
    }

    /// True when this policy is the bit-exact pre-windowing default
    /// (exactly one draw per step, no multi-step hold).
    pub fn is_per_step(&self) -> bool {
        self.w == self.seq
    }

    /// Number of pattern windows inside one training step (>= 1).
    pub fn windows_per_step(&self) -> usize {
        if self.w >= self.seq { 1 } else { self.seq / self.w }
    }

    /// Number of consecutive steps sharing one `(dp, b0)` draw (>= 1;
    /// > 1 only when `W` spans multiple steps).
    pub fn steps_per_draw(&self) -> usize {
        if self.w > self.seq { self.w / self.seq } else { 1 }
    }

    /// Expand per-site step choices into per-site `[seq]` b0 tracks:
    /// entry `t` is the kept residue class for timestep `t`. Window 0
    /// reuses the `b0` already drawn by `Schedule::sample`; each extra
    /// window draws a fresh `rng.next_usize(dp)` (sites outer, windows
    /// inner — see module docs). With one window per step this makes no
    /// RNG draws and the track is constant, preserving today's stream.
    pub fn expand_b0_tracks(&self, choices: &[Choice], rng: &mut Rng)
                            -> Vec<Vec<i32>> {
        let nw = self.windows_per_step();
        let wlen = self.seq / nw;
        choices.iter()
            .map(|c| {
                let mut track = Vec::with_capacity(self.seq);
                track.resize(wlen, c.b0 as i32);
                for _ in 1..nw {
                    let b0 = rng.next_usize(c.dp) as i32;
                    track.resize(track.len() + wlen, b0);
                }
                track
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_per_step() {
        let tw = TimeWindow::resolve(None, 8);
        assert!(tw.is_per_step());
        assert_eq!(tw.windows_per_step(), 1);
        assert_eq!(tw.steps_per_draw(), 1);
        assert_eq!(tw.w(), 8);
    }

    #[test]
    fn divisors_and_multiples_accepted() {
        let tw = TimeWindow::resolve(Some(4), 8);
        assert_eq!((tw.windows_per_step(), tw.steps_per_draw()), (2, 1));
        let tw = TimeWindow::resolve(Some(1), 8);
        assert_eq!((tw.windows_per_step(), tw.steps_per_draw()), (8, 1));
        let tw = TimeWindow::resolve(Some(16), 8);
        assert_eq!((tw.windows_per_step(), tw.steps_per_draw()), (1, 2));
        assert!(!tw.is_per_step(), "multi-step hold is not the default");
    }

    #[test]
    fn incompatible_falls_back_to_default() {
        // seq=5 (the lstmtest arch) under W=4: neither divides.
        let tw = TimeWindow::resolve(Some(4), 5);
        assert!(tw.is_per_step());
        assert_eq!(tw.w(), 5);
        let tw = TimeWindow::resolve(Some(0), 8);
        assert!(tw.is_per_step());
    }

    #[test]
    fn per_step_expansion_draws_nothing() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let tw = TimeWindow::per_step(8);
        let choices = vec![Choice { dp: 2, b0: 1 }, Choice { dp: 4, b0: 3 }];
        let tracks = tw.expand_b0_tracks(&choices, &mut a);
        assert_eq!(tracks, vec![vec![1i32; 8], vec![3i32; 8]]);
        // Stream untouched — bit-exact with the pre-windowing behavior.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn windowed_expansion_is_constant_per_window_and_in_range() {
        let mut rng = Rng::new(7);
        let tw = TimeWindow::resolve(Some(4), 16);
        let choices = vec![Choice { dp: 4, b0: 2 }];
        let tracks = tw.expand_b0_tracks(&choices, &mut rng);
        assert_eq!(tracks.len(), 1);
        let t = &tracks[0];
        assert_eq!(t.len(), 16);
        assert_eq!(&t[..4], &[2, 2, 2, 2], "window 0 reuses the step b0");
        for win in t.chunks(4) {
            assert!(win.iter().all(|&b| b == win[0]), "constant per window");
            assert!((0..4).contains(&win[0]), "b0 in [0, dp)");
        }
    }

    #[test]
    fn draw_order_is_sites_outer_windows_inner() {
        // Reconstruct the expected stream by hand and compare.
        let choices = vec![Choice { dp: 4, b0 : 0 }, Choice { dp: 2, b0: 1 }];
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        let tw = TimeWindow::resolve(Some(2), 8);
        let tracks = tw.expand_b0_tracks(&choices, &mut a);
        let mut expect = Vec::new();
        for c in &choices {
            let mut track = vec![c.b0 as i32; 2];
            for _ in 1..4 {
                let b0 = b.next_usize(c.dp) as i32;
                track.extend([b0, b0]);
            }
            expect.push(track);
        }
        assert_eq!(tracks, expect);
        assert_eq!(a.next_u64(), b.next_u64(), "streams advanced equally");
    }

    #[test]
    fn dp1_sites_still_consume_draws() {
        // The stream shape must not depend on the sampled dp, so dp=1
        // sites burn one draw per extra window like everyone else.
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let tw = TimeWindow::resolve(Some(2), 4);
        let tracks =
            tw.expand_b0_tracks(&[Choice { dp: 1, b0: 0 }], &mut a);
        assert_eq!(tracks, vec![vec![0i32; 4]]);
        b.next_usize(1); // the one extra-window draw
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn env_parsing() {
        // from_env reads the process env, which is racy to mutate in
        // parallel tests — so only exercise the no-knob path here plus
        // the pure `resolve` equivalents of each parse outcome.
        // (Explicit-window constructors exist precisely so tests and
        // benches never need to set AD_TIME_WINDOW.)
        if std::env::var("AD_TIME_WINDOW").is_err() {
            assert!(TimeWindow::from_env(8).is_per_step());
        }
        assert_eq!(TimeWindow::resolve(Some(8), 8),
                   TimeWindow::per_step(8));
    }
}
