//! Generic-driver tests: the refactored `Trainer` must reproduce the
//! sequential trainers' trajectories bit-for-bit on its double-buffered
//! path, `warmup` must cover exactly `schedule.dp_combos()`, trainers
//! sharing one `ExecutorCache` must compile each artifact once, and the
//! lr-decay policy promoted from the LSTM trainer must fire generically.
//!
//! Hermetic: the whole suite runs on the pure-Rust reference backend over
//! the built-in synthetic manifest — no artifacts, no Python, no PJRT —
//! so it must never skip.

use std::collections::BTreeSet;

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::{ArchMeta, Manifest};

fn setup() -> ExecutorCache {
    ExecutorCache::reference(Manifest::builtin_test())
}

fn lstm_trainer(cache: &ExecutorCache, variant: Variant, tokens: &[i32],
                seed: u64) -> LstmTrainer {
    let shared = variant != Variant::Conv;
    let schedule =
        Schedule::new(variant, &[0.5, 0.5], &[2], shared).unwrap();
    LstmTrainer::new(cache, "lstmtest", schedule, tokens, 0.5, seed)
        .unwrap()
}

/// Fixed-seed parity: the pipelined path consumes the front's RNG in the
/// same sequential order as step-by-step training, so the loss/accuracy
/// trajectories must match bit-for-bit — for both the pattern variant and
/// the mask-generating conventional baseline.
#[test]
fn pipelined_matches_sequential_bit_for_bit() {
    let cache = setup();
    let corpus = Corpus::generate(64, 4000, 400, 400, 9);
    for variant in [Variant::Conv, Variant::Rdp] {
        let mut seq = lstm_trainer(&cache, variant, &corpus.train, 77);
        seq.warmup().unwrap();
        for _ in 0..12 {
            seq.step().unwrap();
        }
        let mut pipe = lstm_trainer(&cache, variant, &corpus.train, 77);
        pipe.warmup().unwrap();
        pipe.train_pipelined(&(), 12).unwrap();
        let a: Vec<(f64, f64)> =
            seq.metrics.curve.iter().map(|p| (p.loss, p.acc)).collect();
        let b: Vec<(f64, f64)> =
            pipe.metrics.curve.iter().map(|p| (p.loss, p.acc)).collect();
        assert_eq!(a.len(), 12);
        assert_eq!(a, b,
                   "{variant:?}: pipelined trajectory must be identical");
        assert_eq!(seq.metrics.dispatched, pipe.metrics.dispatched,
                   "{variant:?}: pipelined dispatch must be identical");
    }
}

/// Mixing the two paths mid-run stays on the same trajectory: the staged
/// assembly only moves work in time, never reorders RNG draws.
#[test]
fn mixed_sequential_and_pipelined_chunks_agree() {
    let cache = setup();
    let corpus = Corpus::generate(64, 4000, 400, 400, 10);
    let mut seq = lstm_trainer(&cache, Variant::Rdp, &corpus.train, 5);
    seq.warmup().unwrap();
    for _ in 0..9 {
        seq.step().unwrap();
    }
    let mut mixed = lstm_trainer(&cache, Variant::Rdp, &corpus.train, 5);
    mixed.warmup().unwrap();
    mixed.train_pipelined(&(), 4).unwrap();
    for _ in 0..2 {
        mixed.step().unwrap();
    }
    mixed.train_pipelined(&(), 3).unwrap();
    let a: Vec<f64> = seq.metrics.curve.iter().map(|p| p.loss).collect();
    let b: Vec<f64> = mixed.metrics.curve.iter().map(|p| p.loss).collect();
    assert_eq!(a, b);
}

/// `warmup` pre-compiles one executable per `schedule.dp_combos()` entry,
/// nothing more (the eval graph stays lazy).
#[test]
fn warmup_covers_exactly_dp_combos() {
    let cache = setup();
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
    let combos = schedule.dp_combos();
    assert!(!combos.is_empty());
    let corpus = Corpus::generate(64, 3000, 300, 300, 1);
    let mut tr = LstmTrainer::new(&cache, "lstmtest", schedule,
                                  &corpus.train, 0.5, 1)
        .unwrap();
    assert_eq!(tr.executable_names().len(), combos.len());
    tr.warmup().unwrap();
    assert_eq!(cache.len(), combos.len(),
               "warmup must compile exactly the dp combos");
    assert_eq!(cache.compile_times_s().len(), combos.len());

    // MLP warmup through the same shared cache: its (distinct) artifact
    // names are added on top, and nothing recompiles.
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
    let mlp_combos = schedule.dp_combos().len();
    let mut mlp = MlpTrainer::new(&cache, "mlptest", schedule, 64, 0.05, 2)
        .unwrap();
    mlp.warmup().unwrap();
    assert_eq!(cache.len(), combos.len() + mlp_combos);
}

/// The acceptance scenario: a Conv baseline and an RDP variant running in
/// one process through the shared cache compile each artifact exactly
/// once, even across repeated trainer construction and live stepping.
#[test]
fn shared_cache_compiles_each_artifact_once() {
    let cache = setup();
    let corpus = Corpus::generate(64, 3000, 300, 300, 2);
    let mut conv = lstm_trainer(&cache, Variant::Conv, &corpus.train, 3);
    let mut rdp = lstm_trainer(&cache, Variant::Rdp, &corpus.train, 3);
    conv.warmup().unwrap();
    rdp.warmup().unwrap();
    let compiled = cache.compile_times_s().len();
    assert_eq!(compiled, cache.len());

    // A second baseline/variant pair over the same artifacts, plus live
    // steps on all four trainers: no recompilation.
    let mut conv2 = lstm_trainer(&cache, Variant::Conv, &corpus.train, 4);
    let mut rdp2 = lstm_trainer(&cache, Variant::Rdp, &corpus.train, 4);
    conv2.warmup().unwrap();
    rdp2.warmup().unwrap();
    for _ in 0..3 {
        conv.step().unwrap();
        rdp.step().unwrap();
        conv2.step().unwrap();
        rdp2.step().unwrap();
    }
    assert_eq!(cache.compile_times_s().len(), compiled,
               "warm artifacts must never recompile");
    let unique: BTreeSet<String> = cache
        .compile_times_s()
        .into_iter()
        .map(|(name, _)| name)
        .collect();
    assert_eq!(unique.len(), compiled, "each compile entry is distinct");
}

/// The lr-decay policy formerly hard-wired into the LSTM trainer now
/// lives in the generic driver: after `decay_after` completed data
/// epochs, lr shrinks by `lr_decay` per epoch.
#[test]
fn lr_decay_fires_on_epoch_boundaries() {
    let cache = setup();
    let (batch, seq) = match &cache.manifest().get("lstmtest_conv")
        .unwrap().arch
    {
        ArchMeta::Lstm { batch, seq, .. } => (*batch, *seq),
        _ => panic!("lstmtest is not an LSTM"),
    };
    // track_len = seq + 2 -> one BPTT window per epoch, so every couple
    // of steps crosses an epoch boundary.
    let corpus = Corpus::generate(64, batch * (seq + 2), 64, 64, 5);
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
    let mut tr = LstmTrainer::new(&cache, "lstmtest", schedule,
                                  &corpus.train, 1.0, 6)
        .unwrap();
    tr.lr_decay = 0.5;
    tr.decay_after = 0;
    tr.warmup().unwrap();
    let lr0 = tr.lr;
    for _ in 0..4 {
        tr.step().unwrap();
    }
    assert!(tr.epochs_done() > 0, "tiny corpus must wrap an epoch");
    assert!(tr.lr < lr0, "lr must decay: {lr0} -> {}", tr.lr);
}

/// MLP parity run on the synthetic-data arch (mlpsyn takes the 784-pixel
/// MnistSyn images, so this exercises the real batcher + mask assembly).
#[test]
fn mlp_pipelined_matches_sequential() {
    let cache = setup();
    let data = MnistSyn::generate(256, 3);
    let mk = |seed: u64| {
        let schedule =
            Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2], true)
                .unwrap();
        MlpTrainer::new(&cache, "mlpsyn", schedule, data.n, 0.01, seed)
            .unwrap()
    };
    let mut seq = mk(11);
    seq.warmup().unwrap();
    for _ in 0..6 {
        seq.step(&data).unwrap();
    }
    let mut pipe = mk(11);
    pipe.warmup().unwrap();
    pipe.train_pipelined(&data, 6).unwrap();
    let a: Vec<f64> = seq.metrics.curve.iter().map(|p| p.loss).collect();
    let b: Vec<f64> = pipe.metrics.curve.iter().map(|p| p.loss).collect();
    assert_eq!(a, b);
    assert_eq!(seq.metrics.dispatched, pipe.metrics.dispatched);
}
