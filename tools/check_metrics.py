#!/usr/bin/env python3
"""Validate a METRICS_<run>.json export from the observability layer.

Usage:
    check_metrics.py METRICS_train-mlp.json [--require-phases]
    check_metrics.py --self-test

Every `train-mlp` / `train-lstm` / `serve` / `infer` run of the
`approx-dropout` CLI exports the process metrics registry through
`rust/src/obs/mod.rs`. This checker pins the document's structural
invariants, so a refactor of the registry or the export path cannot
silently produce unparseable or internally inconsistent telemetry:

* the document parses, is `bench == "metrics"`, and names its run kind;
* every required instrument of the static catalog is present (the
  registry is always-on, so even an idle run exports a complete
  catalog with zero values);
* counters and gauges are finite and non-negative (gauges may be
  negative only in `value`, never in `peak`; counters never);
* every histogram row satisfies `sum(counts) == total` (the
  snapshot-consistency contract of the registry) and has exactly
  `len(bounds) + 1` buckets (the trailing overflow cell);
* labeled `dispatch_total` rows sum to the aggregate row's value;
* `phase_time_s` rows (present with AD_TRACE=on) carry a positive
  count, non-negative totals, and `max_s <= total_s`; with
  `--require-phases` at least one phase row must exist — the CI trace
  leg uses this to prove AD_TRACE actually traced.

Exit 0 on a valid document, 1 with a pointed message otherwise.
"""

import argparse
import json
import math
import sys

REQUIRED_INSTRUMENTS = (
    "dispatch_total",
    "sparse_rows_kept",
    "sparse_rows_dropped",
    "sparse_tiles_kept",
    "sparse_tiles_dropped",
    "sparse_panel_bytes",
    "sparse_dyn_rows_kept",
    "sparse_dyn_rows_dropped",
    "gate_wait_s",
    "gate_hold_s",
    "gate_queue_depth",
    "infer_requests",
    "infer_batches",
    "infer_batch_occupancy",
    "infer_latency_s",
    "worker_sync_wait_s",
    "allreduce_total",
)


def fail(msg):
    raise SystemExit(f"check_metrics: FAIL: {msg}")


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def check_doc(doc):
    """Validate one parsed metrics document; returns a summary string."""
    if doc.get("bench") != "metrics":
        fail(f"bench is {doc.get('bench')!r}, expected 'metrics'")
    run = doc.get("run")
    if not isinstance(run, str) or not run:
        fail("missing/empty 'run' kind")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        fail("no rows")

    seen = set()
    labeled_sums = {}
    aggregates = {}
    n_phases = 0
    for i, row in enumerate(rows):
        inst = row.get("instrument")
        kind = row.get("kind")
        if not isinstance(inst, str) or not isinstance(kind, str):
            fail(f"row {i}: missing instrument/kind: {row}")
        if kind == "counter":
            v = row.get("value")
            if not is_num(v) or v < 0:
                fail(f"row {i}: counter {inst} has bad value {v!r}")
            if "label" in row:
                labeled_sums[inst] = labeled_sums.get(inst, 0) + v
            else:
                seen.add(inst)
                aggregates[inst] = v
        elif kind == "gauge":
            seen.add(inst)
            v, peak = row.get("value"), row.get("peak")
            if not is_num(v) or not is_num(peak):
                fail(f"row {i}: gauge {inst} has non-finite cells")
            if peak < 0 or peak < v:
                fail(f"row {i}: gauge {inst} peak {peak} < value {v}")
        elif kind == "histogram":
            seen.add(inst)
            bounds, counts = row.get("bounds"), row.get("counts")
            total, s = row.get("total"), row.get("sum")
            if not isinstance(bounds, list) or not isinstance(counts, list):
                fail(f"row {i}: histogram {inst} missing bounds/counts")
            if len(counts) != len(bounds) + 1:
                fail(f"row {i}: histogram {inst} has {len(counts)} "
                     f"buckets for {len(bounds)} bounds (want +1 overflow)")
            if any(not is_num(c) or c < 0 for c in counts):
                fail(f"row {i}: histogram {inst} has negative/NaN counts")
            if not is_num(total) or not is_num(s) or s < 0:
                fail(f"row {i}: histogram {inst} bad total/sum")
            if sum(counts) != total:
                fail(f"row {i}: histogram {inst} counts sum to "
                     f"{sum(counts)}, total says {total}")
            if list(bounds) != sorted(bounds):
                fail(f"row {i}: histogram {inst} bounds not ascending")
        elif kind == "phase":
            n_phases += 1
            if not row.get("scope") or not row.get("phase"):
                fail(f"row {i}: phase row missing scope/phase")
            c, t, m = row.get("count"), row.get("total_s"), row.get("max_s")
            if not is_num(c) or c <= 0:
                fail(f"row {i}: phase {row.get('phase')} count {c!r}")
            if not is_num(t) or t < 0 or not is_num(m) or m < 0:
                fail(f"row {i}: phase {row.get('phase')} negative time")
            if m > t + 1e-9:
                fail(f"row {i}: phase {row.get('phase')} max_s {m} > "
                     f"total_s {t}")
        else:
            fail(f"row {i}: unknown kind {kind!r}")

    missing = [n for n in REQUIRED_INSTRUMENTS if n not in seen]
    if missing:
        fail(f"missing required instruments: {', '.join(missing)}")
    for inst, label_sum in labeled_sums.items():
        if inst not in aggregates:
            fail(f"labeled rows for {inst} but no aggregate row")
        if label_sum != aggregates[inst]:
            fail(f"{inst}: labels sum to {label_sum}, aggregate says "
                 f"{aggregates[inst]}")
    return (f"run={run} trace={doc.get('trace')} rows={len(rows)} "
            f"phases={n_phases}")


def check_file(path, require_phases):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")
    summary = check_doc(doc)
    if require_phases:
        n_phases = sum(1 for r in doc["rows"] if r.get("kind") == "phase")
        if n_phases == 0:
            fail(f"{path}: --require-phases but no phase_time_s rows "
                 "(was AD_TRACE actually on?)")
    print(f"check_metrics: OK {path}: {summary}")


# ---------------------------------------------------------------------------
# Self-test

def _doc(rows, run="train-mlp"):
    return {"bench": "metrics", "run": run, "trace": True, "rows": rows}


def _catalog(**overrides):
    """A minimal valid catalog, one row per required instrument."""
    rows = []
    for name in REQUIRED_INSTRUMENTS:
        if name.endswith("_s") or name == "infer_batch_occupancy":
            rows.append({"instrument": name, "kind": "histogram",
                         "bounds": [1.0, 2.0], "counts": [1, 2, 0],
                         "total": 3, "sum": 2.5})
        elif name == "gate_queue_depth":
            rows.append({"instrument": name, "kind": "gauge",
                         "value": 0, "peak": 3})
        else:
            rows.append({"instrument": name, "kind": "counter",
                         "value": 7})
    for row in rows:
        if row["instrument"] in overrides:
            row.update(overrides[row["instrument"]])
    return rows


def _expect_fail(rows, needle, label):
    try:
        check_doc(_doc(rows))
    except SystemExit as e:
        if needle not in str(e):
            fail(f"self-test: {label}: wrong message: {e}")
        return
    fail(f"self-test: {label}: bad document passed")


def self_test():
    # 1. A complete catalog with labels and phases passes.
    rows = _catalog()
    rows.append({"instrument": "dispatch_total", "kind": "counter",
                 "label": "sparse/mlpsyn_rdp_2_2", "value": 4})
    rows.append({"instrument": "dispatch_total", "kind": "counter",
                 "label": "sparse/mlpsyn_rdp_1_2", "value": 3})
    rows.append({"instrument": "phase_time_s", "kind": "phase",
                 "scope": "mlpsyn/rdp", "phase": "fwd", "count": 12,
                 "total_s": 0.5, "max_s": 0.1})
    check_doc(_doc(rows))

    # 2. A histogram whose counts don't sum to total fails.
    _expect_fail(_catalog(gate_wait_s={"total": 99}),
                 "counts sum", "sum!=total")

    # 3. Negative counter fails.
    _expect_fail(_catalog(infer_requests={"value": -1}),
                 "bad value", "negative counter")

    # 4. Missing required instrument fails.
    _expect_fail(_catalog()[1:], "missing required", "missing instrument")

    # 5. Wrong bucket count (no overflow cell) fails.
    _expect_fail(_catalog(gate_hold_s={"counts": [1, 2]}),
                 "overflow", "bucket count")

    # 6. Labels that don't sum to the aggregate fail.
    rows = _catalog(dispatch_total={"value": 7})
    rows.append({"instrument": "dispatch_total", "kind": "counter",
                 "label": "sparse/x", "value": 3})
    _expect_fail(rows, "labels sum", "label mismatch")

    # 7. Phase with max_s > total_s fails.
    rows = _catalog()
    rows.append({"instrument": "phase_time_s", "kind": "phase",
                 "scope": "s", "phase": "fwd", "count": 1,
                 "total_s": 0.1, "max_s": 0.5})
    _expect_fail(rows, "max_s", "phase max>total")

    # 8. NaN sneaking in (json.load accepts bare NaN) fails.
    _expect_fail(_catalog(sparse_rows_kept={"value": float("nan")}),
                 "bad value", "nan counter")

    print("self-test OK (8 scenarios)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("metrics", nargs="?",
                    help="METRICS_<run>.json to validate")
    ap.add_argument("--require-phases", action="store_true",
                    help="fail unless phase_time_s rows are present "
                         "(CI AD_TRACE leg)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the built-in scenarios and exit")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if not args.metrics:
        ap.error("need a METRICS_<run>.json path (or use --self-test)")
    check_file(args.metrics, args.require_phases)
    return 0


if __name__ == "__main__":
    sys.exit(main())
