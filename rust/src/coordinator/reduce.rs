//! Deterministic gradient reduction for data-parallel training.
//!
//! The sharded trainer cuts every global batch into a fixed list of
//! *leaves* (contiguous row shards whose count depends only on the batch
//! geometry — never on the worker count, see `ModelFront::shard_leaves`)
//! and combines the per-leaf [`GradOut`]s with [`tree_reduce`]: pairwise
//! adjacent combines in leaf-index order, `(0,1), (2,3), ..` per round,
//! an odd trailing element carried unchanged, repeated until one result
//! remains. Because both the leaves and the association order are fixed,
//! the f32 sums — and therefore the whole training trajectory — are
//! bit-identical at any worker count. This is the same contract the
//! sparse kernel pool honors across `AD_THREADS`: parallelism moves
//! *where* work runs, never *how* results combine.

use crate::runtime::backend::GradOut;

/// Fixed-order binary tree reduction over `leaves`, combining with
/// `pair` in index order: round 1 combines `(0,1), (2,3), ..`; an odd
/// last element is carried to the next round unchanged; rounds repeat
/// until one value remains. `None` on an empty input. The association
/// order is a pure function of `leaves.len()` — the caller's thread
/// layout cannot perturb it.
pub fn tree_reduce<T>(leaves: Vec<T>, mut pair: impl FnMut(T, T) -> T)
                      -> Option<T> {
    let mut level = leaves;
    if level.is_empty() {
        return None;
    }
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(pair(a, b)),
                None => next.push(a),
            }
        }
        level = next;
    }
    level.pop()
}

/// Combine two leaves' gradient contributions: elementwise f32 adds over
/// every gradient buffer (in the manifest's parameter order), f64 add of
/// the loss sums, f32 add of the correct counts. Panics on mismatched
/// leaf shapes — those only arise from a driver bug, never from data.
pub fn reduce_grad_pair(mut a: GradOut, b: GradOut) -> GradOut {
    assert_eq!(a.grads.len(), b.grads.len(),
               "gradient leaves disagree on parameter count");
    for (ga, gb) in a.grads.iter_mut().zip(&b.grads) {
        assert_eq!(ga.len(), gb.len(),
                   "gradient leaves disagree on a parameter's size");
        for (x, &y) in ga.iter_mut().zip(gb) {
            *x += y;
        }
    }
    a.loss_sum += b.loss_sum;
    a.correct += b.correct;
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tree_reduce_association_order_is_pinned() {
        // Strings make the association order observable: 5 leaves must
        // combine as (((1+2)+(3+4))+5) — pairwise rounds, odd element
        // carried, NOT left-fold ((((1+2)+3)+4)+5).
        let leaves: Vec<String> =
            (1..=5).map(|i| i.to_string()).collect();
        let out = tree_reduce(leaves, |a, b| format!("({a}+{b})"));
        assert_eq!(out.unwrap(), "(((1+2)+(3+4))+5)");
        assert_eq!(tree_reduce(vec!["x".to_string()], |a, _b| a),
                   Some("x".to_string()));
        assert_eq!(tree_reduce(Vec::<i32>::new(), |a, b| a + b), None);
    }

    fn leaf(rng: &mut Rng, nan: bool) -> GradOut {
        let g0: Vec<f32> =
            (0..17).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        let mut g1: Vec<f32> =
            (0..5).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        if nan {
            g1[2] = f32::NAN;
        }
        GradOut {
            grads: vec![g0, g1],
            loss_sum: rng.uniform(0.0, 3.0),
            correct: (rng.uniform(0.0, 8.0) as f32).floor(),
        }
    }

    fn bits(g: &GradOut) -> (Vec<Vec<u32>>, u64, u32) {
        (g.grads.iter()
            .map(|v| v.iter().map(|x| x.to_bits()).collect())
            .collect(),
         g.loss_sum.to_bits(),
         g.correct.to_bits())
    }

    #[test]
    fn reduction_is_bitwise_invariant_to_delivery_order() {
        // The driver collects leaves from however many workers exist and
        // slots them by leaf index before reducing. Property: for random
        // leaf counts and values (including a NaN-poisoned leaf), any
        // delivery permutation produces bit-identical results, because
        // reduction is a pure function of the indexed leaf list.
        let mut rng = Rng::new(0x5eed);
        for case in 0..50 {
            let n = 1 + (rng.next_u64() % 9) as usize;
            let poison = case % 7 == 0;
            let leaves: Vec<GradOut> = (0..n)
                .map(|i| leaf(&mut rng, poison && i == n / 2))
                .collect();
            let baseline = tree_reduce(leaves.clone(), reduce_grad_pair)
                .unwrap();
            // Simulate out-of-order delivery: shuffle, then re-slot by
            // index exactly as the driver does.
            let mut order: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                order.swap(i, j);
            }
            let mut slots: Vec<Option<GradOut>> =
                (0..n).map(|_| None).collect();
            for &i in &order {
                slots[i] = Some(leaves[i].clone());
            }
            let redelivered = tree_reduce(
                slots.into_iter().map(|s| s.unwrap()).collect(),
                reduce_grad_pair).unwrap();
            assert_eq!(bits(&baseline), bits(&redelivered),
                       "case {case}: n={n} poison={poison}");
            if poison {
                assert!(baseline.grads[1][2].is_nan(),
                        "NaN poison must survive reduction");
            }
        }
    }

    #[test]
    fn pair_reduction_adds_elementwise() {
        let a = GradOut { grads: vec![vec![1.0, 2.0]], loss_sum: 0.5,
                          correct: 3.0 };
        let b = GradOut { grads: vec![vec![10.0, 20.0]], loss_sum: 0.25,
                          correct: 1.0 };
        let c = reduce_grad_pair(a, b);
        assert_eq!(c.grads, vec![vec![11.0, 22.0]]);
        assert_eq!(c.loss_sum, 0.75);
        assert_eq!(c.correct, 4.0);
    }
}
