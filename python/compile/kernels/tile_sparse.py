"""L1 Pallas kernel: tile-sparse (block-sparse) matmul for TDP.

The Tile-based Dropout Pattern keeps 1 in every ``dp`` 32x32 tiles of the
weight matrix. Because the kept set is *regular and known before launch*, the
kernel receives the kept tile coordinates as scalar-prefetch operands and its
BlockSpec index_maps fetch **only kept tiles** from HBM — the TPU analog of
the paper's "fetch non-dropped tiles into shared memory and build compact
matrices" (Fig. 3b). Nothing else of the weight matrix is ever touched by
the accumulation phase.

Grid layout: the first ``n_dst`` steps zero-initialise every output block
(cheap: no HBM reads), the remaining ``J`` steps each accumulate one kept
tile into its destination block. Interpret-mode grids execute sequentially
so the read-modify-write accumulation is well-defined; on a real TPU the
kept list would additionally be sorted by destination so output-window
revisits are consecutive (Mosaic's requirement) — noted in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _accum_kernel(src_ref, dst_ref, x_ref, wt_ref, o_ref, *, n_dst: int):
    j = pl.program_id(0)

    @pl.when(j < n_dst)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j >= n_dst)
    def _accum():
        o_ref[...] += jnp.dot(
            x_ref[...], wt_ref[0], preferred_element_type=o_ref.dtype
        )


def _tile_accum(x: jax.Array, wt: jax.Array, src: jax.Array, dst: jax.Array,
                n_out: int) -> jax.Array:
    """out[:, dst[j]*t_dst :+t_dst] += x[:, src[j]*t_src :+t_src] @ wt[j].

    x   [m, K] dense activations, K = (K // t_src) * t_src
    wt  [J, t_src, t_dst] kept tiles
    src/dst [J] int32 block coordinates (any order, duplicates in dst fine)
    returns [m, n_out] with unreferenced destination blocks zeroed.
    """
    m, _ = x.shape
    j_count, t_src, t_dst = wt.shape
    n_dst = n_out // t_dst
    # Phase 1 (j < n_dst): write zeros to block j. Phase 2: accumulate tile
    # j - n_dst. The extended coordinate vectors make one index_map serve
    # both phases.
    zeros_i = jnp.zeros((n_dst,), jnp.int32)
    src_ext = jnp.concatenate([zeros_i, src.astype(jnp.int32)])
    dst_ext = jnp.concatenate(
        [jnp.arange(n_dst, dtype=jnp.int32), dst.astype(jnp.int32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_dst + j_count,),
        in_specs=[
            pl.BlockSpec((m, t_src), lambda j, src, dst: (0, src[j])),
            pl.BlockSpec(
                (1, t_src, t_dst),
                lambda j, src, dst: (jnp.maximum(j - n_dst, 0), 0, 0)),
        ],
        out_specs=pl.BlockSpec((m, t_dst), lambda j, src, dst: (0, dst[j])),
    )
    return pl.pallas_call(
        functools.partial(_accum_kernel, n_dst=n_dst),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n_out), x.dtype),
        interpret=True,
    )(src_ext, dst_ext, x, wt)


def _per_tile_grad_kernel(src_ref, dst_ref, x_ref, g_ref, o_ref):
    """dwt[j] = x[:, src[j]]^T @ g[:, dst[j]] — one output tile per step,
    no accumulation conflicts."""
    o_ref[0] = jnp.dot(
        x_ref[...].T, g_ref[...], preferred_element_type=o_ref.dtype
    )


def _tile_grads(x: jax.Array, g: jax.Array, src: jax.Array, dst: jax.Array,
                t_src: int, t_dst: int) -> jax.Array:
    m, _ = x.shape
    j_count = src.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(j_count,),
        in_specs=[
            pl.BlockSpec((m, t_src), lambda j, src, dst: (0, src[j])),
            pl.BlockSpec((m, t_dst), lambda j, src, dst: (0, dst[j])),
        ],
        out_specs=pl.BlockSpec((1, t_src, t_dst), lambda j, src, dst: (j, 0, 0)),
    )
    return pl.pallas_call(
        _per_tile_grad_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((j_count, t_src, t_dst), x.dtype),
        interpret=True,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), x, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def tile_sparse_matmul(x: jax.Array, wt: jax.Array, rows: jax.Array,
                       cols: jax.Array, n_out: int) -> jax.Array:
    """``x @ W_sparse`` where W [K, n_out] is given only by its kept tiles.

    x    [m, K]
    wt   [J, t_r, t_c] kept tiles (``patterns.gather_tiles``)
    rows/cols [J] kept tile coordinates (``patterns.tile_kept_rc``)

    Differentiable: dx reuses the same sparse accumulation with tiles
    transposed, dwt is a per-kept-tile outer-product kernel — the backward
    pass also never touches dropped tiles (the paper's compute saving holds
    for fwd *and* bwd).
    """
    return _tile_accum(x, wt, rows, cols, n_out)


def _ts_fwd(x, wt, rows, cols, n_out):
    return _tile_accum(x, wt, rows, cols, n_out), (x, wt, rows, cols)


def _ts_bwd(n_out, res, g):
    x, wt, rows, cols = res
    k = x.shape[1]
    dx = _tile_accum(g, jnp.transpose(wt, (0, 2, 1)), cols, rows, k)
    dwt = _tile_grads(x, g, rows, cols, wt.shape[1], wt.shape[2])
    return dx, dwt, None, None


tile_sparse_matmul.defvjp(_ts_fwd, _ts_bwd)
