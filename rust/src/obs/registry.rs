//! Process-wide metrics registry: atomic counters, gauges, and
//! fixed-bucket histograms behind a static named-instrument catalog.
//!
//! Design constraints (see DESIGN.md section 12):
//!
//! * **Lock-free on the hot path.** Every instrument that sits inside a
//!   kernel, gate, or request loop is a plain static whose update is one
//!   (or two) `Relaxed` atomic RMWs — no allocation, no locking, no
//!   branching on configuration. The only locked instrument is
//!   [`LabeledCounter`] (dynamic label set), used once per *training
//!   step* — milliseconds of GEMM per lock, never per-element.
//! * **Snapshot-consistent on read.** A histogram snapshot derives its
//!   `total` from the bucket counts it just read, so `sum(counts) ==
//!   total` holds by construction even while writers race the reader
//!   (`tools/check_metrics.py` pins the invariant on every exported
//!   file). Counters/gauges are single-word reads and need no protocol.
//! * **Observers only.** Nothing here draws RNG, takes time-dependent
//!   branches, or reorders caller work — metrics stay enabled always and
//!   cannot perturb trajectories (the `AD_TRACE` bit-identity test in
//!   `rust/tests/obs.rs` covers the span layer; this layer has no off
//!   switch to diverge under).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (queue depth, jobs running) with a
/// high-watermark. `add` is a single RMW; the peak is maintained with
/// `fetch_max`, so concurrent movers never lose a watermark.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
    peak: AtomicI64,
}

impl Gauge {
    pub const fn new() -> Self {
        Gauge { v: AtomicI64::new(0), peak: AtomicI64::new(0) }
    }

    #[inline]
    pub fn add(&self, d: i64) {
        let now = self.v.fetch_add(d, Ordering::Relaxed) + d;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
        self.peak.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> i64 {
        self.peak.load(Ordering::Relaxed)
    }
}

/// Upper cap on histogram bucket-bound count, so the bucket array can be
/// a fixed-size field of a `const`-constructible static (bounds.len()
/// finite buckets + 1 overflow bucket).
pub const MAX_BOUNDS: usize = 15;

/// Fixed-bucket histogram: bucket `i` counts observations `v <=
/// bounds[i]` (first match, ascending bounds), the last bucket counts
/// the overflow `v > bounds[last]`. Observation is a short linear scan
/// plus two `Relaxed` RMWs — no float-to-bucket division, no locks.
///
/// The running value sum is kept in integer micro-units so it can live
/// in one `AtomicU64` (f64 has no portable atomic add); at microsecond
/// granularity the sums this repo records (seconds, batch rows) lose
/// nothing that matters for a mean.
#[derive(Debug)]
pub struct Histogram {
    bounds: &'static [f64],
    buckets: [AtomicU64; MAX_BOUNDS + 1],
    sum_micros: AtomicU64,
}

impl Histogram {
    pub const fn new(bounds: &'static [f64]) -> Self {
        assert!(bounds.len() <= MAX_BOUNDS,
                "histogram bounds exceed MAX_BOUNDS");
        // No array-repeat for non-Copy AtomicU64 in const fn; spell the
        // 16 zero cells out once here instead of at every static.
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            bounds,
            buckets: [Z, Z, Z, Z, Z, Z, Z, Z, Z, Z, Z, Z, Z, Z, Z, Z],
            sum_micros: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        let mut idx = self.bounds.len();
        for (i, &b) in self.bounds.iter().enumerate() {
            if v <= b {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        if v.is_finite() && v > 0.0 {
            self.sum_micros
                .fetch_add((v * 1e6) as u64, Ordering::Relaxed);
        }
    }

    /// Consistent snapshot: `total` is the sum of the `counts` read
    /// here, never a separately-raced cell.
    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> = self.buckets[..=self.bounds.len()]
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total = counts.iter().sum();
        HistSnapshot {
            bounds: self.bounds.to_vec(),
            counts,
            total,
            sum: self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

/// One consistent histogram read: `counts.len() == bounds.len() + 1`
/// (the extra cell is the overflow bucket) and `total == sum(counts)`.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub total: u64,
    /// Sum of observed values (microsecond-granular), for means.
    pub sum: f64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.sum / self.total as f64
    }
}

/// Counter keyed by a dynamic label (backend/artifact names are only
/// known at dispatch time). Mutex-guarded — used at step granularity
/// only; never put one inside a kernel loop.
#[derive(Debug)]
pub struct LabeledCounter {
    cells: Mutex<BTreeMap<String, u64>>,
}

impl LabeledCounter {
    pub const fn new() -> Self {
        LabeledCounter { cells: Mutex::new(BTreeMap::new()) }
    }

    pub fn add(&self, label: &str, n: u64) {
        let mut m = self.cells.lock().unwrap_or_else(|e| e.into_inner());
        *m.entry(label.to_string()).or_insert(0) += n;
    }

    pub fn inc(&self, label: &str) {
        self.add(label, 1);
    }

    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let m = self.cells.lock().unwrap_or_else(|e| e.into_inner());
        m.iter().map(|(k, &v)| (k.clone(), v)).collect()
    }

    /// Total across all labels.
    pub fn total(&self) -> u64 {
        let m = self.cells.lock().unwrap_or_else(|e| e.into_inner());
        m.values().sum()
    }
}

// ---------------------------------------------------------------------------
// Named instrument catalog (the registry)
// ---------------------------------------------------------------------------

const TIME_BOUNDS_S: [f64; 8] =
    [1e-5, 1e-4, 1e-3, 3e-3, 1e-2, 0.1, 1.0, 10.0];
const OCCUPANCY_BOUNDS: [f64; 8] =
    [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];

/// Executed dispatches, labeled `<backend>/<artifact>` — the
/// observable the paper's pattern->executable mapping produces.
pub static DISPATCH_TOTAL: LabeledCounter = LabeledCounter::new();

/// Shared-dimension rows the sparse engine actually touched / skipped
/// (TensorDash-style touched-vs-skipped work accounting).
pub static SPARSE_ROWS_KEPT: Counter = Counter::new();
pub static SPARSE_ROWS_DROPPED: Counter = Counter::new();
/// Weight tiles walked / skipped by the tile kernels.
pub static SPARSE_TILES_KEPT: Counter = Counter::new();
pub static SPARSE_TILES_DROPPED: Counter = Counter::new();
/// Bytes packed into per-(site, window) kept-row weight panels.
pub static SPARSE_PANEL_BYTES: Counter = Counter::new();
/// Shared-dimension rows the dynamic backward masks (plan `DynMask`
/// nodes: ReLU-zero columns, zero LSTM initial state) kept / skipped on
/// top of the static pattern — separate from the static row counters so
/// `AD_DYN_BWD=off` runs stay comparable.
pub static SPARSE_DYN_ROWS_KEPT: Counter = Counter::new();
pub static SPARSE_DYN_ROWS_DROPPED: Counter = Counter::new();

/// Backend-slot gate: time spent waiting for a slot, time a slot was
/// held, and the live waiter-queue depth (+peak).
pub static GATE_WAIT_S: Histogram = Histogram::new(&TIME_BOUNDS_S);
pub static GATE_HOLD_S: Histogram = Histogram::new(&TIME_BOUNDS_S);
pub static GATE_QUEUE_DEPTH: Gauge = Gauge::new();

/// Inference: requests served, coalesced-batch occupancy, and
/// per-request latency (submit -> response).
pub static INFER_REQUESTS: Counter = Counter::new();
pub static INFER_BATCHES: Counter = Counter::new();
pub static INFER_BATCH_OCCUPANCY: Histogram =
    Histogram::new(&OCCUPANCY_BOUNDS);
pub static INFER_LATENCY_S: Histogram = Histogram::new(&TIME_BOUNDS_S);

/// Data-parallel training: per-worker time between a worker's last leaf
/// finishing and the full leaf set being collected (the straggler wait
/// the reduction barrier imposes), and completed tree reductions.
pub static WORKER_SYNC_WAIT_S: Histogram = Histogram::new(&TIME_BOUNDS_S);
pub static ALLREDUCE_TOTAL: Counter = Counter::new();

/// One instrument read, tagged for export (`obs::metrics_report`).
#[derive(Clone, Debug)]
pub enum InstrumentSnapshot {
    Counter { name: &'static str, value: u64 },
    Labeled { name: &'static str, cells: Vec<(String, u64)> },
    Gauge { name: &'static str, value: i64, peak: i64 },
    Histogram { name: &'static str, h: HistSnapshot },
}

/// Read the whole catalog. Each instrument is internally consistent;
/// cross-instrument skew is inherent (and harmless) while writers run.
pub fn snapshot_all() -> Vec<InstrumentSnapshot> {
    use InstrumentSnapshot as S;
    vec![
        S::Labeled { name: "dispatch_total",
                     cells: DISPATCH_TOTAL.snapshot() },
        S::Counter { name: "sparse_rows_kept",
                     value: SPARSE_ROWS_KEPT.get() },
        S::Counter { name: "sparse_rows_dropped",
                     value: SPARSE_ROWS_DROPPED.get() },
        S::Counter { name: "sparse_tiles_kept",
                     value: SPARSE_TILES_KEPT.get() },
        S::Counter { name: "sparse_tiles_dropped",
                     value: SPARSE_TILES_DROPPED.get() },
        S::Counter { name: "sparse_panel_bytes",
                     value: SPARSE_PANEL_BYTES.get() },
        S::Counter { name: "sparse_dyn_rows_kept",
                     value: SPARSE_DYN_ROWS_KEPT.get() },
        S::Counter { name: "sparse_dyn_rows_dropped",
                     value: SPARSE_DYN_ROWS_DROPPED.get() },
        S::Histogram { name: "gate_wait_s", h: GATE_WAIT_S.snapshot() },
        S::Histogram { name: "gate_hold_s", h: GATE_HOLD_S.snapshot() },
        S::Gauge { name: "gate_queue_depth",
                   value: GATE_QUEUE_DEPTH.get(),
                   peak: GATE_QUEUE_DEPTH.peak() },
        S::Counter { name: "infer_requests", value: INFER_REQUESTS.get() },
        S::Counter { name: "infer_batches", value: INFER_BATCHES.get() },
        S::Histogram { name: "infer_batch_occupancy",
                       h: INFER_BATCH_OCCUPANCY.snapshot() },
        S::Histogram { name: "infer_latency_s",
                       h: INFER_LATENCY_S.snapshot() },
        S::Histogram { name: "worker_sync_wait_s",
                       h: WORKER_SYNC_WAIT_S.snapshot() },
        S::Counter { name: "allreduce_total",
                     value: ALLREDUCE_TOTAL.get() },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.add(3);
        g.add(2);
        g.add(-4);
        assert_eq!(g.get(), 1);
        assert_eq!(g.peak(), 5);
        g.set(7);
        assert_eq!((g.get(), g.peak()), (7, 7));
    }

    #[test]
    fn histogram_bucket_boundaries() {
        static BOUNDS: [f64; 3] = [1.0, 2.0, 4.0];
        let h = Histogram::new(&BOUNDS);
        h.observe(0.5); // <= 1.0      -> bucket 0
        h.observe(1.0); // == bound    -> bucket 0 (le semantics)
        h.observe(1.5); // <= 2.0      -> bucket 1
        h.observe(4.0); // == last     -> bucket 2
        h.observe(9.0); // overflow    -> bucket 3
        let s = h.snapshot();
        assert_eq!(s.counts, vec![2, 1, 1, 1]);
        assert_eq!(s.total, 5);
        assert_eq!(s.counts.iter().sum::<u64>(), s.total);
        assert!((s.sum - 16.0).abs() < 1e-3);
        assert!((s.mean() - 3.2).abs() < 1e-3);
    }

    #[test]
    fn histogram_ignores_nonpositive_in_sum_but_counts_them() {
        static BOUNDS: [f64; 1] = [1.0];
        let h = Histogram::new(&BOUNDS);
        h.observe(0.0);
        h.observe(-3.0);
        h.observe(f64::NAN); // NaN compares false -> overflow bucket
        let s = h.snapshot();
        assert_eq!(s.total, 3);
        assert_eq!(s.counts, vec![2, 1]);
        assert_eq!(s.sum, 0.0);
    }

    #[test]
    fn empty_histogram_mean_is_nan() {
        static BOUNDS: [f64; 1] = [1.0];
        let h = Histogram::new(&BOUNDS);
        assert!(h.snapshot().mean().is_nan());
    }

    #[test]
    fn labeled_counter_accumulates_per_label() {
        let c = LabeledCounter::new();
        c.inc("a");
        c.add("b", 2);
        c.inc("a");
        assert_eq!(c.snapshot(), vec![("a".to_string(), 2),
                                      ("b".to_string(), 2)]);
        assert_eq!(c.total(), 4);
    }

    #[test]
    fn concurrent_increments_never_lose_updates() {
        // AD_THREADS-style contention: N threads x M ops on one counter
        // and one histogram; relaxed RMWs must still account for every
        // update.
        static BOUNDS: [f64; 4] = [0.25, 0.5, 0.75, 1.0];
        let c = Counter::new();
        let g = Gauge::new();
        let h = Histogram::new(&BOUNDS);
        let (n_threads, per_thread) = (8, 2000);
        std::thread::scope(|s| {
            for t in 0..n_threads {
                s.spawn(|| {
                    for i in 0..per_thread {
                        c.inc();
                        g.add(1);
                        h.observe((i % 5) as f64 * 0.25);
                    }
                    let _ = t;
                });
            }
        });
        let n = (n_threads * per_thread) as u64;
        assert_eq!(c.get(), n);
        assert_eq!(g.get(), n as i64);
        assert_eq!(g.peak(), n as i64);
        let s = h.snapshot();
        assert_eq!(s.total, n);
        assert_eq!(s.counts.iter().sum::<u64>(), s.total);
        // 0.0 and 0.25 both land in bucket 0.
        assert_eq!(s.counts[0], n / 5 * 2);
    }

    #[test]
    fn snapshots_are_monotonic_under_writers() {
        // Totals observed by a racing reader never decrease, and every
        // snapshot independently satisfies sum(counts) == total.
        static BOUNDS: [f64; 2] = [1.0, 2.0];
        let h = Histogram::new(&BOUNDS);
        let stop = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..20_000 {
                    h.observe((i % 3) as f64);
                }
                stop.store(true, Ordering::Relaxed);
            });
            let mut last = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = h.snapshot();
                assert!(snap.total >= last,
                        "total went backwards: {last} -> {}", snap.total);
                assert_eq!(snap.counts.iter().sum::<u64>(), snap.total);
                last = snap.total;
            }
        });
        assert_eq!(h.snapshot().total, 20_000);
    }
}
