//! In-tree property-testing mini-framework (proptest is unavailable
//! offline).
//!
//! Model: a property is a closure over a seeded [`crate::util::rng::Rng`];
//! [`check`] runs it for N cases with distinct seeds and, on failure,
//! reports the seed so the case is replayable. Generators are free
//! functions over `Rng` (`gen_range`, `gen_vec`, ...) — no shrinking, but
//! seeds make failures deterministic, which is what debugging needs most.

use crate::util::rng::Rng;

pub const DEFAULT_CASES: u32 = 64;

/// Run `prop` for `cases` seeded cases; panic with the failing seed on the
/// first failure (assert inside the property for rich messages).
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u32, mut prop: F) {
    for case in 0..cases {
        let seed = 0xAD00_0000_0000_0000u64 | case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut rng),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed \
                 {seed:#x}): {msg}"
            );
        }
    }
}

/// Convenience wrapper with the default case count.
pub fn quickcheck<F: FnMut(&mut Rng)>(name: &str, prop: F) {
    check(name, DEFAULT_CASES, prop)
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

pub fn gen_range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    assert!(lo < hi);
    lo + rng.next_usize(hi - lo)
}

pub fn gen_f64(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    rng.uniform(lo, hi)
}

pub fn gen_vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len)
        .map(|_| lo + (hi - lo) * rng.next_f32())
        .collect()
}

pub fn gen_subset(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// Pick one element of a slice.
pub fn gen_choice<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.next_usize(xs.len())]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", 16, |_rng| {
            count += 1;
        });
        assert_eq!(count, 16);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails-on-big", 64, |rng| {
                let v = gen_range(rng, 0, 100);
                assert!(v < 101, "impossible");
                // Force a failure deterministically on some case:
                assert!(v != 37, "hit 37");
            });
        });
        let err = result.expect_err("should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "msg: {msg}");
    }

    #[test]
    fn generators_in_bounds() {
        quickcheck("gen bounds", |rng| {
            let x = gen_range(rng, 5, 10);
            assert!((5..10).contains(&x));
            let v = gen_vec_f32(rng, 8, -1.0, 1.0);
            assert_eq!(v.len(), 8);
            assert!(v.iter().all(|&f| (-1.0..=1.0).contains(&f)));
            let s = gen_subset(rng, 10, 3);
            assert_eq!(s.len(), 3);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        });
    }
}
