//! The pattern distribution K (paper section III-C/D): probabilities over
//! the divisor support set, from which the coordinator samples one
//! `(dp, b0)` per dropout site per training iteration — `dp ~ K`,
//! `b0 ~ U{0..dp-1}`.

use crate::patterns::Choice;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct PatternDistribution {
    /// Divisor support set (e.g. [1, 2, 4, 8]); `support[i]` has
    /// probability `probs[i]`.
    pub support: Vec<usize>,
    pub probs: Vec<f64>,
}

impl PatternDistribution {
    pub fn new(support: Vec<usize>, probs: Vec<f64>) -> Self {
        assert_eq!(support.len(), probs.len());
        let sum: f64 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "probs sum to {sum}");
        assert!(probs.iter().all(|&p| p >= -1e-12));
        PatternDistribution { support, probs }
    }

    /// Point mass on dp = 1 (no dropout).
    pub fn degenerate() -> Self {
        PatternDistribution { support: vec![1], probs: vec![1.0] }
    }

    /// Sample one pattern: dp from K, bias uniform (paper section III-D).
    pub fn sample(&self, rng: &mut Rng) -> Choice {
        let i = rng.sample_discrete(&self.probs);
        let dp = self.support[i];
        Choice { dp, b0: rng.next_usize(dp) }
    }

    /// Expected global dropout rate  p_g = sum_i k_i (dp_i - 1)/dp_i
    /// (paper Eq. 3).
    pub fn expected_rate(&self) -> f64 {
        self.support
            .iter()
            .zip(&self.probs)
            .map(|(&dp, &k)| k * (dp as f64 - 1.0) / dp as f64)
            .sum()
    }

    /// Shannon entropy (nats) — the sub-model diversity proxy the search
    /// maximizes.
    pub fn entropy(&self) -> f64 {
        -self
            .probs
            .iter()
            .filter(|&&p| p > 0.0)
            .map(|&p| p * p.ln())
            .sum::<f64>()
    }

    /// Per-unit drop probability implied by the distribution (paper Eq. 2):
    /// equals `expected_rate` because biases are uniform — asserting this
    /// identity is one of the repo's core property tests.
    pub fn per_unit_drop_probability(&self) -> f64 {
        // P(unit dropped) = sum_i k_i * P(dropped | dp_i)
        //                 = sum_i k_i * (1 - 1/dp_i)
        self.expected_rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    #[test]
    fn expected_rate_formula() {
        let d = PatternDistribution::new(vec![1, 2, 4], vec![0.2, 0.3, 0.5]);
        let expect = 0.2 * 0.0 + 0.3 * 0.5 + 0.5 * 0.75;
        assert!((d.expected_rate() - expect).abs() < 1e-12);
    }

    #[test]
    fn sample_respects_support_and_bias_range() {
        let d = PatternDistribution::new(vec![2, 4, 8],
                                         vec![0.5, 0.25, 0.25]);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let c = d.sample(&mut rng);
            assert!(d.support.contains(&c.dp));
            assert!(c.b0 < c.dp);
        }
    }

    #[test]
    fn empirical_dp_frequencies_match_probs() {
        let d = PatternDistribution::new(vec![1, 2, 4, 8],
                                         vec![0.1, 0.4, 0.3, 0.2]);
        let mut rng = Rng::new(2);
        let n = 100_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let c = d.sample(&mut rng);
            let i = d.support.iter().position(|&s| s == c.dp).unwrap();
            counts[i] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((c as f64 / n as f64 - d.probs[i]).abs() < 0.01);
        }
    }

    #[test]
    fn statistical_equivalence_of_per_neuron_rate() {
        // Paper Eq. 2-3: empirical per-neuron drop frequency over many
        // sampled patterns converges to the expected global rate. This is
        // the paper's central statistical claim.
        testkit::check("per-neuron rate", 8, |rng| {
            let d = PatternDistribution::new(vec![1, 2, 4, 8],
                                             vec![0.507, 0.135, 0.155,
                                                  0.203]);
            let m = 96; // layer width (divisible by all dp)
            let iters = 40_000;
            let mut dropped = vec![0u32; m];
            for _ in 0..iters {
                let c = d.sample(rng);
                let kept0 = c.b0;
                for (i, d) in dropped.iter_mut().enumerate() {
                    if i % c.dp != kept0 {
                        *d += 1;
                    }
                }
            }
            let target = d.per_unit_drop_probability();
            for (i, &cnt) in dropped.iter().enumerate() {
                let f = cnt as f64 / iters as f64;
                // CLT bound: ~4 sigma with sigma <= 0.5/sqrt(iters) = .0025
                assert!((f - target).abs() < 0.012,
                        "neuron {i}: {f} vs {target}");
            }
        });
    }

    #[test]
    fn entropy_extremes() {
        let point = PatternDistribution::degenerate();
        assert_eq!(point.entropy(), 0.0);
        let unif = PatternDistribution::new(vec![1, 2, 4, 8],
                                            vec![0.25; 4]);
        assert!((unif.entropy() - 4f64.ln()).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_non_normalized() {
        PatternDistribution::new(vec![1, 2], vec![0.5, 0.6]);
    }
}
