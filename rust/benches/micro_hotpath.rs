//! Hot-path micro-benchmarks (EXPERIMENTS.md section Perf): the L3
//! coordinator costs that sit around every PJRT call. L3 must not be the
//! bottleneck — compare each against the train-step execute time from the
//! e2e benches.

use approx_dropout::bench::{bench, fmt_time, BenchReport, BenchResult,
                            Table};
use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, Schedule,
                                  Variant};
use approx_dropout::data::Corpus;
use approx_dropout::patterns::MaskGen;
use approx_dropout::runtime::{HostTensor, TrainState, Value};
use approx_dropout::search::{self, SearchConfig};
use approx_dropout::util::json::Json;
use approx_dropout::util::rng::Rng;

/// Record one measurement in the machine-readable report (same numbers
/// as the printed table).
fn record(report: &mut BenchReport, r: &BenchResult, note: &str) {
    report.row(vec![
        ("op", Json::str(&r.name)),
        ("median_s", Json::num(r.median_s)),
        ("mad_s", Json::num(r.mad_s)),
        ("mean_s", Json::num(r.mean_s)),
        ("per_sec", Json::num(r.per_sec())),
        ("reps", Json::num(r.reps as f64)),
        ("note", Json::str(note)),
    ]);
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&["op", "median", "per-sec", "note"]);
    let mut report =
        BenchReport::new("micro_hotpath", "rust/benches/micro_hotpath.rs");

    // 1. Bernoulli mask fill (baseline hot path): 128 x 2048 mask.
    let mut rng = Rng::new(1);
    let mut gen = MaskGen::new();
    let r = bench("mask_fill_128x2048", 3, 50,
                  || gen.fill(&mut rng, 0.5, 128 * 2048).len());
    table.row(&["mask fill 128x2048".into(), fmt_time(r.median_s),
                format!("{:.0}/s", r.per_sec()),
                "per conv iteration x2".into()]);
    record(&mut report, &r, "per conv iteration x2");

    // 2. Pattern sampling (approximate-dropout hot path).
    let schedule = Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2, 4, 8],
                                 false)?;
    let mut rng2 = Rng::new(2);
    let r = bench("pattern_sample", 10, 1000,
                  || schedule.sample(&mut rng2));
    table.row(&["pattern sample (2 sites)".into(), fmt_time(r.median_s),
                format!("{:.0}/s", r.per_sec()),
                "per rdp/tdp iteration".into()]);
    record(&mut report, &r, "per rdp/tdp iteration");

    // 3. Algorithm 1 search (one-time cost).
    let cfg = SearchConfig::default();
    let r = bench("sgd_search", 1, 10,
                  || search::search(0.7, &[1, 2, 4, 8], &cfg).iters);
    table.row(&["Algorithm 1 search".into(), fmt_time(r.median_s),
                format!("{:.1}/s", r.per_sec()), "one-time, init".into()]);
    record(&mut report, &r, "one-time, init");

    // 4. HostTensor -> backend-value marshalling (per-step upload prep)
    //    via a full tiny-artifact execute, isolating coordinator overhead.
    let cache = ExecutorCache::from_env(approx_dropout::manifest_or_builtin()?)?;
    let backend = cache.backend().clone();
    let exe = cache.get("mlptest_rdp_2_2")?;
    let mut rng3 = Rng::new(3);
    let meta = cache.manifest().get("mlptest_rdp_2_2")?;
    let mut state = TrainState::init(meta, &mut rng3, backend.as_ref())?;
    let x: Vec<f32> = (0..8 * 32).map(|_| rng3.next_f32()).collect();
    let y: Vec<i32> = (0..8).map(|_| rng3.next_usize(10) as i32).collect();
    // ingest (owned-buffer upload) mirrors the coordinator's dispatch
    // path: the one clone per tensor below is the same copy the fronts'
    // batchers perform per step.
    let r = bench("tiny_train_step", 3, 30, || {
        let tail: Vec<Value> = vec![
            backend.ingest(HostTensor::f32(&[8, 32], x.clone())).unwrap(),
            backend.ingest(HostTensor::i32(&[8], y.clone())).unwrap(),
            backend.ingest(HostTensor::scalar_i32(0)).unwrap(),
            backend.ingest(HostTensor::scalar_i32(1)).unwrap(),
            backend.ingest(HostTensor::scalar_f32(2.0)).unwrap(),
            backend.ingest(HostTensor::scalar_f32(2.0)).unwrap(),
            backend.ingest(HostTensor::scalar_f32(0.05)).unwrap(),
        ];
        state.step(exe.as_ref(), &tail).unwrap()
    });
    table.row(&["tiny mlp train step e2e".into(), fmt_time(r.median_s),
                format!("{:.0}/s", r.per_sec()),
                format!("{} floor: marshal+exec+absorb", backend.name())]);
    record(&mut report, &r,
           &format!("{} floor: marshal+exec+absorb", backend.name()));

    // 5. Eval-graph execute (params only, no state absorb).
    let ev = cache.get("mlptest_eval")?;
    let r = bench("tiny_eval", 3, 30, || {
        let x_v = backend
            .ingest(HostTensor::f32(&[8, 32], x.clone()))
            .unwrap();
        let y_v = backend.ingest(HostTensor::i32(&[8], y.clone())).unwrap();
        let mut refs = state.param_refs();
        refs.push(&x_v);
        refs.push(&y_v);
        ev.run_raw(&refs).unwrap().len()
    });
    table.row(&["tiny mlp eval".into(), fmt_time(r.median_s),
                format!("{:.0}/s", r.per_sec()), "".into()]);
    record(&mut report, &r, "");

    // 6. Sequential vs double-buffered step assembly on the tiny LSTM:
    //    same RNG stream, identical trajectories; the pipelined path hides
    //    host-side assembly behind the PJRT execute.
    let corpus = Corpus::generate(64, 4000, 400, 400, 9);
    let window = 20;
    let mk = |seed: u64| -> anyhow::Result<LstmTrainer> {
        let schedule = Schedule::new(Variant::Conv, &[0.5, 0.5], &[2],
                                     false)?;
        LstmTrainer::new(&cache, "lstmtest", schedule, &corpus.train, 0.5,
                         seed)
    };
    let mut seq = mk(7)?;
    seq.warmup()?;
    let r = bench("lstm_steps_sequential", 1, 5,
                  || seq.train(window).unwrap());
    table.row(&[format!("lstm {window}-step loop (seq)"),
                fmt_time(r.median_s), format!("{:.1}/s", r.per_sec()),
                "assemble then execute".into()]);
    record(&mut report, &r, "assemble then execute");
    let mut pipe = mk(7)?;
    pipe.warmup()?;
    let r = bench("lstm_steps_pipelined", 1, 5,
                  || pipe.train_pipelined(&(), window).unwrap());
    table.row(&[format!("lstm {window}-step loop (pipe)"),
                fmt_time(r.median_s), format!("{:.1}/s", r.per_sec()),
                "assembly overlapped".into()]);
    record(&mut report, &r, "assembly overlapped");

    report.set("backend", Json::str(cache.backend().name()));
    // Only the sparse backend executes microkernels; recording one for
    // reference/pjrt runs would be false provenance.
    if cache.backend().name() == "sparse" {
        report.set("microkernel", Json::str(
            approx_dropout::runtime::SparseKernels::auto().microkernel()));
    }
    println!("== micro hot-path ==");
    table.print();
    let path = report.write_default("BENCH_micro.json")?;
    println!("wrote {} ({} rows)", path.display(), report.n_rows());
    println!("\ninterpretation: mask fill + sampling are orders of \
              magnitude below a 2048-arch train step (hundreds of ms) — \
              the coordinator is not the bottleneck.");
    Ok(())
}
