//! Minimal JSON parser + writer for `artifacts/manifest.json` and the
//! machine-readable bench reports (`BENCH_*.json`).
//!
//! Supports the full JSON grammar we emit (objects, arrays, strings with
//! escapes, numbers, bools, null); serde is unavailable offline. Not a
//! general-purpose library — errors carry byte offsets for debugging.
//! The writer round-trips through the parser (`writer_roundtrip` below);
//! non-finite numbers serialize as `null` (JSON has no NaN/inf).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- construction helpers (bench reports) ------------------------------

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    /// Object from (key, value) pairs; later duplicates win (BTreeMap).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect())
    }

    // -- writer ------------------------------------------------------------

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out, None, 0);
        out
    }

    /// Pretty serialization (2-space indent), ending without a newline.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out, Some(2), 0);
        out
    }

    fn write_into(&self, out: &mut String, indent: Option<usize>,
                  depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth),
                        " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write_into(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(k, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write_into(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Integers print without a fraction; other finite values use Rust's
/// shortest round-trip repr; non-finite becomes `null` (invalid in JSON).
fn fmt_num(n: f64) -> String {
    if !n.is_finite() {
        return "null".to_string();
    }
    if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        format!("{}", n as i64)
    } else {
        format!("{n:?}")
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (we never emit them).
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#)
            .unwrap();
        assert_eq!(v.path("c.d"), Some(&Json::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn manifest_shape() {
        let v = parse(
            r#"{"version":1,"artifacts":[{"name":"m","inputs":
               [{"name":"w1","shape":[784,2048],"dtype":"f32",
                 "kind":"param"}]}]}"#,
        )
        .unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let inp = &a.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
                   Some(2048));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn writer_roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::str("bench \"x\"\n")),
            ("n", Json::num(3.0)),
            ("t", Json::num(0.12345)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("rows", Json::Arr(vec![
                Json::num(-1.5e-7),
                Json::obj(vec![("k", Json::str("v"))]),
                Json::Arr(vec![]),
            ])),
        ]);
        for text in [v.dumps(), v.pretty()] {
            assert_eq!(parse(&text).unwrap(), v, "text:\n{text}");
        }
        // Integers print without fraction; NaN degrades to null.
        assert_eq!(Json::num(3.0).dumps(), "3");
        assert_eq!(Json::num(f64::NAN).dumps(), "null");
        assert_eq!(Json::num(0.5).dumps(), "0.5");
    }

    #[test]
    fn pretty_indents() {
        let v = Json::obj(vec![("a", Json::Arr(vec![Json::num(1.0)]))]);
        assert_eq!(v.pretty(), "{\n  \"a\": [\n    1\n  ]\n}");
    }
}
