//! Versioned training-session checkpoints (`*.ckpt`).
//!
//! A checkpoint captures everything a `Trainer` needs to reproduce the
//! exact trajectory a never-interrupted run would have produced:
//!
//! * the full `TrainState` — parameters and momenta as **f32 bit
//!   patterns** (u32 per element, exact for every value including NaN;
//!   decimal round-tripping would be one rounding bug away from silent
//!   trajectory drift) plus the cumulative step counter,
//! * the model front's assembly state — RNG cursor (the raw 256-bit
//!   Xoshiro state, as hex strings since JSON numbers are f64 and cannot
//!   carry a u64) and batcher position/shuffle order,
//! * the driver state — current lr (f32 bits, it decays over epochs) and
//!   `epochs_done`,
//! * a **config hash** (FNV-1a 64 over the session's canonical
//!   fingerprint) — resuming against a different experiment setup is
//!   rejected up front instead of surfacing as shape errors or, worse, a
//!   quietly different experiment. The data-parallel worker count is
//!   deliberately **excluded** from the fingerprint: it tunes wall-clock
//!   only (the gradient leaf list and reduction order are fixed by the
//!   batch geometry, see `coordinator::reduce`), so a checkpoint saved
//!   at `--workers 1` legally resumes at `--workers 4` — *elastic
//!   resume* — and reproduces the identical trajectory,
//! * the dispatch-log tail — the last few artifact names dispatched
//!   before the checkpoint, for post-mortem cross-checking of resumed
//!   runs against their originals.
//!
//! Serialization goes through `util::json` (serde is unavailable
//! offline). The format is versioned by the `ad_checkpoint` field;
//! readers reject versions they do not understand. See DESIGN.md
//! section 10.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Current checkpoint format version. Bump on any incompatible change to
/// the JSON layout; `Checkpoint::from_json` rejects everything else.
pub const CKPT_VERSION: u64 = 1;

/// How many trailing dispatch-log entries a checkpoint retains.
pub const DISPATCH_TAIL: usize = 32;

/// One serialized f32 tensor (a parameter or momentum buffer).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorCkpt {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// A fully materialized checkpoint. Produced by `Trainer::checkpoint`,
/// consumed by `Trainer::restore` / `Trainer::resume_from`.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub version: u64,
    pub config_hash: u64,
    /// Backend that wrote the checkpoint (informational: trajectories are
    /// only bit-reproducible on the same backend family).
    pub backend: String,
    pub step: u64,
    pub epochs_done: usize,
    pub lr: f32,
    /// Model-front snapshot (RNG cursor + batcher state), opaque here.
    pub front: Json,
    pub params: Vec<TensorCkpt>,
    pub momenta: Vec<TensorCkpt>,
    /// Total dispatches recorded by the session that wrote this.
    pub dispatch_total: usize,
    /// Last `<= DISPATCH_TAIL` artifact names dispatched.
    pub dispatch_tail: Vec<String>,
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ad_checkpoint", Json::num(self.version as f64)),
            ("config_hash", Json::str(&hex_u64(self.config_hash))),
            ("backend", Json::str(&self.backend)),
            ("step", Json::num(self.step as f64)),
            ("epochs_done", Json::num(self.epochs_done as f64)),
            ("lr_bits", Json::num(f64::from(self.lr.to_bits()))),
            ("front", self.front.clone()),
            ("params", tensors_to_json(&self.params)),
            ("momenta", tensors_to_json(&self.momenta)),
            ("dispatch_total", Json::num(self.dispatch_total as f64)),
            ("dispatch_tail", Json::Arr(
                self.dispatch_tail.iter().map(|s| Json::str(s)).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Checkpoint> {
        let version = v
            .get("ad_checkpoint")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("not a checkpoint: missing \
                                    'ad_checkpoint' version field"))?
            as u64;
        if version != CKPT_VERSION {
            bail!("checkpoint format version {version} is not supported \
                   (this build reads version {CKPT_VERSION})");
        }
        let config_hash = parse_hex_u64(
            v.get("config_hash").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("checkpoint: missing config_hash"))?)
            .context("checkpoint: bad config_hash")?;
        let lr_bits = v.get("lr_bits").and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("checkpoint: missing lr_bits"))?;
        Ok(Checkpoint {
            version,
            config_hash,
            backend: v.get("backend").and_then(Json::as_str)
                .unwrap_or("unknown").to_string(),
            step: v.get("step").and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("checkpoint: missing step"))?
                as u64,
            epochs_done: v.get("epochs_done").and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("checkpoint: missing epochs_done"))?,
            lr: f32::from_bits(f64_to_u32(lr_bits)
                .context("checkpoint: bad lr_bits")?),
            front: v.get("front")
                .ok_or_else(|| anyhow!("checkpoint: missing front state"))?
                .clone(),
            params: tensors_from_json(v.get("params"), "params")?,
            momenta: tensors_from_json(v.get("momenta"), "momenta")?,
            dispatch_total: v.get("dispatch_total").and_then(Json::as_usize)
                .unwrap_or(0),
            dispatch_tail: v
                .get("dispatch_tail")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|s| s.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`, so a crash mid-write can never leave a truncated
    /// checkpoint where a good one used to be.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).with_context(
                    || format!("creating {}", dir.display()))?;
            }
        }
        let tmp = path.with_extension("ckpt.tmp");
        let text = format!("{}\n", self.to_json().pretty());
        std::fs::write(&tmp, text)
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path).with_context(
            || format!("renaming {} -> {}", tmp.display(), path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(text.trim())
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&v)
            .with_context(|| format!("parsing checkpoint {}",
                                     path.display()))
    }
}

fn tensors_to_json(ts: &[TensorCkpt]) -> Json {
    Json::Arr(ts.iter().map(|t| {
        Json::obj(vec![
            ("name", Json::str(&t.name)),
            ("shape", Json::Arr(
                t.shape.iter().map(|&d| Json::num(d as f64)).collect())),
            ("bits", Json::Arr(
                t.data.iter()
                    .map(|&x| Json::num(f64::from(x.to_bits())))
                    .collect())),
        ])
    }).collect())
}

fn tensors_from_json(v: Option<&Json>, what: &str) -> Result<Vec<TensorCkpt>> {
    let arr = v
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("checkpoint: missing {what} array"))?;
    arr.iter().map(|t| {
        let name = t.get("name").and_then(Json::as_str)
            .ok_or_else(|| anyhow!("checkpoint {what}: tensor missing \
                                    name"))?
            .to_string();
        let shape: Vec<usize> = t
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint {what}/{name}: missing \
                                    shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(
                || anyhow!("checkpoint {what}/{name}: bad shape entry")))
            .collect::<Result<_>>()?;
        let bits = t.get("bits").and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint {what}/{name}: missing \
                                    bits"))?;
        if bits.len() != shape.iter().product::<usize>() {
            bail!("checkpoint {what}/{name}: {} elements for shape \
                   {shape:?}", bits.len());
        }
        let data = bits.iter().map(|b| {
            let n = b.as_f64().ok_or_else(
                || anyhow!("checkpoint {what}/{name}: non-numeric bits"))?;
            Ok(f32::from_bits(f64_to_u32(n).with_context(
                || format!("checkpoint {what}/{name}"))?))
        }).collect::<Result<_>>()?;
        Ok(TensorCkpt { name, shape, data })
    }).collect()
}

/// Exact f64 -> u32 (JSON numbers are f64; bit patterns must round-trip
/// exactly, so anything fractional or out of range is a corrupt file).
fn f64_to_u32(n: f64) -> Result<u32> {
    if n.fract() != 0.0 || !(0.0..=f64::from(u32::MAX)).contains(&n) {
        bail!("value {n} is not a u32 bit pattern");
    }
    Ok(n as u32)
}

/// FNV-1a 64-bit — the checkpoint config hash. Not cryptographic; it
/// guards against honest config mixups, not adversaries.
pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// u64 -> fixed-width hex (JSON numbers are f64: a u64 would lose bits).
pub fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

pub fn parse_hex_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16)
        .map_err(|e| anyhow!("bad hex u64 '{s}': {e}"))
}

/// Serialize a 256-bit RNG state as a JSON array of four hex strings
/// (model fronts embed this in their snapshots).
pub fn rng_state_to_json(s: [u64; 4]) -> Json {
    Json::Arr(s.iter().map(|&w| Json::str(&hex_u64(w))).collect())
}

pub fn rng_state_from_json(v: &Json) -> Result<[u64; 4]> {
    let arr = v.as_arr()
        .ok_or_else(|| anyhow!("rng state: expected array"))?;
    if arr.len() != 4 {
        bail!("rng state: expected 4 words, got {}", arr.len());
    }
    let mut s = [0u64; 4];
    for (i, w) in arr.iter().enumerate() {
        s[i] = parse_hex_u64(w.as_str().ok_or_else(
            || anyhow!("rng state: non-string word"))?)?;
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            version: CKPT_VERSION,
            config_hash: fnv1a64("mlp tag=x rates=[0.5]"),
            backend: "reference".into(),
            step: 20,
            epochs_done: 1,
            lr: 0.009_999_5,
            front: Json::obj(vec![
                ("kind", Json::str("mlp")),
                ("rng", rng_state_to_json([1, u64::MAX, 3, 4])),
            ]),
            params: vec![TensorCkpt {
                name: "w1".into(),
                shape: vec![2, 3],
                data: vec![1.5, -0.0, f32::NAN, 3.25e-39, 1e30, -7.0],
            }],
            momenta: vec![TensorCkpt {
                name: "w1".into(),
                shape: vec![2, 3],
                data: vec![0.0; 6],
            }],
            dispatch_total: 20,
            dispatch_tail: vec!["a_rdp_2_2".into(), "a_rdp_4_4".into()],
        }
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let c = sample();
        let text = c.to_json().pretty();
        let back = Checkpoint::from_json(&json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.version, c.version);
        assert_eq!(back.config_hash, c.config_hash);
        assert_eq!(back.step, c.step);
        assert_eq!(back.epochs_done, c.epochs_done);
        assert_eq!(back.lr.to_bits(), c.lr.to_bits());
        assert_eq!(back.dispatch_tail, c.dispatch_tail);
        // Bit-exact through the text form — including NaN, -0.0 and
        // subnormals, which decimal JSON floats would mangle.
        for (a, b) in c.params.iter().zip(&back.params) {
            let ab: Vec<u32> = a.data.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb);
            assert_eq!(a.shape, b.shape);
        }
        assert_eq!(
            rng_state_from_json(c.front.get("rng").unwrap()).unwrap(),
            [1, u64::MAX, 3, 4]
        );
    }

    #[test]
    fn file_roundtrip_and_atomic_write() {
        let dir = std::env::temp_dir()
            .join(format!("ad-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        assert!(!path.with_extension("ckpt.tmp").exists(),
                "tmp file must be renamed away");
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.config_hash, c.config_hash);
        assert_eq!(back.params[0].data[0], 1.5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_version_and_garbage() {
        let mut v = sample().to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("ad_checkpoint".into(), Json::num(99.0));
        }
        let err = Checkpoint::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
        assert!(Checkpoint::from_json(&Json::obj(vec![])).is_err());
        // Element count must match the declared shape.
        let mut v = sample().to_json();
        if let Some(Json::Arr(ps)) = v.get("params").cloned() {
            let mut bad = ps.clone();
            if let Json::Obj(m) = &mut bad[0] {
                m.insert("shape".into(),
                         Json::Arr(vec![Json::num(5.0)]));
            }
            if let Json::Obj(top) = &mut v {
                top.insert("params".into(), Json::Arr(bad));
            }
        }
        assert!(Checkpoint::from_json(&v).is_err());
    }

    #[test]
    fn hex_and_hash_helpers() {
        assert_eq!(parse_hex_u64(&hex_u64(u64::MAX)).unwrap(), u64::MAX);
        assert_eq!(parse_hex_u64(&hex_u64(0)).unwrap(), 0);
        assert!(parse_hex_u64("zz").is_err());
        // FNV-1a reference vectors.
        assert_eq!(fnv1a64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64("a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64("config a"), fnv1a64("config b"));
    }

    #[test]
    fn u32_bit_pattern_guard() {
        assert!(f64_to_u32(0.5).is_err());
        assert!(f64_to_u32(-1.0).is_err());
        assert!(f64_to_u32(4.3e9).is_err());
        assert_eq!(f64_to_u32(f64::from(u32::MAX)).unwrap(), u32::MAX);
    }
}
