//! Quickstart: the whole stack in ~60 lines.
//!
//! Runs Algorithm 1 for a 0.5 target rate, trains a few dozen iterations
//! with the Row-based Dropout Pattern through the backend abstraction,
//! and evaluates. With no artifacts directory this runs hermetically on
//! the pure-Rust reference backend; after `make artifacts` (and a
//! `--features pjrt` build) the same code drives PJRT:
//!
//! ```sh
//! cargo run --release --example quickstart            # reference
//! AD_BACKEND=pjrt cargo run --release --features pjrt --example quickstart
//! ```

use approx_dropout::coordinator::{ExecutorCache, Schedule, Variant};
use approx_dropout::runtime::{HostTensor, TrainState, Value};
use approx_dropout::search::{self, SearchConfig};
use approx_dropout::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Load the artifact manifest (or the built-in synthetic registry)
    //    and pick the execution backend from AD_BACKEND.
    let manifest = approx_dropout::manifest_or_builtin()?;
    let cache = ExecutorCache::from_env(manifest)?;
    println!("backend: {}", cache.backend().name());

    // 2. Algorithm 1: distribution K over divisors for target rate 0.5.
    let result = search::search(0.5, &[1, 2], &SearchConfig::default());
    println!("pattern distribution K: {:?} (rate {:.4})",
             result.distribution.probs, result.achieved_rate);

    // 3. Compile the RDP executable for dp = (2, 2) and init state.
    let exe = cache.get("mlptest_rdp_2_2")?;
    let backend = cache.backend().clone();
    let mut rng = Rng::new(42);
    let mut state = TrainState::init(cache.manifest().get("mlptest_rdp_2_2")?,
                                     &mut rng, backend.as_ref())?;

    // 4. Train 50 iterations on random data, sampling a bias per step.
    let schedule = Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true)?;
    let batch = 8;
    for step in 0..50 {
        let choices = schedule.sample(&mut rng);
        let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
        let y: Vec<i32> =
            (0..batch).map(|i| ((i + step) % 10) as i32).collect();
        let tail: Vec<Value> = vec![
            backend.ingest(HostTensor::f32(&[batch, 32], x))?,
            backend.ingest(HostTensor::i32(&[batch], y))?,
            backend.ingest(HostTensor::scalar_i32(choices[0].b0 as i32))?,
            backend.ingest(HostTensor::scalar_i32(choices[1].b0 as i32))?,
            backend.ingest(HostTensor::scalar_f32(2.0))?, // 1/(1-p), p=0.5
            backend.ingest(HostTensor::scalar_f32(2.0))?,
            backend.ingest(HostTensor::scalar_f32(0.05))?, // lr
        ];
        let (loss, _) = state.step(exe.as_ref(), &tail)?;
        if step % 10 == 0 {
            println!("step {step:>3}: loss {loss:.4} \
                      (pattern b0 = {}, {})",
                     choices[0].b0, choices[1].b0);
        }
    }
    println!("quickstart OK — see examples/mlp_mnist.rs for the full \
              training driver");
    Ok(())
}
