//! LSTM language-model driver (paper section IV-C): train the 2-layer LSTM
//! on the synthetic corpus with conventional vs approximate dropout and
//! report perplexity + speedup. Uses the reduced-scale (H=256) model so a
//! laptop-class CPU converges in minutes; pass `--full` for the paper-scale
//! H=1536 timing configuration.
//!
//! ```sh
//! cargo run --release --example lstm_ptb -- [steps] [rate] [--full]
//! ```

use approx_dropout::coordinator::{speedup, ExecutorCache, LstmTrainer,
                                  Schedule, Variant};
use approx_dropout::data::Corpus;
use approx_dropout::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let full = args.iter().any(|a| a == "--full");
    let (tag, vocab) = if full {
        ("lstm2x1536v8800b20", 8800)
    } else {
        ("lstm2x256v2048b20", 2048)
    };

    let manifest = Manifest::load(&approx_dropout::artifacts_dir())?;
    let cache = ExecutorCache::from_env(manifest)?;
    println!("== LSTM LM: {tag}, {steps} steps, rate {rate} ==");
    let corpus = Corpus::generate(vocab, 300_000, 30_000, 30_000, 11);
    println!("unigram baseline perplexity: {:.1}",
             corpus.unigram_xent(&corpus.valid).exp());

    let mut rows = Vec::new();
    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        let schedule = Schedule::new(variant, &[rate, rate], &[1, 2, 4, 8],
                                     variant != Variant::Conv)?;
        let mut tr = LstmTrainer::new(&cache, tag, schedule, &corpus.train,
                                      0.1, 3)?;
        tr.warmup()?;
        let log_every = (steps / 8).max(1);
        for s in 0..steps {
            let (loss, _) = tr.step()?;
            if (s + 1) % log_every == 0 {
                println!("[{}] step {:>4}  train ppl {:.1}",
                         variant.as_str(), s + 1, loss.exp());
            }
        }
        let (_, ppl, acc) = tr.evaluate(&corpus.valid)?;
        let t = tr.metrics.steady_mean_step_s(2);
        println!("[{}] -> valid ppl {ppl:.1}, token-acc {:.2}%, step \
                  {:.0} ms", variant.as_str(), acc * 100.0, t * 1e3);
        rows.push((variant, t, ppl, acc));
    }

    let conv = rows[0].1;
    println!("\n== summary (rate {rate}) ==");
    for (v, t, ppl, acc) in &rows {
        println!("{:<6} step {:.0} ms  speedup {:.2}x  ppl {:.1}  acc \
                  {:.2}%", v.as_str(), t * 1e3, speedup(conv, *t), ppl,
                 acc * 100.0);
    }
    Ok(())
}
