//! Runtime layer: PJRT client wrapper (engine), the artifact manifest
//! contract, and host-side training state.
//!
//! Flow: `Manifest::load` -> `Engine::load(name)` -> `Executable::run` with
//! `HostTensor`s assembled by the coordinator. One compiled executable per
//! (model, variant, dp) — compiled lazily, once per process, by the shared
//! `coordinator::ExecutorCache`.

pub mod engine;
pub mod manifest;
pub mod state;

pub use engine::{Engine, Executable, HostTensor};
pub use manifest::{ArchMeta, ArtifactMeta, Dtype, Kind, Manifest,
                   TensorMeta};
pub use state::TrainState;
