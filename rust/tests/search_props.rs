//! Property tests (via `util/testkit`) for the Algorithm 1 invariants of
//! `search::search`: the returned distribution is a proper simplex point,
//! every support divisor keeps nonzero mass (the entropy term's job), and
//! random targets over random divisor supports are hit to 1e-2.

use approx_dropout::search::{self, SearchConfig};
use approx_dropout::util::testkit;

/// Draw a random divisor support: always contains 1 (no-dropout pattern)
/// and at least one divisor >= 8 so every target rate in [0.2, 0.8] is
/// feasible (max p_u >= 7/8), plus a random subset in between.
fn gen_support(rng: &mut approx_dropout::util::rng::Rng) -> Vec<usize> {
    let pool = [2usize, 3, 4, 5, 6, 8, 10, 16];
    let mut support = vec![1usize];
    for &d in &pool {
        if rng.bernoulli(0.5) {
            support.push(d);
        }
    }
    let anchor = if rng.bernoulli(0.5) { 8 } else { 16 };
    if !support.contains(&anchor) {
        support.push(anchor);
    }
    support.sort_unstable();
    support.dedup();
    support
}

#[test]
fn distribution_is_simplex_with_full_support() {
    testkit::quickcheck("search simplex", |rng| {
        let support = gen_support(rng);
        let p = rng.uniform(0.2, 0.8);
        let r = search::search(p, &support, &SearchConfig::default());
        let d = &r.distribution;
        assert_eq!(d.support, support);
        let sum: f64 = d.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "probs sum to {sum}");
        for (dp, &k) in d.support.iter().zip(&d.probs) {
            assert!(k.is_finite() && k > 0.0,
                    "divisor {dp} got zero/invalid mass {k} \
                     (target {p}, support {support:?})");
        }
    });
}

#[test]
fn achieved_rate_within_1e2_of_random_targets() {
    testkit::quickcheck("search hits target", |rng| {
        let support = gen_support(rng);
        let p = rng.uniform(0.2, 0.8);
        let r = search::search(p, &support, &SearchConfig::default());
        assert!((r.achieved_rate - p).abs() < 1e-2,
                "target {p} achieved {} over {support:?}",
                r.achieved_rate);
        // Internal consistency: SearchResult.achieved_rate IS the
        // distribution's expected rate.
        assert!((r.achieved_rate - r.distribution.expected_rate()).abs()
                < 1e-12);
    });
}

#[test]
fn search_is_deterministic_over_random_supports() {
    testkit::check("search deterministic", 16, |rng| {
        let support = gen_support(rng);
        let p = rng.uniform(0.2, 0.8);
        let cfg = SearchConfig::default();
        let a = search::search(p, &support, &cfg);
        let b = search::search(p, &support, &cfg);
        assert_eq!(a.distribution.probs, b.distribution.probs);
        assert_eq!(a.iters, b.iters);
    });
}
