//! TOML-subset parser for `configs/*.toml` experiment configs.
//!
//! Supported grammar (all the config system needs): `[table]` and
//! `[table.sub]` headers, `key = value` with string / integer / float /
//! bool / homogeneous-array values, `#` comments. Unsupported TOML
//! (multi-line strings, dates, inline tables, array-of-tables) errors out
//! loudly rather than mis-parsing.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path key -> value (e.g. "train.lr").
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Keys under a table prefix, e.g. `keys_under("bench")`.
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        let p = format!("{prefix}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&p))
            .map(|k| k.as_str())
            .collect()
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(input: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut table = String::new();
    for (ln, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(TomlError {
                line: ln + 1,
                msg: "unterminated table header".into(),
            })?;
            if name.starts_with('[') {
                return Err(TomlError {
                    line: ln + 1,
                    msg: "array-of-tables unsupported".into(),
                });
            }
            table = name.trim().to_string();
            continue;
        }
        let eq = line.find('=').ok_or(TomlError {
            line: ln + 1,
            msg: "expected 'key = value'".into(),
        })?;
        let key = line[..eq].trim();
        let val = parse_value(line[eq + 1..].trim()).map_err(|msg| {
            TomlError { line: ln + 1, msg }
        })?;
        let full = if table.is_empty() {
            key.to_string()
        } else {
            format!("{table}.{key}")
        };
        doc.entries.insert(full, val);
    }
    Ok(doc)
}

pub fn parse_file(path: &std::path::Path) -> anyhow::Result<TomlDoc> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote unsupported".into());
        }
        return Ok(TomlValue::Str(inner.replace("\\n", "\n")
                                      .replace("\\t", "\t")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<_>, _> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_doc() {
        let doc = parse(
            "# experiment\ntitle = \"fig4\"\n[train]\nlr = 0.01\n\
             steps = 2000\nshared_dp = true\nrates = [0.3, 0.5, 0.7]\n",
        )
        .unwrap();
        assert_eq!(doc.str_or("title", ""), "fig4");
        assert_eq!(doc.f64_or("train.lr", 0.0), 0.01);
        assert_eq!(doc.i64_or("train.steps", 0), 2000);
        assert!(doc.bool_or("train.shared_dp", false));
        let arr = doc.get("train.rates").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_f64(), Some(0.7));
    }

    #[test]
    fn nested_tables_and_comments() {
        let doc = parse("[a.b]\nx = 1 # trailing\ns = \"ha#sh\"\n").unwrap();
        assert_eq!(doc.i64_or("a.b.x", 0), 1);
        assert_eq!(doc.str_or("a.b.s", ""), "ha#sh");
    }

    #[test]
    fn errors() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("key value\n").is_err());
        assert!(parse("k = [1, 2\n").is_err());
    }

    #[test]
    fn ints_vs_floats() {
        let doc = parse("a = 3\nb = 3.5\nc = 1e3\n").unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64(), Some(3));
        assert_eq!(doc.get("b").unwrap().as_f64(), Some(3.5));
        assert_eq!(doc.get("c").unwrap().as_f64(), Some(1000.0));
        assert_eq!(doc.get("b").unwrap().as_i64(), None);
    }
}
