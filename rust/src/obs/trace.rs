//! Phase-scoped tracing: cheap RAII span timers around the trainer and
//! step-interpreter phases (sample, marshal, prep, fwd, softmax, bptt,
//! sgd, execute), aggregated per (scope, phase) and optionally exported
//! as Chrome trace-event JSON (`--trace-out`).
//!
//! Cost model: when `AD_TRACE` is off, [`span`] is a single `Relaxed`
//! atomic load returning `None` — no clock read, no allocation, no
//! lock. When on, a span reads the monotonic clock twice and takes one
//! short mutex on drop (per *phase*, a handful per step — never per
//! element).
//!
//! Hard contract, pinned by `rust/tests/obs.rs`: spans are pure
//! observers. They never draw from an RNG stream, never reorder or gate
//! caller work, and never branch the traced code path — so
//! trajectories, dispatch sequences, and final parameter bits are
//! bit-identical with tracing on or off. Scopes are thread-local
//! because spans fire on runner/assembly threads (fleet jobs, the
//! pipelined trainer's worker); a thread that never set one reports
//! under `"-"`.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static COLLECT_EVENTS: AtomicBool = AtomicBool::new(false);
static INIT: Once = Once::new();

/// Read `AD_TRACE` once per process (on|1|true => on; off|0|false|unset
/// => off; anything else warns loudly and stays off — same policy as
/// `AD_SIMD`/`AD_LOG`).
pub fn init_from_env() {
    INIT.call_once(|| match std::env::var("AD_TRACE").as_deref() {
        Ok("on" | "1" | "true") => ENABLED.store(true, Ordering::Relaxed),
        Ok("off" | "0" | "false" | "") | Err(_) => {}
        Ok(v) => {
            crate::warn_!("AD_TRACE={v:?} is not a recognized value \
                           (use on|off); tracing stays OFF");
        }
    });
}

/// Explicit switch for tests and benches — avoids racy process-env
/// mutation under parallel test threads (same reason
/// `LstmTrainer::new_with_window` exists).
pub fn force_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The hot-path gate: one relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Scopes: which (config) a span aggregates under
// ---------------------------------------------------------------------------

thread_local! {
    static SCOPE: RefCell<String> = const { RefCell::new(String::new()) };
}

/// Tag this thread's subsequent spans with a config label (e.g.
/// `"mlpsyn/rdp"`). The trainer sets it on the stepping thread and the
/// pipelined assembly worker; fleet runner threads set their job name.
pub fn set_scope(scope: &str) {
    SCOPE.with(|s| {
        let mut s = s.borrow_mut();
        s.clear();
        s.push_str(scope);
    });
}

fn current_scope() -> String {
    SCOPE.with(|s| {
        let s = s.borrow();
        if s.is_empty() { "-".to_string() } else { s.clone() }
    })
}

// ---------------------------------------------------------------------------
// Spans + aggregation
// ---------------------------------------------------------------------------

/// RAII phase timer; records on drop. Hold it in a `let _sp = ...;`
/// binding around the phase body.
pub struct Span {
    phase: &'static str,
    t0: Instant,
}

/// Start a span for `phase` — `None` (and nothing else) when tracing is
/// off.
#[inline]
pub fn span(phase: &'static str) -> Option<Span> {
    if !enabled() {
        return None;
    }
    Some(Span { phase, t0: Instant::now() })
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur_s = self.t0.elapsed().as_secs_f64();
        record(self.phase, self.t0, dur_s);
    }
}

/// Aggregated wall-clock for one (scope, phase).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseAgg {
    pub count: u64,
    pub total_s: f64,
    pub max_s: f64,
}

/// One exported aggregation row.
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub scope: String,
    pub phase: &'static str,
    pub agg: PhaseAgg,
}

static AGG: Mutex<BTreeMap<(String, &'static str), PhaseAgg>> =
    Mutex::new(BTreeMap::new());

fn record(phase: &'static str, t0: Instant, dur_s: f64) {
    let scope = current_scope();
    {
        let mut agg = AGG.lock().unwrap_or_else(|e| e.into_inner());
        let a = agg.entry((scope, phase)).or_default();
        a.count += 1;
        a.total_s += dur_s;
        a.max_s = a.max_s.max(dur_s);
    }
    if COLLECT_EVENTS.load(Ordering::Relaxed) {
        push_event(phase, t0, dur_s);
    }
}

/// Read the aggregation table (sorted by scope then phase).
pub fn phase_snapshot() -> Vec<PhaseRow> {
    let agg = AGG.lock().unwrap_or_else(|e| e.into_inner());
    agg.iter()
        .map(|((scope, phase), a)| PhaseRow {
            scope: scope.clone(),
            phase,
            agg: *a,
        })
        .collect()
}

/// Drain the aggregation table — benches snapshot per-config deltas by
/// draining between configs.
pub fn take_phases() -> Vec<PhaseRow> {
    let mut agg = AGG.lock().unwrap_or_else(|e| e.into_inner());
    std::mem::take(&mut *agg)
        .into_iter()
        .map(|((scope, phase), a)| PhaseRow { scope, phase, agg: a })
        .collect()
}

// ---------------------------------------------------------------------------
// Chrome trace-event export (--trace-out)
// ---------------------------------------------------------------------------

/// Cap on buffered events so a long traced run cannot grow without
/// bound; past it, aggregation keeps counting but the flamegraph stops.
const MAX_EVENTS: usize = 200_000;

struct Event {
    phase: &'static str,
    scope: String,
    ts_us: u64,
    dur_us: u64,
    tid: u64,
}

static EVENTS: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Also buffer individual span events for [`write_chrome_trace`]
/// (requires tracing to be enabled to have any effect).
pub fn collect_events(on: bool) {
    if on {
        // Pin the timeline origin before the first event.
        let _ = EPOCH.get_or_init(Instant::now);
    }
    COLLECT_EVENTS.store(on, Ordering::Relaxed);
}

fn push_event(phase: &'static str, t0: Instant, dur_s: f64) {
    let epoch = *EPOCH.get_or_init(Instant::now);
    let ts_us = t0.saturating_duration_since(epoch).as_micros() as u64;
    let tid = TID.with(|t| *t);
    let mut ev = EVENTS.lock().unwrap_or_else(|e| e.into_inner());
    if ev.len() >= MAX_EVENTS {
        return;
    }
    ev.push(Event {
        phase,
        scope: current_scope(),
        ts_us,
        dur_us: (dur_s * 1e6) as u64,
        tid,
    });
}

/// Write buffered events as a Chrome trace-event JSON array
/// (`chrome://tracing` / Perfetto "X" complete events). Returns the
/// number of events written.
pub fn write_chrome_trace(path: &Path) -> anyhow::Result<usize> {
    use anyhow::Context;
    let ev = EVENTS.lock().unwrap_or_else(|e| e.into_inner());
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "[")?;
    for (i, e) in ev.iter().enumerate() {
        let comma = if i + 1 < ev.len() { "," } else { "" };
        // Names are static phase idents + config tags we generate: no
        // JSON-escaping hazards beyond quotes, which neither contains.
        writeln!(
            w,
            "  {{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \
             \"pid\": 1, \"tid\": {}, \"ts\": {}, \"dur\": {}}}{comma}",
            e.phase, e.scope, e.tid, e.ts_us, e.dur_us
        )?;
    }
    writeln!(w, "]")?;
    w.flush()?;
    Ok(ev.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The global ENABLED flag is shared across the parallel test
    // harness, so every path through this test restores "off" before
    // asserting anything that other tests could observe.
    #[test]
    fn spans_aggregate_only_when_enabled() {
        force_enabled(false);
        assert!(span("unit_test_phase_off").is_none());

        force_enabled(true);
        set_scope("obs-unit");
        {
            let _sp = span("unit_test_phase_on");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        force_enabled(false);

        let rows = phase_snapshot();
        let row = rows
            .iter()
            .find(|r| r.phase == "unit_test_phase_on"
                  && r.scope == "obs-unit")
            .expect("span recorded");
        assert!(row.agg.count >= 1);
        assert!(row.agg.total_s > 0.0);
        assert!(row.agg.max_s <= row.agg.total_s + 1e-12);
        assert!(!rows.iter().any(|r| r.phase == "unit_test_phase_off"));
    }

    #[test]
    fn chrome_trace_writes_parseable_json() {
        force_enabled(true);
        collect_events(true);
        set_scope("obs-chrome");
        {
            let _sp = span("unit_test_chrome_event");
        }
        collect_events(false);
        force_enabled(false);

        let path = std::env::temp_dir()
            .join(format!("ad-trace-{}.json", std::process::id()));
        let n = write_chrome_trace(&path).unwrap();
        assert!(n >= 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(text.trim()).unwrap();
        let arr = v.as_arr().expect("top-level array");
        assert!(arr.iter().any(|e| {
            e.get("name").and_then(|n| n.as_str())
                == Some("unit_test_chrome_event")
                && e.get("ph").and_then(|p| p.as_str()) == Some("X")
        }));
        std::fs::remove_file(&path).ok();
    }
}
