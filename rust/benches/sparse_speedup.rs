//! The paper's Figure-level speedup claim, reproduced in-repo: dense
//! (conventional masked dropout) vs **row-skip** (RDP) vs **tile-skip**
//! (TDP) train-step wall-clock on the structured-sparse backend, at
//! global dropout rates 0.3 / 0.5 / 0.7, on the `mlpsyn` and `lstmsyn`
//! archs.
//!
//! All three configurations run the identical coordinator path and the
//! identical step program (`runtime::step`); the only difference is what
//! the kernels may skip — conventional dropout's Bernoulli masks have no
//! structure, so its steps pay full dense math plus per-step mask
//! generation, exactly the baseline the paper measures against.
//!
//! A windowed section re-times the structured lstmsyn configurations
//! with the dropout pattern re-drawn every `W` timesteps
//! (`row-skip@w1` / `tile-skip@w16` rows, the `AD_TIME_WINDOW` runtime
//! knob) against the same dense baseline; larger windows amortize the
//! cached kept-row weight panels over more timesteps.
//!
//! A `dyn-bwd` section re-times the row-skip configurations with the
//! sparse backend's **dynamic backward sparsity** enabled (`AD_DYN_BWD`;
//! plan `DynMask` nodes skipping runtime-dead gradient rows), paired
//! against a static-only run so each row carries both `speedup_vs_dense`
//! and the isolated `dyn_vs_static` ratio. All other sections pin
//! dynamic masks OFF, so their rows measure the same static-skip work
//! they always did.
//!
//! When the CPU has SIMD microkernels (AVX2+FMA / NEON; see
//! `runtime::sparse::simd`), a second section re-times the GEMM-dominated
//! `mlpsyn` configurations on the scalar microkernels (`<config>@scalar`
//! rows, `AD_SIMD=off` equivalent) so the report also carries the
//! SIMD-vs-scalar speedup the microkernel layer is responsible for.
//!
//! Output: a paper-style table on stdout plus machine-readable
//! `BENCH_sparse.json` (repo root, or `$AD_BENCH_OUT/`) through the
//! shared `bench::report` writer. Any run of this binary is a *native*
//! measurement — the report's `provenance` says so, and CI's
//! `bench-regression` job uploads it as the refresh candidate for the
//! checked-in baseline (`tools/check_bench_regression.py
//! --refresh-baseline`).
//!
//! Knobs: `AD_BENCH_SMOKE=1` (tiny rep counts, CI smoke job),
//! `AD_BENCH_REPS` (timed steps per configuration), `AD_THREADS`
//! (sparse worker pool size), `AD_SIMD` (microkernel selection).

use std::sync::Arc;

use anyhow::Result;

use approx_dropout::bench::drivers::env_usize;
use approx_dropout::bench::{bench, fmt_time, BenchReport, BenchResult,
                            Table};
use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::obs::trace;
use approx_dropout::runtime::sparse::threads_from_env;
use approx_dropout::runtime::{ArchMeta, Manifest, SparseBackend,
                              SparseKernels};
use approx_dropout::util::json::Json;

const SUPPORT: &[usize] = &[1, 2, 4];
const RATES: &[f64] = &[0.3, 0.5, 0.7];

/// Time-window sizes (timesteps per pattern draw) for the windowed
/// lstmsyn section. The unannotated lstmsyn rows are `W = seq` — one
/// draw per step, the paper's per-iteration policy; `W = 16` holds one
/// draw across two steps (seq is 8), `W < seq` re-draws within the
/// step. Larger windows amortize the per-window weight-panel prep over
/// more timesteps, which is where the LSTM speedup gap closes.
const WINDOWS: &[usize] = &[1, 4, 16];

/// Rates re-timed on the scalar microkernels for the SIMD-vs-scalar
/// section (the regression gate's operating points).
const SIMD_CMP_RATES: &[f64] = &[0.5, 0.7];

struct Cfg {
    label: &'static str,
    variant: Variant,
}

const CFGS: &[Cfg] = &[
    Cfg { label: "dense", variant: Variant::Conv },
    Cfg { label: "row-skip", variant: Variant::Rdp },
    Cfg { label: "tile-skip", variant: Variant::Tdp },
];

/// The datasets + repetition settings every measurement shares.
struct Bencher {
    mnist: MnistSyn,
    corpus: Corpus,
    warm: usize,
    reps: usize,
}

impl Bencher {
    /// One timed (arch, rate, config) measurement on a given cache.
    fn run(&self, cache: &ExecutorCache, arch: &str, rate: f64,
           cfg: &Cfg) -> Result<BenchResult> {
        Ok(match arch {
            "mlpsyn" => {
                let schedule = Schedule::new(cfg.variant, &[rate, rate],
                                             SUPPORT, false)?;
                let mut tr = MlpTrainer::new(cache, arch, schedule,
                                             self.mnist.n, 0.01, 7)?;
                tr.warmup()?;
                bench(cfg.label, self.warm, self.reps,
                      || tr.step(&self.mnist).unwrap())
            }
            _ => self.run_lstm(cache, arch, rate, cfg, None)?,
        })
    }

    /// One timed LSTM measurement at an explicit time window. `None`
    /// pins the default per-step policy (W = seq) so the report stays
    /// self-describing no matter what `AD_TIME_WINDOW` is set to in the
    /// environment — every row's window is in the row itself.
    fn run_lstm(&self, cache: &ExecutorCache, arch: &str, rate: f64,
                cfg: &Cfg, window: Option<usize>) -> Result<BenchResult> {
        let shared = cfg.variant != Variant::Conv;
        let schedule = Schedule::new(cfg.variant, &[rate, rate], SUPPORT,
                                     shared)?;
        let mut tr = LstmTrainer::new_with_window(cache, arch, schedule,
                                                  &self.corpus.train, 0.1,
                                                  13, window)?;
        tr.warmup()?;
        Ok(bench(cfg.label, self.warm, self.reps, || tr.step().unwrap()))
    }
}

/// Identity of one report row (everything but the measurement itself).
struct RowCtx<'a> {
    arch: &'a str,
    rate: f64,
    label: &'a str,
    variant: Variant,
    microkernel: &'a str,
    /// Timesteps per pattern draw (LSTM rows only; `None` for MLP rows,
    /// where there is no time axis to window).
    window: Option<usize>,
}

/// The two output surfaces every row lands on.
struct Sink {
    report: BenchReport,
    table: Table,
}

/// Per-config phase breakdown: drain the span aggregator (so each
/// config's rows cover only its own warmup+timed reps) and fold into
/// `{phase: total_s}`. Warmup reps are included — the breakdown is for
/// *proportions* (where does a step's time go), not absolute medians.
fn drain_phases() -> Json {
    let mut m = std::collections::BTreeMap::new();
    for row in trace::take_phases() {
        let e = m.entry(row.phase.to_string())
            .or_insert(Json::Num(0.0));
        if let Json::Num(v) = e {
            *v += row.agg.total_s;
        }
    }
    Json::Obj(m)
}

impl Sink {
    fn push(&mut self, ctx: &RowCtx<'_>, r: &BenchResult, dense_s: f64) {
        self.push_row(ctx, r, dense_s, None);
    }

    /// A `dyn-bwd` row: the same schema plus `dyn_vs_static`, the paired
    /// dyn-enabled-vs-static-only ratio on the identical configuration.
    fn push_dyn(&mut self, ctx: &RowCtx<'_>, r: &BenchResult,
                dense_s: f64, static_s: f64) {
        self.push_row(ctx, r, dense_s, Some(static_s / r.median_s));
    }

    fn push_row(&mut self, ctx: &RowCtx<'_>, r: &BenchResult,
                dense_s: f64, dyn_vs_static: Option<f64>) {
        let speedup = dense_s / r.median_s;
        self.table.row(&[ctx.arch.to_string(), format!("{}", ctx.rate),
                         ctx.label.to_string(),
                         ctx.microkernel.to_string(),
                         fmt_time(r.median_s),
                         format!("{:.1}", r.per_sec()),
                         format!("{speedup:.2}x")]);
        let mut row = vec![
            ("arch", Json::str(ctx.arch)),
            ("rate", Json::num(ctx.rate)),
            ("config", Json::str(ctx.label)),
            ("variant", Json::str(ctx.variant.as_str())),
            ("microkernel", Json::str(ctx.microkernel)),
            ("median_step_s", Json::num(r.median_s)),
            ("mad_s", Json::num(r.mad_s)),
            ("mean_step_s", Json::num(r.mean_s)),
            ("reps", Json::num(r.reps as f64)),
            ("speedup_vs_dense", Json::num(speedup)),
        ];
        if let Some(w) = ctx.window {
            row.push(("window", Json::num(w as f64)));
        }
        if let Some(ratio) = dyn_vs_static {
            row.push(("dyn_vs_static", Json::num(ratio)));
        }
        row.push(("phase_s", drain_phases()));
        self.report.row(row);
    }
}

fn main() -> Result<()> {
    // Phase spans on for every measurement: the breakdown rides along in
    // each row's `phase_s`. Tracing is a pure observer (pinned by the
    // bit-identity test in tests/obs.rs), so the timings stay honest.
    trace::force_enabled(true);
    let smoke = env_usize("AD_BENCH_SMOKE", 0) == 1;
    let reps = env_usize("AD_BENCH_REPS", if smoke { 3 } else { 40 });
    let warm = if smoke { 1 } else { 5 };
    let threads = threads_from_env();
    let mk = SparseKernels::auto().microkernel();

    let manifest = Manifest::builtin_test();
    let lstm_seq = match &manifest.get("lstmsyn_conv")?.arch {
        ArchMeta::Lstm { seq, .. } => *seq,
        _ => unreachable!("lstmsyn is an LSTM arch"),
    };
    // Static sections pin dynamic backward sparsity OFF so every
    // pre-existing row keeps measuring exactly what it always measured
    // (static structured skips only) regardless of `AD_DYN_BWD`; the
    // dyn-bwd section below times the dynamic layer against these.
    let cache = ExecutorCache::new(
        Arc::new(SparseBackend::with_kernels(
            SparseKernels::auto().with_dyn(false))),
        manifest,
    );
    let (mnist, _) = MnistSyn::train_test(512, 64, 42);
    let bencher = Bencher {
        mnist,
        corpus: Corpus::generate(64, 8000, 800, 800, 9),
        warm,
        reps,
    };

    let mut report =
        BenchReport::new("sparse_speedup",
                         "native: rust/benches/sparse_speedup.rs \
                          (cargo run --release --bin sparse_speedup)");
    report
        .set("backend", Json::str("sparse"))
        .set("threads", Json::num(threads as f64))
        .set("microkernel", Json::str(mk))
        .set("target_arch", Json::str(std::env::consts::ARCH))
        .set("smoke", Json::Bool(smoke))
        .set("reps", Json::num(reps as f64))
        .set("support", Json::Arr(
            SUPPORT.iter().map(|&d| Json::num(d as f64)).collect()))
        .set("windows", Json::Arr(
            WINDOWS.iter().map(|&w| Json::num(w as f64)).collect()))
        .set("lstm_seq", Json::num(lstm_seq as f64));
    let mut sink = Sink {
        report,
        table: Table::new(&["arch", "rate", "config", "microkernel",
                            "median step", "steps/s", "speedup"]),
    };

    // Dense medians per (arch, rate), reused as the baseline for the
    // windowed and dyn-bwd sections (conventional dropout has no
    // time-window or dynamic-mask axis — re-timing it per section would
    // only duplicate its gate key).
    let mut dense_med: Vec<(&str, f64, f64)> = Vec::new();
    for arch in ["mlpsyn", "lstmsyn"] {
        for &rate in RATES {
            let mut dense_s = f64::NAN;
            for cfg in CFGS {
                let r = bencher.run(&cache, arch, rate, cfg)?;
                if cfg.label == "dense" {
                    dense_s = r.median_s;
                    dense_med.push((arch, rate, dense_s));
                }
                let window =
                    (arch == "lstmsyn").then_some(lstm_seq);
                sink.push(&RowCtx { arch, rate, label: cfg.label,
                                    variant: cfg.variant,
                                    microkernel: mk, window },
                          &r, dense_s);
            }
        }
    }

    // Windowed lstmsyn section: the rows the paper's LSTM speedup gap
    // closes on. `row-skip@wN` / `tile-skip@wN` re-time the structured
    // configurations with the pattern re-drawn every N timesteps; the
    // per-(site, window) prepped weight panels amortize over N steps of
    // forward+backward, so speedup should grow with N. W = seq rows are
    // the unannotated `row-skip` / `tile-skip` rows above.
    let dense_of = |meds: &[(&str, f64, f64)], arch: &str, rate: f64| {
        meds.iter()
            .find(|&&(a, r0, _)| a == arch && r0 == rate)
            .map(|&(_, _, d)| d)
            .unwrap_or(f64::NAN)
    };
    for &rate in RATES {
        let dense_s = dense_of(&dense_med, "lstmsyn", rate);
        for &w in WINDOWS {
            for cfg in CFGS.iter().filter(|c| c.label != "dense") {
                let r = bencher.run_lstm(&cache, "lstmsyn", rate, cfg,
                                         Some(w))?;
                let label = format!("{}@w{w}", cfg.label);
                sink.push(&RowCtx { arch: "lstmsyn", rate, label: &label,
                                    variant: cfg.variant,
                                    microkernel: mk, window: Some(w) },
                          &r, dense_s);
            }
        }
    }

    // Dynamic-backward section: the first net-new consumer of the
    // SparsityPlan IR. `dyn-bwd` rows re-time the row-skip (RDP)
    // configuration with dynamic masks ON — the backward pass skips
    // runtime-dead gradient rows (ReLU-zero units; the LSTM's zero
    // initial state at t==0) on top of the static pattern — paired
    // against a static-only run of the identical configuration.
    // `speedup_vs_dense` keeps the rows comparable to the rest of the
    // table; `dyn_vs_static` isolates what the dynamic layer adds.
    {
        let dyn_cache = ExecutorCache::new(
            Arc::new(SparseBackend::with_kernels(
                SparseKernels::auto().with_dyn(true))),
            Manifest::builtin_test(),
        );
        let rdp = &CFGS[1];
        debug_assert_eq!(rdp.label, "row-skip");
        for arch in ["mlpsyn", "lstmsyn"] {
            for &rate in RATES {
                let dense_s = dense_of(&dense_med, arch, rate);
                // Paired back-to-back runs: the static re-measurement
                // (not the earlier row-skip row) is the denominator, so
                // machine drift between sections cancels out.
                let rs = bencher.run(&cache, arch, rate, rdp)?;
                let rd = bencher.run(&dyn_cache, arch, rate, rdp)?;
                sink.push_dyn(
                    &RowCtx { arch, rate, label: "dyn-bwd",
                              variant: Variant::Rdp, microkernel: mk,
                              window: (arch == "lstmsyn")
                                  .then_some(lstm_seq) },
                    &rd, dense_s, rs.median_s);
            }
        }
    }

    // SIMD-vs-scalar section: only meaningful when the active
    // microkernel is actually vectorized. The GEMM-dominated mlpsyn
    // configurations are where the microkernel layer carries the load.
    if mk != "scalar" {
        let scalar_cache = ExecutorCache::new(
            Arc::new(SparseBackend::with_kernels(
                SparseKernels::scalar().with_dyn(false))),
            Manifest::builtin_test(),
        );
        for &rate in SIMD_CMP_RATES {
            let mut dense_s = f64::NAN;
            for cfg in CFGS {
                let r = bencher.run(&scalar_cache, "mlpsyn", rate, cfg)?;
                if cfg.label == "dense" {
                    dense_s = r.median_s;
                }
                let label = format!("{}@scalar", cfg.label);
                sink.push(&RowCtx { arch: "mlpsyn", rate, label: &label,
                                    variant: cfg.variant,
                                    microkernel: "scalar",
                                    window: None },
                          &r, dense_s);
            }
        }
    }

    println!("== sparse speedup (dense vs row-skip vs tile-skip, \
              {threads} thread(s), {mk} microkernel) ==");
    sink.table.print();
    let path = sink.report.write_default("BENCH_sparse.json")?;
    println!("\nwrote {} ({} rows)", path.display(),
             sink.report.n_rows());
    println!("interpretation: the paper's claim is that regular dropout \
              patterns turn dropped rows/tiles into *skipped* work; \
              speedup should grow with the dropout rate and tile-skip \
              should track row-skip (fig. 7/8). The @wN rows re-draw the \
              LSTM pattern every N timesteps (AD_TIME_WINDOW equivalent) \
              — larger windows amortize the cached weight panels and \
              should widen the LSTM speedup. The dyn-bwd rows re-time \
              row-skip with dynamic backward masks on (AD_DYN_BWD): the \
              backward pass additionally skips runtime-dead gradient \
              rows, so dyn_vs_static should be >= 1.0. The @scalar rows \
              isolate the SIMD microkernel contribution on the \
              GEMM-dominated mlpsyn configs (AD_SIMD=off equivalent).");
    Ok(())
}
