#!/usr/bin/env python3
"""Checkpoint/resume smoke check: interrupted == uninterrupted.

Usage:
    check_resume_smoke.py FIRST.json RESUMED.json FULL.json
                          [--workers N1,N2]

FIRST   — curve of a run that trained N steps and wrote a checkpoint
RESUMED — curve of a run that resumed that checkpoint and trained M more
FULL    — curve of an uninterrupted N+M-step run (same config/seed)

Asserts the concatenation FIRST + RESUMED equals FULL *exactly* — step
numbers, losses and accuracies — i.e. resume reproduces the trajectory
bit-for-bit (curve JSON carries shortest-round-trip f64 decimals, so
float equality after json.load is bit equality).

--workers N1,N2 labels an *elastic* resume: FIRST ran with N1
data-parallel workers and RESUMED re-sharded onto N2. The assertion is
unchanged — worker counts must not perturb the trajectory (that is the
reduction-tree contract, DESIGN.md §13) — but the labels make a failure
report say which elasticity leg diverged. FULL is expected at N1.
"""

import argparse
import json
import sys


def rows(path):
    with open(path) as f:
        return [(r["step"], r["loss"], r["acc"])
                for r in json.load(f)["rows"]]


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("first")
    ap.add_argument("resumed")
    ap.add_argument("full")
    ap.add_argument("--workers", default=None,
                    help="N1,N2 — worker counts of the first and "
                         "resumed runs (elastic-resume labeling)")
    args = ap.parse_args()
    label_first, label_resumed = "first", "resumed"
    if args.workers is not None:
        try:
            n1, n2 = (int(x) for x in args.workers.split(","))
        except ValueError:
            print(f"bad --workers {args.workers!r} (want N1,N2)")
            return 2
        if n1 < 1 or n2 < 1:
            print(f"bad --workers {args.workers!r} (counts must be >= 1)")
            return 2
        label_first = f"first[w{n1}]"
        label_resumed = f"resumed[w{n2}]"
    first, resumed, full = map(rows, (args.first, args.resumed, args.full))
    stitched = first + resumed
    print(f"{label_first}: {len(first)} steps, "
          f"{label_resumed}: {len(resumed)} steps, "
          f"full: {len(full)} steps")
    if len(stitched) != len(full):
        print(f"FAIL: stitched has {len(stitched)} steps, full has "
              f"{len(full)}")
        return 1
    bad = [(a, b) for a, b in zip(stitched, full) if a != b]
    if bad:
        print(f"FAIL: {len(bad)} step(s) diverge; first: "
              f"stitched={bad[0][0]} full={bad[0][1]}")
        return 1
    print("OK: resumed trajectory is identical to the uninterrupted run")
    return 0


if __name__ == "__main__":
    sys.exit(main())
