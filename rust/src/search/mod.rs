//! SGD-based Search Algorithm (paper Algorithm 1).
//!
//! Given a target global dropout rate `p` and a divisor support set, find
//! the distribution `K = softmax(v)` minimizing
//!
//! ```text
//! Loss = l1 * (K . p_u - p)^2  +  l2 * (1/N) sum_i K_i ln K_i
//! ```
//!
//! where `p_u[i] = (dp_i - 1) / dp_i` is the global dropout rate of pattern
//! `dp_i`. The first term pins the expected rate to the target (Eq. 3);
//! the second term is negative entropy — minimizing it *maximizes*
//! sub-model diversity. Gradients are analytic (the softmax Jacobian is
//! closed-form), so no autodiff machinery is needed and the search runs in
//! microseconds at init time, matching the paper's "one-time effort".

use crate::patterns::PatternDistribution;

#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Rate-matching weight (paper lambda_1).
    pub lambda1: f64,
    /// Negative-entropy weight (paper lambda_2); lambda1 + lambda2 = 1.
    pub lambda2: f64,
    pub lr: f64,
    pub max_iters: usize,
    /// Stop when |delta loss| < threshold (paper's loop condition).
    pub threshold: f64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            // The entropy term trades rate accuracy for sub-model
            // diversity; 99:1 keeps |achieved - target| < 5e-3 while still
            // spreading mass across every feasible divisor (see tests).
            lambda1: 0.99,
            lambda2: 0.01,
            lr: 0.5,
            max_iters: 50_000,
            threshold: 1e-12,
        }
    }
}

#[derive(Clone, Debug)]
pub struct SearchResult {
    pub distribution: PatternDistribution,
    pub loss: f64,
    pub iters: usize,
    pub achieved_rate: f64,
}

fn softmax(v: &[f64]) -> Vec<f64> {
    let mx = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = v.iter().map(|x| (x - mx).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.iter().map(|e| e / sum).collect()
}

fn loss_and_grad_v(v: &[f64], p_u: &[f64], p: f64, cfg: &SearchConfig)
                   -> (f64, Vec<f64>) {
    let n = v.len();
    let d = softmax(v);
    let ep_diff: f64 = d.iter().zip(p_u).map(|(di, pi)| di * pi).sum::<f64>()
        - p;
    let e_p = ep_diff * ep_diff;
    let e_n: f64 = d.iter()
        .map(|&di| if di > 0.0 { di * di.ln() } else { 0.0 })
        .sum::<f64>()
        / n as f64;
    let loss = cfg.lambda1 * e_p + cfg.lambda2 * e_n;

    // dLoss/dd_i
    let g_d: Vec<f64> = (0..n)
        .map(|i| {
            cfg.lambda1 * 2.0 * ep_diff * p_u[i]
                + cfg.lambda2 / n as f64 * (d[i].ln() + 1.0)
        })
        .collect();
    // Chain through softmax: dLoss/dv_j = d_j * (g_j - sum_i g_i d_i)
    let dot: f64 = g_d.iter().zip(&d).map(|(g, di)| g * di).sum();
    let g_v: Vec<f64> = (0..n).map(|j| d[j] * (g_d[j] - dot)).collect();
    (loss, g_v)
}

/// Run Algorithm 1 over an explicit divisor support set.
pub fn search(target_rate: f64, support: &[usize], cfg: &SearchConfig)
              -> SearchResult {
    assert!(!support.is_empty());
    assert!((0.0..1.0).contains(&target_rate),
            "target rate {target_rate} out of [0,1)");
    let p_u: Vec<f64> = support
        .iter()
        .map(|&dp| (dp as f64 - 1.0) / dp as f64)
        .collect();
    let max_rate = p_u.iter().cloned().fold(0.0f64, f64::max);
    assert!(target_rate <= max_rate + 1e-9,
            "target rate {target_rate} unreachable with support {support:?} \
             (max {max_rate})");

    // Deterministic init (line 1: "arbitrary"); zeros = uniform softmax.
    let mut v = vec![0.0f64; support.len()];
    let mut prev_loss = f64::INFINITY;
    let mut iters = 0;
    for it in 0..cfg.max_iters {
        let (loss, grad) = loss_and_grad_v(&v, &p_u, target_rate, cfg);
        for (vj, gj) in v.iter_mut().zip(&grad) {
            *vj -= cfg.lr * gj;
        }
        iters = it + 1;
        if (loss - prev_loss).abs() < cfg.threshold {
            prev_loss = loss;
            break;
        }
        prev_loss = loss;
    }
    let d = softmax(&v);
    let dist = PatternDistribution::new(support.to_vec(), d);
    let achieved = dist.expected_rate();
    SearchResult { distribution: dist, loss: prev_loss, iters,
                   achieved_rate: achieved }
}

/// Paper-exact variant: support = {1..N} with p_u = [0, 1/2, 2/3, ...].
pub fn search_paper(target_rate: f64, n: usize, cfg: &SearchConfig)
                    -> SearchResult {
    let support: Vec<usize> = (1..=n).collect();
    search(target_rate, &support, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    #[test]
    fn softmax_is_simplex() {
        let d = softmax(&[0.0, 1.0, -2.0, 5.0]);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let cfg = SearchConfig::default();
        let p_u = [0.0, 0.5, 0.75, 0.875];
        let v = [0.3, -0.2, 0.7, 0.1];
        let (_, g) = loss_and_grad_v(&v, &p_u, 0.6, &cfg);
        let eps = 1e-6;
        for j in 0..v.len() {
            let mut vp = v;
            vp[j] += eps;
            let mut vm = v;
            vm[j] -= eps;
            let (lp, _) = loss_and_grad_v(&vp, &p_u, 0.6, &cfg);
            let (lm, _) = loss_and_grad_v(&vm, &p_u, 0.6, &cfg);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - g[j]).abs() < 1e-6,
                    "grad[{j}]: analytic {} vs fd {fd}", g[j]);
        }
    }

    #[test]
    fn hits_target_rates() {
        // The paper's experimental rates on our artifact support set.
        let cfg = SearchConfig::default();
        for &p in &[0.3, 0.4, 0.5, 0.6, 0.7] {
            let r = search(p, &[1, 2, 4, 8], &cfg);
            assert!((r.achieved_rate - p).abs() < 5e-3,
                    "target {p}: achieved {}", r.achieved_rate);
            // Entropy should not collapse to a (near-)point mass.
            assert!(r.distribution.entropy() > 0.5,
                    "target {p}: entropy {}", r.distribution.entropy());
        }
    }

    #[test]
    fn paper_support_1_to_n() {
        let cfg = SearchConfig::default();
        let r = search_paper(0.5, 10, &cfg);
        assert!((r.achieved_rate - 0.5).abs() < 5e-3);
        assert_eq!(r.distribution.support, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn entropy_term_spreads_mass() {
        // With lambda2 = 0 there are many exact solutions; the entropy term
        // must pick a dense one. Compare a run with strong entropy weight
        // against a pure point-mass-feasible target.
        let mut cfg = SearchConfig::default();
        cfg.lambda1 = 0.9;
        cfg.lambda2 = 0.1;
        let r = search(0.5, &[1, 2, 4, 8], &cfg);
        // 0.5 is exactly p_u of dp=2; without entropy the solver could put
        // all mass there. Entropy must keep >= 3 patterns above 1%.
        let live = r.distribution.probs.iter().filter(|&&p| p > 0.01).count();
        assert!(live >= 3, "probs {:?}", r.distribution.probs);
    }

    #[test]
    fn zero_rate_feasible() {
        let cfg = SearchConfig::default();
        let r = search(0.0, &[1, 2, 4, 8], &cfg);
        // Must put almost all mass on dp=1; rate term dominates entropy.
        assert!(r.achieved_rate < 0.02, "rate {}", r.achieved_rate);
    }

    #[test]
    #[should_panic]
    fn unreachable_rate_rejected() {
        search(0.95, &[1, 2], &SearchConfig::default());
    }

    #[test]
    fn converges_quickly_and_deterministically() {
        let cfg = SearchConfig::default();
        let a = search(0.7, &[1, 2, 4, 8], &cfg);
        let b = search(0.7, &[1, 2, 4, 8], &cfg);
        assert_eq!(a.distribution.probs, b.distribution.probs);
        assert!(a.iters <= cfg.max_iters);
    }

    #[test]
    fn random_targets_property() {
        testkit::quickcheck("search hits random targets", |rng| {
            let p = rng.uniform(0.05, 0.85);
            let r = search(p, &[1, 2, 4, 8, 16], &SearchConfig::default());
            assert!((r.achieved_rate - p).abs() < 1e-2,
                    "target {p} achieved {}", r.achieved_rate);
        });
    }
}
