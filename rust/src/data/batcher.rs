//! Batch iterators: shuffled epochs for image classification, contiguous
//! BPTT windows for language modeling (the standard PTB protocol).
//!
//! Both batchers fill caller-owned buffers (`*_into`): the coordinator's
//! step assembly owns its tail tensors (the pipelined path ships them
//! across a thread), and reusing the caller's Vec capacity keeps the
//! steady state down to the one unavoidable copy out of the dataset.

use crate::data::mnist::{MnistSyn, IMG_PIXELS};
use crate::util::rng::Rng;

/// Shuffled mini-batch iterator over an image dataset.
#[derive(Debug)]
pub struct MnistBatcher {
    order: Vec<usize>,
    cursor: usize,
    pub batch: usize,
    pub epoch: usize,
}

impl MnistBatcher {
    pub fn new(n: usize, batch: usize) -> Self {
        assert!(batch <= n);
        MnistBatcher {
            order: (0..n).collect(),
            cursor: usize::MAX, // force shuffle on first call
            batch,
            epoch: 0,
        }
    }

    /// Fill the next batch from `data` into `x` ([batch * 784]) and `y`
    /// ([batch]); buffers are cleared first and their capacity is reused
    /// across calls. Reshuffles at epoch boundaries (drops the ragged
    /// tail batch, as Caffe does).
    pub fn next_batch_into(&mut self, data: &MnistSyn, rng: &mut Rng,
                           x: &mut Vec<f32>, y: &mut Vec<i32>) {
        if self.cursor == usize::MAX
            || self.cursor + self.batch > self.order.len()
        {
            rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        x.clear();
        y.clear();
        x.reserve(self.batch * IMG_PIXELS);
        y.reserve(self.batch);
        for &i in &self.order[self.cursor..self.cursor + self.batch] {
            x.extend_from_slice(data.image(i));
            y.push(data.labels[i] as i32);
        }
        self.cursor += self.batch;
    }
}

/// Contiguous BPTT batcher: the token stream is laid out as `batch`
/// parallel contiguous tracks; each call yields the next `seq`-token
/// window with targets shifted by one. x/y layout: [batch, seq] row-major.
#[derive(Debug)]
pub struct BpttBatcher {
    tracks: Vec<i32>, // batch x track_len, row-major
    track_len: usize,
    pub batch: usize,
    pub seq: usize,
    pos: usize,
    pub epoch: usize,
}

impl BpttBatcher {
    pub fn new(tokens: &[i32], batch: usize, seq: usize) -> Self {
        let track_len = tokens.len() / batch;
        assert!(track_len > seq, "corpus too small for batch x seq");
        let mut tracks = vec![0i32; batch * track_len];
        for b in 0..batch {
            tracks[b * track_len..(b + 1) * track_len]
                .copy_from_slice(&tokens[b * track_len..(b + 1) * track_len]);
        }
        BpttBatcher { tracks, track_len, batch, seq, pos: 0, epoch: 0 }
    }

    /// Number of windows per epoch.
    pub fn windows_per_epoch(&self) -> usize {
        (self.track_len - 1) / self.seq
    }

    /// Fill the next BPTT window into caller-owned buffers (cleared
    /// first; capacity is reused across calls).
    pub fn next_window_into(&mut self, x: &mut Vec<i32>, y: &mut Vec<i32>) {
        if self.pos + self.seq + 1 > self.track_len {
            self.pos = 0;
            self.epoch += 1;
        }
        x.clear();
        y.clear();
        x.reserve(self.batch * self.seq);
        y.reserve(self.batch * self.seq);
        for b in 0..self.batch {
            let base = b * self.track_len + self.pos;
            x.extend_from_slice(&self.tracks[base..base + self.seq]);
            y.extend_from_slice(&self.tracks[base + 1..base + self.seq + 1]);
        }
        self.pos += self.seq;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist::MnistSyn;

    fn mnist_next(b: &mut MnistBatcher, data: &MnistSyn, rng: &mut Rng)
                  -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        b.next_batch_into(data, rng, &mut x, &mut y);
        (x, y)
    }

    fn bptt_next(b: &mut BpttBatcher) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        b.next_window_into(&mut x, &mut y);
        (x, y)
    }

    #[test]
    fn mnist_batches_cover_epoch_without_repeats() {
        let data = MnistSyn::generate(64, 1);
        let mut b = MnistBatcher::new(64, 16);
        let mut rng = Rng::new(2);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let (_, y) = mnist_next(&mut b, &data, &mut rng);
            assert_eq!(y.len(), 16);
            // Track coverage via the shuffled order indices instead of
            // labels (labels repeat); recover by comparing x rows.
            seen.extend(y.iter().cloned().map(|v| v as i64));
        }
        assert_eq!(b.epoch, 1);
        // After one epoch a new shuffle starts.
        mnist_next(&mut b, &data, &mut rng);
        assert_eq!(b.epoch, 2);
        assert!(!seen.is_empty());
    }

    #[test]
    fn mnist_batch_contents_match_dataset() {
        let data = MnistSyn::generate(32, 3);
        let mut b = MnistBatcher::new(32, 8);
        let mut rng = Rng::new(4);
        let (x, y) = mnist_next(&mut b, &data, &mut rng);
        // Every batch row must be an exact dataset image with its label.
        for bi in 0..8 {
            let row = &x[bi * IMG_PIXELS..(bi + 1) * IMG_PIXELS];
            let found = (0..data.n).any(|i| {
                data.image(i) == row && data.labels[i] as i32 == y[bi]
            });
            assert!(found, "batch row {bi} not found in dataset");
        }
    }

    #[test]
    fn mnist_buffer_capacity_is_reused() {
        let data = MnistSyn::generate(32, 5);
        let mut b = MnistBatcher::new(32, 8);
        let mut rng = Rng::new(6);
        let mut x = Vec::new();
        let mut y = Vec::new();
        b.next_batch_into(&data, &mut rng, &mut x, &mut y);
        let (cx, cy) = (x.capacity(), y.capacity());
        let px = x.as_ptr();
        b.next_batch_into(&data, &mut rng, &mut x, &mut y);
        assert_eq!(x.len(), 8 * IMG_PIXELS);
        assert_eq!((x.capacity(), y.capacity()), (cx, cy));
        assert_eq!(x.as_ptr(), px, "no reallocation in steady state");
    }

    #[test]
    fn bptt_windows_are_contiguous_and_shifted() {
        let tokens: Vec<i32> = (0..103).collect();
        let mut b = BpttBatcher::new(&tokens, 2, 5);
        let (x, y) = bptt_next(&mut b);
        // Track 0 starts at 0, track 1 at track_len = 51.
        assert_eq!(&x[..5], &[0, 1, 2, 3, 4]);
        assert_eq!(&y[..5], &[1, 2, 3, 4, 5]);
        assert_eq!(&x[5..10], &[51, 52, 53, 54, 55]);
        let (x2, _) = bptt_next(&mut b);
        assert_eq!(&x2[..5], &[5, 6, 7, 8, 9]);
    }

    #[test]
    fn bptt_epoch_wraps() {
        let tokens: Vec<i32> = (0..40).collect();
        let mut b = BpttBatcher::new(&tokens, 2, 6);
        let per_epoch = b.windows_per_epoch();
        assert_eq!(per_epoch, (20 - 1) / 6);
        for _ in 0..per_epoch {
            bptt_next(&mut b);
        }
        assert_eq!(b.epoch, 0);
        bptt_next(&mut b);
        assert_eq!(b.epoch, 1);
    }
}
