//! Deterministic PRNG stack: SplitMix64 for seeding, Xoshiro256++ as the
//! workhorse generator (the `rand` crate is unavailable offline).
//!
//! Everything downstream (data synthesis, pattern sampling, mask
//! generation, weight init) takes an explicit `Rng`, so whole experiments
//! are reproducible from a single u64 seed.

/// SplitMix64: used to expand a u64 seed into Xoshiro state; also a fine
/// standalone generator for non-critical uses.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (for parallel/substream use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Raw 256-bit generator state — the checkpoint "RNG cursor". Restoring
    /// via [`Rng::from_state`] resumes the stream exactly where it left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot. The all-zero
    /// state is the Xoshiro256++ fixed point (a dead generator), so it is
    /// rejected here rather than surfacing as a silently-constant stream.
    pub fn from_state(s: [u64; 4]) -> Option<Rng> {
        if s == [0, 0, 0, 0] {
            return None;
        }
        Some(Rng { s })
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Bernoulli(p) -> bool.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (one value; the pair's twin is
    /// discarded — simplicity over throughput; weight init is one-time).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Owned-buffer variant of [`Rng::fill_mask`]: allocate exactly `len`
    /// entries and fill them without an intermediate zero-fill pass. MUST
    /// consume the RNG stream identically to `fill_mask` (one `next_u64`
    /// per entry) — the coordinator's seed-parity guarantees depend on it.
    pub fn mask_vec(&mut self, keep: f64, len: usize) -> Vec<f32> {
        let thresh = (keep * (1u64 << 24) as f64) as u64;
        (0..len)
            .map(|_| {
                if (self.next_u64() >> 40) < thresh { 1.0 } else { 0.0 }
            })
            .collect()
    }

    /// Fill a 0/1 f32 Bernoulli(keep) mask. This is the conventional-dropout
    /// hot path (one mask per layer per iteration, like Caffe's cuRAND
    /// fill); it consumes one u64 per 64 mask entries.
    pub fn fill_mask(&mut self, keep: f64, out: &mut [f32]) {
        // Fast path for keep expressible per-bit comparison: draw 24-bit
        // uniforms in blocks. Straightforward loop is already ~1 GB/s which
        // is plenty; keep it simple and exact.
        let thresh = (keep * (1u64 << 24) as f64) as u64;
        for v in out.iter_mut() {
            let bits = self.next_u64() >> 40;
            *v = if bits < thresh { 1.0 } else { 0.0 };
        }
    }

    /// Sample an index from a discrete distribution (probabilities summing
    /// to ~1). Linear scan — distributions here have <= ~16 support points.
    pub fn sample_discrete(&mut self, probs: &[f64]) -> usize {
        let u = self.next_f64();
        let mut acc = 0.0;
        for (i, p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return i;
            }
        }
        probs.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(123);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap).unwrap();
        let resumed: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
        assert!(Rng::from_state([0; 4]).is_none(), "dead state rejected");
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn mask_vec_matches_fill_mask_stream() {
        // The owned-buffer variant must be draw-for-draw identical to
        // fill_mask — trainer seed parity depends on it.
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        let mut filled = vec![0.0f32; 999];
        a.fill_mask(0.3, &mut filled);
        let owned = b.mask_vec(0.3, 999);
        assert_eq!(filled, owned);
        // Both generators end in the same state.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.next_usize(5)] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 5.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(),
                    "count {c} vs {expect}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(11);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn mask_fill_rate_and_values() {
        let mut r = Rng::new(17);
        let mut buf = vec![0f32; 100_000];
        r.fill_mask(0.7, &mut buf);
        assert!(buf.iter().all(|&v| v == 0.0 || v == 1.0));
        let keep = buf.iter().filter(|&&v| v == 1.0).count() as f64
            / buf.len() as f64;
        assert!((keep - 0.7).abs() < 0.01, "keep {keep}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_discrete_matches_probs() {
        let mut r = Rng::new(23);
        let probs = [0.1, 0.2, 0.3, 0.4];
        let mut counts = [0usize; 4];
        let n = 200_000;
        for _ in 0..n {
            counts[r.sample_discrete(&probs)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((c as f64 / n as f64 - probs[i]).abs() < 0.01);
        }
    }
}
