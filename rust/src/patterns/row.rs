//! Row-based Dropout Pattern (paper section III-A).
//!
//! For a layer of `m` neurons and divisor `dp`, bias `b0 in [0, dp)`:
//! kept neuron indices are `{b0 + dp*j : j in [0, m/dp)}` — exactly
//! `m / dp` neurons (floor), so the kept count (and hence the AOT graph
//! shape) is identical for every bias. Dropping a neuron == dropping the
//! corresponding row of the next layer's weight matrix (Fig. 3a).

use crate::patterns::Choice;

#[derive(Clone, Copy, Debug)]
pub struct RowPattern {
    /// Layer width (number of neurons at this dropout site).
    pub m: usize,
    pub choice: Choice,
}

impl RowPattern {
    pub fn new(m: usize, dp: usize, b0: usize) -> Self {
        assert!(dp >= 1 && dp <= m, "dp={dp} out of range for m={m}");
        assert!(b0 < dp, "b0={b0} must be < dp={dp}");
        RowPattern { m, choice: Choice { dp, b0 } }
    }

    /// Number of kept neurons — static per dp, independent of bias.
    pub fn kept_count(&self) -> usize {
        self.m / self.choice.dp
    }

    pub fn kept_indices(&self) -> Vec<usize> {
        let Choice { dp, b0 } = self.choice;
        (0..self.kept_count()).map(|j| b0 + dp * j).collect()
    }

    /// True iff neuron `i` is kept under this pattern.
    pub fn keeps(&self, i: usize) -> bool {
        let Choice { dp, b0 } = self.choice;
        i < self.kept_count() * dp && i % dp == b0
    }

    /// Fraction of neurons dropped ("global dropout rate" of this pattern).
    pub fn global_rate(&self) -> f64 {
        1.0 - self.kept_count() as f64 / self.m as f64
    }

    /// Inverted-dropout scale = 1 / keep-ratio (mirrors model.row_scale).
    pub fn scale(&self) -> f32 {
        self.m as f32 / self.kept_count() as f32
    }

    /// Dense 0/1 keep mask (testing / host-side reconstructions).
    pub fn mask(&self) -> Vec<f32> {
        (0..self.m).map(|i| if self.keeps(i) { 1.0 } else { 0.0 }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{self, gen_choice, gen_range};

    #[test]
    fn example_from_paper() {
        // dp=3, b=1 (1-based) == b0=0: keep rows 0,3,6,... drop 2 of 3.
        let p = RowPattern::new(9, 3, 0);
        assert_eq!(p.kept_indices(), vec![0, 3, 6]);
        assert!((p.global_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn dp1_keeps_everything() {
        let p = RowPattern::new(64, 1, 0);
        assert_eq!(p.kept_count(), 64);
        assert_eq!(p.global_rate(), 0.0);
        assert_eq!(p.scale(), 1.0);
        assert!(p.mask().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn kept_count_static_across_bias() {
        for dp in [2, 3, 4, 8] {
            let counts: Vec<usize> = (0..dp)
                .map(|b0| RowPattern::new(2048, dp, b0).kept_count())
                .collect();
            assert!(counts.windows(2).all(|w| w[0] == w[1]), "dp={dp}");
        }
    }

    #[test]
    fn biases_partition_neurons() {
        // Every neuron in [0, dp * (m/dp)) is kept by exactly one bias —
        // the uniformity premise of the paper's Eq. 2.
        testkit::quickcheck("row partition", |rng| {
            let m = gen_range(rng, 8, 300);
            let dp = *gen_choice(rng, &[1usize, 2, 3, 4, 5, 8]);
            if dp > m {
                return;
            }
            let covered = m / dp * dp;
            let mut count = vec![0usize; m];
            for b0 in 0..dp {
                for i in RowPattern::new(m, dp, b0).kept_indices() {
                    count[i] += 1;
                }
            }
            for (i, &c) in count.iter().enumerate() {
                let expect = if i < covered { 1 } else { 0 };
                assert_eq!(c, expect, "neuron {i} kept {c}x (m={m} dp={dp})");
            }
        });
    }

    #[test]
    fn indices_strictly_increasing_with_stride_dp() {
        testkit::quickcheck("row stride", |rng| {
            let m = gen_range(rng, 16, 4096);
            let dp = *gen_choice(rng, &[2usize, 3, 4, 8]);
            let b0 = gen_range(rng, 0, dp);
            let idx = RowPattern::new(m, dp, b0).kept_indices();
            assert_eq!(idx.len(), m / dp);
            assert!(idx.iter().all(|&i| i < m));
            assert!(idx.windows(2).all(|w| w[1] - w[0] == dp));
            assert_eq!(idx[0], b0);
        });
    }

    #[test]
    fn global_rate_close_to_nominal() {
        // When dp | m the rate is exactly (dp-1)/dp; otherwise within 1/m.
        let p = RowPattern::new(2048, 4, 1);
        assert!((p.global_rate() - 0.75).abs() < 1e-12);
        let q = RowPattern::new(100, 3, 2);
        assert!((q.global_rate() - 2.0 / 3.0).abs() < 1.0 / 100.0 + 1e-12);
    }

    #[test]
    fn mask_agrees_with_indices() {
        let p = RowPattern::new(37, 5, 3);
        let mask = p.mask();
        for (i, &v) in mask.iter().enumerate() {
            assert_eq!(v == 1.0, p.kept_indices().contains(&i));
        }
    }
}
