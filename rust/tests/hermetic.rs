//! Hermetic end-to-end tests: the full sample→dispatch→step→metrics loop
//! on the pure-Rust reference backend. No artifacts directory, no Python,
//! no PJRT — this suite must ALWAYS run (never skip) and is the CI
//! default test path.
//!
//! What is pinned here:
//! * short MLP and LSTM training runs actually learn (loss decreases)
//!   under all three dropout variants,
//! * the artifact-name dispatch sequence is seed-deterministic, covers
//!   exactly the schedule's dp combos, and empirically follows the
//!   searched distribution K,
//! * the host interpreters (reference AND sparse) reproduce the semantic
//!   invariants the PJRT integration suite asserts (dropped RDP
//!   rows/TDP tiles frozen, eval graph == host forward),
//! * the structured-sparse backend matches the reference backend to
//!   <= 1e-5 relative on one full train step for all six
//!   (model x variant) cases, dispatches identical artifact-name
//!   sequences, and tracks the reference loss trajectory step-for-step,
//! * (with `--features pjrt` and generated artifacts) reference and PJRT
//!   produce the identical dispatch sequence for the same seed.

mod common;

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::{ArchMeta, ArtifactMeta, Dtype, Executor,
                              HostTensor, Kind, Manifest, TrainState,
                              Value};
use approx_dropout::util::rng::Rng;

use common::host_mlp_eval;

fn reference_cache() -> ExecutorCache {
    ExecutorCache::reference(Manifest::builtin_test())
}

fn sparse_cache() -> ExecutorCache {
    ExecutorCache::sparse(Manifest::builtin_test())
}

/// Both hermetic host backends; cross-backend tests iterate these.
fn host_caches() -> [ExecutorCache; 2] {
    [reference_cache(), sparse_cache()]
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Short real training on the 784-dim synthetic-MNIST arch for every
/// dropout variant: the loss trend must be downward and evaluation must
/// produce sane numbers — all with zero artifacts on disk.
#[test]
fn mlp_training_learns_all_variants() {
    let cache = reference_cache();
    let (train, test) = MnistSyn::train_test(512, 64, 42);
    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        let schedule =
            Schedule::new(variant, &[0.5, 0.5], &[1, 2], false).unwrap();
        // lr note: RDP's shared per-batch pattern raises gradient
        // variance (see bench/drivers.rs); 0.01 is stable for all
        // variants at rate 0.5.
        let mut tr = MlpTrainer::new(&cache, "mlpsyn", schedule, train.n,
                                     0.01, 7)
            .unwrap();
        tr.warmup().unwrap();
        let steps = 80;
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (loss, acc) = tr.step(&train).unwrap();
            assert!(loss.is_finite(), "{variant:?}: loss not finite");
            assert!((0.0..=1.0).contains(&acc));
            losses.push(loss);
        }
        let first = mean(&losses[..10]);
        let last = mean(&losses[steps - 10..]);
        assert!(last < first,
                "{variant:?}: no learning ({first:.3} -> {last:.3})");
        let (eval_loss, eval_acc) = tr.evaluate(&test).unwrap();
        assert!(eval_loss.is_finite() && eval_loss > 0.0);
        assert!((0.0..=1.0).contains(&eval_acc));
    }
}

/// Same for the LSTM LM on the synthetic corpus; also checks perplexity
/// comes out of the eval graph sanely.
#[test]
fn lstm_training_learns_all_variants() {
    let cache = reference_cache();
    let corpus = Corpus::generate(64, 8000, 800, 800, 9);
    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        let shared = variant != Variant::Conv;
        let schedule =
            Schedule::new(variant, &[0.5, 0.5], &[1, 2], shared).unwrap();
        // lr note: with momentum 0.9 the stable setting is ~0.1 (see
        // bench/drivers.rs trace_lstm_curve).
        let mut tr = LstmTrainer::new(&cache, "lstmsyn", schedule,
                                      &corpus.train, 0.1, 13)
            .unwrap();
        tr.warmup().unwrap();
        let steps = 60;
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (loss, _) = tr.step().unwrap();
            assert!(loss.is_finite(), "{variant:?}: loss not finite");
            losses.push(loss);
        }
        let first = mean(&losses[..10]);
        let last = mean(&losses[steps - 10..]);
        assert!(last < first,
                "{variant:?}: no learning ({first:.3} -> {last:.3})");
        // ppl bound: uniform over the 64-token vocab is 64; a briefly
        // trained model sits below it, but leave slack for eval noise.
        let (xent, ppl, acc) = tr.evaluate(&corpus.valid).unwrap();
        assert!(xent.is_finite() && ppl > 1.0 && ppl < 90.0);
        assert!((0.0..=1.0).contains(&acc));
    }
}

/// The dispatch sequence — the observable that encodes the paper's
/// pattern->static-shape mapping — is deterministic for a fixed seed,
/// stays inside the schedule's dp combos, and empirically mixes the
/// divisors per the searched distribution K.
#[test]
fn dispatch_sequence_matches_seeded_schedule() {
    let cache = reference_cache();
    let corpus = Corpus::generate(64, 8000, 800, 800, 3);
    let steps = 40;
    let run = |seed: u64| -> (Vec<String>, Vec<String>) {
        // Target rate 0.25 over {1, 2} puts roughly half the mass on
        // each divisor, so both artifact names must appear.
        let schedule =
            Schedule::new(Variant::Rdp, &[0.25, 0.25], &[1, 2], true)
                .unwrap();
        let mut tr = LstmTrainer::new(&cache, "lstmsyn", schedule,
                                      &corpus.train, 0.1, seed)
            .unwrap();
        let names = tr.executable_names();
        for _ in 0..steps {
            tr.step().unwrap();
        }
        (tr.metrics.dispatched.clone(), names)
    };
    let (a, names) = run(77);
    assert_eq!(a.len(), steps);
    // Every dispatched artifact is one the schedule can sample.
    for n in &a {
        assert!(names.contains(n), "dispatched {n} not in {names:?}");
    }
    // Both divisors actually occur, with a plausible K-mix (K(2) ~ 0.5;
    // [0.2, 0.8] is a ±3.8 sigma band at 40 samples).
    let dp2 = a.iter().filter(|n| n.ends_with("_2")).count() as f64
        / steps as f64;
    assert!((0.2..=0.8).contains(&dp2), "dp=2 fraction {dp2}");
    // Seed-determinism, and seeds actually matter.
    let (b, _) = run(77);
    assert_eq!(a, b, "same seed must dispatch identically");
    let (c, _) = run(78);
    assert_ne!(a, c, "different seed must explore differently");
}

/// The reference eval executor must agree with the independent host
/// reimplementation (`tests/common`) to float tolerance — the same
/// cross-check the PJRT integration suite runs against the AOT eval
/// graph.
#[test]
fn reference_eval_matches_host_forward() {
    let cache = reference_cache();
    let exe = cache.get("mlptest_eval").unwrap();
    let backend = cache.backend().clone();
    let mut rng = Rng::new(7);
    let meta = cache.manifest().get("mlptest_conv").unwrap();
    let state = TrainState::init(meta, &mut rng, backend.as_ref()).unwrap();

    let batch = 8;
    let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.next_usize(10) as i32).collect();
    let x_v = backend
        .upload(&HostTensor::f32(&[batch, 32], x.clone()))
        .unwrap();
    let y_v = backend
        .upload(&HostTensor::i32(&[batch], y.clone()))
        .unwrap();
    let mut refs = state.param_refs();
    refs.push(&x_v);
    refs.push(&y_v);
    let out = exe.run_raw(&refs).unwrap();
    let loss_ref = out[0].scalar_f64().unwrap();
    let correct_ref = out[1].scalar_f64().unwrap();

    let host_params: Vec<Vec<f32>> =
        (0..6).map(|i| state.param_f32(i).unwrap()).collect();
    let (loss_host, correct_host) = host_mlp_eval(&host_params, &x, &y,
                                                  batch);
    assert!((loss_ref - loss_host).abs() < 1e-4,
            "reference {loss_ref} vs host {loss_host}");
    assert_eq!(correct_ref, correct_host);
}

fn rdp_step(cache: &ExecutorCache, state: &mut TrainState,
            exe: &dyn Executor, rng: &mut Rng, b0: (i32, i32), lr: f32)
            -> (f64, f64) {
    let backend = cache.backend();
    let batch = 8;
    let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.next_usize(10) as i32).collect();
    let tail: Vec<Value> = vec![
        backend.upload(&HostTensor::f32(&[batch, 32], x)).unwrap(),
        backend.upload(&HostTensor::i32(&[batch], y)).unwrap(),
        backend.upload(&HostTensor::scalar_i32(b0.0)).unwrap(),
        backend.upload(&HostTensor::scalar_i32(b0.1)).unwrap(),
        backend.upload(&HostTensor::scalar_f32(2.0)).unwrap(),
        backend.upload(&HostTensor::scalar_f32(2.0)).unwrap(),
        backend.upload(&HostTensor::scalar_f32(lr)).unwrap(),
    ];
    state.step(exe, &tail).unwrap()
}

/// The interpreters (reference AND sparse) must reproduce the pattern's
/// exact gradient-sparsity claim: dropped rows of w3 receive no update,
/// bit-for-bit.
#[test]
fn rdp_freezes_dropped_rows_in_w3_on_host_backends() {
    for cache in host_caches() {
        let backend_name = cache.backend().name();
        let exe = cache.get("mlptest_rdp_2_2").unwrap();
        let mut rng = Rng::new(33);
        let meta = cache.manifest().get("mlptest_rdp_2_2").unwrap();
        let mut state =
            TrainState::init(meta, &mut rng, cache.backend().as_ref())
                .unwrap();
        let w3_before = state.param_f32(4).unwrap();

        let b0_1 = 1; // site-2 pattern: keep rows {1, 3, 5, ...}
        let (loss, correct) =
            rdp_step(&cache, &mut state, exe.as_ref(), &mut rng, (0, b0_1),
                     0.1);
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=8.0).contains(&correct));
        let w3_after = state.param_f32(4).unwrap();

        let mut kept_changed = 0;
        for i in 0..64 {
            let row_changed = (0..10)
                .any(|j| w3_before[i * 10 + j] != w3_after[i * 10 + j]);
            if i % 2 == b0_1 as usize {
                kept_changed += usize::from(row_changed);
            } else {
                assert!(!row_changed,
                        "{backend_name}: dropped row {i} must be frozen");
            }
        }
        assert!(kept_changed >= 16,
                "{backend_name}: only {kept_changed}/32 kept rows updated");
    }
}

/// TDP on both host backends: dropped tiles of w1 must be frozen, per
/// the tile pattern's DropConnect semantics.
#[test]
fn tdp_freezes_dropped_tiles_in_w1_on_host_backends() {
    use approx_dropout::patterns::TilePattern;
    for cache in host_caches() {
        let backend_name = cache.backend().name();
        let exe = cache.get("mlptest_tdp_2_2").unwrap();
        let mut rng = Rng::new(5);
        let meta = cache.manifest().get("mlptest_tdp_2_2").unwrap();
        assert_eq!(meta.tile, 16,
                   "tiny arch tile must survive the manifest");
        let mut state =
            TrainState::init(meta, &mut rng, cache.backend().as_ref())
                .unwrap();
        let w1_before = state.param_f32(0).unwrap();
        let b0_0 = 1;
        let (loss, _) = rdp_step(&cache, &mut state, exe.as_ref(),
                                 &mut rng, (b0_0, 0), 0.1);
        assert!(loss.is_finite());
        let w1_after = state.param_f32(0).unwrap();
        // w1 is [32, 64], tile 16 -> 2x4 grid; kept iff
        // (c - b0 - r) % 2 == 0.
        let pat = TilePattern::new(32, 64, 2, b0_0 as usize, 16);
        for r in 0..2 {
            for c in 0..4 {
                let changed = (0..16).any(|i| (0..16).any(|j| {
                    let idx = (r * 16 + i) * 64 + (c * 16 + j);
                    w1_before[idx] != w1_after[idx]
                }));
                if pat.keeps_tile(r, c) {
                    assert!(changed,
                            "{backend_name}: kept tile ({r},{c}) must \
                             update");
                } else {
                    assert!(!changed,
                            "{backend_name}: dropped tile ({r},{c}) must \
                             be frozen");
                }
            }
        }
    }
}

/// Cross-backend acceptance: for the same seed, the reference backend
/// (built-in manifest) and PJRT (generated artifacts) dispatch the
/// identical artifact-name sequence, and early losses agree to float
/// tolerance. Runs on PJRT only when artifacts exist — with one loud
/// skip line otherwise; the reference half of the claim is covered
/// unconditionally by `dispatch_sequence_matches_seeded_schedule`.
#[cfg(feature = "pjrt")]
#[test]
fn dispatch_parity_reference_vs_pjrt() {
    let corpus = Corpus::generate(64, 4000, 400, 400, 17);
    let run = |cache: &ExecutorCache| -> (Vec<String>, Vec<f64>) {
        let schedule =
            Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
        let mut tr = LstmTrainer::new(cache, "lstmtest", schedule,
                                      &corpus.train, 0.5, 123)
            .unwrap();
        for _ in 0..6 {
            tr.step().unwrap();
        }
        (tr.metrics.dispatched.clone(),
         tr.metrics.curve.iter().map(|p| p.loss).collect())
    };
    let (ref_names, ref_losses) = run(&reference_cache());

    let dir = approx_dropout::artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP dispatch_parity_reference_vs_pjrt: no \
                       artifacts at {} ({e:#})", dir.display());
            return;
        }
    };
    let pjrt = match ExecutorCache::pjrt_cpu(manifest) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP dispatch_parity_reference_vs_pjrt: {e:#}");
            return;
        }
    };
    let (pjrt_names, pjrt_losses) = run(&pjrt);
    assert_eq!(ref_names, pjrt_names,
               "dispatch sequences must be backend-independent");
    for (i, (a, b)) in ref_losses.iter().zip(&pjrt_losses).enumerate() {
        assert!((a - b).abs() < 1e-2,
                "step {i}: reference loss {a} vs pjrt {b}");
    }
}

// ---------------------------------------------------------------------------
// Sparse-vs-reference parity (the sparse subsystem's acceptance tests)
// ---------------------------------------------------------------------------

/// Synthesize the post-(params ++ momenta) tail of a train step from the
/// manifest metas: x/y data, Bernoulli masks (conv), b0 bias scalars
/// (rdp/tdp), scales, lr. One host-side tensor list, ingested into each
/// backend, so both see bit-identical inputs.
fn synth_tail(meta: &ArtifactMeta, rng: &mut Rng) -> Vec<HostTensor> {
    let np = meta.n_params();
    let (label_hi, vocab) = match &meta.arch {
        ArchMeta::Mlp { n_out, .. } => (*n_out, 0),
        ArchMeta::Lstm { vocab, .. } => (*vocab, *vocab),
    };
    let mut site = 0usize;
    let mut tail = Vec::new();
    for t in &meta.inputs[2 * np..] {
        let ht = match t.kind {
            Kind::X => match t.dtype {
                Dtype::F32 => HostTensor::f32(
                    &t.shape,
                    (0..t.elements()).map(|_| rng.next_f32()).collect()),
                Dtype::I32 => HostTensor::i32(
                    &t.shape,
                    (0..t.elements())
                        .map(|_| rng.next_usize(vocab) as i32)
                        .collect()),
            },
            Kind::Y => HostTensor::i32(
                &t.shape,
                (0..t.elements())
                    .map(|_| rng.next_usize(label_hi) as i32)
                    .collect()),
            Kind::Mask => HostTensor::f32(&t.shape,
                                          rng.mask_vec(0.5, t.elements())),
            Kind::Bias => {
                let dp = meta.dp[site];
                site += 1;
                // MLP b0 extras are scalars; LSTM b0 extras are [seq]
                // per-timestep tracks. Drawing every entry independently
                // deliberately produces mixed tracks, so this parity
                // suite also exercises the interpreter's window-run
                // grouping (both backends see the identical track).
                HostTensor::i32(
                    &t.shape,
                    (0..t.elements())
                        .map(|_| rng.next_usize(dp) as i32)
                        .collect())
            }
            Kind::Scale => HostTensor::scalar_f32(2.0),
            Kind::Lr => HostTensor::scalar_f32(0.05),
            other => panic!("unexpected tail tensor kind {other:?}"),
        };
        tail.push(ht);
    }
    tail
}

fn assert_close_rel(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!((x - y).abs() <= tol * scale,
                "{what}[{i}]: reference {x} vs sparse {y}");
    }
}

/// Satellite acceptance: `AD_BACKEND=sparse` vs `reference` agree to
/// <= 1e-5 relative on one full train step — updated params, updated
/// momenta, loss, and correct-count — for all six (model x variant)
/// cases on the syn archs.
#[test]
fn sparse_matches_reference_on_one_full_step_all_six_cases() {
    let rc = reference_cache();
    let sc = sparse_cache();
    for name in ["mlpsyn_conv", "mlpsyn_rdp_2_2", "mlpsyn_tdp_2_2",
                 "lstmsyn_conv", "lstmsyn_rdp_2", "lstmsyn_tdp_2"] {
        let meta = rc.manifest().get(name).unwrap().clone();
        let mut data_rng = Rng::new(0xC0FFEE);
        let tail = synth_tail(&meta, &mut data_rng);

        let run = |cache: &ExecutorCache| -> (Vec<Vec<f32>>, f64, f64) {
            let backend = cache.backend();
            let exe = cache.get(name).unwrap();
            // Same init seed -> bit-identical params on both backends
            // (draws happen on host buffers before upload).
            let mut rng = Rng::new(4242);
            let mut state =
                TrainState::init(&meta, &mut rng, backend.as_ref())
                    .unwrap();
            let vals: Vec<Value> = tail
                .iter()
                .map(|t| backend.ingest(t.clone()).unwrap())
                .collect();
            let (loss, correct) =
                state.step(exe.as_ref(), &vals).unwrap();
            let mut tensors = Vec::new();
            for i in 0..state.params.len() {
                tensors.push(state.param_f32(i).unwrap());
            }
            for m in &state.momenta {
                tensors.push(m.to_f32().unwrap());
            }
            (tensors, loss, correct)
        };

        let (ref_t, ref_loss, ref_correct) = run(&rc);
        let (sp_t, sp_loss, sp_correct) = run(&sc);
        assert!((ref_loss - sp_loss).abs()
                    <= 1e-5 * ref_loss.abs().max(1.0),
                "{name}: loss {ref_loss} vs {sp_loss}");
        assert_eq!(ref_correct, sp_correct, "{name}: correct count");
        for (i, (a, b)) in ref_t.iter().zip(&sp_t).enumerate() {
            assert_close_rel(a, b, 1e-5, &format!("{name} tensor {i}"));
        }
    }
}

/// The sparse backend must be invisible to the coordinator: identical
/// artifact-name dispatch sequences for the same seed, and per-step
/// losses matching the reference trajectory, across every variant on
/// both models.
#[test]
fn sparse_dispatch_sequences_match_reference() {
    let rc = reference_cache();
    // Pinned to scalar microkernels: these two loops compare 10-step
    // loss *trajectories* at 1e-4 relative, and trajectory comparisons
    // compound per-step kernel rounding differences through the
    // parameters. The SIMD microkernels' FMA/reassociation noise is
    // within the single-step 1e-5 contract (covered by
    // `sparse_matches_reference_on_one_full_step_all_six_cases` and the
    // AD_SIMD CI matrix) but can drift a compounded trajectory past
    // 1e-4; the scalar kernels share the reference's summation order, so
    // this test stays about *structure* (skip handling, dispatch), not
    // about floating-point reassociation.
    let sc = ExecutorCache::sparse_scalar(Manifest::builtin_test());
    let (mnist, _) = MnistSyn::train_test(256, 64, 21);
    let corpus = Corpus::generate(64, 6000, 600, 600, 5);
    let steps = 10;

    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        // MLP.
        let run_mlp = |cache: &ExecutorCache| {
            let schedule =
                Schedule::new(variant, &[0.5, 0.5], &[1, 2], false)
                    .unwrap();
            let mut tr = MlpTrainer::new(cache, "mlpsyn", schedule,
                                         mnist.n, 0.01, 31)
                .unwrap();
            for _ in 0..steps {
                tr.step(&mnist).unwrap();
            }
            (tr.metrics.dispatched.clone(),
             tr.metrics.curve.iter().map(|p| p.loss).collect::<Vec<_>>())
        };
        let (ref_names, ref_losses) = run_mlp(&rc);
        let (sp_names, sp_losses) = run_mlp(&sc);
        assert_eq!(ref_names, sp_names, "{variant:?}: mlp dispatch");
        for (i, (a, b)) in ref_losses.iter().zip(&sp_losses).enumerate() {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0),
                    "{variant:?}: mlp step {i} loss {a} vs {b}");
        }

        // LSTM.
        let shared = variant != Variant::Conv;
        let run_lstm = |cache: &ExecutorCache| {
            let schedule =
                Schedule::new(variant, &[0.5, 0.5], &[1, 2], shared)
                    .unwrap();
            let mut tr = LstmTrainer::new(cache, "lstmsyn", schedule,
                                          &corpus.train, 0.1, 17)
                .unwrap();
            for _ in 0..steps {
                tr.step().unwrap();
            }
            (tr.metrics.dispatched.clone(),
             tr.metrics.curve.iter().map(|p| p.loss).collect::<Vec<_>>())
        };
        let (ref_names, ref_losses) = run_lstm(&rc);
        let (sp_names, sp_losses) = run_lstm(&sc);
        assert_eq!(ref_names, sp_names, "{variant:?}: lstm dispatch");
        for (i, (a, b)) in ref_losses.iter().zip(&sp_losses).enumerate() {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0),
                    "{variant:?}: lstm step {i} loss {a} vs {b}");
        }
    }
}

/// Time-windowed dropout parity: with `AD_TIME_WINDOW`-style per-window
/// draws (passed explicitly — env mutation is racy under parallel test
/// threads), the structured-sparse backend must track the masked-dense
/// reference trajectory for every window the bench grid exercises:
/// W=1 (fresh pattern every timestep), W=4 (two windows per seq=8 step),
/// and W=16 (one pattern held across two steps). Both backends draw the
/// identical window schedule from the checkpointable RNG, so dispatch
/// sequences must also agree exactly.
#[test]
fn windowed_sparse_matches_reference_trajectories() {
    let rc = reference_cache();
    // Scalar kernels for the same trajectory-compounding reason as
    // `sparse_dispatch_sequences_match_reference` above; the windowed
    // packed-panel SIMD paths are pinned bit-exact against the unpacked
    // kernels in the sparse unit suite instead.
    let sc = ExecutorCache::sparse_scalar(Manifest::builtin_test());
    let corpus = Corpus::generate(64, 6000, 600, 600, 41);
    let steps = 8;
    for window in [Some(1usize), Some(4), Some(16)] {
        for variant in [Variant::Rdp, Variant::Tdp] {
            let run = |cache: &ExecutorCache| {
                let schedule =
                    Schedule::new(variant, &[0.5, 0.5], &[1, 2], true)
                        .unwrap();
                let mut tr = LstmTrainer::new_with_window(
                    cache, "lstmsyn", schedule, &corpus.train, 0.1, 53,
                    window)
                    .unwrap();
                for _ in 0..steps {
                    tr.step().unwrap();
                }
                (tr.metrics.dispatched.clone(),
                 tr.metrics.curve.iter().map(|p| p.loss)
                     .collect::<Vec<_>>())
            };
            let (ref_names, ref_losses) = run(&rc);
            let (sp_names, sp_losses) = run(&sc);
            assert_eq!(ref_names, sp_names,
                       "{variant:?} W={window:?}: dispatch");
            for (i, (a, b)) in
                ref_losses.iter().zip(&sp_losses).enumerate()
            {
                assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0),
                        "{variant:?} W={window:?} step {i}: \
                         loss {a} vs {b}");
            }
        }
    }
}

/// The default window (per-step, `W = seq`) must reproduce the
/// pre-windowing behavior bit for bit: same RNG draw count, same
/// dispatch, same losses. Pinned by running the explicit `Some(seq)`
/// override against the `None` default on the reference backend.
#[test]
fn default_window_is_bit_identical_to_per_step() {
    let cache = reference_cache();
    let corpus = Corpus::generate(64, 6000, 600, 600, 43);
    let steps = 6;
    let run = |window: Option<usize>| {
        let schedule =
            Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2], true)
                .unwrap();
        let mut tr = LstmTrainer::new_with_window(
            &cache, "lstmsyn", schedule, &corpus.train, 0.1, 59, window)
            .unwrap();
        for _ in 0..steps {
            tr.step().unwrap();
        }
        (tr.metrics.dispatched.clone(),
         tr.metrics.curve.iter().map(|p| p.loss).collect::<Vec<_>>())
    };
    // lstmsyn has seq=8; Some(8) and None must be the same policy.
    let (names_a, losses_a) = run(None);
    let (names_b, losses_b) = run(Some(8));
    assert_eq!(names_a, names_b);
    assert_eq!(losses_a, losses_b,
               "explicit W=seq must be bit-identical to the default");
}

/// `AD_SIMD=off` hermetic smoke: the scalar-microkernel sparse backend
/// (exactly what `AD_SIMD=off` selects, pinned here through
/// `ExecutorCache::sparse_scalar` so the test never touches process env)
/// trains end to end, learns, and tracks the reference trajectory —
/// whatever microkernel the rest of this process happens to run on.
#[test]
fn sparse_scalar_microkernels_train_and_match_reference() {
    let rc = reference_cache();
    let sc = ExecutorCache::sparse_scalar(Manifest::builtin_test());
    let (mnist, _) = MnistSyn::train_test(256, 64, 33);
    let steps = 12;
    let run = |cache: &ExecutorCache| {
        let schedule =
            Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2], false)
                .unwrap();
        let mut tr = MlpTrainer::new(cache, "mlpsyn", schedule, mnist.n,
                                     0.01, 11)
            .unwrap();
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (loss, _) = tr.step(&mnist).unwrap();
            assert!(loss.is_finite());
            losses.push(loss);
        }
        (tr.metrics.dispatched.clone(), losses)
    };
    let (ref_names, ref_losses) = run(&rc);
    let (sp_names, sp_losses) = run(&sc);
    assert_eq!(ref_names, sp_names, "scalar-kernel dispatch");
    for (i, (a, b)) in ref_losses.iter().zip(&sp_losses).enumerate() {
        assert!((a - b).abs() <= 1e-5 * a.abs().max(1.0),
                "step {i}: reference {a} vs scalar-sparse {b}");
    }
    assert!(mean(&sp_losses[steps / 2..]) < mean(&sp_losses[..steps / 2]),
            "scalar-kernel run did not learn: {sp_losses:?}");
}

/// Evaluation graphs agree across the host backends too (dense math on
/// both, but routed through different kernels).
#[test]
fn sparse_eval_matches_reference_eval() {
    let rc = reference_cache();
    let sc = sparse_cache();
    let meta = rc.manifest().get("mlpsyn_conv").unwrap().clone();
    let mut data_rng = Rng::new(77);
    let batch = meta.batch();
    let x: Vec<f32> =
        (0..batch * 784).map(|_| data_rng.next_f32()).collect();
    let y: Vec<i32> =
        (0..batch).map(|_| data_rng.next_usize(10) as i32).collect();
    let run = |cache: &ExecutorCache| -> (f64, f64) {
        let backend = cache.backend();
        let exe = cache.get("mlpsyn_eval").unwrap();
        let mut rng = Rng::new(123);
        let state = TrainState::init(&meta, &mut rng, backend.as_ref())
            .unwrap();
        let extra = vec![
            backend
                .ingest(HostTensor::f32(&[batch, 784], x.clone()))
                .unwrap(),
            backend
                .ingest(HostTensor::i32(&[batch], y.clone()))
                .unwrap(),
        ];
        state.eval_step(exe.as_ref(), &extra).unwrap()
    };
    let (rl, rcorrect) = run(&rc);
    let (sl, scorrect) = run(&sc);
    // 1e-5: the contractual cross-backend bound — the sparse side now
    // runs FMA SIMD microkernels by default, so eval losses are no
    // longer tighter than the contract guarantees.
    assert!((rl - sl).abs() <= 1e-5 * rl.abs().max(1.0),
            "eval loss {rl} vs {sl}");
    assert_eq!(rcorrect, scorrect);
}
