#!/usr/bin/env python3
"""Gate native sparse-speedup numbers against the checked-in baseline.

Usage:
    check_bench_regression.py NATIVE.json CHECKED_IN.json [--tolerance 0.25]

Fails (exit 1) if any gated row's native `speedup_vs_dense` falls more
than `tolerance` (fraction) below the checked-in value. Gated rows are
the paper-relevant operating points: rate in {0.5, 0.7} for the
row-skip and tile-skip configs, on every arch present in the baseline.
Dense rows (speedup 1.0 by construction) and the low-rate smoke points
are reported but not gated.

The checked-in BENCH_sparse.json's `provenance` field records which
harness produced it (the numpy scale model vs a native cargo run); the
gate applies either way — a >25% drop below the recorded operating
points is a regression signal worth a red build, and the tolerance knob
is there for recalibration when the baseline is regenerated natively.
"""

import argparse
import json
import sys

GATED_RATES = (0.5, 0.7)
GATED_CONFIGS = ("row-skip", "tile-skip")


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    return doc, {
        (r["arch"], r["rate"], r["config"]): r["speedup_vs_dense"]
        for r in doc["rows"]
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("native")
    ap.add_argument("checked_in")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional drop below baseline")
    args = ap.parse_args()

    native_doc, native = load_rows(args.native)
    checked_doc, checked = load_rows(args.checked_in)
    print(f"baseline provenance: {checked_doc['provenance']}")
    print(f"native   provenance: {native_doc['provenance']}")
    print(f"tolerance: native >= (1 - {args.tolerance}) * baseline\n")
    print(f"{'arch':8} {'rate':>5} {'config':>10} {'native':>8} "
          f"{'baseline':>9} {'floor':>7}  verdict")

    failures = []
    for key in sorted(checked):
        arch, rate, config = key
        base = checked[key]
        nat = native.get(key)
        gated = rate in GATED_RATES and config in GATED_CONFIGS
        if nat is None:
            line_verdict = "MISSING" if gated else "missing (ungated)"
            if gated:
                failures.append(f"{key}: missing from native report")
            print(f"{arch:8} {rate:5} {config:>10} {'-':>8} {base:9.2f} "
                  f"{'-':>7}  {line_verdict}")
            continue
        floor = (1.0 - args.tolerance) * base
        if gated:
            ok = nat >= floor
            verdict = "ok" if ok else "REGRESSION"
            if not ok:
                failures.append(
                    f"{key}: native {nat:.2f} < floor {floor:.2f} "
                    f"(baseline {base:.2f})")
        else:
            verdict = "info"
        print(f"{arch:8} {rate:5} {config:>10} {nat:8.2f} {base:9.2f} "
              f"{floor:7.2f}  {verdict}")

    if failures:
        print(f"\nFAIL: {len(failures)} gated speedup(s) regressed "
              f">{args.tolerance:.0%} below the checked-in baseline:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: all gated speedups within tolerance of the baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
