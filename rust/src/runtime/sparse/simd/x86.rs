//! AVX2 + FMA microkernels (x86_64). 8 f32 lanes, 2x unrolled — 16
//! elements per iteration — with `vfmadd` doing the multiply-add in one
//! rounding. Selected only after `is_x86_feature_detected!("avx2")` and
//! `("fma")` both pass (see `simd::detected`), which is the safety
//! argument for every `#[target_feature]` call below.
//!
//! Determinism: lane assignment, unroll factor, and the horizontal
//! reduction order in `dot_acc` are fixed, so results are bit-stable
//! across calls, repetitions, and thread counts. The scalar tails use
//! `mul_add` so tail elements get the same fused rounding the vector
//! body gets.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::{
    _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps,
    _mm256_setzero_ps, _mm256_storeu_ps,
};

use super::Microkernel;

pub static AVX2: Microkernel = Microkernel {
    name: "avx2",
    axpy: axpy_shim,
    axpy2: axpy2_shim,
    dot_acc: dot_acc_shim,
};

// Plain `unsafe fn` shims: fn-pointer coercion rules for
// `#[target_feature]` items vary across toolchains, so the statics point
// here and these forward one call deeper (the pointer call already
// prevents inlining; the shim adds a single direct jump).

/// # Safety
/// As [`axpy`].
unsafe fn axpy_shim(a: f32, x: *const f32, y: *mut f32, n: usize) {
    axpy(a, x, y, n)
}

/// # Safety
/// As [`axpy2`].
unsafe fn axpy2_shim(a0: f32, x0: *const f32, a1: f32, x1: *const f32,
                     y: *mut f32, n: usize) {
    axpy2(a0, x0, a1, x1, y, n)
}

/// # Safety
/// As [`dot_acc`].
unsafe fn dot_acc_shim(init: f32, x: *const f32, y: *const f32, n: usize)
                       -> f32 {
    dot_acc(init, x, y, n)
}

const W: usize = 8;

/// `y[i] += a * x[i]` — each element gets `fma(a, x[i], y[i])`.
///
/// # Safety
/// `x`/`y` valid for `n` reads / read-writes; AVX2+FMA present.
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy(a: f32, x: *const f32, y: *mut f32, n: usize) {
    let va = _mm256_set1_ps(a);
    let mut i = 0;
    while i + 2 * W <= n {
        let y0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(x.add(i)),
                                 _mm256_loadu_ps(y.add(i)));
        let y1 = _mm256_fmadd_ps(va, _mm256_loadu_ps(x.add(i + W)),
                                 _mm256_loadu_ps(y.add(i + W)));
        _mm256_storeu_ps(y.add(i), y0);
        _mm256_storeu_ps(y.add(i + W), y1);
        i += 2 * W;
    }
    if i + W <= n {
        let y0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(x.add(i)),
                                 _mm256_loadu_ps(y.add(i)));
        _mm256_storeu_ps(y.add(i), y0);
        i += W;
    }
    while i < n {
        *y.add(i) = a.mul_add(*x.add(i), *y.add(i));
        i += 1;
    }
}

/// `y[i] += a0 * x0[i] + a1 * x1[i]` as nested FMAs — bit-identical to
/// two sequential `axpy` passes.
///
/// # Safety
/// `x0`/`x1`/`y` valid for `n` reads / read-writes; AVX2+FMA present.
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy2(a0: f32, x0: *const f32, a1: f32, x1: *const f32,
                y: *mut f32, n: usize) {
    let v0 = _mm256_set1_ps(a0);
    let v1 = _mm256_set1_ps(a1);
    let mut i = 0;
    while i + W <= n {
        let t = _mm256_fmadd_ps(v0, _mm256_loadu_ps(x0.add(i)),
                                _mm256_loadu_ps(y.add(i)));
        let t = _mm256_fmadd_ps(v1, _mm256_loadu_ps(x1.add(i)), t);
        _mm256_storeu_ps(y.add(i), t);
        i += W;
    }
    while i < n {
        let t = a0.mul_add(*x0.add(i), *y.add(i));
        *y.add(i) = a1.mul_add(*x1.add(i), t);
        i += 1;
    }
}

/// `init + Σ x[i] * y[i]`: two independent 8-lane FMA accumulators over
/// the body, then a fixed-order reduction (acc0 + acc1 elementwise, lanes
/// 0..7 summed ascending onto `init`, scalar tail last).
///
/// # Safety
/// `x`/`y` valid for `n` reads; AVX2+FMA present.
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_acc(init: f32, x: *const f32, y: *const f32, n: usize)
                  -> f32 {
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0;
    while i + 2 * W <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(x.add(i)),
                               _mm256_loadu_ps(y.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(x.add(i + W)),
                               _mm256_loadu_ps(y.add(i + W)), acc1);
        i += 2 * W;
    }
    if i + W <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(x.add(i)),
                               _mm256_loadu_ps(y.add(i)), acc0);
        i += W;
    }
    let mut lanes = [0f32; W];
    _mm256_storeu_ps(lanes.as_mut_ptr(),
                     _mm256_add_ps(acc0, acc1));
    let mut acc = init;
    for l in lanes {
        acc += l;
    }
    while i < n {
        acc = (*x.add(i)).mul_add(*y.add(i), acc);
        i += 1;
    }
    acc
}
