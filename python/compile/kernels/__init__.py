"""L1 Pallas kernels (interpret mode) + pure-jnp reference oracles."""

from .matmul import matmul, pick_block
from .masked_matmul import masked_matmul
from .tile_sparse import tile_sparse_matmul
from . import ref

__all__ = [
    "matmul",
    "pick_block",
    "masked_matmul",
    "tile_sparse_matmul",
    "ref",
]
