"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

Nothing here uses Pallas; pytest (python/tests/) asserts the kernels match
these to float tolerance across hypothesis-driven shape/pattern sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b)


def masked_matmul_ref(a: jax.Array, b: jax.Array, mask: jax.Array,
                      scale) -> jax.Array:
    return jnp.dot(a, b) * mask * scale


def tile_sparse_matmul_ref(x: jax.Array, wt: jax.Array, rows: jax.Array,
                           cols: jax.Array, n_out: int) -> jax.Array:
    """Dense reconstruction: scatter kept tiles into a zero weight matrix,
    then one dense matmul."""
    j, t_r, t_c = wt.shape
    k = x.shape[1]
    tk, tn = k // t_r, n_out // t_c
    dense4 = jnp.zeros((tk, tn, t_r, t_c), wt.dtype)
    dense4 = dense4.at[rows, cols].set(wt)
    dense = dense4.transpose(0, 2, 1, 3).reshape(k, n_out)
    return jnp.dot(x, dense)


def row_dropout_ref(h: jax.Array, dp: int, b0, scale=None) -> jax.Array:
    """Conventional-style emulation of RDP on activations ``h`` [batch, M]:
    zero the dropped columns, scale the kept ones by dp (inverted dropout)."""
    from .. import patterns

    m = h.shape[-1]
    mask = patterns.row_mask(m, dp, b0)
    s = dp if scale is None else scale
    return h * mask * s
