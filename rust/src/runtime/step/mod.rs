//! The shared step interpreter: manifest-driven MLP/LSTM train/eval step
//! *programs* (forward, backward/BPTT, Caffe SGD-momentum), factored out
//! of the reference backend so that model **semantics** live in exactly
//! one place and execution backends differ only in their element math
//! (the [`Kernels`] implementation they plug in).
//!
//! * `ReferenceBackend` runs a [`StepProgram`] over [`DenseKernels`] —
//!   the masked-dense interpretation the hermetic suite has always
//!   pinned.
//! * `SparseBackend` (`runtime::sparse`) runs the *same program* over
//!   row-/tile-skipping kernels that never touch dropped coordinates.
//!
//! Semantics contract (mirrors `python/compile/model.py`, pinned by
//! `rust/tests/hermetic.rs` and cross-checked against PJRT by
//! `rust/tests/integration.rs` when artifacts exist):
//!
//! * Same manifest calling convention: inputs `params ++ momenta ++ x, y,
//!   extras, lr`; outputs `params' ++ momenta' ++ loss, correct`.
//! * RDP multiplies activations by the row pattern's 0/1 keep vector
//!   (`{b0 + dp*j}`) and the runtime `1/(1-p)` scale; TDP masks the
//!   weight matrix with the diagonal-stripe tile pattern. Kept
//!   coordinates compute exactly what the compact graph computes;
//!   dropped coordinates (and their gradients) are exactly zero — e.g.
//!   dropped rows of `w3` stay bit-identical across a step.
//! * SGD with momentum in Caffe semantics: `m' = mu*m + g`,
//!   `p' = p - lr*m'`, `mu` from the manifest.
//! * All math is f32 on host (loss accumulation in f64). Summation
//!   *order* differs from XLA, so losses agree with PJRT to float
//!   tolerance; dispatch sequences and RNG draw order agree exactly.

pub mod kernels;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::obs::trace;
use crate::runtime::backend::{Executor, GradOut, HostTensor, LeafSpec,
                              Value};
use crate::runtime::manifest::{ArchMeta, ArtifactMeta, Kind, Manifest};
use crate::runtime::plan::{DynMask, Feed, GemmNode, NtNode, SparsityPlan,
                           TnNode};

pub use kernels::{DenseKernels, Kernels, PreppedWeight, Skip};

const FORGET_BIAS: f32 = 1.0;

/// One interpreted artifact: the step program for a `(model, variant,
/// dp)` manifest entry, bound to one [`Kernels`] implementation. Holds
/// everything `run_raw` needs: the manifest metadata (shapes, dp
/// combination, per-arch tile edge) and the manifest-level momentum.
pub struct StepProgram {
    meta: ArtifactMeta,
    momentum: f32,
    kern: Arc<dyn Kernels>,
}

impl StepProgram {
    /// Build the interpreter for one manifest artifact over `kern`.
    pub fn new(manifest: &Manifest, name: &str, kern: Arc<dyn Kernels>)
               -> Result<StepProgram> {
        let meta = manifest.get(name)?.clone();
        match meta.model.as_str() {
            "mlp" | "lstm" => {}
            other => bail!("step interpreter: unknown model '{other}' \
                            (artifact {name})"),
        }
        Ok(StepProgram { meta, momentum: manifest.momentum as f32, kern })
    }
}

impl Executor for StepProgram {
    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn run_raw(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: {} inputs given, manifest says {}", self.meta.name,
                  inputs.len(), self.meta.inputs.len());
        }
        let host: Vec<&HostTensor> = inputs
            .iter()
            .map(|v| v.as_host())
            .collect::<Result<_>>()?;
        for (t, m) in host.iter().zip(&self.meta.inputs) {
            t.check(m)?;
        }
        match (self.meta.model.as_str(), self.meta.variant.as_str()) {
            ("mlp", "eval") => self.mlp_eval(&host),
            ("mlp", _) => self.mlp_train(&host),
            ("lstm", "eval") => self.lstm_eval(&host),
            ("lstm", _) => self.lstm_train(&host),
            (m, v) => bail!("step interpreter: unsupported artifact \
                             {m}/{v}"),
        }
    }

    /// Forward/backward over one batch shard (the data-parallel leaf
    /// path): slice the batch-indexed inputs (x, y, conv masks) down to
    /// the leaf's rows, run the shared fwd/bwd with the *global* batch as
    /// gradient denominator, and return the raw per-leaf sums. Shared
    /// inputs (params, b0 bias scalars/tracks, 1/(1-p) scales) pass
    /// through unsliced; momenta and lr are ignored — the optimizer apply
    /// happens once, after reduction, in the driver.
    fn run_grads(&self, inputs: &[&HostTensor], leaf: &LeafSpec)
                 -> Result<GradOut> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: {} inputs given, manifest says {}", self.meta.name,
                  inputs.len(), self.meta.inputs.len());
        }
        for (t, m) in inputs.iter().zip(&self.meta.inputs) {
            t.check(m)?;
        }
        if self.meta.variant == "eval" {
            bail!("{}: eval graphs have no gradients", self.meta.name);
        }
        let batch = self.meta.batch();
        if leaf.global_rows != batch || leaf.rows == 0
            || leaf.lo + leaf.rows > batch
        {
            bail!("{}: leaf {leaf:?} does not fit batch {batch}",
                  self.meta.name);
        }
        let mut owned: Vec<Option<HostTensor>> =
            Vec::with_capacity(inputs.len());
        for (t, m) in inputs.iter().zip(&self.meta.inputs) {
            owned.push(match m.kind {
                Kind::X | Kind::Y | Kind::Mask =>
                    Some(slice_rows(t, leaf.lo, leaf.rows)?),
                _ => None,
            });
        }
        let sliced: Vec<&HostTensor> = owned.iter().zip(inputs)
            .map(|(o, &t)| o.as_ref().unwrap_or(t))
            .collect();
        let (params, _momenta, xt, y, extras, _lr) =
            self.split_train(&sliced)?;
        let (loss_sum, correct, grads) = match self.meta.model.as_str() {
            "mlp" => self.mlp_fwd_bwd(&params, xt.as_f32()?, y, &extras,
                                      leaf.rows, leaf.global_rows)?,
            "lstm" => self.lstm_fwd_bwd(&params, xt.as_i32()?, y, &extras,
                                        leaf.rows, leaf.global_rows)?,
            other => bail!("step interpreter: unsupported model \
                            '{other}'"),
        };
        Ok(GradOut { grads, loss_sum, correct })
    }
}

// ---------------------------------------------------------------------------
// Cheap elementwise helpers (O(m*n); stay outside the Kernels trait)
// ---------------------------------------------------------------------------

/// `x [m,n] += bias [n]` broadcast over rows.
fn add_row_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    for row in x.chunks_mut(n) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column sums of `x [m,n]` -> `[n]`, accumulated into `out`.
fn colsum_acc(x: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n);
    for row in x.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn scale_vec(a: &[f32], s: f32) -> Vec<f32> {
    a.iter().map(|x| x * s).collect()
}

/// Slice rows `lo .. lo+rows` of a tensor's leading dimension (the batch
/// axis of x/y/mask inputs). The row width is the product of the
/// remaining dims, so `[batch]`, `[batch, n]` and `[batch, seq]` all
/// slice the same way.
fn slice_rows(t: &HostTensor, lo: usize, rows: usize)
              -> Result<HostTensor> {
    let shape = t.shape();
    if shape.is_empty() {
        bail!("cannot row-slice a scalar tensor");
    }
    if lo + rows > shape[0] {
        bail!("row slice {lo}..{} exceeds leading dim {}", lo + rows,
              shape[0]);
    }
    let width: usize = shape[1..].iter().product();
    let mut ns = shape.to_vec();
    ns[0] = rows;
    Ok(match t {
        HostTensor::F32 { data, .. } => HostTensor::f32(
            &ns, data[lo * width..(lo + rows) * width].to_vec()),
        HostTensor::I32 { data, .. } => HostTensor::i32(
            &ns, data[lo * width..(lo + rows) * width].to_vec()),
    })
}

/// Softmax cross-entropy over `rows` rows of `cols` logits against int
/// targets. Returns (f64 nll sum, correct count, d_logits) with the
/// gradient already scaled by `1/denom`. Full-batch callers pass
/// `denom == rows` (the mean, matching `model.softmax_xent`); batch
/// *shards* pass the global row count so that summing per-shard gradients
/// reproduces the full-batch mean gradient exactly.
fn softmax_xent_grad(logits: &[f32], targets: &[i32], rows: usize,
                     cols: usize, denom: usize)
                     -> Result<(f64, f32, Vec<f32>)> {
    debug_assert_eq!(logits.len(), rows * cols);
    let mut loss = 0f64;
    let mut correct = 0f32;
    let mut grad = vec![0f32; rows * cols];
    let inv = 1.0 / denom as f32;
    for r in 0..rows {
        let y = targets[r];
        if y < 0 || y as usize >= cols {
            bail!("label {y} out of range [0, {cols})");
        }
        let row = &logits[r * cols..(r + 1) * cols];
        let mut mx = f32::NEG_INFINITY;
        let mut argmax = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > mx {
                mx = v;
                argmax = j;
            }
        }
        let mut sum = 0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let lse = sum.ln() + mx;
        loss += (lse - row[y as usize]) as f64;
        if argmax == y as usize {
            correct += 1.0;
        }
        let grow = &mut grad[r * cols..(r + 1) * cols];
        for (j, (g, &v)) in grow.iter_mut().zip(row).enumerate() {
            let p = (v - lse).exp();
            *g = (p - if j == y as usize { 1.0 } else { 0.0 }) * inv;
        }
    }
    Ok((loss, correct, grad))
}

/// Per-row softmax cross-entropy: one `(nll, correct-flag)` pair per row,
/// no gradient. The eval paths derive their batch aggregates from these
/// values (f64 accumulation in row order), which reproduces the fused
/// [`softmax_xent_grad`] aggregates bit for bit — and additionally exposes
/// per-example results. Every row of the eval forward pass depends only on
/// its own input row, so the inference service can pack unrelated requests
/// into one padded batch and hand each caller exactly the numbers a solo
/// dispatch would have produced.
fn softmax_xent_rows(logits: &[f32], targets: &[i32], rows: usize,
                     cols: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    debug_assert_eq!(logits.len(), rows * cols);
    let mut nll = Vec::with_capacity(rows);
    let mut hit = Vec::with_capacity(rows);
    for r in 0..rows {
        let y = targets[r];
        if y < 0 || y as usize >= cols {
            bail!("label {y} out of range [0, {cols})");
        }
        let row = &logits[r * cols..(r + 1) * cols];
        let mut mx = f32::NEG_INFINITY;
        let mut argmax = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > mx {
                mx = v;
                argmax = j;
            }
        }
        let mut sum = 0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let lse = sum.ln() + mx;
        nll.push(lse - row[y as usize]);
        hit.push(if argmax == y as usize { 1.0 } else { 0.0 });
    }
    Ok((nll, hit))
}

/// Batch aggregates from per-row values: (mean nll, correct count), with
/// the exact accumulation order/types of the historical fused computation.
fn xent_aggregate(nll: &[f32], hit: &[f32]) -> (f32, f32) {
    let mut loss = 0f64;
    let mut correct = 0f32;
    for (&l, &h) in nll.iter().zip(hit) {
        loss += l as f64;
        correct += h;
    }
    ((loss / nll.len().max(1) as f64) as f32, correct)
}

// ---------------------------------------------------------------------------
// Program internals
// ---------------------------------------------------------------------------
//
// Dropout-site structure (Feed, FeedRun, the b0/track decoding, pattern
// validation) lives in `runtime::plan` — the interpreter receives a
// `SparsityPlan` and executes it; it never re-derives what can be
// skipped.

impl StepProgram {
    fn n_params(&self) -> usize {
        self.meta.n_params()
    }

    /// Split train-step inputs per the manifest convention.
    fn split_train<'a>(&self, inp: &[&'a HostTensor])
                       -> Result<(Vec<&'a [f32]>, Vec<&'a [f32]>,
                                  &'a HostTensor, &'a [i32],
                                  Vec<&'a HostTensor>, f32)> {
        let np = self.n_params();
        let params: Vec<&[f32]> =
            inp[..np].iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let momenta: Vec<&[f32]> = inp[np..2 * np]
            .iter()
            .map(|t| t.as_f32())
            .collect::<Result<_>>()?;
        let x = inp[2 * np];
        let y = inp[2 * np + 1].as_i32()?;
        let extras: Vec<&HostTensor> =
            inp[2 * np + 2..inp.len() - 1].to_vec();
        let lr = inp[inp.len() - 1].as_f32()?[0];
        Ok((params, momenta, x, y, extras, lr))
    }

    /// Pack `(new params, new momenta, loss, correct)` in manifest output
    /// order.
    fn pack(&self, new_p: Vec<Vec<f32>>, new_m: Vec<Vec<f32>>, loss: f32,
            correct: f32) -> Result<Vec<Value>> {
        let np = self.n_params();
        let mut out = Vec::with_capacity(2 * np + 2);
        for (i, p) in new_p.into_iter().enumerate() {
            out.push(Value::Host(HostTensor::f32(
                &self.meta.outputs[i].shape, p)));
        }
        for (i, m) in new_m.into_iter().enumerate() {
            out.push(Value::Host(HostTensor::f32(
                &self.meta.outputs[np + i].shape, m)));
        }
        out.push(Value::Host(HostTensor::scalar_f32(loss)));
        out.push(Value::Host(HostTensor::scalar_f32(correct)));
        Ok(out)
    }

    /// `m' = mu*m + g`, `p' = p - lr*m'` (Caffe semantics).
    fn sgd(&self, params: &[&[f32]], momenta: &[&[f32]],
           grads: &[Vec<f32>], lr: f32)
           -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mu = self.momentum;
        let mut new_p = Vec::with_capacity(params.len());
        let mut new_m = Vec::with_capacity(params.len());
        for ((p, m), g) in params.iter().zip(momenta).zip(grads) {
            let nm: Vec<f32> = m.iter().zip(g.iter())
                .map(|(&mv, &gv)| mu * mv + gv)
                .collect();
            let np: Vec<f32> = p.iter().zip(&nm)
                .map(|(&pv, &mv)| pv - lr * mv)
                .collect();
            new_p.push(np);
            new_m.push(nm);
        }
        (new_p, new_m)
    }

    // -- MLP ---------------------------------------------------------------

    fn mlp_dims(&self) -> Result<(usize, usize, usize, usize, usize)> {
        match &self.meta.arch {
            ArchMeta::Mlp { n_in, hidden, n_out, batch } => {
                if hidden.len() != 2 {
                    bail!("step interpreter mlp supports 2 hidden layers, \
                           got {}", hidden.len());
                }
                Ok((*n_in, hidden[0], hidden[1], *n_out, *batch))
            }
            _ => bail!("artifact {} is not an MLP", self.meta.name),
        }
    }

    fn mlp_train(&self, inp: &[&HostTensor]) -> Result<Vec<Value>> {
        let (_, _, _, _, batch) = self.mlp_dims()?;
        let (params, momenta, xt, y, extras, lr) = self.split_train(inp)?;
        let (loss_sum, correct, grads) =
            self.mlp_fwd_bwd(&params, xt.as_f32()?, y, &extras, batch,
                             batch)?;
        let loss = (loss_sum / batch as f64) as f32;
        let (new_p, new_m) = {
            let _sp = trace::span("sgd");
            self.sgd(&params, &momenta, &grads, lr)
        };
        self.pack(new_p, new_m, loss, correct)
    }

    /// Forward + backward over `batch` rows of x/y/extras, softmax
    /// gradient scaled by `1/denom`. The full-batch step passes
    /// `denom == batch`; a gradient shard passes its leaf's rows with
    /// the *global* batch as denom, so per-leaf grads sum to the
    /// full-batch mean gradient. Returns the f64 nll sum, the correct
    /// count, and grads in param order `[dw1, db1, dw2, db2, dw3, db3]`.
    fn mlp_fwd_bwd(&self, params: &[&[f32]], x: &[f32], y: &[i32],
                   extras: &[&HostTensor], batch: usize, denom: usize)
                   -> Result<(f64, f32, Vec<Vec<f32>>)> {
        let kern = self.kern.as_ref();
        let (n_in, h1, h2, n_out, _) = self.mlp_dims()?;
        let (w1, b1, w2, b2, w3, b3) = (params[0], params[1], params[2],
                                        params[3], params[4], params[5]);
        let plan = SparsityPlan::per_step(&self.meta, extras, &[h1, h2],
                                          &[(n_in, h1), (h1, h2)])?;
        let (feed0, feed1) = (plan.feed(0), plan.feed(1));
        let (sk0, sk1) = (feed0.skip(), feed1.skip());
        const DENSE: Skip = Skip::Dense;

        // Forward. Two shapes: activation-masked (conv/rdp) applies the
        // site mask after relu; weight-masked (tdp) masks w and scales the
        // product before the bias (mirrors _mlp_logits_tdp).
        let sp_fwd = trace::span("fwd");
        let weight_masked = matches!(feed0, Feed::Weight { .. });
        // Activation-space structure per site: for weight-masked (tdp)
        // sites the activations are dense — only the w1/w2 matmuls carry
        // the (tile) skip, while the w3 layer and the relu-gradient hops
        // run dense.
        let (ask0, ask1) = if weight_masked {
            (DENSE, DENSE)
        } else {
            (sk0, sk1)
        };
        // `w2p` is the prepared w2 for the tdp path (masked copy on
        // dense backends, no-op handle on structure-exploiting ones). It
        // outlives the forward because the backward's input-gradient
        // matmul runs against the same prepared weight.
        let (out0, out1, w2p);
        if weight_masked {
            let s1 = match feed0 {
                Feed::Weight { s, .. } => *s,
                _ => unreachable!(),
            };
            let s2 = match feed1 {
                Feed::Weight { s, .. } => *s,
                _ => unreachable!(),
            };
            let w1p = kern.prep(w1, n_in, h1, &sk0);
            w2p = kern.prep(w2, h1, h2, &sk1);
            let mut z1 = scale_vec(
                &kern.gemm_node(x, w1,
                                &GemmNode::new(sk0, DENSE).with_pw(&w1p),
                                batch, n_in, h1),
                s1);
            add_row_bias(&mut z1, b1);
            relu_inplace(&mut z1);
            let mut z2 = scale_vec(
                &kern.gemm_node(&z1, w2,
                                &GemmNode::new(sk1, DENSE).with_pw(&w2p),
                                batch, h1, h2),
                s2);
            add_row_bias(&mut z2, b2);
            relu_inplace(&mut z2);
            out0 = z1;
            out1 = z2;
        } else {
            // Dropped output columns of z1/z2 are masked to zero right
            // below, so the kernels may skip computing them (`out_skip`).
            let mut z1 = kern.gemm_node(x, w1, &GemmNode::new(DENSE, sk0),
                                        batch, n_in, h1);
            add_row_bias(&mut z1, b1);
            relu_inplace(&mut z1);
            let o0 = feed0.mask_act(&z1, batch, h1);
            let mut z2 = kern.gemm_node(&o0, w2,
                                        &GemmNode::new(sk0, sk1), batch,
                                        h1, h2);
            add_row_bias(&mut z2, b2);
            relu_inplace(&mut z2);
            let o1 = feed1.mask_act(&z2, batch, h2);
            out0 = o0;
            out1 = o1;
            w2p = PreppedWeight::dense();
        }
        let mut logits = kern.gemm_node(&out1, w3,
                                        &GemmNode::new(ask1, DENSE),
                                        batch, h2, n_out);
        add_row_bias(&mut logits, b3);
        let (loss_sum, correct, dlogits) =
            softmax_xent_grad(&logits, y, batch, n_out, denom)?;
        drop(sp_fwd);

        // Backward. Dynamic masks: units whose forward activation is
        // zero on every batch row carry exactly-zero gradient — their
        // weight-gradient rows accumulate nothing (bitwise, on every
        // backend) and their input-gradient columns are annihilated by
        // the relu-derivative gate right below. Scanning happens only
        // when the kernels opt in; the masks never change which kernel
        // calls run, only what a call may skip internally.
        let sp_bwd = trace::span("bptt");
        let dyn1 = if kern.dyn_backward() {
            DynMask::scan_cols(&out1, batch, h2, &ask1)
        } else {
            None
        };
        let dw3 = kern.gemm_tn_node(
            &out1, &dlogits,
            &TnNode::new(ask1, DENSE).with_dyn(dyn1.as_ref()), batch, h2,
            n_out);
        let mut db3 = vec![0f32; n_out];
        colsum_acc(&dlogits, n_out, &mut db3);
        let dout1 = kern.gemm_nt_node(
            &dlogits, w3, &NtNode::new(ask1).with_dyn(dyn1.as_ref()),
            batch, n_out, h2);

        let (dw1, db1, dw2, db2);
        if weight_masked {
            let s1 = match feed0 {
                Feed::Weight { s, .. } => *s,
                _ => unreachable!(),
            };
            let s2 = match feed1 {
                Feed::Weight { s, .. } => *s,
                _ => unreachable!(),
            };
            // out1 = relu((out0 @ w2m)*s2 + b2)
            let dz2: Vec<f32> = dout1.iter().zip(&out1)
                .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
                .collect();
            let mut db2v = vec![0f32; h2];
            colsum_acc(&dz2, h2, &mut db2v);
            let du2 = scale_vec(&dz2, s2);
            // Tile-skipped gradients carry no dynamic mask (tile
            // structure has no flat column view — `DynMask::scan_cols`
            // is `None` for `Tiles` by contract).
            let dw2v = kern.gemm_tn_node(&out0, &du2,
                                         &TnNode::new(sk1, DENSE), batch,
                                         h1, h2);
            let dout0 = kern.gemm_nt_node(&du2, w2,
                                          &NtNode::new(sk1).with_pw(&w2p),
                                          batch, h2, h1);
            let dz1: Vec<f32> = dout0.iter().zip(&out0)
                .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
                .collect();
            let mut db1v = vec![0f32; h1];
            colsum_acc(&dz1, h1, &mut db1v);
            let du1 = scale_vec(&dz1, s1);
            let dw1v = kern.gemm_tn_node(x, &du1,
                                         &TnNode::new(sk0, DENSE), batch,
                                         n_in, h1);
            dw1 = dw1v;
            db1 = db1v;
            dw2 = dw2v;
            db2 = db2v;
        } else {
            // out1 = relu(out0 @ w2 + b2) ∘ m2 ∘ s2. The relu derivative
            // tests the *pre-mask* activation; recover it from out1 only
            // where the mask keeps (dropped units have zero upstream grad
            // after the mask anyway).
            let da1 = feed1.mask_act(&dout1, batch, h2);
            // a2 > 0 wherever out1 > 0 OR (masked-out unit): for masked-out
            // units da1 is already zero, so using out1's sign is exact on
            // every coordinate that can carry gradient.
            let dz2: Vec<f32> = da1.iter().zip(&out1)
                .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
                .collect();
            let mut db2v = vec![0f32; h2];
            colsum_acc(&dz2, h2, &mut db2v);
            let dyn0 = if kern.dyn_backward() {
                DynMask::scan_cols(&out0, batch, h1, &sk0)
            } else {
                None
            };
            let dw2v = kern.gemm_tn_node(
                &out0, &dz2,
                &TnNode::new(sk0, sk1).with_dyn(dyn0.as_ref()), batch, h1,
                h2);
            let dout0 = kern.gemm_nt_node(
                &dz2, w2, &NtNode::new(sk0).with_dyn(dyn0.as_ref()),
                batch, h2, h1);
            let da0 = feed0.mask_act(&dout0, batch, h1);
            let dz1: Vec<f32> = da0.iter().zip(&out0)
                .map(|(&g, &a)| if a > 0.0 { g } else { 0.0 })
                .collect();
            let mut db1v = vec![0f32; h1];
            colsum_acc(&dz1, h1, &mut db1v);
            let dw1v = kern.gemm_tn_node(x, &dz1,
                                         &TnNode::new(DENSE, sk0), batch,
                                         n_in, h1);
            dw1 = dw1v;
            db1 = db1v;
            dw2 = dw2v;
            db2 = db2v;
        }

        drop(sp_bwd);

        Ok((loss_sum, correct, vec![dw1, db1, dw2, db2, dw3, db3]))
    }

    fn mlp_eval(&self, inp: &[&HostTensor]) -> Result<Vec<Value>> {
        let kern = self.kern.as_ref();
        let (n_in, h1, h2, n_out, batch) = self.mlp_dims()?;
        let np = self.n_params();
        let params: Vec<&[f32]> =
            inp[..np].iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let x = inp[np].as_f32()?;
        let y = inp[np + 1].as_i32()?;
        const DENSE: Skip = Skip::Dense;
        let mut a1 = kern.gemm(x, params[0], batch, n_in, h1, &DENSE,
                               &DENSE);
        add_row_bias(&mut a1, params[1]);
        relu_inplace(&mut a1);
        let mut a2 = kern.gemm(&a1, params[2], batch, h1, h2, &DENSE,
                               &DENSE);
        add_row_bias(&mut a2, params[3]);
        relu_inplace(&mut a2);
        let mut logits = kern.gemm(&a2, params[4], batch, h2, n_out,
                                   &DENSE, &DENSE);
        add_row_bias(&mut logits, params[5]);
        // Eval outputs: the 2 aggregate scalars of the manifest contract,
        // plus per-example vectors ([batch] nll, [batch] correct flags)
        // the hermetic backends expose for the inference service. Extra
        // outputs are backward compatible: `TrainState::eval_step` reads
        // the first two only.
        let (nll, hit) = softmax_xent_rows(&logits, y, batch, n_out)?;
        let (loss, correct) = xent_aggregate(&nll, &hit);
        Ok(vec![
            Value::Host(HostTensor::scalar_f32(loss)),
            Value::Host(HostTensor::scalar_f32(correct)),
            Value::Host(HostTensor::f32(&[batch], nll)),
            Value::Host(HostTensor::f32(&[batch], hit)),
        ])
    }

    // -- LSTM --------------------------------------------------------------

    fn lstm_dims(&self) -> Result<(usize, usize, usize, usize, usize)> {
        match &self.meta.arch {
            ArchMeta::Lstm { vocab, hidden, layers, seq, batch } =>
                Ok((*vocab, *hidden, *layers, *seq, *batch)),
            _ => bail!("artifact {} is not an LSTM", self.meta.name),
        }
    }

    fn lstm_train(&self, inp: &[&HostTensor]) -> Result<Vec<Value>> {
        let (_, _, _, seq, batch) = self.lstm_dims()?;
        let (params, momenta, xt, y, extras, lr) = self.split_train(inp)?;
        let (loss_sum, correct, grads) =
            self.lstm_fwd_bwd(&params, xt.as_i32()?, y, &extras, batch,
                              batch)?;
        let loss = (loss_sum / (seq * batch) as f64) as f32;
        let (new_p, new_m) = {
            let _sp = trace::span("sgd");
            self.sgd(&params, &momenta, &grads, lr)
        };
        self.pack(new_p, new_m, loss, correct)
    }

    /// Forward + BPTT over `batch` tracks of x/y/extras, softmax gradient
    /// scaled by `1/(seq*denom)`. The full-batch step passes
    /// `denom == batch`; a gradient shard passes its leaf's tracks with
    /// the global batch as denom. Tracks evolve independently through the
    /// recurrence, so a contiguous track shard computes exactly the rows
    /// the full batch would. Returns the f64 nll sum, the correct count,
    /// and grads in param order (emb, (wx, wh, bg) per layer, wsoft,
    /// bsoft).
    fn lstm_fwd_bwd(&self, params: &[&[f32]], x: &[i32], y: &[i32],
                    extras: &[&HostTensor], batch: usize, denom: usize)
                    -> Result<(f64, f32, Vec<Vec<f32>>)> {
        let (vocab, h, layers, seq, _) = self.lstm_dims()?;
        // Sites: site l-1 guards layer l's input for l in 1..L; site L-1
        // guards the softmax input (Zaremba-style non-recurrent dropout).
        let widths = vec![h; layers];
        let mut wdims = Vec::with_capacity(layers);
        for _ in 0..layers.saturating_sub(1) {
            wdims.push((h, 4 * h)); // tdp masks wx of the consuming layer
        }
        wdims.push((h, vocab)); // last site masks wsoft
        let plan = SparsityPlan::windowed(&self.meta, extras, seq,
                                          &widths, &wdims)?;

        let fwd = self.lstm_forward(params, x, batch, Some(&plan), true)?;
        let rows = seq * batch;
        let mut targets = vec![0i32; rows];
        for b in 0..batch {
            for t in 0..seq {
                targets[t * batch + b] = y[b * seq + t];
            }
        }
        let (loss_sum, correct, dlogits) =
            softmax_xent_grad(&fwd.logits, &targets, rows, vocab,
                              seq * denom)?;
        let grads = self.lstm_backward(params, x, batch, &plan, &fwd,
                                       &dlogits)?;
        Ok((loss_sum, correct, grads))
    }

    fn lstm_eval(&self, inp: &[&HostTensor]) -> Result<Vec<Value>> {
        let (vocab, _h, _layers, seq, batch) = self.lstm_dims()?;
        let np = self.n_params();
        let params: Vec<&[f32]> =
            inp[..np].iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let x = inp[np].as_i32()?;
        let y = inp[np + 1].as_i32()?;
        let fwd = self.lstm_forward(&params, x, batch, None, false)?;
        let rows = seq * batch;
        let mut targets = vec![0i32; rows];
        for b in 0..batch {
            for t in 0..seq {
                targets[t * batch + b] = y[b * seq + t];
            }
        }
        let (nll, hit) =
            softmax_xent_rows(&fwd.logits, &targets, rows, vocab)?;
        let (loss, correct) = xent_aggregate(&nll, &hit);
        // Per-track results (logit row t*batch + b belongs to track b):
        // mean nll over the track's seq targets plus its correct-token
        // count — the per-example outputs behind the inference service.
        // Tracks evolve independently through the recurrence, so these
        // are invariant to what the other batch rows hold.
        let mut ex_loss = vec![0f32; batch];
        let mut ex_hit = vec![0f32; batch];
        for b in 0..batch {
            let mut s = 0f64;
            let mut c = 0f32;
            for t in 0..seq {
                s += nll[t * batch + b] as f64;
                c += hit[t * batch + b];
            }
            ex_loss[b] = (s / seq as f64) as f32;
            ex_hit[b] = c;
        }
        Ok(vec![
            Value::Host(HostTensor::scalar_f32(loss)),
            Value::Host(HostTensor::scalar_f32(correct)),
            Value::Host(HostTensor::f32(&[batch], ex_loss)),
            Value::Host(HostTensor::f32(&[batch], ex_hit)),
        ])
    }

    fn lstm_forward(&self, params: &[&[f32]], x: &[i32], batch: usize,
                    plan: Option<&SparsityPlan>, keep_caches: bool)
                    -> Result<LstmFwd> {
        let kern = self.kern.as_ref();
        let (vocab, h, layers, seq, _) = self.lstm_dims()?;
        const DENSE: Skip = Skip::Dense;
        let emb = params[0];
        let cells: Vec<(&[f32], &[f32], &[f32])> = (0..layers)
            .map(|l| (params[1 + 3 * l], params[2 + 3 * l],
                      params[3 + 3 * l]))
            .collect();
        let wsoft = params[params.len() - 2];
        let bsoft = params[params.len() - 1];

        // Timestep -> run index per site, and per-(layer, run) prepared
        // input weights: prep is hoisted out of the timestep loop and
        // paid once per (site, window) — dense backends materialize
        // tdp-masked copies, the sparse backend packs kept-row panels for
        // rdp, and `Skip::Dense` prep is an allocation-free no-op.
        // prepped_wx[l][ri] guards layer l's input (l >= 1) during run
        // ri of site l-1; the handles are reused by the backward pass.
        let run_of = plan.map(|p| p.run_lookup(seq)).unwrap_or_default();
        let mut prepped_wx: Vec<Vec<PreppedWeight>> =
            (0..layers).map(|_| Vec::new()).collect();
        if let Some(p) = plan {
            let _sp = trace::span("prep");
            for l in 1..layers {
                prepped_wx[l] = p.runs(l - 1).iter()
                    .map(|r| kern.prep(cells[l].0, h, 4 * h,
                                       &r.feed.skip()))
                    .collect();
            }
        }

        let mut h_state = vec![vec![0f32; batch * h]; layers];
        let mut c_state = vec![vec![0f32; batch * h]; layers];
        let mut caches: Vec<CellCache> = Vec::new();
        let mut flat = vec![0f32; seq * batch * h];

        let sp_fwd = trace::span("fwd");
        for t in 0..seq {
            // Embedding rows for timestep t: e_t [batch, h].
            let mut inp = vec![0f32; batch * h];
            for b in 0..batch {
                let tok = x[b * seq + t];
                if tok < 0 || tok as usize >= vocab {
                    bail!("token {tok} out of range [0, {vocab})");
                }
                let row = &emb[tok as usize * h..(tok as usize + 1) * h];
                inp[b * h..(b + 1) * h].copy_from_slice(row);
            }
            for l in 0..layers {
                let (wx, wh, bg) = cells[l];
                // Input contribution to the gates, per the site's feed.
                let (minp, mut gates) = if l == 0 {
                    let g = kern.gemm(&inp, wx, batch, h, 4 * h, &DENSE,
                                      &DENSE);
                    (inp.clone(), g)
                } else {
                    let site = plan.map(|p| {
                        let ri = run_of[l - 1][t];
                        (&p.runs(l - 1)[ri].feed, &prepped_wx[l][ri])
                    });
                    match site {
                        Some((f @ Feed::Act { .. }, pw)) => {
                            let mi = f.mask_act(&inp, batch, h);
                            let node = GemmNode::new(f.skip(), DENSE)
                                .with_pw(pw);
                            let g = kern.gemm_node(&mi, wx, &node, batch,
                                                   h, 4 * h);
                            (mi, g)
                        }
                        Some((Feed::Weight { s, skip }, pw)) => {
                            let node = GemmNode::new(*skip, DENSE)
                                .with_pw(pw);
                            let g = scale_vec(
                                &kern.gemm_node(&inp, wx, &node, batch,
                                                h, 4 * h),
                                *s);
                            (inp.clone(), g)
                        }
                        _ => {
                            let g = kern.gemm(&inp, wx, batch, h, 4 * h,
                                              &DENSE, &DENSE);
                            (inp.clone(), g)
                        }
                    }
                };
                let rec = kern.gemm(&h_state[l], wh, batch, h, 4 * h,
                                    &DENSE, &DENSE);
                for (g, r) in gates.iter_mut().zip(&rec) {
                    *g += r;
                }
                add_row_bias(&mut gates, bg);

                // Gate order i, f, g, o (jnp.split(gates, 4, axis=-1)).
                let mut gi = vec![0f32; batch * h];
                let mut gf = vec![0f32; batch * h];
                let mut gg = vec![0f32; batch * h];
                let mut go = vec![0f32; batch * h];
                for b in 0..batch {
                    for j in 0..h {
                        let base = b * 4 * h;
                        gi[b * h + j] = sigmoid(gates[base + j]);
                        gf[b * h + j] =
                            sigmoid(gates[base + h + j] + FORGET_BIAS);
                        gg[b * h + j] = gates[base + 2 * h + j].tanh();
                        go[b * h + j] = sigmoid(gates[base + 3 * h + j]);
                    }
                }
                let c_prev = std::mem::take(&mut c_state[l]);
                let h_prev = std::mem::take(&mut h_state[l]);
                let mut c = vec![0f32; batch * h];
                let mut tanh_c = vec![0f32; batch * h];
                let mut hn = vec![0f32; batch * h];
                for j in 0..batch * h {
                    c[j] = gf[j] * c_prev[j] + gi[j] * gg[j];
                    tanh_c[j] = c[j].tanh();
                    hn[j] = go[j] * tanh_c[j];
                }
                c_state[l] = c.clone();
                h_state[l] = hn.clone();
                if keep_caches {
                    caches.push(CellCache {
                        minp,
                        gi,
                        gf,
                        gg,
                        go,
                        c_prev,
                        tanh_c,
                        h_prev,
                    });
                }
                inp = hn;
            }
            // Top-layer output for timestep t, flat row t*batch + b.
            for b in 0..batch {
                flat[(t * batch + b) * h..(t * batch + b + 1) * h]
                    .copy_from_slice(
                        &h_state[layers - 1][b * h..(b + 1) * h]);
            }
        }

        drop(sp_fwd);

        // Softmax projection per run of the last site: each window's
        // flat rows are contiguous (`t0*batch .. t1*batch`), so the
        // projection runs one GEMM per window against that window's
        // prepared wsoft. The per-step default is a single run covering
        // every row — exactly the old single-GEMM shape.
        let _sp_soft = trace::span("softmax");
        let rows = seq * batch;
        let (mflat, logits, prepped_wsoft);
        match plan.map(|p| p.runs(layers - 1)) {
            Some(runs) => {
                let pws: Vec<PreppedWeight> = runs.iter()
                    .map(|r| kern.prep(wsoft, h, vocab, &r.feed.skip()))
                    .collect();
                let mut lg = vec![0f32; rows * vocab];
                // dp is fixed per step, so run feeds share one shape;
                // mflat is cached iff the site is activation-masked.
                let mut mf_buf =
                    if matches!(runs.first().map(|r| &r.feed),
                                Some(Feed::Act { .. })) {
                        Some(vec![0f32; rows * h])
                    } else {
                        None
                    };
                for (ri, r) in runs.iter().enumerate() {
                    let (r0, r1) = (r.t0 * batch, r.t1 * batch);
                    let nrows = r1 - r0;
                    let fslice = &flat[r0 * h..r1 * h];
                    let seg = match &r.feed {
                        f @ Feed::Act { .. } => {
                            let mf = f.mask_act(fslice, nrows, h);
                            let node = GemmNode::new(f.skip(), DENSE)
                                .with_pw(&pws[ri]);
                            let g = kern.gemm_node(&mf, wsoft, &node,
                                                   nrows, h, vocab);
                            mf_buf.as_mut().expect("act run set")
                                [r0 * h..r1 * h]
                                .copy_from_slice(&mf);
                            g
                        }
                        Feed::Weight { s, skip } => {
                            let node = GemmNode::new(*skip, DENSE)
                                .with_pw(&pws[ri]);
                            scale_vec(&kern.gemm_node(fslice, wsoft,
                                                      &node, nrows, h,
                                                      vocab),
                                      *s)
                        }
                        Feed::Plain => kern.gemm(fslice, wsoft, nrows, h,
                                                 vocab, &DENSE, &DENSE),
                    };
                    lg[r0 * vocab..r1 * vocab].copy_from_slice(&seg);
                }
                mflat = mf_buf;
                logits = lg;
                prepped_wsoft = pws;
            }
            None => {
                mflat = None;
                logits = kern.gemm(&flat, wsoft, rows, h, vocab, &DENSE,
                                   &DENSE);
                prepped_wsoft = Vec::new();
            }
        }
        let mut logits = logits;
        add_row_bias(&mut logits, bsoft);
        Ok(LstmFwd { caches, flat, mflat, prepped_wx, prepped_wsoft,
                     logits })
    }

    fn lstm_backward(&self, params: &[&[f32]], x: &[i32], batch: usize,
                     plan: &SparsityPlan, fwd: &LstmFwd,
                     dlogits: &[f32])
                     -> Result<Vec<Vec<f32>>> {
        let kern = self.kern.as_ref();
        let _sp = trace::span("bptt");
        let (vocab, h, layers, seq, _) = self.lstm_dims()?;
        const DENSE: Skip = Skip::Dense;
        let cells: Vec<(&[f32], &[f32], &[f32])> = (0..layers)
            .map(|l| (params[1 + 3 * l], params[2 + 3 * l],
                      params[3 + 3 * l]))
            .collect();
        let wsoft = params[params.len() - 2];
        let rows = seq * batch;
        let run_of = plan.run_lookup(seq);

        let mut demb = vec![0f32; vocab * h];
        let mut dwx: Vec<Vec<f32>> =
            (0..layers).map(|_| vec![0f32; h * 4 * h]).collect();
        let mut dwh: Vec<Vec<f32>> =
            (0..layers).map(|_| vec![0f32; h * 4 * h]).collect();
        let mut dbg: Vec<Vec<f32>> =
            (0..layers).map(|_| vec![0f32; 4 * h]).collect();
        let mut dbsoft = vec![0f32; vocab];
        colsum_acc(dlogits, vocab, &mut dbsoft);

        // Softmax projection backward, one segment per window run.
        // `dwsoft` accumulates across runs, so a unit dropped in one
        // window still collects gradient from windows that kept it —
        // matching the masked-dense reference exactly. With a single
        // run this is bit-identical to the old whole-sequence GEMMs
        // (gemm_tn is zero-init + gemm_tn_acc).
        let mut dwsoft = vec![0f32; h * vocab];
        let mut dflat = vec![0f32; rows * h];
        for (ri, r) in plan.runs(layers - 1).iter().enumerate() {
            let (r0, r1) = (r.t0 * batch, r.t1 * batch);
            let nrows = r1 - r0;
            let dl = &dlogits[r0 * vocab..r1 * vocab];
            // No dynamic masks here or anywhere in the LSTM backward
            // except the t==0 warmup below: the input-gradient columns
            // (dflat, dinp) feed additive recurrence sums with no
            // zeroing gate, so leaving dynamically-dead columns
            // uncomputed would not be value-preserving.
            let seg = match &r.feed {
                f @ Feed::Act { .. } => {
                    let mf = &fwd.mflat.as_ref().expect("mflat cached")
                        [r0 * h..r1 * h];
                    let sk = f.skip();
                    kern.gemm_tn_acc_node(mf, dl,
                                          &TnNode::new(sk, DENSE), nrows,
                                          h, vocab, &mut dwsoft);
                    let nt = NtNode::new(sk)
                        .with_pw(&fwd.prepped_wsoft[ri]);
                    let df_pre = kern.gemm_nt_node(dl, wsoft, &nt, nrows,
                                                   vocab, h);
                    f.mask_act(&df_pre, nrows, h)
                }
                Feed::Weight { s, skip } => {
                    let ds = scale_vec(dl, *s);
                    kern.gemm_tn_acc_node(&fwd.flat[r0 * h..r1 * h], &ds,
                                          &TnNode::new(*skip, DENSE),
                                          nrows, h, vocab, &mut dwsoft);
                    let nt = NtNode::new(*skip)
                        .with_pw(&fwd.prepped_wsoft[ri]);
                    kern.gemm_nt_node(&ds, wsoft, &nt, nrows, vocab, h)
                }
                Feed::Plain => {
                    kern.gemm_tn_acc_node(&fwd.flat[r0 * h..r1 * h], dl,
                                          &TnNode::new(DENSE, DENSE),
                                          nrows, h, vocab, &mut dwsoft);
                    kern.gemm_nt_node(dl, wsoft, &NtNode::new(DENSE),
                                      nrows, vocab, h)
                }
            };
            dflat[r0 * h..r1 * h].copy_from_slice(&seg);
        }

        // BPTT over the cached cells. The one dynamic mask the LSTM
        // carries is plan-known rather than scanned: at t == 0 every
        // layer's previous hidden state is the architectural zero init,
        // so the recurrent weight gradient accumulates nothing there —
        // a backend honoring the mask skips the whole `dwh` walk for
        // that timestep, bitwise exactly (every coefficient is zero).
        let warm = if kern.dyn_backward() {
            Some(DynMask::zero_state(h))
        } else {
            None
        };
        let mut dh_next = vec![vec![0f32; batch * h]; layers];
        let mut dc_next = vec![vec![0f32; batch * h]; layers];
        for t in (0..seq).rev() {
            let mut dh_cur: Vec<Vec<f32>> = dh_next.clone();
            // Top-layer output fed the softmax at this timestep.
            for b in 0..batch {
                let src = &dflat[(t * batch + b) * h
                                 ..(t * batch + b + 1) * h];
                let dst = &mut dh_cur[layers - 1][b * h..(b + 1) * h];
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
            for l in (0..layers).rev() {
                let cache = &fwd.caches[t * layers + l];
                let (wx, wh, _bg) = cells[l];
                let dh = &dh_cur[l];
                let dc_in = &dc_next[l];
                let n = batch * h;
                let mut da = vec![0f32; batch * 4 * h];
                let mut dc_prev = vec![0f32; n];
                for b in 0..batch {
                    for j in 0..h {
                        let k = b * h + j;
                        let (i_, f_, g_, o_) = (cache.gi[k], cache.gf[k],
                                                cache.gg[k], cache.go[k]);
                        let tc = cache.tanh_c[k];
                        let do_ = dh[k] * tc;
                        let dc = dc_in[k] + dh[k] * o_ * (1.0 - tc * tc);
                        let df = dc * cache.c_prev[k];
                        let di = dc * g_;
                        let dg = dc * i_;
                        dc_prev[k] = dc * f_;
                        let base = b * 4 * h;
                        da[base + j] = di * i_ * (1.0 - i_);
                        da[base + h + j] = df * f_ * (1.0 - f_);
                        da[base + 2 * h + j] = dg * (1.0 - g_ * g_);
                        da[base + 3 * h + j] = do_ * o_ * (1.0 - o_);
                    }
                }
                colsum_acc(&da, 4 * h, &mut dbg[l]);
                let dwh_node = TnNode::new(DENSE, DENSE)
                    .with_dyn(if t == 0 { warm.as_ref() } else { None });
                kern.gemm_tn_acc_node(&cache.h_prev, &da, &dwh_node,
                                      batch, h, 4 * h, &mut dwh[l]);
                dh_next[l] = kern.gemm_nt_node(&da, wh,
                                               &NtNode::new(DENSE), batch,
                                               4 * h, h);
                dc_next[l] = dc_prev;

                // Input path.
                if l == 0 {
                    kern.gemm_tn_acc_node(&cache.minp, &da,
                                          &TnNode::new(DENSE, DENSE),
                                          batch, h, 4 * h, &mut dwx[0]);
                    let de = kern.gemm_nt_node(&da, wx,
                                               &NtNode::new(DENSE), batch,
                                               4 * h, h);
                    for b in 0..batch {
                        let tok = x[b * seq + t] as usize;
                        let dst = &mut demb[tok * h..(tok + 1) * h];
                        let src = &de[b * h..(b + 1) * h];
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                } else {
                    let ri = run_of[l - 1][t];
                    let pw = &fwd.prepped_wx[l][ri];
                    match &plan.runs(l - 1)[ri].feed {
                        f @ Feed::Act { .. } => {
                            let sk = f.skip();
                            kern.gemm_tn_acc_node(
                                &cache.minp, &da,
                                &TnNode::new(sk, DENSE), batch, h, 4 * h,
                                &mut dwx[l]);
                            let dmi = kern.gemm_nt_node(
                                &da, wx, &NtNode::new(sk).with_pw(pw),
                                batch, 4 * h, h);
                            let dinp = f.mask_act(&dmi, batch, h);
                            for (d, &s) in
                                dh_cur[l - 1].iter_mut().zip(&dinp)
                            {
                                *d += s;
                            }
                        }
                        Feed::Weight { s, skip } => {
                            let dgs = scale_vec(&da, *s);
                            kern.gemm_tn_acc_node(
                                &cache.minp, &dgs,
                                &TnNode::new(*skip, DENSE), batch, h,
                                4 * h, &mut dwx[l]);
                            let dinp = kern.gemm_nt_node(
                                &dgs, wx,
                                &NtNode::new(*skip).with_pw(pw), batch,
                                4 * h, h);
                            for (d, &s2) in
                                dh_cur[l - 1].iter_mut().zip(&dinp)
                            {
                                *d += s2;
                            }
                        }
                        Feed::Plain => {
                            kern.gemm_tn_acc_node(
                                &cache.minp, &da,
                                &TnNode::new(DENSE, DENSE), batch, h,
                                4 * h, &mut dwx[l]);
                            let dinp = kern.gemm_nt_node(
                                &da, wx, &NtNode::new(DENSE), batch,
                                4 * h, h);
                            for (d, &s2) in
                                dh_cur[l - 1].iter_mut().zip(&dinp)
                            {
                                *d += s2;
                            }
                        }
                    }
                }
            }
        }

        // Assemble grads in param order: emb, (wx, wh, bg) per layer,
        // wsoft, bsoft.
        let mut grads = Vec::with_capacity(3 * layers + 3);
        grads.push(demb);
        for l in 0..layers {
            grads.push(std::mem::take(&mut dwx[l]));
            grads.push(std::mem::take(&mut dwh[l]));
            grads.push(std::mem::take(&mut dbg[l]));
        }
        grads.push(dwsoft);
        grads.push(dbsoft);
        Ok(grads)
    }
}

/// Per-(t, l) forward cache for BPTT. All buffers are [batch, h] except
/// `minp` (the matrix actually multiplied into `wx`, i.e. masked input for
/// act-mask sites, raw input otherwise).
struct CellCache {
    minp: Vec<f32>,
    gi: Vec<f32>,
    gf: Vec<f32>,
    gg: Vec<f32>,
    go: Vec<f32>,
    c_prev: Vec<f32>,
    tanh_c: Vec<f32>,
    h_prev: Vec<f32>,
}

/// Forward-pass artifacts the backward pass consumes.
struct LstmFwd {
    caches: Vec<CellCache>,
    /// Top-layer outputs [seq*batch, h], row t*batch + b.
    flat: Vec<f32>,
    /// Masked+scaled flat (act-mask softmax sites only). Each window
    /// run's rows are masked with that run's pattern.
    mflat: Option<Vec<f32>>,
    /// Per-layer, per-run prepared wx: prepped once per (site, window)
    /// and reused across every timestep in the window, for forward and
    /// backward. Empty per-layer vec for layer 0 / feed-less runs.
    prepped_wx: Vec<Vec<PreppedWeight>>,
    /// Per-run prepared wsoft (same convention; empty when feeds are
    /// absent, i.e. eval).
    prepped_wsoft: Vec<PreppedWeight>,
    /// [seq*batch, vocab] including bsoft.
    logits: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_xent_matches_hand_computation() {
        // Two rows, 3 classes; uniform logits -> mean loss = ln 3.
        let logits = [0f32; 6];
        let (loss_sum, correct, grad) =
            softmax_xent_grad(&logits, &[0, 2], 2, 3, 2).unwrap();
        let loss = (loss_sum / 2.0) as f32;
        assert!((loss - 3f32.ln()).abs() < 1e-6);
        // argmax of a uniform row is index 0 (first max).
        assert_eq!(correct, 1.0);
        // grad rows sum to zero; target entry is (1/3 - 1)/denom.
        let s: f32 = grad[..3].iter().sum();
        assert!(s.abs() < 1e-6);
        assert!((grad[0] - (1.0 / 3.0 - 1.0) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_xent_shards_sum_to_the_full_batch() {
        // Two single-row shards with the global denom reproduce the
        // full 2-row call bitwise: per-row work is independent and the
        // 1/denom scale is identical, so sharding only splits the sums.
        let logits = [0.3f32, -1.2, 0.7, 2.0, 0.1, -0.4];
        let (full_sum, full_c, full_g) =
            softmax_xent_grad(&logits, &[0, 2], 2, 3, 2).unwrap();
        let (s0, c0, g0) =
            softmax_xent_grad(&logits[..3], &[0], 1, 3, 2).unwrap();
        let (s1, c1, g1) =
            softmax_xent_grad(&logits[3..], &[2], 1, 3, 2).unwrap();
        assert_eq!((s0 + s1).to_bits(), full_sum.to_bits());
        assert_eq!(c0 + c1, full_c);
        let stitched: Vec<f32> =
            g0.iter().chain(&g1).copied().collect();
        assert_eq!(stitched, full_g);
    }

    #[test]
    fn softmax_xent_rejects_bad_labels() {
        assert!(softmax_xent_grad(&[0f32; 3], &[3], 1, 3, 1).is_err());
        assert!(softmax_xent_grad(&[0f32; 3], &[-1], 1, 3, 1).is_err());
    }

    #[test]
    fn slice_rows_cuts_the_leading_dim() {
        let t = HostTensor::f32(&[4, 2],
                                (0..8).map(|v| v as f32).collect());
        let s = slice_rows(&t, 1, 2).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[2.0, 3.0, 4.0, 5.0]);
        let y = HostTensor::i32(&[3], vec![7, 8, 9]);
        let sy = slice_rows(&y, 2, 1).unwrap();
        assert_eq!(sy.as_i32().unwrap(), &[9]);
        assert!(slice_rows(&y, 2, 2).is_err());
        assert!(slice_rows(&HostTensor::scalar_f32(1.0), 0, 1).is_err());
    }
}
