//! Shared experiment drivers used by `rust/benches/*` — each paper
//! table/figure bench composes these.
//!
//! All trainers in one `BenchCtx` dispatch through a single shared
//! [`ExecutorCache`], so a baseline-vs-variant sweep (the paper's headline
//! measurement) compiles each artifact — including the shared `_conv` and
//! `_eval` graphs — exactly once across every configuration.
//!
//! Environment knobs (all benches):
//! * `AD_BENCH_STEPS`       timed steps per configuration (default 6)
//! * `AD_BENCH_TRAIN_STEPS` convergence steps for accuracy/perplexity
//!                          columns (default 0 = timing-only; the paper's
//!                          accuracy deltas need hundreds of steps)
//! * `AD_BENCH_PIPELINE`    set to 1 to run the convergence steps through
//!                          the double-buffered assembly path (timed steps
//!                          stay sequential so per-step numbers remain
//!                          comparable to older runs)
//! * `AD_BENCH_FULL`        set to 1 to use paper-scale LSTM (H=1536)
//! * `AD_BACKEND`           pjrt|reference|sparse (host backends interpret
//!                          — timing columns then measure the
//!                          interpreter, not the paper's hardware claim)

use anyhow::Result;

use crate::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer, Schedule,
                         Variant};
use crate::data::{Corpus, MnistSyn};

pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub struct BenchCtx {
    pub cache: ExecutorCache,
    pub timed_steps: usize,
    pub train_steps: usize,
    pub pipeline: bool,
}

impl BenchCtx {
    pub fn new() -> Result<BenchCtx> {
        let manifest = crate::manifest_or_builtin()?;
        Ok(BenchCtx {
            cache: ExecutorCache::from_env(manifest)?,
            timed_steps: env_usize("AD_BENCH_STEPS", 6),
            train_steps: env_usize("AD_BENCH_TRAIN_STEPS", 0),
            pipeline: env_usize("AD_BENCH_PIPELINE", 0) == 1,
        })
    }
}

/// Timing + (optional) accuracy for one MLP configuration.
/// Returns (steady secs/step, Option<test accuracy>).
pub fn run_mlp(ctx: &BenchCtx, tag: &str, variant: Variant, rates: &[f64],
               shared_dp: bool, data: &MnistSyn, test: &MnistSyn,
               seed: u64) -> Result<(f64, Option<f64>)> {
    let schedule = Schedule::new(variant, rates, &[1, 2, 4, 8], shared_dp)?;
    let mut tr = MlpTrainer::new(&ctx.cache, tag, schedule, data.n, 0.01,
                                 seed)?;
    tr.warmup()?;
    // Warmup steps (cache effects) then timed steps.
    for _ in 0..2 {
        tr.step(data)?;
    }
    for _ in 0..ctx.timed_steps {
        tr.step(data)?;
    }
    let per_step = tr.metrics.steady_mean_step_s(2);
    let acc = if ctx.train_steps > 0 {
        if ctx.pipeline {
            tr.train_pipelined(data, ctx.train_steps)?;
        } else {
            tr.train(data, ctx.train_steps)?;
        }
        Some(tr.evaluate(test)?.1)
    } else {
        None
    };
    Ok((per_step, acc))
}

/// Timing + (optional) perplexity/accuracy for one LSTM configuration.
/// Returns (steady secs/step, Option<(ppl, token accuracy)>).
pub fn run_lstm(ctx: &BenchCtx, tag: &str, variant: Variant, rate: f64,
                sites: usize, corpus: &Corpus, lr: f32, seed: u64)
                -> Result<(f64, Option<(f64, f64)>)> {
    run_lstm_support(ctx, tag, variant, rate, sites, corpus, lr, seed,
                     &[1, 2, 4, 8])
}

/// Like `run_lstm` with an explicit divisor support set (the fig6b batch
/// sweep's artifact set only covers dp in {1, 2, 4}).
#[allow(clippy::too_many_arguments)]
pub fn run_lstm_support(ctx: &BenchCtx, tag: &str, variant: Variant,
                        rate: f64, sites: usize, corpus: &Corpus, lr: f32,
                        seed: u64, support: &[usize])
                        -> Result<(f64, Option<(f64, f64)>)> {
    let rates = vec![rate; sites];
    let schedule = Schedule::new(variant, &rates, support,
                                 variant != Variant::Conv)?;
    let mut tr = LstmTrainer::new(&ctx.cache, tag, schedule, &corpus.train,
                                  lr, seed)?;
    tr.warmup()?;
    for _ in 0..2 {
        tr.step()?;
    }
    for _ in 0..ctx.timed_steps {
        tr.step()?;
    }
    let per_step = tr.metrics.steady_mean_step_s(2);
    let quality = if ctx.train_steps > 0 {
        if ctx.pipeline {
            tr.train_pipelined(&(), ctx.train_steps)?;
        } else {
            tr.train(ctx.train_steps)?;
        }
        let (_, ppl, acc) = tr.evaluate(&corpus.valid)?;
        Some((ppl, acc))
    } else {
        None
    };
    Ok((per_step, quality))
}

/// Trace a training curve: (step, train loss) points every `every` steps.
pub fn trace_lstm_curve(ctx: &BenchCtx, tag: &str, variant: Variant,
                        rate: f64, sites: usize, corpus: &Corpus,
                        steps: usize, every: usize, seed: u64)
                        -> Result<Vec<(u64, f64, f64)>> {
    let rates = vec![rate; sites];
    let schedule = Schedule::new(variant, &rates, &[1, 2, 4, 8],
                                 variant != Variant::Conv)?;
    // lr note: the paper's Caffe "base lr 1" is plain-SGD convention; with
    // momentum 0.9 the equivalent stable setting is ~0.1 (RDP's shared
    // per-batch pattern raises gradient variance, so lr 1.0 diverges).
    let mut tr = LstmTrainer::new(&ctx.cache, tag, schedule, &corpus.train,
                                  0.1, seed)?;
    tr.warmup()?;
    let mut out = Vec::new();
    for s in 0..steps {
        let (loss, acc) = tr.step()?;
        if (s + 1) % every == 0 {
            out.push(((s + 1) as u64, loss, acc));
        }
    }
    Ok(out)
}

pub fn fmt_opt_pct(v: Option<f64>) -> String {
    v.map(|a| format!("{:.2}%", a * 100.0)).unwrap_or_else(|| "-".into())
}

pub fn fmt_opt_ppl(v: Option<(f64, f64)>) -> String {
    v.map(|(p, _)| format!("{p:.1}")).unwrap_or_else(|| "-".into())
}
