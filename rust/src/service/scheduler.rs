//! Multi-job scheduler: run a fleet of MLP/LSTM training sessions
//! concurrently over one shared `ExecutorCache`, with fair backend-slot
//! accounting, periodic checkpoint ticks, and a crash-isolation boundary.
//!
//! Design:
//! * **One runner thread per job, gated by a FIFO slot queue.** Sessions
//!   are pinned to their thread for life — backend-resident `Value`s
//!   never cross threads (the PJRT literal form is thread-affine). The
//!   [`SlotGate`] is the job queue: `slots` tokens, strict FIFO handoff,
//!   so N jobs over S slots interleave round-robin with a quantum of
//!   `tick_steps` steps. Compilation (warmup) and evaluation count as
//!   slot work too — at most `slots` sessions touch the backend at any
//!   instant, which is what keeps a fleet from oversubscribing the
//!   sparse worker pool.
//! * **Crash isolation.** Every slice of backend work runs under
//!   `catch_unwind`: a panicking job (bad artifact, kernel bug) is
//!   quarantined — marked failed, logged at warn level, its slot
//!   released — and every sibling proceeds. This extends the PR 3
//!   poison-recovery work: the shared cache already survives a
//!   panicked compile; now the fleet survives a panicked session.
//! * **Checkpoint ticks.** With a `ckpt_dir`, each job writes
//!   `<name>.ckpt` every `checkpoint_every` steps (atomic rename) and on
//!   completion; a rerun of the same manifest resumes every job from its
//!   last checkpoint (`Trainer::resume_from`), so preemption costs at
//!   most one tick of work.
//!
//! Per-job trajectories are deterministic regardless of fleet
//! interleaving: each session owns its RNG/batcher, and both hermetic
//! backends are bit-stable under concurrency (disjoint state; the sparse
//! pool's determinism contract is thread-count independent).

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::bench::report::BenchReport;
use crate::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer, Schedule,
                         Variant};
use crate::data::{Corpus, MnistSyn, IMG_PIXELS};
use crate::obs::registry;
use crate::runtime::ArchMeta;
use crate::service::jobs::{JobSpec, ModelKind, ServiceConfig};
use crate::util::json::Json;
use crate::util::Timer;
use crate::{info, warn_};

// ---------------------------------------------------------------------------
// Slot gate

/// FIFO semaphore: `slots` tokens, strictly ordered handoff. The wait
/// queue doubles as the service's job queue — a session re-acquiring
/// after a tick goes to the back, behind every sibling already waiting.
pub struct SlotGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    available: usize,
    queue: VecDeque<u64>,
    next_ticket: u64,
    in_use: usize,
    peak: usize,
}

/// RAII slot hold; releases (and wakes the queue head) on drop — also on
/// the unwind path, so a panicking job can never leak its slot.
pub struct SlotHold<'a> {
    gate: &'a SlotGate,
    /// Started at acquisition; drop observes it into `GATE_HOLD_S`.
    held: Timer,
}

impl SlotGate {
    pub fn new(slots: usize) -> SlotGate {
        SlotGate {
            state: Mutex::new(GateState {
                available: slots.max(1),
                queue: VecDeque::new(),
                next_ticket: 0,
                in_use: 0,
                peak: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until this caller reaches the head of the queue and a slot
    /// is free.
    pub fn acquire(&self) -> SlotHold<'_> {
        let waited = Timer::start();
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let ticket = g.next_ticket;
        g.next_ticket += 1;
        g.queue.push_back(ticket);
        registry::GATE_QUEUE_DEPTH.set(g.queue.len() as i64);
        while !(g.available > 0 && g.queue.front() == Some(&ticket)) {
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
        g.queue.pop_front();
        g.available -= 1;
        g.in_use += 1;
        g.peak = g.peak.max(g.in_use);
        registry::GATE_QUEUE_DEPTH.set(g.queue.len() as i64);
        registry::GATE_WAIT_S.observe(waited.elapsed_s());
        // With >1 slot the *new* head may have woken on the same release
        // burst we did, observed itself mid-queue, and gone back to
        // sleep — if a slot is still free, wake the queue again or it
        // idles until the next release (missed-wakeup hazard).
        let wake_next = g.available > 0 && !g.queue.is_empty();
        drop(g);
        if wake_next {
            self.cv.notify_all();
        }
        SlotHold { gate: self, held: Timer::start() }
    }

    /// Take a slot only if one is free *and* nobody is queued (jumping
    /// the FIFO would starve waiters). Non-blocking; used by
    /// [`SlotGate::acquire_n`] to account a sharded job's extra gradient
    /// workers without risking deadlock.
    pub fn try_acquire(&self) -> Option<SlotHold<'_>> {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if g.available == 0 || !g.queue.is_empty() {
            return None;
        }
        g.available -= 1;
        g.in_use += 1;
        g.peak = g.peak.max(g.in_use);
        Some(SlotHold { gate: self, held: Timer::start() })
    }

    /// Acquire slots for a job that runs `n` threads: one *blocking*
    /// acquire (the job's turn in the FIFO) plus up to `n - 1`
    /// best-effort extras. Deliberately not all-or-nothing — two
    /// sharded jobs each blocking for N slots on an N-slot gate would
    /// deadlock; under contention a sharded job simply runs with fewer
    /// accounted slots (its threads still run; the gate models backend
    /// occupancy, not a hard thread budget).
    pub fn acquire_n(&self, n: usize) -> Vec<SlotHold<'_>> {
        let mut holds = vec![self.acquire()];
        while holds.len() < n {
            match self.try_acquire() {
                Some(h) => holds.push(h),
                None => break,
            }
        }
        holds
    }

    /// Highest concurrent-hold count observed (fairness accounting).
    pub fn peak(&self) -> usize {
        self.state.lock().unwrap_or_else(|p| p.into_inner()).peak
    }

    /// Instantaneous (holds in use, callers queued) — heartbeat fodder.
    pub fn depth(&self) -> (usize, usize) {
        let g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        (g.in_use, g.queue.len())
    }
}

impl Drop for SlotHold<'_> {
    fn drop(&mut self) {
        registry::GATE_HOLD_S.observe(self.held.elapsed_s());
        let mut g = self.gate.state.lock()
            .unwrap_or_else(|p| p.into_inner());
        g.available += 1;
        g.in_use -= 1;
        drop(g);
        self.gate.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Sessions

/// One live training session: a trainer plus its (deterministically
/// regenerated) dataset. Pinned to its runner thread.
enum Session {
    Mlp {
        tr: MlpTrainer,
        train: MnistSyn,
        test: MnistSyn,
    },
    Lstm {
        tr: LstmTrainer,
        valid: Vec<i32>,
    },
}

impl Session {
    /// Build (and optionally resume) a session. Runs under a slot: setup
    /// includes weight init, warmup compilation and checkpoint ingest.
    fn build(cache: &ExecutorCache, spec: &JobSpec, ckpt: Option<&Path>)
             -> Result<Session> {
        let mut session = match spec.model {
            ModelKind::Mlp => {
                let conv = cache.manifest()
                    .get(&format!("{}_conv", spec.tag))?;
                let (n_in, sites) = match &conv.arch {
                    ArchMeta::Mlp { n_in, hidden, .. } =>
                        (*n_in, hidden.len()),
                    _ => bail!("job '{}': {} is not an MLP tag",
                               spec.name, spec.tag),
                };
                if n_in != IMG_PIXELS {
                    bail!("job '{}': tag {} takes {}-wide inputs but the \
                           service feeds {IMG_PIXELS}-pixel synthetic \
                           MNIST", spec.name, spec.tag, n_in);
                }
                let schedule = Schedule::new(
                    spec.variant, &expand_rates(&spec.rates, sites),
                    &spec.support, spec.shared_dp)?;
                let (train, test) = MnistSyn::train_test(
                    spec.n_train, spec.n_test, spec.seed);
                let mut tr = MlpTrainer::new(cache, &spec.tag, schedule,
                                             spec.n_train,
                                             spec.lr as f32, spec.seed)?;
                tr.lr_decay = spec.lr_decay as f32;
                tr.decay_after = spec.decay_after;
                Session::Mlp { tr, train, test }
            }
            ModelKind::Lstm => {
                let conv = cache.manifest()
                    .get(&format!("{}_conv", spec.tag))?;
                let (sites, vocab) = match &conv.arch {
                    ArchMeta::Lstm { layers, vocab, .. } =>
                        (*layers, *vocab),
                    _ => bail!("job '{}': {} is not an LSTM tag",
                               spec.name, spec.tag),
                };
                // LSTM artifact sets cover equal-dp combos only.
                let shared = spec.variant != Variant::Conv;
                let schedule = Schedule::new(
                    spec.variant, &expand_rates(&spec.rates, sites),
                    &spec.support, shared)?;
                let corpus = Corpus::generate(
                    vocab, spec.tokens, spec.tokens / 10,
                    spec.tokens / 10, spec.seed);
                let mut tr = LstmTrainer::new(cache, &spec.tag, schedule,
                                              &corpus.train,
                                              spec.lr as f32, spec.seed)?;
                tr.lr_decay = spec.lr_decay as f32;
                tr.decay_after = spec.decay_after;
                Session::Lstm { tr, valid: corpus.valid }
            }
        };
        if let Some(path) = ckpt {
            if path.exists() {
                session.resume_from(path)?;
                info!("job resumed from {} at step {}", path.display(),
                      session.steps_done());
            }
        }
        session.warmup()?;
        Ok(session)
    }

    fn resume_from(&mut self, path: &Path) -> Result<()> {
        match self {
            Session::Mlp { tr, .. } => tr.resume_from(path),
            Session::Lstm { tr, .. } => tr.resume_from(path),
        }
    }

    fn warmup(&mut self) -> Result<()> {
        match self {
            Session::Mlp { tr, .. } => tr.warmup(),
            Session::Lstm { tr, .. } => tr.warmup(),
        }
    }

    fn steps_done(&self) -> usize {
        match self {
            Session::Mlp { tr, .. } => tr.state.step as usize,
            Session::Lstm { tr, .. } => tr.state.step as usize,
        }
    }

    /// Run `n` steps: the plain sequential path when `workers == 0`,
    /// the data-parallel sharded path otherwise. The split is a config
    /// fork, not a trajectory fork within each mode — but the two modes
    /// are NOT bit-identical to each other (different gradient summation
    /// order), so a job keeps whichever mode it declared.
    fn run(&mut self, n: usize, workers: usize) -> Result<()> {
        match self {
            Session::Mlp { tr, train, .. } => {
                if workers >= 1 {
                    tr.sharded(workers)?.train_with(train, n)?;
                } else {
                    tr.train_with(train, n)?;
                }
            }
            Session::Lstm { tr, .. } => {
                if workers >= 1 {
                    tr.sharded(workers)?.train_with(&(), n)?;
                } else {
                    tr.train(n)?;
                }
            }
        }
        Ok(())
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        match self {
            Session::Mlp { tr, .. } => tr.save_checkpoint(path),
            Session::Lstm { tr, .. } => tr.save_checkpoint(path),
        }
    }

    /// (eval loss, eval accuracy) through the dropout-free eval graph.
    fn evaluate(&mut self) -> Result<(f64, f64)> {
        match self {
            Session::Mlp { tr, test, .. } => tr.evaluate_with(test),
            Session::Lstm { tr, valid } => {
                tr.evaluate_with(valid.as_slice())
            }
        }
    }

    fn curve(&self) -> Vec<(u64, f64, f64)> {
        let m = match self {
            Session::Mlp { tr, .. } => &tr.metrics,
            Session::Lstm { tr, .. } => &tr.metrics,
        };
        m.curve.iter().map(|p| (p.step, p.loss, p.acc)).collect()
    }

    fn last_loss(&self) -> f64 {
        match self {
            Session::Mlp { tr, .. } => tr.metrics.last_loss(),
            Session::Lstm { tr, .. } => tr.metrics.last_loss(),
        }
    }

    fn median_step_s(&self) -> f64 {
        match self {
            Session::Mlp { tr, .. } => tr.metrics.median_step_s(),
            Session::Lstm { tr, .. } => tr.metrics.median_step_s(),
        }
    }

    fn dispatched(&self) -> usize {
        match self {
            Session::Mlp { tr, .. } => tr.metrics.dispatched.len(),
            Session::Lstm { tr, .. } => tr.metrics.dispatched.len(),
        }
    }
}

fn expand_rates(rates: &[f64], sites: usize) -> Vec<f64> {
    if rates.len() == 1 && sites > 1 {
        vec![rates[0]; sites]
    } else {
        rates.to_vec()
    }
}

// ---------------------------------------------------------------------------
// Outcomes

/// Terminal state of one job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    Done,
    /// Quarantined: the reason string starts with "panic:" when the job
    /// died by panic rather than by error.
    Failed(String),
}

#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub status: JobStatus,
    /// Absolute step count reached (includes pre-resume steps).
    pub steps_done: usize,
    /// Step the session resumed from, when it started from a checkpoint.
    pub resumed_at: Option<usize>,
    /// Slot holds this job consumed (fairness accounting).
    pub ticks: usize,
    pub final_loss: f64,
    pub eval: Option<(f64, f64)>,
    pub wall_s: f64,
    pub report_path: Option<PathBuf>,
}

impl JobOutcome {
    pub fn ok(&self) -> bool {
        self.status == JobStatus::Done
    }
}

/// Fleet result: per-job outcomes (manifest order) plus the fairness
/// accounting the slot gate observed.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub outcomes: Vec<JobOutcome>,
    /// Peak concurrent slot holds — never exceeds the configured slots.
    pub peak_slots: usize,
}

impl ServiceReport {
    pub fn all_ok(&self) -> bool {
        self.outcomes.iter().all(JobOutcome::ok)
    }
}

// ---------------------------------------------------------------------------
// The fleet loop

/// Run every job to completion (or quarantine) and return the outcomes
/// in `specs` order. See the module docs for the scheduling model.
pub fn run_jobs(cache: &ExecutorCache, specs: &[JobSpec],
                cfg: &ServiceConfig) -> Result<ServiceReport> {
    // PJRT: serialize all backend access through a single slot. The C
    // API is thread-safe, but the offline `xla` crate's wrapper types
    // have not been audited for concurrent use from multiple sessions
    // (see the Send/Sync notes in runtime/engine.rs); one slot makes
    // every backend touch happen-before the next via the gate mutex.
    let slots = if cache.backend().name() == "pjrt" && cfg.slots > 1 {
        warn_!("service: PJRT backend — clamping {} slots to 1 \
                (serialized backend access)", cfg.slots);
        1
    } else {
        cfg.slots
    };
    run_jobs_with_gate(cache, specs, cfg, Arc::new(SlotGate::new(slots)))
}

/// [`run_jobs`] over a caller-provided gate, so training jobs can share
/// backend slots FIFO with other fleet users (the inference servers from
/// `service::infer`). The caller owns the slot count — including the
/// PJRT single-slot rule when it applies.
pub fn run_jobs_with_gate(cache: &ExecutorCache, specs: &[JobSpec],
                          cfg: &ServiceConfig, gate: Arc<SlotGate>)
                          -> Result<ServiceReport> {
    for s in specs {
        s.validate()?;
        // Fail the whole manifest up front on sizing that would only
        // surface as a mid-fleet setup quarantine (or a batcher panic).
        s.validate_sizing(cache.manifest())?;
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let stop = AtomicBool::new(false);
    let done_ct = AtomicUsize::new(0);
    let failed_ct = AtomicUsize::new(0);
    // Per-job worker occupancy (gradient threads live this instant),
    // maintained by the runners and read by the heartbeat.
    let occupancy: Mutex<BTreeMap<String, usize>> =
        Mutex::new(BTreeMap::new());
    let outcomes: Vec<JobOutcome> = std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|spec| {
                let gate = &gate;
                let occupancy = &occupancy;
                let (done_ct, failed_ct) = (&done_ct, &failed_ct);
                scope.spawn(move || {
                    let o = run_one(cache, spec, cfg, gate, occupancy);
                    let ct = if o.ok() { done_ct } else { failed_ct };
                    ct.fetch_add(1, Ordering::Relaxed);
                    o
                })
            })
            .collect();
        // Periodic one-line fleet status while runners work; stops (and
        // joins, via the scope) once every outcome is collected.
        scope.spawn(|| heartbeat_loop(&stop, &done_ct, &failed_ct,
                                      specs.len(), &gate, &occupancy));
        let outs = handles
            .into_iter()
            .zip(specs)
            .map(|(h, spec)| h.join().unwrap_or_else(|_| JobOutcome {
                // Unreachable in practice: run_one contains every panic.
                name: spec.name.clone(),
                status: JobStatus::Failed("runner thread died".into()),
                steps_done: 0,
                resumed_at: None,
                ticks: 0,
                final_loss: f64::NAN,
                eval: None,
                wall_s: 0.0,
                report_path: None,
            }))
            .collect();
        stop.store(true, Ordering::Relaxed);
        outs
    });
    Ok(ServiceReport { outcomes, peak_slots: gate.peak() })
}

fn ckpt_path(cfg: &ServiceConfig, spec: &JobSpec) -> Option<PathBuf> {
    cfg.ckpt_dir.as_ref().map(|d| d.join(format!("{}.ckpt", spec.name)))
}

/// Heartbeat cadence — long enough that a healthy fleet log is mostly
/// job progress, short enough that a wedged gate is visible in seconds.
const HEARTBEAT_EVERY_S: f64 = 5.0;

/// Emit a one-line fleet status every [`HEARTBEAT_EVERY_S`] until `stop`:
/// jobs running / queued-at-gate / done / quarantined, slot occupancy,
/// per-job worker occupancy (sharded jobs currently stepping), and the
/// dispatch rate (steps/s fleet-wide, from the process registry) since
/// the previous beat. Pure observer — reads shared counters only.
fn heartbeat_loop(stop: &AtomicBool, done: &AtomicUsize,
                  failed: &AtomicUsize, total: usize, gate: &SlotGate,
                  occupancy: &Mutex<BTreeMap<String, usize>>) {
    let mut last_dispatch = registry::DISPATCH_TOTAL.total();
    let mut t = Timer::start();
    loop {
        // Sleep in short slices so shutdown never waits a full beat.
        while t.elapsed_s() < HEARTBEAT_EVERY_S {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        let dt = t.elapsed_s();
        t.restart();
        let d = done.load(Ordering::Relaxed);
        let f = failed.load(Ordering::Relaxed);
        let dispatch = registry::DISPATCH_TOTAL.total();
        let qps = (dispatch - last_dispatch) as f64 / dt.max(1e-9);
        last_dispatch = dispatch;
        let (in_use, queued) = gate.depth();
        let workers: Vec<String> = occupancy
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .iter()
            .map(|(job, w)| format!("{job}={w}"))
            .collect();
        let workers = if workers.is_empty() {
            String::new()
        } else {
            format!(", workers: {}", workers.join(" "))
        };
        info!("fleet: {} running, {queued} queued, {d}/{total} done, \
               {f} quarantined, {in_use} slot(s) busy, {qps:.1} \
               steps/s{workers}",
              total - d - f);
    }
}

/// Drive one job to its terminal state. Never panics: backend work is
/// wrapped in `catch_unwind`, and a panic quarantines this job only.
fn run_one(cache: &ExecutorCache, spec: &JobSpec, cfg: &ServiceConfig,
           gate: &SlotGate, occupancy: &Mutex<BTreeMap<String, usize>>)
           -> JobOutcome {
    // Every log line from this runner thread carries the job name; the
    // prefix is thread-local and this thread is pinned to this job.
    crate::util::log::set_job_prefix(&spec.name);
    let timer = Timer::start();
    let mut out = JobOutcome {
        name: spec.name.clone(),
        status: JobStatus::Done,
        steps_done: 0,
        resumed_at: None,
        ticks: 0,
        final_loss: f64::NAN,
        eval: None,
        wall_s: 0.0,
        report_path: None,
    };
    let ckpt = ckpt_path(cfg, spec);
    let fail = |mut out: JobOutcome, why: String, timer: &Timer| {
        warn_!("job '{}' quarantined: {why}", spec.name);
        out.status = JobStatus::Failed(why);
        out.wall_s = timer.elapsed_s();
        out
    };

    // -- setup (under a slot: init + warmup compile are backend work) --
    let hold = gate.acquire();
    out.ticks += 1;
    let built = catch_unwind(AssertUnwindSafe(
        || Session::build(cache, spec, ckpt.as_deref())));
    drop(hold);
    let mut session = match built {
        Ok(Ok(s)) => s,
        Ok(Err(e)) => return fail(out, format!("setup: {e:#}"), &timer),
        Err(p) => return fail(out, format!("panic: setup: {}",
                                           panic_msg(&p)), &timer),
    };
    if session.steps_done() > 0 {
        out.resumed_at = Some(session.steps_done());
        out.steps_done = session.steps_done();
    }

    // -- train in fairness quanta --
    let mut last_ckpt_at = session.steps_done();
    while session.steps_done() < spec.steps {
        let n = cfg.tick_steps.min(spec.steps - session.steps_done());
        // A sharded job runs `workers` gradient threads per step: claim
        // one slot FIFO-fairly plus best-effort extras so the gate's
        // occupancy accounting sees the real thread pressure.
        let holds = gate.acquire_n(spec.workers.max(1));
        out.ticks += holds.len();
        occupancy.lock().unwrap_or_else(|p| p.into_inner())
            .insert(spec.name.clone(), spec.workers.max(1));
        let r = catch_unwind(AssertUnwindSafe(
            || session.run(n, spec.workers)));
        occupancy.lock().unwrap_or_else(|p| p.into_inner())
            .remove(&spec.name);
        drop(holds);
        match r {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                return fail(out, format!("step {}: {e:#}",
                                         session.steps_done() + 1),
                            &timer);
            }
            Err(p) => {
                return fail(out, format!("panic: step {}: {}",
                                         session.steps_done() + 1,
                                         panic_msg(&p)), &timer);
            }
        }
        out.steps_done = session.steps_done();
        out.final_loss = session.last_loss();
        if let Some(path) = &ckpt {
            let due = cfg.checkpoint_every > 0
                && session.steps_done() - last_ckpt_at
                    >= cfg.checkpoint_every;
            if due {
                match session.save_checkpoint(path) {
                    Ok(()) => last_ckpt_at = session.steps_done(),
                    // Non-fatal: training state is intact; the next tick
                    // retries the write.
                    Err(e) => warn_!("job '{}': checkpoint write failed \
                                      ({e:#}); continuing", spec.name),
                }
            }
        }
    }

    // -- final checkpoint + evaluation + report --
    if let Some(path) = &ckpt {
        if let Err(e) = session.save_checkpoint(path) {
            warn_!("job '{}': final checkpoint failed ({e:#})", spec.name);
        }
    }
    let hold = gate.acquire();
    out.ticks += 1;
    let ev = catch_unwind(AssertUnwindSafe(|| session.evaluate()));
    drop(hold);
    match ev {
        Ok(Ok(pair)) => out.eval = Some(pair),
        Ok(Err(e)) => return fail(out, format!("eval: {e:#}"), &timer),
        Err(p) => return fail(out, format!("panic: eval: {}",
                                           panic_msg(&p)), &timer),
    }
    out.final_loss = session.last_loss();
    // A rerun that resumed an already-complete checkpoint trains zero
    // new steps: `Trainer::restore` starts metrics empty, so the curve
    // has no points and `last_loss()` is NaN. The eval above still ran —
    // its loss is the honest final loss for the restored parameters.
    if !out.final_loss.is_finite() {
        if let Some((el, _)) = out.eval {
            out.final_loss = el;
        }
    }
    out.wall_s = timer.elapsed_s();
    if let Some(dir) = &cfg.out_dir {
        let path = dir.join(format!("REPORT_{}.json", spec.name));
        let new_steps = out.steps_done - out.resumed_at.unwrap_or(0);
        if new_steps == 0 && path.exists() {
            // Zero new steps means this process observed no training
            // curve; rewriting would clobber the completed run's report
            // (rows and all) with an empty one. Keep the original.
            info!("job '{}': resumed already complete ({} steps) — \
                   keeping the existing report at {}", spec.name,
                  out.steps_done, path.display());
            out.report_path = Some(path);
        } else {
            match write_report(dir, spec, &session, &out) {
                Ok(p) => out.report_path = Some(p),
                Err(e) => warn_!("job '{}': report write failed ({e:#})",
                                 spec.name),
            }
        }
    }
    info!("job '{}' done: {} steps, final loss {:.4}, {:.1}s wall",
          spec.name, out.steps_done, out.final_loss, out.wall_s);
    out
}

/// Per-job `TrainMetrics` as JSON through the shared bench-report writer
/// (same schema family as `BENCH_*.json`: meta + rows).
fn write_report(dir: &Path, spec: &JobSpec, session: &Session,
                out: &JobOutcome) -> Result<PathBuf> {
    let r = build_report(spec, &session.curve(), session.median_step_s(),
                         session.dispatched(), out);
    let path = dir.join(format!("REPORT_{}.json", spec.name));
    r.write(&path)?;
    Ok(path)
}

/// Assemble the report document from plain values (separated from the
/// session so non-finite-metric rendering is unit-testable: `Json::num`
/// serializes NaN/inf as `null`, keeping the file parseable).
fn build_report(spec: &JobSpec, curve: &[(u64, f64, f64)],
                median_step_s: f64, dispatched: usize,
                out: &JobOutcome) -> BenchReport {
    let mut r = BenchReport::new("serve", "service::scheduler");
    r.set("job", Json::str(&spec.name));
    r.set("model", Json::str(spec.model.as_str()));
    r.set("tag", Json::str(&spec.tag));
    r.set("variant", Json::str(spec.variant.as_str()));
    r.set("seed", Json::num(spec.seed as f64));
    r.set("steps", Json::num(out.steps_done as f64));
    r.set("resumed_at", match out.resumed_at {
        Some(s) => Json::num(s as f64),
        None => Json::Null,
    });
    r.set("ticks", Json::num(out.ticks as f64));
    r.set("final_loss", Json::num(out.final_loss));
    if let Some((el, ea)) = out.eval {
        r.set("eval_loss", Json::num(el));
        r.set("eval_acc", Json::num(ea));
        if spec.model == ModelKind::Lstm {
            r.set("eval_ppl", Json::num(el.exp()));
        }
    }
    r.set("median_step_s", Json::num(median_step_s));
    r.set("dispatched", Json::num(dispatched as f64));
    r.set("wall_s", Json::num(out.wall_s));
    for &(step, loss, acc) in curve {
        r.row(vec![
            ("step", Json::num(step as f64)),
            ("loss", Json::num(loss)),
            ("acc", Json::num(acc)),
        ]);
    }
    r
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// One numeric table cell: fixed-point when finite, "-" otherwise.
fn fmt_cell(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "-".into()
    }
}

/// Human summary printed by the `serve` CLI.
pub fn summarize(report: &ServiceReport) -> String {
    let mut s = format!("{:<16} {:>8} {:>7} {:>10} {:>10} {:>8}  status\n",
                        "job", "steps", "ticks", "final", "eval", "wall_s");
    for o in &report.outcomes {
        // Non-finite metrics (quarantined jobs, NaN losses) print as "-"
        // instead of leaking "NaN"/"inf" into the table.
        let fin = fmt_cell(o.final_loss);
        let eval = o.eval.map(|(l, _)| fmt_cell(l))
            .unwrap_or_else(|| "-".into());
        let status = match &o.status {
            JobStatus::Done => "done".to_string(),
            JobStatus::Failed(why) => format!("FAILED: {why}"),
        };
        s.push_str(&format!("{:<16} {:>8} {:>7} {:>10} {:>10} {:>8.1}  \
                             {}\n",
                            o.name, o.steps_done, o.ticks, fin,
                            eval, o.wall_s, status));
    }
    s.push_str(&format!("peak concurrent slots: {}\n", report.peak_slots));
    s
}

/// Convenience used by the CLI: fail loudly when any job failed.
pub fn ensure_all_ok(report: &ServiceReport) -> Result<()> {
    let failed: Vec<&JobOutcome> = report
        .outcomes
        .iter()
        .filter(|o| !o.ok())
        .collect();
    if failed.is_empty() {
        return Ok(());
    }
    Err(anyhow!("{} job(s) failed: {}", failed.len(),
                failed.iter().map(|o| o.name.as_str())
                    .collect::<Vec<_>>().join(", ")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn outcome(final_loss: f64, eval: Option<(f64, f64)>) -> JobOutcome {
        JobOutcome {
            name: "j".into(),
            status: JobStatus::Done,
            steps_done: 3,
            resumed_at: None,
            ticks: 5,
            final_loss,
            eval,
            wall_s: 0.25,
            report_path: None,
        }
    }

    #[test]
    fn gate_try_and_multi_acquire_account_slots() {
        let gate = SlotGate::new(2);
        // 1 blocking + best-effort extras, capped by free slots.
        let holds = gate.acquire_n(3);
        assert_eq!(holds.len(), 2);
        assert!(gate.try_acquire().is_none(), "gate is full");
        drop(holds);
        let h = gate.try_acquire().expect("slot free again");
        assert_eq!(gate.depth().0, 1);
        drop(h);
        assert_eq!(gate.depth().0, 0);
        assert_eq!(gate.peak(), 2);
    }

    #[test]
    fn report_with_nonfinite_metrics_stays_parseable() {
        // NaN final loss (quarantine mid-run) and an eval loss large
        // enough that eval_ppl = exp(loss) overflows to +inf: both must
        // land as JSON null, not bare NaN/inf tokens no parser accepts.
        let mut spec = JobSpec::named("j");
        spec.model = ModelKind::Lstm;
        let out = outcome(f64::NAN, Some((800.0, 0.0)));
        let r = build_report(&spec, &[(1, 2.5, 0.1), (2, f64::NAN, 0.2)],
                             f64::INFINITY, 7, &out);
        let text = r.to_json().pretty();
        assert!(!text.contains("NaN") && !text.contains("inf"),
                "non-finite leaked into JSON: {text}");
        let v = json::parse(&text).expect("report must parse");
        let is_null = |key: &str| matches!(v.get(key), Some(Json::Null));
        assert!(is_null("final_loss"));
        assert!(is_null("eval_ppl"),
                "exp(800) overflows; must serialize as null");
        assert!(is_null("median_step_s"));
        // Finite neighbors are untouched.
        assert_eq!(v.get("dispatched").unwrap().as_f64(), Some(7.0));
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert!(matches!(rows[1].get("loss"), Some(Json::Null)));
    }

    #[test]
    fn summarize_prints_placeholder_for_nonfinite_losses() {
        let report = ServiceReport {
            outcomes: vec![
                outcome(f64::NAN, None),
                outcome(1.2345, Some((f64::INFINITY, 0.5))),
            ],
            peak_slots: 1,
        };
        let s = summarize(&report);
        assert!(!s.contains("NaN") && !s.contains("inf"),
                "table must not print raw non-finite values:\n{s}");
        assert!(s.contains("1.2345"), "finite values still print:\n{s}");
        assert!(s.contains('-'), "placeholder shown:\n{s}");
    }
}
