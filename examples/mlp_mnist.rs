//! End-to-end driver (DESIGN.md deliverable (b)/E2E): train the paper's
//! 4-layer MLP (784-2048-2048-10, ~5.8M params) on the synthetic MNIST
//! task for several hundred steps with all three dropout variants, logging
//! the loss curve and reporting accuracy + per-step wall-clock + speedup.
//!
//! ```sh
//! cargo run --release --example mlp_mnist -- [steps] [rate]
//! ```
//!
//! Results land in EXPERIMENTS.md section "E2E".

use approx_dropout::coordinator::{speedup, ExecutorCache, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::MnistSyn;
use approx_dropout::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let tag = "mlp2048x2048";
    let (n_train, n_test) = (20_000, 2_048);

    let manifest = Manifest::load(&approx_dropout::artifacts_dir())?;
    // One shared cache across all three variants: the eval graph (and any
    // overlapping train artifacts) compile exactly once for the whole run.
    let cache = ExecutorCache::from_env(manifest)?;
    println!("== E2E: {tag} on MNIST-syn ({n_train} train / {n_test} \
              test), {steps} steps, rate {rate} ==");
    let (train, test) = MnistSyn::train_test(n_train, n_test, 7);

    let mut step_times = Vec::new();
    for variant in [Variant::Conv, Variant::Rdp, Variant::Tdp] {
        let schedule = Schedule::new(variant, &[rate, rate], &[1, 2, 4, 8],
                                     false)?;
        let mut tr = MlpTrainer::new(&cache, tag, schedule, n_train, 0.01,
                                     42)?;
        eprintln!("[{}] compiling {} executables...",
                  variant.as_str(), tr.executable_names().len());
        tr.warmup()?;
        let log_every = (steps / 15).max(1);
        for s in 0..steps {
            let (loss, acc) = tr.step(&train)?;
            if (s + 1) % log_every == 0 {
                println!("[{}] step {:>4}  loss {loss:.4}  batch-acc \
                          {acc:.3}", variant.as_str(), s + 1);
            }
        }
        let (eval_loss, eval_acc) = tr.evaluate(&test)?;
        let t = tr.metrics.steady_mean_step_s(2);
        step_times.push((variant, t, eval_acc));
        println!("[{}] -> test loss {eval_loss:.4}, accuracy {:.2}%, \
                  step {:.1} ms", variant.as_str(), eval_acc * 100.0,
                 t * 1e3);
    }

    let conv = step_times[0].1;
    println!("\n== summary (rate {rate}) ==");
    for (v, t, acc) in &step_times {
        println!("{:<6} step {:.1} ms  speedup {:.2}x  test-acc {:.2}%",
                 v.as_str(), t * 1e3, speedup(conv, *t), acc * 100.0);
    }
    Ok(())
}
