//! PJRT execution backend (cargo feature `pjrt`): load HLO-text
//! artifacts, compile them on the CPU client, and execute train/eval
//! steps with XLA literals kept resident between steps.
//!
//! Design notes:
//! * Interchange is HLO text (`HloModuleProto::from_text_file`) — see
//!   /opt/xla-example/README.md for why serialized protos are rejected.
//! * Train-step graphs return a single tuple; the `xla` crate's execute
//!   does not set `untuple_result`, so the result comes back as one tuple
//!   buffer which we convert to host literals and decompose. Params
//!   therefore live host-side between steps; upload cost is identical for
//!   the baseline and the pattern variants, so speedup ratios are
//!   unaffected (EXPERIMENTS.md section Perf quantifies this).
//! * The [`Backend`]/[`Executor`] traits (`runtime::backend`) wrap all of
//!   this: the coordinator sees [`Value`]s, and `Value::Pjrt` keeps the
//!   zero-copy literal path of the old engine intact.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::backend::{Backend, Executor, HostTensor, Value};
use crate::runtime::manifest::{ArtifactMeta, Dtype, Manifest, TensorMeta};

/// Owns the PJRT client. One per process.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, manifest: &Manifest, name: &str) -> Result<Executable> {
        let meta = manifest.get(name)?.clone();
        let path = manifest.hlo_path(&meta);
        self.load_from(&path, meta)
    }

    pub fn load_from(&self, path: &Path, meta: ArtifactMeta)
                     -> Result<Executable> {
        if !path.exists() {
            bail!("artifact file missing: {} (run `make artifacts`)",
                  path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable { exe, meta })
    }
}

/// The PJRT [`Backend`]: compile-by-name over the artifacts directory,
/// literal upload/download.
pub struct PjrtBackend {
    engine: Engine,
}

impl PjrtBackend {
    pub fn cpu() -> Result<PjrtBackend> {
        Ok(PjrtBackend { engine: Engine::cpu()? })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, manifest: &Manifest, name: &str)
               -> Result<Arc<dyn Executor>> {
        Ok(Arc::new(self.engine.load(manifest, name)?))
    }

    fn upload(&self, t: &HostTensor) -> Result<Value> {
        Ok(Value::Pjrt(t.to_literal()?))
    }
}

fn f32_bytes(data: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    }
}

/// Build an f32 literal from host data in one copy.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32, shape, f32_bytes(data))
        .map_err(|e| anyhow!("literal f32 {shape:?}: {e:?}"))
}

/// Build an i32 literal from host data in one copy.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("literal i32 {shape:?}: {e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

impl HostTensor {
    /// Single-copy conversion to an XLA literal. Rank-0 tensors take the
    /// dedicated scalar constructor so coordinator-assembled host steps
    /// produce literals identical to the direct `lit_scalar_*` path.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32 { shape, data } if shape.is_empty() =>
                Ok(lit_scalar_f32(data[0])),
            HostTensor::I32 { shape, data } if shape.is_empty() =>
                Ok(lit_scalar_i32(data[0])),
            HostTensor::F32 { shape, data } => lit_f32(shape, data),
            HostTensor::I32 { shape, data } => lit_i32(shape, data),
        }
    }
}

/// Copy a literal back into a host tensor described by `meta`.
pub fn host_from_literal(lit: &xla::Literal, meta: &TensorMeta)
                         -> Result<HostTensor> {
    match meta.dtype {
        Dtype::F32 => Ok(HostTensor::F32 {
            shape: meta.shape.clone(),
            data: lit.to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec f32 {}: {e:?}", meta.name))?,
        }),
        Dtype::I32 => Ok(HostTensor::I32 {
            shape: meta.shape.clone(),
            data: lit.to_vec::<i32>()
                .map_err(|e| anyhow!("to_vec i32 {}: {e:?}", meta.name))?,
        }),
    }
}

/// A compiled artifact plus its manifest metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

// SAFETY: the PJRT C API guarantees client/executable thread safety
// (PJRT_Client and PJRT_LoadedExecutable may be used concurrently from
// multiple threads). The `Backend`/`Executor` traits require
// Send + Sync so the shared `ExecutorCache` can serve concurrent
// service sessions. CAUTION: the offline `xla` crate's Rust wrappers
// have NOT been audited for internal non-atomic state (e.g. Rc-based
// handle sharing) — until that audit happens, the service layer
// defensively serializes every PJRT backend touch behind a single slot
// (see service/scheduler.rs `run_jobs`), so cross-thread accesses are
// totally ordered by the gate mutex rather than truly concurrent.
// `Value::Pjrt` literals deliberately carry no Send/Sync claim —
// sessions keep their resident values on one thread.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with pre-built literals (manifest input order) and return
    /// the decomposed output literals. This is the hot path: no per-tensor
    /// host copies beyond PJRT's own transfers (`decompose_tuple` is
    /// zero-copy).
    pub fn run_raw_literals(&self, inputs: &[&xla::Literal])
                            -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: {} inputs given, manifest says {}", self.meta.name,
                  inputs.len(), self.meta.inputs.len());
        }
        let result = self.exe.execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.meta.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = tuple.to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.meta.outputs.len() {
            bail!("{}: {} outputs returned, manifest says {}",
                  self.meta.name, parts.len(), self.meta.outputs.len());
        }
        Ok(parts)
    }

    /// Execute with the full input list (manifest order), with shape/dtype
    /// validation. Returns host tensors in manifest output order.
    /// Convenience path for tests/examples; trainers use `run_raw`.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: {} inputs given, manifest says {}", self.meta.name,
                  inputs.len(), self.meta.inputs.len());
        }
        for (t, m) in inputs.iter().zip(&self.meta.inputs) {
            t.check(m).with_context(|| format!("artifact {}",
                                               self.meta.name))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        let parts = self.run_raw_literals(&refs)?;
        parts
            .iter()
            .zip(&self.meta.outputs)
            .map(|(lit, m)| host_from_literal(lit, m))
            .collect()
    }
}

impl Executor for Executable {
    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn run_raw(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        // Host-resident inputs (the dispatch tail on a cold path) are
        // converted once here; literal-resident state passes straight
        // through with no copy.
        let converted: Vec<Option<xla::Literal>> = inputs
            .iter()
            .map(|v| match v {
                Value::Host(t) => t.to_literal().map(Some),
                Value::Pjrt(_) => Ok(None),
            })
            .collect::<Result<_>>()?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
        for (v, c) in inputs.iter().zip(converted.iter()) {
            match (*v, c) {
                (Value::Pjrt(l), _) => refs.push(l),
                (Value::Host(_), Some(l)) => refs.push(l),
                (Value::Host(_), None) => unreachable!("converted above"),
            }
        }
        let parts = self.run_raw_literals(&refs)?;
        Ok(parts.into_iter().map(Value::Pjrt).collect())
    }
}
