//! Executor pool: one compiled PJRT executable per (model, variant, dp)
//! artifact, compiled lazily on first use and cached for the rest of the
//! run. This mirrors the paper's setup where the pattern distribution (and
//! hence the set of matrix shapes) is fixed before training starts —
//! compilation is a one-time cost off the steady-state hot path.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::{Engine, Executable, Manifest};

pub struct ExecutorPool<'e> {
    engine: &'e Engine,
    manifest: &'e Manifest,
    cache: HashMap<String, Executable>,
    /// Compile wall-clock per artifact (diagnostics / EXPERIMENTS Perf).
    pub compile_times_s: Vec<(String, f64)>,
}

impl<'e> ExecutorPool<'e> {
    pub fn new(engine: &'e Engine, manifest: &'e Manifest) -> Self {
        ExecutorPool {
            engine,
            manifest,
            cache: HashMap::new(),
            compile_times_s: Vec::new(),
        }
    }

    /// Fetch (compiling if needed) the executable for `name`.
    pub fn get(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let t = crate::util::Timer::start();
            let exe = self.engine.load(self.manifest, name)?;
            self.compile_times_s.push((name.to_string(), t.elapsed_s()));
            crate::debug!("compiled {name} in {:.2}s",
                          self.compile_times_s.last().unwrap().1);
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    /// Pre-compile a list of artifacts (e.g. every dp combo the schedule
    /// can sample) so the training loop never stalls on compilation.
    pub fn warm(&mut self, names: &[String]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}
