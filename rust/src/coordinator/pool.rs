//! Process-wide executor cache: one compiled executor per
//! (model, variant, dp) artifact, compiled lazily on first use and shared
//! by every trainer in the process. This mirrors the paper's setup where
//! the pattern distribution (and hence the set of matrix shapes) is fixed
//! before training starts — compilation is a one-time cost off the
//! steady-state hot path, and a baseline-vs-variant comparison (the
//! paper's headline measurement) compiles each artifact exactly once no
//! matter how many trainers run.
//!
//! The cache is generic over the execution [`Backend`]: PJRT compiles HLO
//! artifacts, the reference/sparse backends build step interpreters from
//! the manifest alone. The handle is cheap to clone (`Arc` all the way
//! down); clones share the underlying map. Lookups take a read lock on
//! the hit path and upgrade to a write lock only to compile, using the
//! `HashMap` entry API so a miss costs a single hash probe under the
//! write lock.
//!
//! ## Poisoning
//!
//! A panicking compile used to poison the `RwLock` and wedge every later
//! trainer in the process with an opaque `PoisonError`. The cache now
//! *recovers* the guard instead: the map is never left mid-mutation by a
//! compile panic (the entry is only inserted after `compile` returns
//! `Ok`), so the data is consistent and the panic stays what it was — one
//! failed compile, not a process-wide outage. `cache_poisoning_recovers`
//! below pins this.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard,
                RwLockWriteGuard};

use anyhow::Result;

use crate::runtime::{backend_from_env, Backend, Executor, Manifest,
                     ReferenceBackend, SparseBackend};
use crate::util::Timer;

type ExeMap = HashMap<String, Arc<dyn Executor>>;

#[derive(Clone)]
pub struct ExecutorCache {
    backend: Arc<dyn Backend>,
    manifest: Arc<Manifest>,
    exes: Arc<RwLock<ExeMap>>,
    /// Compile wall-clock per artifact (diagnostics / EXPERIMENTS Perf).
    compile_log: Arc<Mutex<Vec<(String, f64)>>>,
}

impl ExecutorCache {
    pub fn new(backend: Arc<dyn Backend>, manifest: Manifest) -> Self {
        ExecutorCache {
            backend,
            manifest: Arc::new(manifest),
            exes: Arc::new(RwLock::new(HashMap::new())),
            compile_log: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Cache over the pure-Rust reference backend (hermetic: no
    /// artifacts, no PJRT).
    pub fn reference(manifest: Manifest) -> Self {
        Self::new(Arc::new(ReferenceBackend::new()), manifest)
    }

    /// Cache over the structured-sparse compute engine (hermetic; worker
    /// pool sized by `AD_THREADS`, microkernels by `AD_SIMD` + CPU
    /// feature detection).
    pub fn sparse(manifest: Manifest) -> Self {
        Self::new(Arc::new(SparseBackend::new()), manifest)
    }

    /// Cache over the sparse engine pinned to the portable scalar
    /// microkernels — the `AD_SIMD=off` configuration, constructible
    /// without touching process env (tests, the speedup bench's
    /// SIMD-vs-scalar comparison).
    pub fn sparse_scalar(manifest: Manifest) -> Self {
        Self::new(
            Arc::new(SparseBackend::with_kernels(
                crate::runtime::SparseKernels::scalar())),
            manifest,
        )
    }

    /// Cache over the PJRT CPU client.
    #[cfg(feature = "pjrt")]
    pub fn pjrt_cpu(manifest: Manifest) -> Result<Self> {
        Ok(Self::new(Arc::new(crate::runtime::PjrtBackend::cpu()?),
                     manifest))
    }

    /// Backend selected by `AD_BACKEND` (reference|sparse|pjrt);
    /// defaults to PJRT when compiled in, reference otherwise.
    pub fn from_env(manifest: Manifest) -> Result<Self> {
        Ok(Self::new(backend_from_env()?, manifest))
    }

    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Read guard over the map, recovering from poison (see module docs:
    /// a compile panic cannot leave the map mid-mutation).
    fn exes_read(&self) -> RwLockReadGuard<'_, ExeMap> {
        self.exes.read().unwrap_or_else(|p| p.into_inner())
    }

    fn exes_write(&self) -> RwLockWriteGuard<'_, ExeMap> {
        self.exes.write().unwrap_or_else(|p| p.into_inner())
    }

    fn log_guard(&self) -> MutexGuard<'_, Vec<(String, f64)>> {
        self.compile_log.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Fetch (compiling if needed) the executor for `name`. The returned
    /// `Arc` is independent of the cache's locks, so callers hold no borrow
    /// across the subsequent execute.
    pub fn get(&self, name: &str) -> Result<Arc<dyn Executor>> {
        if let Some(exe) = self.exes_read().get(name) {
            return Ok(Arc::clone(exe));
        }
        // Compilation runs under the write lock on purpose: it guarantees
        // each artifact compiles exactly once process-wide (the invariant
        // the benches and tests assert via `compile_times_s`). Readers
        // briefly queue behind a first-time compile; steady-state hits
        // never touch the write lock.
        let mut map = self.exes_write();
        match map.entry(name.to_string()) {
            // Another trainer may have compiled it between the locks.
            Entry::Occupied(e) => Ok(Arc::clone(e.get())),
            Entry::Vacant(slot) => {
                let t = Timer::start();
                let exe = self.backend.compile(&self.manifest, name)?;
                let dt = t.elapsed_s();
                crate::debug!("compiled {name} in {dt:.2}s \
                               ({})", self.backend.name());
                self.log_guard().push((name.to_string(), dt));
                Ok(Arc::clone(slot.insert(exe)))
            }
        }
    }

    /// Pre-compile a list of artifacts (e.g. every dp combo a schedule can
    /// sample) so training loops never stall on compilation.
    pub fn warm(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Number of compiled executors currently cached.
    pub fn len(&self) -> usize {
        self.exes_read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of (artifact name, compile seconds), one entry per compile
    /// actually performed — a shared cache therefore lists each artifact
    /// at most once.
    pub fn compile_times_s(&self) -> Vec<(String, f64)> {
        self.log_guard().clone()
    }

    /// Total compilation wall-clock absorbed by this cache.
    pub fn total_compile_s(&self) -> f64 {
        self.log_guard().iter().map(|(_, s)| s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn reference_cache_compiles_once_and_counts() {
        let cache = ExecutorCache::reference(Manifest::builtin_test());
        assert!(cache.is_empty());
        let a = cache.get("mlptest_rdp_2_2").unwrap();
        let b = cache.get("mlptest_rdp_2_2").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hit must return the same executor");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.compile_times_s().len(), 1);
        assert!(cache.total_compile_s() >= 0.0);
        assert!(cache.get("nonexistent").is_err());
        // Clones share the map.
        let clone = cache.clone();
        clone.get("mlptest_eval").unwrap();
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn sparse_cache_compiles() {
        let cache = ExecutorCache::sparse(Manifest::builtin_test());
        assert_eq!(cache.backend().name(), "sparse");
        cache.get("mlpsyn_rdp_2_2").unwrap();
        assert_eq!(cache.len(), 1);
    }

    /// A backend whose first compile panics (simulating a compiler bug);
    /// later compiles succeed.
    #[derive(Debug)]
    struct FlakyBackend {
        poisoned_once: AtomicBool,
        inner: ReferenceBackend,
    }

    impl Backend for FlakyBackend {
        fn name(&self) -> &'static str {
            "flaky"
        }

        fn compile(&self, manifest: &Manifest, name: &str)
                   -> Result<Arc<dyn Executor>> {
            if !self.poisoned_once.swap(true, Ordering::SeqCst) {
                panic!("injected compile panic");
            }
            self.inner.compile(manifest, name)
        }

        fn upload(&self, t: &crate::runtime::HostTensor)
                  -> Result<crate::runtime::Value> {
            self.inner.upload(t)
        }
    }

    #[test]
    fn cache_poisoning_recovers() {
        let cache = ExecutorCache::new(
            Arc::new(FlakyBackend {
                poisoned_once: AtomicBool::new(false),
                inner: ReferenceBackend::new(),
            }),
            Manifest::builtin_test(),
        );
        // First compile panics while the write lock is held, poisoning
        // the RwLock.
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| cache.get("mlptest_eval")));
        assert!(r.is_err(), "injected panic must propagate");
        // The cache must keep working — previously this deadlocked every
        // later trainer in the process on a PoisonError.
        let exe = cache.get("mlptest_eval").expect("recovered compile");
        assert_eq!(exe.meta().name, "mlptest_eval");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.compile_times_s().len(), 1,
                   "the panicked attempt must not be logged");
    }
}
