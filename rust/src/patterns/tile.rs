//! Tile-based Dropout Pattern (paper section III-B).
//!
//! The `[k, n]` weight matrix is split into tiles (32x32 where the dims
//! allow; adapted down via `pick_block` otherwise, e.g. 784 -> 28-row
//! tiles). Kept tile at grid position `(r, c)` iff
//! `(c - b0 - r) mod dp == 0` — diagonal stripes; see
//! `python/compile/patterns.py` for why the paper's row-major stride is
//! skewed by `r`. The kept count is static across biases whenever `dp`
//! divides one tile-grid edge (enforced — it determines the AOT shape).

use crate::patterns::{pick_block, Choice};

#[derive(Clone, Copy, Debug)]
pub struct TilePattern {
    /// Weight matrix dims.
    pub k: usize,
    pub n: usize,
    /// Tile edge sizes (t_r, t_c).
    pub tr: usize,
    pub tc: usize,
    pub choice: Choice,
}

impl TilePattern {
    pub fn new(k: usize, n: usize, dp: usize, b0: usize, tile: usize) -> Self {
        let tr = pick_block(k, tile);
        let tc = pick_block(n, tile);
        let (tk, tn) = (k / tr, n / tc);
        assert!(
            tn % dp == 0 || tk % dp == 0,
            "dp={dp} must divide one tile-grid edge of {tk}x{tn} \
             (weight {k}x{n}, tile {tr}x{tc})"
        );
        assert!(b0 < dp);
        TilePattern { k, n, tr, tc, choice: Choice { dp, b0 } }
    }

    pub fn grid(&self) -> (usize, usize) {
        (self.k / self.tr, self.n / self.tc)
    }

    /// Number of kept tiles — static across biases.
    pub fn kept_count(&self) -> usize {
        let (tk, tn) = self.grid();
        let dp = self.choice.dp;
        if tn % dp == 0 {
            tk * (tn / dp)
        } else {
            (tk / dp) * tn
        }
    }

    pub fn keeps_tile(&self, r: usize, c: usize) -> bool {
        let Choice { dp, b0 } = self.choice;
        (c % dp + dp - (b0 + r) % dp) % dp == 0
    }

    /// Kept tile coordinates in row-major order (mirrors the python
    /// `jnp.nonzero` enumeration order).
    pub fn kept_tiles(&self) -> Vec<(usize, usize)> {
        let (tk, tn) = self.grid();
        let mut out = Vec::with_capacity(self.kept_count());
        for r in 0..tk {
            for c in 0..tn {
                if self.keeps_tile(r, c) {
                    out.push((r, c));
                }
            }
        }
        out
    }

    /// Fraction of synapses dropped.
    pub fn global_rate(&self) -> f64 {
        let (tk, tn) = self.grid();
        1.0 - self.kept_count() as f64 / (tk * tn) as f64
    }

    /// Inverted-dropout scale (mirrors model.tile_scale).
    pub fn scale(&self) -> f32 {
        let (tk, tn) = self.grid();
        (tk * tn) as f32 / self.kept_count() as f32
    }

    /// Dense 0/1 keep mask of the full weight matrix (tests only).
    pub fn mask(&self) -> Vec<f32> {
        let mut m = vec![0f32; self.k * self.n];
        for (r, c) in self.kept_tiles() {
            for i in 0..self.tr {
                for j in 0..self.tc {
                    m[(r * self.tr + i) * self.n + (c * self.tc + j)] = 1.0;
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{self, gen_choice};

    #[test]
    fn paper_tile_size_32() {
        let p = TilePattern::new(2048, 2048, 4, 1, 32);
        assert_eq!((p.tr, p.tc), (32, 32));
        assert_eq!(p.grid(), (64, 64));
        assert_eq!(p.kept_count(), 64 * 16);
        assert!((p.global_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn adapts_tile_to_non_divisible_dims() {
        let p = TilePattern::new(784, 2048, 2, 0, 32);
        assert_eq!(p.tr, 28); // 784 = 28 * 28
        assert_eq!(p.tc, 32);
    }

    #[test]
    fn kept_count_static_across_bias() {
        for dp in [2usize, 4, 8] {
            for (k, n) in [(2048, 2048), (1024, 64), (1536, 8800)] {
                let counts: Vec<usize> = (0..dp)
                    .map(|b0| TilePattern::new(k, n, dp, b0, 32).kept_count())
                    .collect();
                assert!(counts.windows(2).all(|w| w[0] == w[1]),
                        "k={k} n={n} dp={dp}: {counts:?}");
            }
        }
    }

    #[test]
    fn biases_partition_tiles() {
        testkit::quickcheck("tile partition", |rng| {
            let dims = [(256usize, 128usize), (128, 256)];
            let (k, n) = *gen_choice(rng, &dims);
            let dp = *gen_choice(rng, &[2usize, 4]);
            let mut count = std::collections::BTreeMap::new();
            for b0 in 0..dp {
                for rc in TilePattern::new(k, n, dp, b0, 32).kept_tiles() {
                    *count.entry(rc).or_insert(0usize) += 1;
                }
            }
            let p = TilePattern::new(k, n, dp, 0, 32);
            let (tk, tn) = p.grid();
            assert_eq!(count.len(), tk * tn, "every tile kept by some bias");
            assert!(count.values().all(|&c| c == 1),
                    "each tile kept by exactly one bias");
        });
    }

    #[test]
    fn every_output_column_covered() {
        // Needed so the sparse kernel writes every output block: for each
        // tile-column c there is at least one kept tile.
        testkit::quickcheck("tile column cover", |rng| {
            let (k, n) = (256usize, 256usize);
            let dp = *gen_choice(rng, &[2usize, 4, 8]);
            let b0 = rng.next_usize(dp);
            let p = TilePattern::new(k, n, dp, b0, 32);
            let (_, tn) = p.grid();
            let mut cols = vec![false; tn];
            for (_, c) in p.kept_tiles() {
                cols[c] = true;
            }
            assert!(cols.iter().all(|&x| x), "dp={dp} b0={b0}");
        });
    }

    #[test]
    fn mask_density_matches_rate() {
        let p = TilePattern::new(256, 128, 4, 2, 32);
        let m = p.mask();
        let ones = m.iter().filter(|&&v| v == 1.0).count();
        let density = ones as f64 / m.len() as f64;
        assert!((density - (1.0 - p.global_rate())).abs() < 1e-12);
    }

    #[test]
    fn dp_divides_tk_case() {
        // 1024x64: tile grid 32x2; dp=8 divides tk=32 but not tn=2.
        let p = TilePattern::new(1024, 64, 8, 3, 32);
        assert_eq!(p.kept_count(), (32 / 8) * 2);
        let (tk, tn) = p.grid();
        let kept = p.kept_tiles();
        assert_eq!(kept.len(), p.kept_count());
        assert!(kept.iter().all(|&(r, c)| r < tk && c < tn));
    }
}
