//! Helpers shared by the integration test crates (not itself a test
//! crate: files under `tests/common/` are only compiled when a test
//! declares `mod common;`).

#![allow(dead_code)] // each test crate uses a subset

/// Host-side forward pass of the tiny MLP (32 -> 64 -> 64 -> 10):
/// an independent reimplementation of the eval-graph semantics, used to
/// cross-check both the PJRT eval artifact (`tests/integration.rs`) and
/// the reference interpreter (`tests/hermetic.rs`).
/// Returns (mean loss, correct count).
pub fn host_mlp_eval(params: &[Vec<f32>], x: &[f32], y: &[i32],
                     batch: usize) -> (f64, f64) {
    let dims = [(32usize, 64usize), (64, 64), (64, 10)];
    let mut act: Vec<f32> = x.to_vec();
    let mut width = 32;
    for (li, &(k, n)) in dims.iter().enumerate() {
        let w = &params[2 * li];
        let b = &params[2 * li + 1];
        let mut next = vec![0f32; batch * n];
        for bi in 0..batch {
            for j in 0..n {
                let mut acc = b[j];
                for i in 0..k {
                    acc += act[bi * width + i] * w[i * n + j];
                }
                // ReLU on hidden layers only.
                next[bi * n + j] = if li < 2 { acc.max(0.0) } else { acc };
            }
        }
        act = next;
        width = n;
    }
    // Softmax CE + correct count.
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    for bi in 0..batch {
        let logits = &act[bi * 10..(bi + 1) * 10];
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 =
            logits.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
        loss -= (logits[y[bi] as usize] - lse) as f64;
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if argmax == y[bi] as usize {
            correct += 1.0;
        }
    }
    (loss / batch as f64, correct)
}
