//! Hand-rolled worker-thread pool for the sparse kernel library (rayon is
//! unavailable offline; std::thread::scope would respawn OS threads on
//! every GEMM call, which at our matrix sizes costs more than the math).
//!
//! Model: one process-wide pool of `AD_THREADS - 1` persistent workers
//! (the caller participates, so `AD_THREADS=1` means fully inline).
//! [`ThreadPool::run`] publishes one *job* — a `Fn(usize)` over chunk
//! indices `0..n_chunks` — and returns only when every chunk has executed.
//! Chunks are claimed from a shared atomic counter, so load-balancing is
//! dynamic while the work *assignment* stays irrelevant to the result:
//!
//! ## Determinism contract
//!
//! Kernels partition their **output** into disjoint index ranges, one per
//! chunk, and every output element is computed entirely within its chunk
//! with a fixed inner accumulation order (fixed per process — the
//! microkernel selection is pinned once; see `sparse::simd`). Which
//! thread runs a chunk (and how many threads exist) therefore cannot
//! change any result bit — `AD_THREADS=1` and `AD_THREADS=64` produce
//! identical buffers, which `rust/tests/sparse_kernels.rs` pins.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// One published job: a chunk runner plus the claim/completion counters.
/// `task` is a caller-stack closure laundered to `'static`; see the
/// SAFETY argument in [`ThreadPool::run`].
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    n_chunks: usize,
    epoch: u64,
    next: AtomicUsize,
    done: AtomicUsize,
    panicked: AtomicBool,
    finished: Mutex<bool>,
    done_cv: Condvar,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

struct Slot {
    job: Option<Arc<Job>>,
    epoch: u64,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Pool with `n_threads` total executors: the caller plus
    /// `n_threads - 1` spawned workers. `n_threads <= 1` spawns nothing
    /// and [`Self::run`] executes inline.
    pub fn new(n_threads: usize) -> ThreadPool {
        let n_threads = n_threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { job: None, epoch: 0 }),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (1..n_threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("ad-sparse-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn sparse worker")
            })
            .collect();
        ThreadPool { shared, handles, n_threads }
    }

    /// Total executor count (callers size their chunking off this).
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Run `task` over chunk indices `0..n_chunks`, blocking until every
    /// chunk has completed. Panics (after all chunks drain) if any chunk
    /// panicked on a worker.
    pub fn run(&self, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        if self.handles.is_empty() || n_chunks == 1 {
            for c in 0..n_chunks {
                task(c);
            }
            return;
        }
        // SAFETY: `run` does not return until `done == n_chunks`, i.e.
        // every invocation of `task` has finished (workers that race past
        // the end only observe an exhausted chunk counter and never call
        // `task` again). The laundered reference therefore never outlives
        // the borrow it came from in any observable way.
        let task: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(task) };
        let job = {
            let mut slot = self.shared.slot.lock().expect("pool slot");
            slot.epoch += 1;
            let job = Arc::new(Job {
                task,
                n_chunks,
                epoch: slot.epoch,
                next: AtomicUsize::new(0),
                done: AtomicUsize::new(0),
                panicked: AtomicBool::new(false),
                finished: Mutex::new(false),
                done_cv: Condvar::new(),
            });
            slot.job = Some(Arc::clone(&job));
            job
        };
        self.shared.work_cv.notify_all();
        work_on(&job); // the caller is executor #0
        let mut fin = job.finished.lock().expect("job finished lock");
        while !*fin {
            fin = job.done_cv.wait(fin).expect("job finished wait");
        }
        drop(fin);
        // Retire the job so idle workers park instead of re-inspecting
        // it — but only if the slot still holds *this* job: another
        // caller may have published a newer one concurrently, and
        // clearing that would silently strand its workers.
        {
            let mut slot = self.shared.slot.lock().expect("pool slot");
            if slot.job.as_ref().is_some_and(|j| j.epoch == job.epoch) {
                slot.job = None;
            }
        }
        if job.panicked.load(Ordering::Acquire) {
            panic!("sparse kernel chunk panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().expect("pool slot");
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                let fresh = match &slot.job {
                    Some(j) if j.epoch != last_epoch =>
                        Some(Arc::clone(j)),
                    _ => None,
                };
                if let Some(j) = fresh {
                    break j;
                }
                slot = shared.work_cv.wait(slot).expect("pool slot wait");
            }
        };
        last_epoch = job.epoch;
        work_on(&job);
    }
}

/// Claim and run chunks until the counter is exhausted. Chunk panics are
/// contained (recorded on the job, re-raised by the caller) so a bad
/// kernel never wedges the completion protocol.
fn work_on(job: &Job) {
    loop {
        let c = job.next.fetch_add(1, Ordering::Relaxed);
        if c >= job.n_chunks {
            return;
        }
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| (job.task)(c)));
        if r.is_err() {
            job.panicked.store(true, Ordering::Release);
        }
        if job.done.fetch_add(1, Ordering::AcqRel) + 1 == job.n_chunks {
            let mut fin = job.finished.lock().expect("job finished lock");
            *fin = true;
            job.done_cv.notify_all();
        }
    }
}

/// Thread count from `AD_THREADS`, defaulting to the machine's available
/// parallelism. `AD_THREADS=1` disables the workers entirely (fully
/// inline execution on the calling thread).
///
/// Invalid values (`AD_THREADS=abc`, `=0`, `=-3`) used to degrade to a
/// *single* thread with only a warn-level hint — an order-of-magnitude
/// silent slowdown on big machines. They now fall back to the same
/// default as an unset variable (all cores), loudly; an empty/whitespace
/// value is treated as unset. Results are bit-identical either way (see
/// the determinism contract above), so the fallback can never change a
/// trajectory — only wall-clock.
pub fn threads_from_env() -> usize {
    let default = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    match std::env::var("AD_THREADS") {
        Ok(v) if v.trim().is_empty() => default,
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                crate::warn_!("AD_THREADS='{v}' is not a positive integer; \
                               falling back to all {default} core(s) (same \
                               as unset; results are thread-count \
                               independent)");
                default
            }
        },
        Err(_) => default,
    }
}

/// The process-wide pool the sparse kernels dispatch through, built
/// lazily from `AD_THREADS` on first use.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(threads_from_env()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = ThreadPool::new(4);
        for n_chunks in [0usize, 1, 2, 7, 64] {
            let hits: Vec<AtomicUsize> =
                (0..n_chunks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n_chunks, &|c| {
                hits[c].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i}");
            }
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.n_threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.run(5, &|c| {
            sum.fetch_add(c, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = ThreadPool::new(3);
        for round in 1..=20usize {
            let sum = AtomicUsize::new(0);
            pool.run(round, &|c| {
                sum.fetch_add(c + 1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed),
                       round * (round + 1) / 2);
        }
    }

    #[test]
    fn disjoint_output_writes_are_visible_to_caller() {
        // The pattern every kernel uses: chunks write disjoint ranges of
        // one output buffer through a raw pointer.
        struct Ptr(*mut f32);
        unsafe impl Send for Ptr {}
        unsafe impl Sync for Ptr {}
        let pool = ThreadPool::new(4);
        let n = 1024;
        let chunk = 64;
        let mut out = vec![0f32; n];
        let p = Ptr(out.as_mut_ptr());
        let n_chunks = n / chunk;
        pool.run(n_chunks, &|c| {
            let base = c * chunk;
            let seg = unsafe {
                std::slice::from_raw_parts_mut(p.0.add(base), chunk)
            };
            for (i, v) in seg.iter_mut().enumerate() {
                *v = (base + i) as f32;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn worker_panic_is_reraised_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run(8, &|c| {
                    if c == 3 {
                        panic!("boom");
                    }
                });
            }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // The pool still works afterwards.
        let sum = AtomicUsize::new(0);
        pool.run(4, &|c| {
            sum.fetch_add(c, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn env_parsing_defaults() {
        // Only exercise the parse paths that don't depend on process env
        // mutation (env vars are process-global in tests).
        assert!(threads_from_env() >= 1);
    }
}
