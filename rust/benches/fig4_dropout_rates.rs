//! Fig. 4 — "Comparing different dropout rate combinations on specific
//! network": MLP 2048x2048, dropout rates (0.3,0.3)..(0.7,0.7), speedup
//! and accuracy for RDP and TDP vs the conventional baseline.
//!
//! Paper shape to reproduce: RDP speedup 1.2->1.8 as the rate grows,
//! TDP 1.18->1.6 (slightly below RDP), accuracy loss < 0.47%.
//!
//! Timing-only by default; set AD_BENCH_TRAIN_STEPS (e.g. 400) to add the
//! accuracy columns.

use approx_dropout::bench::drivers::{fmt_opt_pct, run_mlp, BenchCtx};
use approx_dropout::bench::{fmt_time, Table};
use approx_dropout::coordinator::{speedup, Variant};
use approx_dropout::data::MnistSyn;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new()?;
    let tag = "mlp2048x2048";
    let (train, test) = MnistSyn::train_test(8_192, 2_048, 7);
    println!("== Fig 4: {tag}, rate sweep, {} timed steps/config ==",
             ctx.timed_steps);

    let rates = [0.3, 0.4, 0.5, 0.6, 0.7];
    let mut table = Table::new(&["rates", "conv step", "RDP step",
                                 "RDP speedup", "TDP step", "TDP speedup",
                                 "conv acc", "RDP acc", "TDP acc"]);
    for &r in &rates {
        let rr = [r, r];
        let (t_conv, a_conv) = run_mlp(&ctx, tag, Variant::Conv, &rr, false,
                                       &train, &test, 42)?;
        let (t_rdp, a_rdp) = run_mlp(&ctx, tag, Variant::Rdp, &rr, false,
                                     &train, &test, 42)?;
        let (t_tdp, a_tdp) = run_mlp(&ctx, tag, Variant::Tdp, &rr, false,
                                     &train, &test, 42)?;
        table.row(&[
            format!("({r},{r})"),
            fmt_time(t_conv),
            fmt_time(t_rdp),
            format!("{:.2}x", speedup(t_conv, t_rdp)),
            fmt_time(t_tdp),
            format!("{:.2}x", speedup(t_conv, t_tdp)),
            fmt_opt_pct(a_conv),
            fmt_opt_pct(a_rdp),
            fmt_opt_pct(a_tdp),
        ]);
        println!("  rate {r}: conv {} | rdp {:.2}x | tdp {:.2}x",
                 fmt_time(t_conv), speedup(t_conv, t_rdp),
                 speedup(t_conv, t_tdp));
    }
    println!();
    table.print();
    println!("\npaper: RDP 1.2-1.8x, TDP 1.18-1.6x over the same sweep; \
              accuracy loss < 0.47% (set AD_BENCH_TRAIN_STEPS=400 for \
              accuracy columns)");
    Ok(())
}
