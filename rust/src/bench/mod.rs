//! Bench harness (criterion is unavailable offline): warmup + N timed
//! repetitions, median +- MAD reporting, and paper-style table printing.
//! Every `rust/benches/*.rs` binary builds on this.

pub mod drivers;
pub mod report;

pub use report::BenchReport;

use crate::util::stats;
use crate::util::Timer;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub mean_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::NAN
        }
    }
}

/// Time `f` for `reps` repetitions after `warmup` unrecorded calls.
/// The closure result is returned through a black-box sink so the work is
/// not optimized away.
pub fn bench<F: FnMut() -> R, R>(name: &str, warmup: usize, reps: usize,
                                 mut f: F) -> BenchResult {
    for _ in 0..warmup {
        sink(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        sink(f());
        times.push(t.elapsed_s());
    }
    BenchResult {
        name: name.to_string(),
        reps,
        median_s: stats::median(&times),
        mad_s: stats::mad(&times),
        mean_s: stats::mean(&times),
    }
}

#[inline]
fn sink<R>(r: R) {
    // Opaque drop; prevents the optimizer from deleting the benched call.
    let _keep = std::hint::black_box(r);
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
                .collect();
            println!("| {} |", padded.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format seconds as adaptive ms/s string.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..200_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.reps, 5);
        assert!(r.median_s > 0.0);
        assert!(r.mean_s > 0.0);
        assert!(r.per_sec().is_finite());
    }

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new(&["config", "speedup"]);
        t.row(&["rdp 0.7".to_string(), "1.77".to_string()]);
        t.row(&["tile 0.5".to_string(), "1.41".to_string()]);
        t.print(); // smoke: no panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-5).ends_with("us"));
        assert!(fmt_time(5e-2).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
