//! Generic training driver — the shared per-iteration structure that used
//! to be copy-pasted between the MLP and LSTM coordinators.
//!
//! Split of responsibilities:
//! * [`ModelFront`] is the architecture-specific half: it owns the
//!   schedule, the RNG, the batcher and the mask generation, and knows how
//!   to turn one sampled pattern + one batch into the executable's tail
//!   inputs (and how to lay out eval batches). A new architecture is one
//!   `ModelFront` impl (~100 LoC), not a third copied trainer.
//! * [`Trainer`] is the generic half: warmup, the train/evaluate loops,
//!   the lr-decay policy (promoted here from the old LSTM-only trainer),
//!   metric recording, and dispatch through the process-wide
//!   [`ExecutorCache`].
//!
//! Per iteration (paper Fig. 2): sample `(dp, b0)` per site from the
//! searched distribution K, assemble the literal tail, resolve the
//! `(tag, variant, dp)` artifact name, `TrainState::step`, record metrics.
//!
//! The driver also offers a **double-buffered** step path
//! ([`Trainer::train_pipelined`]): a scoped worker thread runs the front's
//! assembly (pattern sampling, batch marshalling, Bernoulli mask fills —
//! plain `Send` host buffers only) one iteration ahead while the main
//! thread uploads through the backend and executes. The worker draws from
//! the front's RNG in exactly the sequential order, so the pipelined path
//! is bit-for-bit identical to [`Trainer::step_with`] loops — only
//! wall-clock changes. Backend values (e.g. XLA literals) are never
//! created off the main thread.
//!
//! Orthogonally, the **data-parallel** path ([`Trainer::sharded`] ->
//! [`ShardedTrainer`]) splits each global batch into a fixed leaf list,
//! runs per-leaf forward/backward on `AD_WORKERS` threads through the
//! step interpreter's `run_grads`, and combines gradients with the
//! fixed-order reduction tree in [`crate::coordinator::reduce`] before
//! one SGD-momentum apply — bit-identical trajectories at any worker
//! count (hermetic backends only).

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::TrainMetrics;
use crate::coordinator::pool::ExecutorCache;
use crate::coordinator::reduce::{reduce_grad_pair, tree_reduce};
use crate::coordinator::schedule::{Schedule, Variant};
use crate::obs::{registry, trace};
use crate::patterns::Choice;
use crate::runtime::{GradOut, HostTensor, LeafSpec, TrainState, Value};
use crate::util::log;
use crate::service::checkpoint::{fnv1a64, Checkpoint, TensorCkpt,
                                 CKPT_VERSION, DISPATCH_TAIL};
use crate::util::json::Json;
use crate::util::Timer;

/// One fully assembled training step, host-side: everything except the
/// trailing lr scalar, which the driver appends at dispatch time so staged
/// steps observe lr-decay updates exactly like sequential ones.
#[derive(Debug)]
pub struct StepInput {
    /// Artifact to dispatch to (resolved from the sampled dp combination).
    pub name: String,
    /// Tail tensors in manifest order: x, y, masks-or-biases, scales.
    pub tail: Vec<HostTensor>,
    /// Examples covered by this step (batch, or batch*seq tokens).
    pub examples: usize,
    /// Whether drawing this step's batch completed a data epoch (drives
    /// the generic lr-decay policy).
    pub epoch_boundary: bool,
}

/// Architecture-specific input assembly. Implementations own every
/// RNG-consuming resource (schedule sampling, batching, mask generation)
/// so that assembly — and therefore the random stream — is a single
/// sequential process whether it runs inline or on the pipeline thread.
pub trait ModelFront {
    /// Training data passed to each step (`()` when the front owns its
    /// token stream, as the LSTM batcher does).
    type Data: ?Sized + Sync;
    /// Evaluation data for the dropout-free eval graph.
    type EvalData: ?Sized + Sync;

    /// Artifact tag, e.g. `mlp2048x2048`.
    fn tag(&self) -> &str;

    fn schedule(&self) -> &Schedule;

    /// Artifact name for one sampled dp combination (architectures with
    /// equal-dp artifact sets truncate, see the LSTM front).
    fn artifact_for(&self, dp: &[usize]) -> String;

    /// Assemble one training step: sample pattern choices, draw the batch,
    /// and build the host-side tail. Must not create XLA literals — this
    /// runs off the main thread on the pipelined path.
    fn assemble(&mut self, data: &Self::Data) -> Result<StepInput>;

    /// Number of full eval batches `data` yields.
    fn eval_num_batches(&self, data: &Self::EvalData) -> usize;

    /// One eval batch's inputs (x, y) in manifest order, `bi` in
    /// `0..eval_num_batches(data)`. Batches are built on demand so the
    /// eval loop holds one batch in host memory at a time.
    fn eval_batch(&self, data: &Self::EvalData, bi: usize)
                  -> Result<Vec<HostTensor>>;

    /// Examples per eval batch (batch, or batch*seq tokens).
    fn eval_examples_per_batch(&self) -> usize;

    /// Canonical one-line fingerprint of the front's configuration (tag,
    /// variant, rates, artifact combos, geometry). Hashed into
    /// checkpoints so a resume against a different experiment setup is
    /// rejected up front. Must be deterministic across processes.
    fn config_line(&self) -> String;

    /// Serializable assembly-state snapshot — the RNG cursor and batcher
    /// position/order; everything beyond `TrainState` a resumed run needs
    /// to reproduce the uninterrupted trajectory bit-for-bit.
    fn snapshot(&self) -> Json;

    /// Restore a [`ModelFront::snapshot`]. Must validate: a corrupt or
    /// mismatched snapshot is an error, never a silently different
    /// random stream.
    fn restore(&mut self, snap: &Json) -> Result<()>;

    /// Number of gradient *leaves* the sharded trainer cuts each global
    /// batch into: the largest divisor of `batch` that is at most 8.
    /// Deliberately a function of the batch geometry only — never of the
    /// worker count — so the leaf list (and therefore the reduction
    /// tree's association order, see `coordinator::reduce`) is identical
    /// at any `--workers N`; workers merely claim contiguous leaf
    /// ranges. Divisibility keeps every leaf the same height, so no
    /// shard needs a remainder path.
    fn shard_leaves(&self, batch: usize) -> usize {
        (1..=batch.min(8)).rev().find(|s| batch % s == 0).unwrap_or(1)
    }
}

/// Params-only eval entry: restore just the parameter tensors of a
/// checkpoint into an eval-only [`TrainState`] for `tag`, without
/// constructing a `Trainer` (no schedule, batcher, RNG or dataset — none
/// of which the dropout-free `<tag>_eval` graph consumes). The inference
/// registry holds one of these per served model.
///
/// Validates every checkpoint tensor against the manifest's parameter
/// schema for `tag` (name and shape, in order) — serving an MLP
/// checkpoint under an LSTM tag, or a checkpoint from a different
/// geometry, is rejected here rather than surfacing as a kernel shape
/// panic mid-request. Momenta are deliberately not ingested: inference
/// never steps, and skipping them halves the resident bytes per model.
pub fn eval_state_from_checkpoint(cache: &ExecutorCache, tag: &str,
                                  ckpt: &Checkpoint) -> Result<TrainState> {
    if ckpt.version != CKPT_VERSION {
        bail!("checkpoint version {} unsupported (expected {CKPT_VERSION})",
              ckpt.version);
    }
    let meta = cache.manifest().get(&format!("{tag}_conv"))
        .with_context(|| format!("tag {tag} has no conv artifact in the \
                                  manifest"))?;
    let param_metas: Vec<_> = meta.inputs.iter()
        .filter(|t| t.kind == crate::runtime::manifest::Kind::Param)
        .cloned()
        .collect();
    if ckpt.params.len() != param_metas.len() {
        bail!("checkpoint has {} param tensors, tag {tag} declares {}",
              ckpt.params.len(), param_metas.len());
    }
    let backend = cache.backend();
    let mut params = Vec::with_capacity(param_metas.len());
    for (t, m) in ckpt.params.iter().zip(&param_metas) {
        if t.name != m.name || t.shape != m.shape {
            bail!("checkpoint tensor {}:{:?} does not match tag {tag}'s \
                   parameter {}:{:?}", t.name, t.shape, m.name, m.shape);
        }
        params.push(backend.ingest(HostTensor::f32(&t.shape,
                                                   t.data.clone()))?);
    }
    TrainState::eval_only(param_metas, params, ckpt.step)
}

/// Push one `b0` bias scalar per site (approximate-dropout variants).
pub fn push_bias_scalars(tail: &mut Vec<HostTensor>, choices: &[Choice]) {
    for c in choices {
        tail.push(HostTensor::scalar_i32(c.b0 as i32));
    }
}

/// Push one `[seq]` b0 bias track per site (LSTM approximate-dropout
/// variants): entry `t` is the kept residue class for timestep `t`,
/// constant within each time window. The step interpreter re-derives the
/// window boundaries by run-grouping equal consecutive entries, so the
/// runtime needs no window knob of its own.
pub fn push_bias_tracks(tail: &mut Vec<HostTensor>, tracks: &[Vec<i32>]) {
    for t in tracks {
        tail.push(HostTensor::i32(&[t.len()], t.clone()));
    }
}

/// Push the inverted-dropout correction scalars: constant 1/(1-p) of each
/// site's long-run rate (Caffe semantics), NOT the per-iteration 1/dp —
/// see model.py `_mlp_logits_rdp`.
pub fn push_scale_scalars(tail: &mut Vec<HostTensor>, rates: &[f64]) {
    for rate in rates {
        tail.push(HostTensor::scalar_f32((1.0 / (1.0 - rate)) as f32));
    }
}

/// The dispatch half of one iteration, borrowed apart from the front so
/// the pipelined path can run assembly and dispatch concurrently.
struct LoopCtx<'a> {
    cache: &'a ExecutorCache,
    state: &'a mut TrainState,
    metrics: &'a mut TrainMetrics,
    lr: &'a mut f32,
    lr_decay: f32,
    decay_after: usize,
    epochs_done: &'a mut usize,
}

impl LoopCtx<'_> {
    /// Upload the staged host tensors through the backend, append lr,
    /// execute, absorb state, record metrics (including the dispatched
    /// artifact name), and apply the epoch lr-decay policy.
    /// Returns (loss, accuracy-in-[0,1]).
    fn dispatch(&mut self, input: StepInput, timer: Timer) -> Result<(f64, f64)> {
        let StepInput { name, tail, examples, epoch_boundary } = input;
        let backend = self.cache.backend();
        let mut vals: Vec<Value> = Vec::with_capacity(tail.len() + 1);
        {
            let _sp = trace::span("marshal");
            for t in tail {
                vals.push(backend.ingest(t)?);
            }
            vals.push(backend.ingest(HostTensor::scalar_f32(*self.lr))?);
        }
        let exe = self.cache.get(&name)?;
        let (loss, correct) = {
            let _sp = trace::span("execute");
            self.state.step(exe.as_ref(), &vals)?
        };
        registry::DISPATCH_TOTAL
            .inc(&format!("{}/{name}", backend.name()));
        self.metrics.record(self.state.step, loss, correct, examples,
                            timer.elapsed_s());
        self.metrics.dispatched.push(name);
        if epoch_boundary {
            *self.epochs_done += 1;
            if *self.epochs_done > self.decay_after {
                *self.lr *= self.lr_decay;
            }
        }
        Ok((loss, correct / examples as f64))
    }
}

/// Generic trainer: one loop, any [`ModelFront`].
pub struct Trainer<F: ModelFront> {
    pub front: F,
    cache: ExecutorCache,
    pub state: TrainState,
    pub metrics: TrainMetrics,
    pub lr: f32,
    /// Multiplied into lr after each completed data epoch beyond
    /// `decay_after` (generic; formerly LSTM-only).
    pub lr_decay: f32,
    pub decay_after: usize,
    epochs_done: usize,
    /// Construction-time lr. `lr` above is *state* (it decays and is
    /// restored from checkpoints); the initial value is *config* and is
    /// folded into the checkpoint config hash, so resuming under a
    /// different `--lr` is rejected instead of silently ignored.
    lr0: f32,
}

impl<F: ModelFront> Trainer<F> {
    /// Assemble a trainer from an already-initialized front and state.
    /// Architecture-specific constructors (`Trainer::<MlpFront>::new`,
    /// `Trainer::<LstmFront>::new`) wrap this.
    pub fn from_parts(cache: &ExecutorCache, front: F, state: TrainState,
                      lr: f32) -> Self {
        Trainer {
            front,
            cache: cache.clone(),
            state,
            metrics: TrainMetrics::default(),
            lr,
            lr_decay: 1.0,
            decay_after: usize::MAX,
            epochs_done: 0,
            lr0: lr,
        }
    }

    /// Shared-cache handle this trainer dispatches through.
    pub fn cache(&self) -> &ExecutorCache {
        &self.cache
    }

    /// Completed data epochs observed so far.
    pub fn epochs_done(&self) -> usize {
        self.epochs_done
    }

    /// Every executable this trainer's schedule can dispatch to — exactly
    /// `schedule.dp_combos()` mapped through the front's naming (or the
    /// single conventional graph).
    pub fn executable_names(&self) -> Vec<String> {
        match self.front.schedule().variant {
            Variant::Conv => vec![format!("{}_conv", self.front.tag())],
            _ => self
                .front
                .schedule()
                .dp_combos()
                .iter()
                .map(|dp| self.front.artifact_for(dp))
                .collect(),
        }
    }

    /// Pre-compile every executable the schedule can dispatch to, so the
    /// timed loop measures steady-state iteration cost only. Artifacts
    /// already compiled by another trainer sharing the cache are skipped.
    pub fn warmup(&mut self) -> Result<()> {
        let names = self.executable_names();
        self.cache.warm(&names)
    }

    fn loop_ctx(&mut self) -> LoopCtx<'_> {
        LoopCtx {
            cache: &self.cache,
            state: &mut self.state,
            metrics: &mut self.metrics,
            lr: &mut self.lr,
            lr_decay: self.lr_decay,
            decay_after: self.decay_after,
            epochs_done: &mut self.epochs_done,
        }
    }

    /// One full training iteration; returns (loss, accuracy in [0,1]).
    /// Hot path: host buffers are uploaded through the backend once and
    /// the parameter state stays backend-resident (see runtime::state).
    pub fn step_with(&mut self, data: &F::Data) -> Result<(f64, f64)> {
        if trace::enabled() {
            trace::set_scope(&self.scope_label());
        }
        let timer = Timer::start();
        let input = {
            let _sp = trace::span("assemble");
            self.front.assemble(data)?
        };
        self.loop_ctx().dispatch(input, timer)
    }

    /// Label traced spans aggregate under: `<tag>/<variant>`.
    fn scope_label(&self) -> String {
        format!("{}/{}", self.front.tag(),
                self.front.schedule().variant.as_str())
    }

    /// Run `n` sequential steps; returns mean loss over the window.
    pub fn train_with(&mut self, data: &F::Data, n: usize) -> Result<f64> {
        let mut sum = 0.0;
        for _ in 0..n {
            sum += self.step_with(data)?.0;
        }
        Ok(sum / n.max(1) as f64)
    }

    /// Run `n` steps with double-buffered assembly: a scoped worker thread
    /// assembles iteration k+1's host inputs (pattern sampling, batch
    /// copy, Bernoulli mask fills) while the main thread executes
    /// iteration k. Bit-for-bit identical trajectories to `train_with` —
    /// the worker consumes the front's RNG in the same sequential order —
    /// with assembly cost hidden behind the PJRT execute.
    ///
    /// Returns mean loss over the window. The recorded per-step times
    /// cover literal conversion + execute + absorb only (assembly is off
    /// the measured path by construction).
    pub fn train_pipelined(&mut self, data: &F::Data, n: usize) -> Result<f64>
    where
        F: Send,
    {
        if n == 0 {
            return Ok(0.0);
        }
        let scope_label = if trace::enabled() {
            trace::set_scope(&self.scope_label());
            Some(self.scope_label())
        } else {
            None
        };
        let Trainer { front, cache, state, metrics, lr, lr_decay,
                      decay_after, epochs_done, .. } = self;
        let mut ctx = LoopCtx {
            cache,
            state,
            metrics,
            lr,
            lr_decay: *lr_decay,
            decay_after: *decay_after,
            epochs_done,
        };
        std::thread::scope(|scope| -> Result<f64> {
            // Capacity 1 = one staged step beyond the one being assembled.
            let (tx, rx) =
                std::sync::mpsc::sync_channel::<Result<StepInput>>(1);
            scope.spawn(move || {
                // Spans fire on this thread too; tag them with the same
                // config label as the dispatching thread.
                if let Some(s) = &scope_label {
                    trace::set_scope(s);
                }
                for _ in 0..n {
                    let input = {
                        let _sp = trace::span("assemble");
                        front.assemble(data)
                    };
                    let stop = input.is_err();
                    // Receiver gone (dispatch error) or assembly error:
                    // stop producing; the scope joins us either way.
                    if tx.send(input).is_err() || stop {
                        break;
                    }
                }
            });
            let mut sum = 0.0;
            for _ in 0..n {
                let input = rx
                    .recv()
                    .map_err(|_| anyhow!("assembly thread exited early"))??;
                // Timer starts after recv: recorded step time covers
                // literal conversion + execute + absorb, keeping assembly
                // (and any wait for it) off the measured path.
                let timer = Timer::start();
                sum += ctx.dispatch(input, timer)?.0;
            }
            Ok(sum / n as f64)
        })
    }

    /// Borrow this trainer as a data-parallel view that runs every step
    /// through [`ShardedTrainer::step_with`]'s fan-out/reduce path with
    /// `workers` gradient threads. `workers` is capped per step at the
    /// leaf count ([`ModelFront::shard_leaves`]); it is *elastic* config,
    /// deliberately excluded from [`Trainer::config_hash`] — a
    /// checkpoint saved at one N resumes at any other and reproduces the
    /// identical trajectory (see DESIGN.md §13).
    pub fn sharded(&mut self, workers: usize)
                   -> Result<ShardedTrainer<'_, F>> {
        if workers == 0 {
            bail!("worker count must be >= 1 (got 0); omit --workers \
                   for the single-threaded path");
        }
        Ok(ShardedTrainer { tr: self, workers })
    }

    /// One data-parallel training iteration: assemble exactly as the
    /// plain path does (same RNG draws, same artifact choice), fan the
    /// fixed leaf list out over `workers` threads through the shared
    /// executor's `run_grads`, combine per-leaf gradients with the
    /// fixed-order reduction tree, and apply one host-side SGD-momentum
    /// update. Bit-identical across worker counts by construction; NOT
    /// bit-identical to the fused single-graph path (different summation
    /// association), which is why the N=1 identity baseline in tests and
    /// CI is always the sharded path itself.
    fn step_sharded(&mut self, workers: usize, data: &F::Data)
                    -> Result<(f64, f64)> {
        if trace::enabled() {
            trace::set_scope(&self.scope_label());
        }
        let timer = Timer::start();
        let input = {
            let _sp = trace::span("assemble");
            self.front.assemble(data)?
        };
        let StepInput { name, tail, examples, epoch_boundary } = input;
        let exe = self.cache.get(&name)?;
        let batch = exe.meta().batch();
        let leaves = self.front.shard_leaves(batch);
        let rows_per = batch / leaves;
        let nw = workers.min(leaves);
        // Worker threads inherit this job's log attribution as
        // `<job>/w<k>`; standalone runs fall back to the model tag.
        let job = {
            let j = log::current_job();
            if j.is_empty() { self.front.tag().to_string() } else { j }
        };
        let lr_t = HostTensor::scalar_f32(self.lr);
        let reduced = {
            // `host_inputs` immutably borrows the training state; this
            // block scopes the borrow so the SGD apply below can mutate
            // the state again.
            let mut host_inputs: Vec<&HostTensor> = Vec::with_capacity(
                2 * self.state.params.len() + tail.len() + 1);
            for v in self.state.params.iter().chain(&self.state.momenta) {
                host_inputs.push(v.as_host().map_err(|_| {
                    anyhow!("sharded training requires a hermetic host \
                             backend (AD_BACKEND=reference|sparse)")
                })?);
            }
            host_inputs.extend(tail.iter());
            host_inputs.push(&lr_t);
            let _sp = trace::span("execute");
            let exe_ref: &dyn crate::runtime::Executor = exe.as_ref();
            let inputs: &[&HostTensor] = &host_inputs;
            let mut results: Vec<Option<GradOut>> =
                (0..leaves).map(|_| None).collect();
            let mut finish: Vec<Option<Instant>> =
                (0..nw).map(|_| None).collect();
            std::thread::scope(|scope| -> Result<()> {
                let (tx, rx) = std::sync::mpsc::channel();
                for k in 0..nw {
                    let tx = tx.clone();
                    let job = job.clone();
                    scope.spawn(move || {
                        log::set_worker_prefix(&job, k);
                        // Contiguous leaf range for worker k; the leaf
                        // list itself never depends on nw.
                        for l in (k * leaves / nw)..((k + 1) * leaves / nw)
                        {
                            let out = exe_ref.run_grads(
                                inputs,
                                &LeafSpec { lo: l * rows_per,
                                            rows: rows_per,
                                            global_rows: batch });
                            let failed = out.is_err();
                            if tx.send((k, l, out, Instant::now()))
                                 .is_err() || failed
                            {
                                break;
                            }
                        }
                    });
                }
                drop(tx);
                for _ in 0..leaves {
                    let (k, l, out, at) = rx.recv().map_err(|_| {
                        anyhow!("gradient worker exited without \
                                 reporting")
                    })?;
                    results[l] = Some(out.with_context(
                        || format!("gradient leaf {l} (worker {k})"))?);
                    finish[k] = Some(at);
                }
                Ok(())
            })?;
            // Sync-wait per worker: idle time between its last leaf and
            // the barrier (full collection) completing.
            let t_done = Instant::now();
            for f in finish.into_iter().flatten() {
                registry::WORKER_SYNC_WAIT_S
                    .observe(t_done.saturating_duration_since(f)
                             .as_secs_f64());
            }
            registry::ALLREDUCE_TOTAL.inc();
            tree_reduce(results.into_iter()
                            .map(|r| r.expect("every leaf reported"))
                            .collect(),
                        reduce_grad_pair)
                .ok_or_else(|| anyhow!("batch produced no gradient \
                                        leaves"))?
        };
        if reduced.grads.len() != self.state.metas.len() {
            bail!("reduction produced {} gradient tensors, model has {}",
                  reduced.grads.len(), self.state.metas.len());
        }
        // Host-side SGD-momentum, identical formula to the fused step:
        // m' = mu*m + g; p' = p - lr*m'. Two phases so the read borrows
        // end before the state is overwritten.
        let mu = self.cache.manifest().momentum as f32;
        let backend = self.cache.backend().clone();
        {
            let _sp = trace::span("sgd");
            let mut updates = Vec::with_capacity(reduced.grads.len());
            for (i, g) in reduced.grads.iter().enumerate() {
                let p = self.state.params[i].as_host()?.as_f32()?;
                let m = self.state.momenta[i].as_host()?.as_f32()?;
                if p.len() != g.len() {
                    bail!("gradient {} has {} elements, parameter {} \
                           has {}", i, g.len(),
                          self.state.metas[i].name, p.len());
                }
                let mut np = Vec::with_capacity(p.len());
                let mut nm = Vec::with_capacity(p.len());
                for j in 0..p.len() {
                    let mv = mu * m[j] + g[j];
                    nm.push(mv);
                    np.push(p[j] - self.lr * mv);
                }
                updates.push((np, nm));
            }
            for (i, (np, nm)) in updates.into_iter().enumerate() {
                let shape = self.state.metas[i].shape.clone();
                self.state.params[i] =
                    backend.ingest(HostTensor::f32(&shape, np))?;
                self.state.momenta[i] =
                    backend.ingest(HostTensor::f32(&shape, nm))?;
            }
        }
        self.state.step += 1;
        let loss = (reduced.loss_sum / examples as f64) as f32 as f64;
        let correct = reduced.correct as f64;
        registry::DISPATCH_TOTAL
            .inc(&format!("{}/{name}", backend.name()));
        self.metrics.record(self.state.step, loss, correct, examples,
                            timer.elapsed_s());
        self.metrics.dispatched.push(name);
        if epoch_boundary {
            self.epochs_done += 1;
            if self.epochs_done > self.decay_after {
                self.lr *= self.lr_decay;
            }
        }
        Ok((loss, correct / examples as f64))
    }

    /// FNV-1a hash of the session's canonical fingerprint: the front's
    /// config line plus the driver hyper-parameters and parameter schema.
    /// Stored in checkpoints; `restore` rejects a mismatch.
    pub fn config_hash(&self) -> u64 {
        let metas: Vec<String> = self
            .state
            .metas
            .iter()
            .map(|t| format!("{}:{:?}", t.name, t.shape))
            .collect();
        fnv1a64(&format!("{} | lr0_bits={} lr_decay={} decay_after={} \
                          | {}",
                         self.front.config_line(), self.lr0.to_bits(),
                         self.lr_decay, self.decay_after,
                         metas.join(",")))
    }

    /// Capture the full resumable session state — see
    /// `service::checkpoint` for what a checkpoint contains and why.
    /// Works on any backend (`Value::to_f32` copies device-resident
    /// params back to host).
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let dump = |vals: &[Value]| -> Result<Vec<TensorCkpt>> {
            vals.iter()
                .zip(&self.state.metas)
                .map(|(v, m)| {
                    Ok(TensorCkpt {
                        name: m.name.clone(),
                        shape: m.shape.clone(),
                        data: v.to_f32().with_context(
                            || format!("checkpointing {}", m.name))?,
                    })
                })
                .collect()
        };
        let tail_at = self.metrics.dispatched.len()
            .saturating_sub(DISPATCH_TAIL);
        Ok(Checkpoint {
            version: CKPT_VERSION,
            config_hash: self.config_hash(),
            backend: self.cache.backend().name().to_string(),
            step: self.state.step,
            epochs_done: self.epochs_done,
            lr: self.lr,
            front: self.front.snapshot(),
            params: dump(&self.state.params)?,
            momenta: dump(&self.state.momenta)?,
            dispatch_total: self.metrics.dispatched.len(),
            dispatch_tail: self.metrics.dispatched[tail_at..].to_vec(),
        })
    }

    /// `checkpoint()` + atomic write to `path`.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.checkpoint()?.save(path)
    }

    /// Overwrite this trainer's state with a checkpoint, after verifying
    /// the format version and config hash. The trainer must have been
    /// constructed with the same configuration (same constructor
    /// arguments); continuing afterwards reproduces, bit for bit, the
    /// trajectory the checkpointed run would have produced without the
    /// interruption. Metrics restart empty — curve/dispatch entries
    /// recorded after a resume carry absolute step numbers, and the
    /// checkpoint's `dispatch_tail` stays available for cross-checking.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> Result<()> {
        if ckpt.version != CKPT_VERSION {
            bail!("checkpoint version {} unsupported (expected \
                   {CKPT_VERSION})", ckpt.version);
        }
        let want = self.config_hash();
        if ckpt.config_hash != want {
            bail!("checkpoint config hash {:016x} does not match this \
                   trainer's configuration {want:016x} — refusing to \
                   resume a different experiment (tag/variant/rates/\
                   support/seed/lr-policy must all match)",
                  ckpt.config_hash);
        }
        if ckpt.params.len() != self.state.metas.len()
            || ckpt.momenta.len() != self.state.metas.len()
        {
            bail!("checkpoint has {} params / {} momenta, model has {}",
                  ckpt.params.len(), ckpt.momenta.len(),
                  self.state.metas.len());
        }
        let backend = self.cache.backend().clone();
        let ingest = |ts: &[TensorCkpt]| -> Result<Vec<Value>> {
            ts.iter()
                .zip(&self.state.metas)
                .map(|(t, m)| {
                    if t.shape != m.shape || t.name != m.name {
                        bail!("checkpoint tensor {}:{:?} does not match \
                               model tensor {}:{:?}", t.name, t.shape,
                              m.name, m.shape);
                    }
                    backend.ingest(HostTensor::f32(&t.shape,
                                                   t.data.clone()))
                })
                .collect()
        };
        // Validate both halves fully before mutating anything: a failed
        // restore must leave the trainer as it was.
        let params = ingest(&ckpt.params)?;
        let momenta = ingest(&ckpt.momenta)?;
        self.front.restore(&ckpt.front)?;
        self.state.params = params;
        self.state.momenta = momenta;
        self.state.step = ckpt.step;
        self.lr = ckpt.lr;
        self.epochs_done = ckpt.epochs_done;
        self.metrics = TrainMetrics::default();
        Ok(())
    }

    /// Load a `*.ckpt` file and [`Trainer::restore`] from it.
    pub fn resume_from(&mut self, path: &Path) -> Result<()> {
        let ckpt = Checkpoint::load(path)?;
        self.restore(&ckpt)
            .with_context(|| format!("resuming from {}", path.display()))
    }

    /// Evaluate through the dropout-free `<tag>_eval` graph; returns
    /// (mean per-batch loss, accuracy in [0,1]).
    pub fn evaluate_with(&mut self, data: &F::EvalData) -> Result<(f64, f64)> {
        let name = format!("{}_eval", self.front.tag());
        let exe = self.cache.get(&name)?;
        let per_batch = self.front.eval_examples_per_batch() as f64;
        let num_batches = self.front.eval_num_batches(data);
        if num_batches == 0 {
            // A silent (0, 0) here would read as a perfect model
            // (perplexity 1.0); make an undersized eval set loud instead.
            bail!("{}: eval data yields no full batch (need at least {} \
                   examples)", self.front.tag(),
                  self.front.eval_examples_per_batch());
        }
        let mut total_loss = 0.0;
        let mut total_correct = 0.0;
        let mut n = 0.0f64;
        for bi in 0..num_batches {
            let b = self.front.eval_batch(data, bi)?;
            let vals: Vec<Value> = b
                .into_iter()
                .map(|t| self.cache.backend().ingest(t))
                .collect::<Result<_>>()?;
            let (loss, correct) = self.state.eval_step(exe.as_ref(),
                                                       &vals)?;
            total_loss += loss;
            total_correct += correct;
            n += 1.0;
        }
        Ok((total_loss / n, total_correct / (n * per_batch)))
    }
}

/// Borrowed data-parallel view over a [`Trainer`], created by
/// [`Trainer::sharded`]. Every step fans the fixed leaf partition of the
/// global batch out across `workers` threads and combines gradients
/// through the fixed-order reduction tree (`coordinator::reduce`), so
/// trajectories are bit-identical for any worker count — `workers` tunes
/// wall-clock only. Checkpoint/resume stays on the underlying trainer:
/// drop the view, save or restore, and re-borrow at any N (elastic
/// resume; N is not part of the config hash).
pub struct ShardedTrainer<'a, F: ModelFront> {
    tr: &'a mut Trainer<F>,
    workers: usize,
}

impl<F: ModelFront> ShardedTrainer<'_, F> {
    /// Requested worker count (the per-step fan-out additionally caps at
    /// the batch's leaf count).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// One data-parallel iteration; returns (loss, accuracy in [0,1]).
    pub fn step_with(&mut self, data: &F::Data) -> Result<(f64, f64)> {
        self.tr.step_sharded(self.workers, data)
    }

    /// Run `n` sharded steps; returns mean loss over the window.
    pub fn train_with(&mut self, data: &F::Data, n: usize) -> Result<f64> {
        let mut sum = 0.0;
        for _ in 0..n {
            sum += self.step_with(data)?.0;
        }
        Ok(sum / n.max(1) as f64)
    }
}
