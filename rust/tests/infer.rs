//! Inference-serving tests: dynamic micro-batching parity, registry
//! validation, and the request-front error contract.
//!
//! Hermetic: runs on the in-process backends (reference and
//! structured-sparse) over the built-in synthetic manifest. The central
//! property pinned here is the micro-batching correctness contract —
//! a request answered from a coalesced multi-request dispatch carries
//! the exact bits a solo dispatch of that request would produce.

use std::path::{Path, PathBuf};

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::Manifest;
use approx_dropout::service::checkpoint::Checkpoint;
use approx_dropout::service::{Example, InferConfig, InferRequest,
                              InferServer, ModelSpec};

fn caches() -> Vec<(&'static str, ExecutorCache)> {
    vec![
        ("reference", ExecutorCache::reference(Manifest::builtin_test())),
        ("sparse", ExecutorCache::sparse(Manifest::builtin_test())),
    ]
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ad-infer-{}-{tag}",
                                              std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Train a few mlpsyn steps and checkpoint — the weights the registry
/// serves. Short on purpose: serving correctness does not depend on
/// model quality.
fn mlp_ckpt(cache: &ExecutorCache, dir: &Path, name: &str) -> PathBuf {
    let data = MnistSyn::generate(64, 3);
    let schedule =
        Schedule::new(Variant::Rdp, &[0.25, 0.25], &[1, 2], true).unwrap();
    let mut tr =
        MlpTrainer::new(cache, "mlpsyn", schedule, data.n, 0.01, 7)
            .unwrap();
    tr.warmup().unwrap();
    tr.train_with(&data, 3).unwrap();
    let p = dir.join(format!("{name}.ckpt"));
    tr.save_checkpoint(&p).unwrap();
    p
}

fn lstm_ckpt(cache: &ExecutorCache, corpus: &Corpus, dir: &Path,
             name: &str) -> PathBuf {
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true).unwrap();
    let mut tr =
        LstmTrainer::new(cache, "lstmtest", schedule, &corpus.train, 0.5, 7)
            .unwrap();
    tr.warmup().unwrap();
    tr.train(3).unwrap();
    let p = dir.join(format!("{name}.ckpt"));
    tr.save_checkpoint(&p).unwrap();
    p
}

/// Distinct single-image requests (mlpsyn: 784 pixels, 10 classes).
fn mlp_examples(n: usize) -> Vec<Example> {
    let d = MnistSyn::generate(n, 5);
    (0..n)
        .map(|i| Example::Mlp {
            x: d.image(i).to_vec(),
            y: d.labels[i] as i32,
        })
        .collect()
}

/// Consecutive 5-token windows of the validation split (lstmtest).
fn lstm_examples(corpus: &Corpus, n: usize) -> Vec<Example> {
    let seq = 5;
    (0..n)
        .map(|i| {
            let s = i * seq;
            Example::Lstm {
                x: corpus.valid[s..s + seq].to_vec(),
                y: corpus.valid[s + 1..s + seq + 1].to_vec(),
            }
        })
        .collect()
}

fn request(ex: &Example) -> InferRequest {
    InferRequest { model: "m".into(), example: ex.clone() }
}

fn spec(tag: &str, ckpt: &Path) -> ModelSpec {
    ModelSpec {
        name: "m".into(),
        tag: tag.into(),
        ckpt: ckpt.to_path_buf(),
        expect_hash: None,
    }
}

/// The acceptance property: results from coalesced dispatches are
/// bit-identical to sequential single-request serving, on both hermetic
/// backends, for both architectures — and the coalesced server actually
/// batched (observed max batch > 1).
#[test]
fn coalesced_results_match_sequential_bit_for_bit() {
    let dir = tmp_dir("parity");
    let corpus = Corpus::generate(64, 4000, 400, 400, 9);
    for (bname, cache) in caches() {
        for model in ["mlp", "lstm"] {
            let (ckpt, tag, examples) = if model == "mlp" {
                (mlp_ckpt(&cache, &dir, &format!("{bname}-mlp")),
                 "mlpsyn", mlp_examples(6))
            } else {
                (lstm_ckpt(&cache, &corpus, &dir,
                           &format!("{bname}-lstm")),
                 "lstmtest", lstm_examples(&corpus, 6))
            };
            let sp = spec(tag, &ckpt);

            // Sequential truth: max_batch = 1, one dispatch per request.
            let solo = InferServer::start(
                &cache, std::slice::from_ref(&sp),
                &InferConfig { slots: 1, max_batch: 1 }).unwrap();
            let mut seq = Vec::new();
            for ex in &examples {
                let r = solo.submit(request(ex)).unwrap()
                    .recv().unwrap().unwrap();
                assert_eq!(r.batch, 1, "{bname}/{model}: solo dispatch");
                seq.push((r.loss, r.correct));
            }
            let st = solo.stats().into_iter().next().unwrap();
            assert_eq!(st.served, examples.len());
            assert_eq!(st.max_batch_observed, 1);
            drop(solo);

            // Coalesced: hold the server's only slot while every request
            // queues, so the worker wakes with a full queue — the
            // concurrent-load shape, made deterministic.
            let srv = InferServer::start(
                &cache, std::slice::from_ref(&sp),
                &InferConfig { slots: 1, max_batch: 0 }).unwrap();
            let hold = srv.gate().acquire();
            let tickets: Vec<_> = examples.iter()
                .map(|ex| srv.submit(request(ex)).unwrap())
                .collect();
            drop(hold);
            let mut max_batch = 0;
            for (i, t) in tickets.into_iter().enumerate() {
                let r = t.recv().unwrap().unwrap();
                max_batch = max_batch.max(r.batch);
                assert!(r.latency_s >= 0.0);
                assert_eq!(r.loss.to_bits(), seq[i].0.to_bits(),
                           "{bname}/{model} request {i}: loss changed \
                            under batching ({} vs {})", r.loss, seq[i].0);
                assert_eq!(r.correct.to_bits(), seq[i].1.to_bits(),
                           "{bname}/{model} request {i}: correct changed \
                            under batching");
            }
            assert!(max_batch > 1,
                    "{bname}/{model}: queued requests never coalesced");
            assert_eq!(srv.stats()[0].max_batch_observed, max_batch);
            assert_eq!(srv.stats()[0].served, examples.len());
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Registry load is the fail-fast boundary: pinned-hash mismatches and
/// tag/checkpoint schema mismatches reject at `start`, never as a shape
/// panic on the first request.
#[test]
fn registry_rejects_mismatched_checkpoints() {
    let dir = tmp_dir("registry");
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    let ckpt = mlp_ckpt(&cache, &dir, "reg");
    let loaded = Checkpoint::load(&ckpt).unwrap();

    // Pinned to the right config hash: serves.
    let mut ok = spec("mlpsyn", &ckpt);
    ok.expect_hash = Some(loaded.config_hash);
    let srv = InferServer::start(&cache, std::slice::from_ref(&ok),
                                 &InferConfig::default()).unwrap();
    assert_eq!(srv.stats()[0].config_hash, loaded.config_hash);
    assert_eq!(srv.stats()[0].step, 3);
    drop(srv);

    // Pinned to a different config: rejected with the hashes named.
    let mut bad = spec("mlpsyn", &ckpt);
    bad.expect_hash = Some(loaded.config_hash ^ 1);
    let err = InferServer::start(&cache, std::slice::from_ref(&bad),
                                 &InferConfig::default())
        .unwrap_err().to_string();
    assert!(err.contains("does not match the pinned hash"), "{err}");

    // An MLP checkpoint cannot serve an LSTM tag (schema mismatch).
    let cross = spec("lstmtest", &ckpt);
    let err = format!("{:#}", InferServer::start(
        &cache, std::slice::from_ref(&cross),
        &InferConfig::default()).unwrap_err());
    assert!(err.to_lowercase().contains("param"), "{err}");

    // A future-format checkpoint is rejected by version, not parsed on
    // hope.
    let mut future = loaded.clone();
    future.version = 99;
    let fpath = dir.join("future.ckpt");
    future.save(&fpath).unwrap();
    let err = format!("{:#}", InferServer::start(
        &cache, std::slice::from_ref(&spec("mlpsyn", &fpath)),
        &InferConfig::default()).unwrap_err());
    assert!(err.contains("version 99"), "{err}");

    // Duplicate model names cannot both register.
    let err = InferServer::start(
        &cache, &[spec("mlpsyn", &ckpt), spec("mlpsyn", &ckpt)],
        &InferConfig::default()).unwrap_err().to_string();
    assert!(err.contains("duplicate"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

/// Submit-time validation: malformed requests are caller errors and
/// never reach (or poison) a worker's batch.
#[test]
fn submit_rejects_malformed_requests() {
    let dir = tmp_dir("submit");
    let cache = ExecutorCache::reference(Manifest::builtin_test());
    let ckpt = mlp_ckpt(&cache, &dir, "sub");
    let srv = InferServer::start(&cache, &[spec("mlpsyn", &ckpt)],
                                 &InferConfig::default()).unwrap();

    // Unknown model names the registry contents.
    let err = srv.submit(InferRequest {
        model: "nope".into(),
        example: Example::Mlp { x: vec![0.0; 784], y: 0 },
    }).unwrap_err().to_string();
    assert!(err.contains("no model 'nope'") && err.contains("serving: m"),
            "{err}");

    // Wrong pixel count.
    assert!(srv.submit(request(&Example::Mlp { x: vec![0.0; 3], y: 0 }))
        .is_err());
    // Label out of range (mlpsyn has 10 classes).
    assert!(srv.submit(request(&Example::Mlp { x: vec![0.0; 784], y: 10 }))
        .is_err());
    assert!(srv.submit(request(&Example::Mlp { x: vec![0.0; 784], y: -1 }))
        .is_err());
    // Architecture mismatch.
    assert!(srv.submit(request(&Example::Lstm { x: vec![0; 5],
                                                y: vec![0; 5] }))
        .is_err());

    // The server is still healthy after every rejection.
    let r = srv.submit(request(&mlp_examples(1)[0])).unwrap()
        .recv().unwrap().unwrap();
    assert_eq!(r.model, "m");
    assert!(r.loss.is_finite());
    assert_eq!(srv.stats()[0].served, 1,
               "rejected submits must not count as served");

    std::fs::remove_dir_all(&dir).ok();
}
