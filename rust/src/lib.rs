//! # approx-dropout
//!
//! Production-grade reproduction of **"Approximate Random Dropout for DNN
//! training acceleration in GPGPU"** (Song, Wang, Yu, Huang, Peng, Jiang —
//! 2018) on a Rust + JAX + Pallas three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): compact/tiled
//!   matmuls whose BlockSpecs fetch only kept data.
//! * **L2** — JAX train-step graphs (`python/compile/model.py`), AOT-lowered
//!   to HLO text, one executable per `(model, variant, dp)`.
//! * **L3** — this crate: the coordinator that samples dropout patterns
//!   from the searched distribution K and drives PJRT.
//!
//! See DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for measured paper-vs-repro results.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod obs;
pub mod patterns;
pub mod runtime;
pub mod search;
pub mod service;
pub mod util;

/// Crate version (from Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory: `$AD_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("AD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts")
        })
}

/// The artifacts manifest when one exists, else the built-in synthetic
/// registry ([`runtime::Manifest::builtin_test`]) — which only the
/// hermetic host backends (reference, sparse) can execute. When the
/// effective backend is PJRT
/// (per `AD_BACKEND` / the `pjrt` feature default) a missing manifest
/// stays a loud fail-fast error: falling back would only defer it to an
/// opaque HLO-file-not-found at first compile.
pub fn manifest_or_builtin() -> anyhow::Result<runtime::Manifest> {
    let dir = artifacts_dir();
    match runtime::Manifest::load(&dir) {
        Ok(m) => Ok(m),
        Err(e) => {
            // Same selection rule as backend_from_env — and a typo'd
            // AD_BACKEND surfaces as itself here, not as a
            // missing-artifacts complaint.
            if !runtime::backend::env_selects_hermetic()? {
                return Err(e.context(
                    "no artifacts manifest and the PJRT backend needs \
                     one (run `make artifacts`, or set \
                     AD_BACKEND=reference or AD_BACKEND=sparse for the \
                     built-in registry)"));
            }
            crate::info!("no artifacts manifest at {} ({e:#}); using the \
                          built-in synthetic registry", dir.display());
            Ok(runtime::Manifest::builtin_test())
        }
    }
}
