//! Training metrics: loss/accuracy curves, step timing, and the
//! speedup-rate computation reported by every experiment table.

use crate::util::stats;

/// One recorded training point.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub step: u64,
    pub loss: f64,
    /// Batch train accuracy in [0,1].
    pub acc: f64,
}

#[derive(Clone, Debug, Default)]
pub struct TrainMetrics {
    pub curve: Vec<CurvePoint>,
    /// Per-iteration wall-clock seconds (full step: pattern sampling, mask
    /// or index generation, data marshalling, backend execute, state
    /// update).
    pub step_times_s: Vec<f64>,
    /// Artifact name dispatched at each recorded step, in order — the
    /// observable the paper's pattern->executable mapping produces. Tests
    /// pin that this sequence is seed-deterministic and identical across
    /// backends.
    pub dispatched: Vec<String>,
    pub total_correct: f64,
    pub total_examples: f64,
}

impl TrainMetrics {
    pub fn record(&mut self, step: u64, loss: f64, correct: f64,
                  batch: usize, dt_s: f64) {
        self.curve.push(CurvePoint { step, loss,
                                     acc: correct / batch as f64 });
        self.step_times_s.push(dt_s);
        self.total_correct += correct;
        self.total_examples += batch as f64;
    }

    pub fn steps(&self) -> usize {
        self.step_times_s.len()
    }

    /// Median step time — robust against compile/warmup outliers.
    /// A run with zero recorded steps has no step time: explicitly NaN
    /// (rendered as JSON `null` by the report writer, `-` in summary
    /// tables), never a silent 0.0 that reads as infinitely fast.
    pub fn median_step_s(&self) -> f64 {
        if self.step_times_s.is_empty() {
            return f64::NAN;
        }
        stats::median(&self.step_times_s)
    }

    /// Mean step time excluding the first `skip` (warmup) iterations.
    /// NaN on an empty run, like [`Self::median_step_s`].
    pub fn steady_mean_step_s(&self, skip: usize) -> f64 {
        if self.step_times_s.is_empty() {
            return f64::NAN;
        }
        if self.step_times_s.len() <= skip {
            return stats::mean(&self.step_times_s);
        }
        stats::mean(&self.step_times_s[skip..])
    }

    pub fn total_time_s(&self) -> f64 {
        self.step_times_s.iter().sum()
    }

    pub fn running_train_acc(&self) -> f64 {
        if self.total_examples == 0.0 {
            return 0.0;
        }
        self.total_correct / self.total_examples
    }

    pub fn last_loss(&self) -> f64 {
        self.curve.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    /// Mean loss over the last `n` recorded steps (pipelined chunk
    /// reporting).
    pub fn window_mean_loss(&self, n: usize) -> f64 {
        if self.curve.is_empty() {
            return f64::NAN;
        }
        let k = n.min(self.curve.len()).max(1);
        self.curve[self.curve.len() - k..]
            .iter()
            .map(|p| p.loss)
            .sum::<f64>()
            / k as f64
    }
}

/// Speedup of `ours` over `baseline` given per-step times (paper's
/// definition: t_conventional / t_ours).
pub fn speedup(baseline_step_s: f64, ours_step_s: f64) -> f64 {
    if ours_step_s <= 0.0 {
        return f64::NAN;
    }
    baseline_step_s / ours_step_s
}

/// Perplexity from mean token cross-entropy (nats).
pub fn perplexity(xent_nats: f64) -> f64 {
    xent_nats.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_aggregate() {
        let mut m = TrainMetrics::default();
        m.record(1, 2.0, 64.0, 128, 0.10);
        m.record(2, 1.5, 96.0, 128, 0.12);
        m.record(3, 1.0, 120.0, 128, 0.11);
        assert_eq!(m.steps(), 3);
        assert!((m.median_step_s() - 0.11).abs() < 1e-12);
        assert!((m.running_train_acc() - (280.0 / 384.0)).abs() < 1e-12);
        assert_eq!(m.last_loss(), 1.0);
        assert!((m.total_time_s() - 0.33).abs() < 1e-12);
    }

    #[test]
    fn empty_run_step_times_are_explicitly_nan() {
        // Regression: stats::median/mean return 0.0 on empty input, so
        // a zero-step run used to report a 0.0s median step — which
        // reads as infinitely fast. NaN flows through the PR 7
        // non-finite path to JSON null / table `-`.
        let m = TrainMetrics::default();
        assert!(m.median_step_s().is_nan());
        assert!(m.steady_mean_step_s(0).is_nan());
        assert!(m.steady_mean_step_s(5).is_nan());
        assert_eq!(crate::util::json::Json::num(m.median_step_s()).dumps(),
                   "null");
    }

    #[test]
    fn steady_mean_skips_warmup() {
        let mut m = TrainMetrics::default();
        m.record(1, 0.0, 0.0, 1, 10.0); // compile spike
        m.record(2, 0.0, 0.0, 1, 0.1);
        m.record(3, 0.0, 0.0, 1, 0.1);
        assert!((m.steady_mean_step_s(1) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn window_mean_loss_over_tail() {
        let mut m = TrainMetrics::default();
        assert!(m.window_mean_loss(3).is_nan());
        m.record(1, 4.0, 0.0, 1, 0.1);
        m.record(2, 2.0, 0.0, 1, 0.1);
        m.record(3, 1.0, 0.0, 1, 0.1);
        assert!((m.window_mean_loss(2) - 1.5).abs() < 1e-12);
        assert!((m.window_mean_loss(10) - (7.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn speedup_definition() {
        assert!((speedup(2.0, 1.0) - 2.0).abs() < 1e-12);
        assert!((speedup(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert!(speedup(1.0, 0.0).is_nan());
    }

    #[test]
    fn perplexity_of_uniform() {
        let v = 100.0f64;
        assert!((perplexity(v.ln()) - 100.0).abs() < 1e-9);
    }
}
