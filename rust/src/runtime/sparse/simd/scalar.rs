//! Portable scalar microkernels — the fallback every build carries and
//! the `AD_SIMD=off` escape hatch.
//!
//! Bit-compatibility contract: these loops perform exactly the
//! operations the pre-SIMD sparse kernels (and `DenseKernels`) perform —
//! plain multiply-then-add (never `mul_add`: fusing would change
//! rounding), strictly ascending index order, one accumulator — so a
//! scalar-microkernel sparse backend reproduces the reference backend
//! bit-for-bit wherever it did before. The unrolling below is safe for
//! that contract: `axpy`/`axpy2` touch each output element independently
//! (unroll order cannot change any result bit), and `dot_acc` keeps a
//! single accumulator chain.

use super::Microkernel;

pub static SCALAR: Microkernel = Microkernel {
    name: "scalar",
    axpy,
    axpy2,
    dot_acc,
};

const UNROLL: usize = 8;

/// `y[i] += a * x[i]`.
///
/// # Safety
/// `x` and `y` must be valid for `n` reads / read-writes.
unsafe fn axpy(a: f32, x: *const f32, y: *mut f32, n: usize) {
    let x = std::slice::from_raw_parts(x, n);
    let y = std::slice::from_raw_parts_mut(y, n);
    let mut chunks_x = x.chunks_exact(UNROLL);
    let mut chunks_y = y.chunks_exact_mut(UNROLL);
    for (cx, cy) in (&mut chunks_x).zip(&mut chunks_y) {
        for (o, &v) in cy.iter_mut().zip(cx) {
            *o += a * v;
        }
    }
    for (o, &v) in chunks_y.into_remainder().iter_mut()
        .zip(chunks_x.remainder())
    {
        *o += a * v;
    }
}

/// `y[i] += a0 * x0[i] + a1 * x1[i]`, as two adds per element (the exact
/// result of two sequential `axpy` passes).
///
/// # Safety
/// `x0`, `x1`, `y` must be valid for `n` reads / read-writes.
unsafe fn axpy2(a0: f32, x0: *const f32, a1: f32, x1: *const f32,
                y: *mut f32, n: usize) {
    let x0 = std::slice::from_raw_parts(x0, n);
    let x1 = std::slice::from_raw_parts(x1, n);
    let y = std::slice::from_raw_parts_mut(y, n);
    for i in 0..n {
        let v = y[i] + a0 * x0[i];
        y[i] = v + a1 * x1[i];
    }
}

/// `init + Σ x[i] * y[i]` with one sequential accumulator chain.
///
/// # Safety
/// `x` and `y` must be valid for `n` reads.
unsafe fn dot_acc(init: f32, x: *const f32, y: *const f32, n: usize)
                  -> f32 {
    let x = std::slice::from_raw_parts(x, n);
    let y = std::slice::from_raw_parts(y, n);
    let mut acc = init;
    let mut cx = x.chunks_exact(UNROLL);
    let mut cy = y.chunks_exact(UNROLL);
    for (a, b) in (&mut cx).zip(&mut cy) {
        for (&u, &v) in a.iter().zip(b) {
            acc += u * v;
        }
    }
    for (&u, &v) in cx.remainder().iter().zip(cy.remainder()) {
        acc += u * v;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive_loops_bitwise() {
        let n = 21; // crosses the unroll width + tail
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 1.3).sin()).collect();
        let z: Vec<f32> = (0..n).map(|i| (i as f32 * 0.9).cos()).collect();
        let mut y: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let mut want = y.clone();
        for (o, &v) in want.iter_mut().zip(&x) {
            *o += 0.75 * v;
        }
        SCALAR.axpy(0.75, &x, &mut y);
        assert_eq!(y, want);

        let mut naive = 0.5f32;
        for (&u, &v) in x.iter().zip(&z) {
            naive += u * v;
        }
        assert_eq!(SCALAR.dot_acc(0.5, &x, &z), naive);

        let mut via_two = y.clone();
        SCALAR.axpy(0.2, &x, &mut via_two);
        SCALAR.axpy(-0.4, &z, &mut via_two);
        let mut fused = y.clone();
        SCALAR.axpy2(0.2, &x, -0.4, &z, &mut fused);
        assert_eq!(via_two, fused);
    }
}
