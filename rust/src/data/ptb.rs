//! Synthetic Penn-Treebank-like corpus (DESIGN.md section 5/6).
//!
//! Offline substitute for PTB / the paper's 8800-word corpus: a vocabulary
//! with Zipf(1.0) unigram weights and a seeded sparse bigram structure.
//! Each token `t` has 8 preferred successors (derived from a hash of `t`)
//! with geometric weights; generation mixes bigram choice (60%), a skip
//! connection to the second-to-last token's successor table (15%), and a
//! Zipf unigram draw (25%). An LSTM can exploit the bigram/skip structure
//! to reach perplexity well below the unigram baseline, so differences
//! between dropout variants are measurable — which is the quantity the
//! paper's Tables II / Fig 6 compare.

use crate::util::rng::{Rng, SplitMix64};

#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub train: Vec<i32>,
    pub valid: Vec<i32>,
    pub test: Vec<i32>,
}

/// Successors per token in the bigram table.
const FANOUT: usize = 8;
const P_BIGRAM: f64 = 0.60;
const P_SKIP: f64 = 0.15;

#[derive(Clone, Debug)]
pub struct LmGenerator {
    vocab: usize,
    /// Cumulative Zipf distribution for unigram draws.
    zipf_cdf: Vec<f64>,
    seed: u64,
}

impl LmGenerator {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 16);
        let mut weights: Vec<f64> =
            (0..vocab).map(|i| 1.0 / (i as f64 + 2.0)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        LmGenerator { vocab, zipf_cdf: weights, seed }
    }

    fn zipf(&self, rng: &mut Rng) -> i32 {
        self.zipf_inv(rng.next_f64())
    }

    /// The j-th preferred successor of token `t` (deterministic in seed).
    /// Successors are drawn from the Zipf marginal (via inverse-CDF of a
    /// hash-derived uniform), so the corpus stays head-heavy overall while
    /// carrying exploitable bigram structure.
    fn successor(&self, t: i32, j: usize) -> i32 {
        let mut h = SplitMix64::new(
            self.seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (j as u64) << 32,
        );
        let u = (h.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.zipf_inv(u)
    }

    /// Inverse CDF lookup shared by `zipf` and `successor`.
    fn zipf_inv(&self, u: f64) -> i32 {
        let mut lo = 0usize;
        let mut hi = self.vocab - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.zipf_cdf[mid] < u {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as i32
    }

    /// Geometric pick among the FANOUT successors.
    fn pick_successor(&self, t: i32, rng: &mut Rng) -> i32 {
        let mut j = 0;
        while j + 1 < FANOUT && rng.bernoulli(0.45) {
            j += 1;
        }
        self.successor(t, j)
    }

    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut prev = self.zipf(rng);
        let mut prev2 = self.zipf(rng);
        for _ in 0..n {
            let u = rng.next_f64();
            let next = if u < P_BIGRAM {
                self.pick_successor(prev, rng)
            } else if u < P_BIGRAM + P_SKIP {
                self.pick_successor(prev2, rng)
            } else {
                self.zipf(rng)
            };
            out.push(next);
            prev2 = prev;
            prev = next;
        }
        out
    }
}

impl Corpus {
    /// Generate a train/valid/test split, PTB-like proportions.
    pub fn generate(vocab: usize, n_train: usize, n_valid: usize,
                    n_test: usize, seed: u64) -> Self {
        let lm = LmGenerator::new(vocab, seed);
        let mut rng = Rng::new(seed ^ 0x5151_5151);
        Corpus {
            vocab,
            train: lm.generate(n_train, &mut rng),
            valid: lm.generate(n_valid, &mut rng),
            test: lm.generate(n_test, &mut rng),
        }
    }

    /// Unigram cross-entropy (nats/token) of `tokens` under the train-split
    /// empirical unigram model — the baseline an LSTM must beat.
    pub fn unigram_xent(&self, tokens: &[i32]) -> f64 {
        let mut counts = vec![1.0f64; self.vocab]; // +1 smoothing
        for &t in &self.train {
            counts[t as usize] += 1.0;
        }
        let total: f64 = counts.iter().sum();
        let logp: Vec<f64> =
            counts.iter().map(|c| (c / total).ln()).collect();
        -tokens.iter().map(|&t| logp[t as usize]).sum::<f64>()
            / tokens.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::generate(512, 5000, 500, 500, 1);
        let b = Corpus::generate(512, 5000, 500, 500, 1);
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
    }

    #[test]
    fn tokens_in_range_and_splits_sized() {
        let c = Corpus::generate(1000, 2000, 300, 400, 7);
        assert_eq!(c.train.len(), 2000);
        assert_eq!(c.valid.len(), 300);
        assert_eq!(c.test.len(), 400);
        for split in [&c.train, &c.valid, &c.test] {
            assert!(split.iter().all(|&t| (0..1000).contains(&t)));
        }
    }

    #[test]
    fn zipf_head_heavy() {
        let c = Corpus::generate(2048, 50_000, 100, 100, 3);
        let head = c.train.iter().filter(|&&t| t < 100).count() as f64
            / c.train.len() as f64;
        assert!(head > 0.25, "head mass {head} too small for Zipf");
    }

    #[test]
    fn bigram_structure_learnable() {
        // The bigram model must beat unigram by a clear margin — otherwise
        // the corpus carries no sequence signal for the LSTM.
        let c = Corpus::generate(512, 100_000, 1000, 10_000, 5);
        let uni = c.unigram_xent(&c.test);

        // Empirical bigram model with backoff to unigram.
        use std::collections::HashMap;
        let mut big: HashMap<(i32, i32), f64> = HashMap::new();
        let mut ctx: HashMap<i32, f64> = HashMap::new();
        for w in c.train.windows(2) {
            *big.entry((w[0], w[1])).or_default() += 1.0;
            *ctx.entry(w[0]).or_default() += 1.0;
        }
        let mut xent = 0.0;
        let mut n = 0.0;
        let lambda = 0.8;
        let mut uni_counts = vec![1.0f64; c.vocab];
        for &t in &c.train {
            uni_counts[t as usize] += 1.0;
        }
        let uni_total: f64 = uni_counts.iter().sum();
        for w in c.test.windows(2) {
            let p_big = big.get(&(w[0], w[1])).copied().unwrap_or(0.0)
                / ctx.get(&w[0]).copied().unwrap_or(1.0);
            let p_uni = uni_counts[w[1] as usize] / uni_total;
            xent -= (lambda * p_big + (1.0 - lambda) * p_uni).ln();
            n += 1.0;
        }
        let bi = xent / n;
        assert!(bi < uni - 0.3,
                "bigram xent {bi:.3} should beat unigram {uni:.3}");
    }

    #[test]
    fn unigram_baseline_below_uniform() {
        let c = Corpus::generate(1024, 30_000, 100, 3000, 9);
        let uni = c.unigram_xent(&c.test);
        let uniform = (1024f64).ln();
        assert!(uni < uniform - 0.5,
                "unigram {uni:.3} vs uniform {uniform:.3}");
    }
}
