//! Property tests of the structured-sparse kernel library: for randomized
//! shapes, skip-lists, and tilings, every sparse kernel equals the dense
//! kernel applied to the correspondingly *masked* operands — the contract
//! that lets one step program (`runtime::step`) run on either backend.
//!
//! Tolerances: with the **scalar** microkernels
//! (`SparseKernels::scalar()`, the `AD_SIMD=off` configuration) the
//! sparse kernels accumulate the shared dimension in the same ascending
//! order as the dense loops and only skip exactly-zero contributions, so
//! most dense-parity comparisons here are `assert_eq` (bitwise), not
//! epsilon checks. The **SIMD** microkernels (AVX2+FMA / NEON) fuse the
//! multiply-add and reduce vector lanes in a fixed but different order,
//! so the SIMD suite at the bottom asserts agreement with the scalar
//! kernels within the 1e-5 relative contract instead — plus bitwise
//! stability of the SIMD results across repetitions.

use approx_dropout::patterns::{RowPattern, TilePattern};
use approx_dropout::runtime::{DenseKernels, Kernels, Skip, SparseKernels};
use approx_dropout::util::rng::Rng;
use approx_dropout::util::testkit::{self, gen_choice, gen_range,
                                    gen_vec_f32};

const D: Skip = Skip::Dense;

/// Zero the columns of `a [m,k]` that `pat` drops (the structural
/// precondition the step program guarantees for masked activations).
fn mask_cols(a: &mut [f32], m: usize, k: usize, pat: &RowPattern) {
    for i in 0..m {
        for p in 0..k {
            if !pat.keeps(p) {
                a[i * k + p] = 0.0;
            }
        }
    }
}

/// `w ∘ mask` for a tile pattern.
fn mask_tiles(w: &[f32], pat: &TilePattern) -> Vec<f32> {
    w.iter().zip(pat.mask()).map(|(&x, m)| x * m).collect()
}

/// Random tile-pattern weight dims valid for dp in {2, 4} at tile 16.
fn gen_tile_dims(rng: &mut Rng) -> (usize, usize) {
    *gen_choice(rng, &[(32usize, 64usize), (64, 32), (64, 64), (32, 128),
                       (128, 32)])
}

/// Relative-tolerance comparison for the SIMD suite (and the tile-NT
/// paths, whose segment reductions reassociate even in scalar mode).
fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&x, &y)) in got.iter().zip(want).enumerate() {
        assert!((x - y).abs() <= tol * x.abs().max(y.abs()).max(1.0),
                "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn gemm_row_skip_equals_dense_on_masked_activations() {
    testkit::quickcheck("gemm row-skip", |rng| {
        let m = gen_range(rng, 1, 12);
        let dp = *gen_choice(rng, &[1usize, 2, 3, 4]);
        let k = dp * gen_range(rng, 1, 20);
        let n = gen_range(rng, 1, 40);
        let b0 = gen_range(rng, 0, dp);
        let pat = RowPattern::new(k, dp, b0);
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        mask_cols(&mut a, m, k, &pat);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let got = SparseKernels::scalar()
            .gemm(&a, &b, m, k, n, &Skip::Rows(pat), &D);
        let want = DenseKernels.gemm(&a, &b, m, k, n, &D, &D);
        assert_eq!(got, want, "m={m} k={k} n={n} dp={dp} b0={b0}");
    });
}

#[test]
fn gemm_tile_skip_equals_dense_on_masked_weight() {
    testkit::quickcheck("gemm tile-skip", |rng| {
        let m = gen_range(rng, 1, 10);
        let (k, n) = gen_tile_dims(rng);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let b0 = gen_range(rng, 0, dp);
        let pat = TilePattern::new(k, n, dp, b0, 16);
        let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let w = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let skip = Skip::Tiles(pat);
        let s = SparseKernels::scalar();
        // Dense kernels require the prepared (masked) weight; sparse
        // kernels take the raw one — that asymmetry IS the contract.
        let wm = DenseKernels.prep_weight(&w, k, n, &skip).unwrap();
        assert_eq!(wm, mask_tiles(&w, &pat));
        assert!(s.prep_weight(&w, k, n, &skip).is_none());
        let got = s.gemm(&a, &w, m, k, n, &skip, &D);
        let want = DenseKernels.gemm(&a, &wm, m, k, n, &skip, &D);
        assert_eq!(got, want, "k={k} n={n} dp={dp} b0={b0}");
    });
}

#[test]
fn gemm_out_skip_computes_kept_columns_only() {
    testkit::quickcheck("gemm out-skip", |rng| {
        let m = gen_range(rng, 1, 10);
        let k = gen_range(rng, 1, 30);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let n = dp * gen_range(rng, 1, 12);
        let b0 = gen_range(rng, 0, dp);
        let q = RowPattern::new(n, dp, b0);
        let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let got = SparseKernels::scalar()
            .gemm(&a, &b, m, k, n, &D, &Skip::Rows(q));
        let full = DenseKernels.gemm(&a, &b, m, k, n, &D, &D);
        for i in 0..m {
            for j in 0..n {
                if q.keeps(j) {
                    assert_eq!(got[i * n + j], full[i * n + j],
                               "kept ({i},{j})");
                } else {
                    assert_eq!(got[i * n + j], 0.0, "dropped ({i},{j})");
                }
            }
        }
    });
}

#[test]
fn gemm_nt_row_and_tile_skips_match_dense() {
    testkit::quickcheck("gemm_nt skips", |rng| {
        // Rows: output columns restricted.
        let m = gen_range(rng, 1, 10);
        let n = gen_range(rng, 1, 30);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let k = dp * gen_range(rng, 1, 10);
        let b0 = gen_range(rng, 0, dp);
        let q = RowPattern::new(k, dp, b0);
        let a = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let s = SparseKernels::scalar();
        let got = s.gemm_nt(&a, &b, m, n, k, &Skip::Rows(q));
        let full = DenseKernels.gemm_nt(&a, &b, m, n, k, &D);
        for i in 0..m {
            for j in 0..k {
                if q.keeps(j) {
                    assert_eq!(got[i * k + j], full[i * k + j]);
                } else {
                    assert_eq!(got[i * k + j], 0.0);
                }
            }
        }

        // Tiles: B tile-masked.
        let (tk2, tn2) = gen_tile_dims(rng);
        let pat = TilePattern::new(tk2, tn2, dp, b0, 16);
        let a2 = gen_vec_f32(rng, m * tn2, -1.0, 1.0);
        let w = gen_vec_f32(rng, tk2 * tn2, -1.0, 1.0);
        let got = s.gemm_nt(&a2, &w, m, tn2, tk2, &Skip::Tiles(pat));
        let want = DenseKernels.gemm_nt(&a2, &mask_tiles(&w, &pat), m,
                                        tn2, tk2, &D);
        assert_close(&got, &want, 1e-6, "nt tiles");
    });
}

#[test]
fn gemm_tn_acc_freezes_dropped_rows_cols_and_tiles() {
    testkit::quickcheck("gemm_tn_acc skips", |rng| {
        let m = gen_range(rng, 1, 10);
        let dpr = *gen_choice(rng, &[2usize, 4]);
        let dpc = *gen_choice(rng, &[1usize, 2]);
        let k = dpr * gen_range(rng, 1, 10);
        let n = dpc * gen_range(rng, 1, 15);
        let pr = RowPattern::new(k, dpr, gen_range(rng, 0, dpr));
        let qc = RowPattern::new(n, dpc, gen_range(rng, 0, dpc));
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        mask_cols(&mut a, m, k, &pr);
        let mut b = gen_vec_f32(rng, m * n, -1.0, 1.0);
        mask_cols(&mut b, m, n, &qc);
        let prior = 0.25f32;
        let mut got = vec![prior; k * n];
        SparseKernels::scalar().gemm_tn_acc(&a, &b, m, k, n,
                                            &Skip::Rows(pr),
                                            &Skip::Rows(qc), &mut got);
        let mut want = vec![prior; k * n];
        DenseKernels.gemm_tn_acc(&a, &b, m, k, n, &D, &D, &mut want);
        assert_eq!(got, want);
        // Dropped gradient rows keep their prior value bit-for-bit (the
        // momentum/param freeze invariant) — under EVERY microkernel:
        // the SIMD panels must never write a dropped row either.
        let mut simd_out = None;
        if let Some(s) = SparseKernels::simd() {
            let mut out = vec![prior; k * n];
            s.gemm_tn_acc(&a, &b, m, k, n, &Skip::Rows(pr),
                          &Skip::Rows(qc), &mut out);
            simd_out = Some(out);
        }
        for p in 0..k {
            if !pr.keeps(p) {
                for j in 0..n {
                    assert_eq!(got[p * n + j], prior);
                    if let Some(out) = &simd_out {
                        assert_eq!(out[p * n + j], prior,
                                   "SIMD wrote dropped row {p}");
                    }
                }
            }
        }
    });
}

#[test]
fn gemm_tn_acc_tiles_matches_dense_masked_accumulation() {
    testkit::quickcheck("gemm_tn_acc tiles", |rng| {
        let m = gen_range(rng, 1, 8);
        let (k, n) = gen_tile_dims(rng);
        let dp = *gen_choice(rng, &[2usize, 4]);
        let b0 = gen_range(rng, 0, dp);
        let pat = TilePattern::new(k, n, dp, b0, 16);
        let a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        let b = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let skip = Skip::Tiles(pat);
        let mut got = vec![1.5f32; k * n];
        SparseKernels::scalar().gemm_tn_acc(&a, &b, m, k, n, &skip, &D,
                                            &mut got);
        let mut want = vec![1.5f32; k * n];
        DenseKernels.gemm_tn_acc(&a, &b, m, k, n, &skip, &D, &mut want);
        assert_eq!(got, want);
        let (gk, gn) = pat.grid();
        for r in 0..gk {
            for c in 0..gn {
                if !pat.keeps_tile(r, c) {
                    let v = got[(r * pat.tr) * n + c * pat.tc];
                    assert_eq!(v, 1.5, "dropped tile ({r},{c})");
                }
            }
        }
    });
}

#[test]
fn gemv_is_the_single_row_gemm() {
    testkit::quickcheck("gemv", |rng| {
        let dp = *gen_choice(rng, &[1usize, 2, 4]);
        let k = dp * gen_range(rng, 1, 16);
        let n = gen_range(rng, 1, 40);
        let pat = RowPattern::new(k, dp, gen_range(rng, 0, dp));
        let mut x = gen_vec_f32(rng, k, -1.0, 1.0);
        mask_cols(&mut x, 1, k, &pat);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);
        let skip = Skip::Rows(pat);
        let got = SparseKernels::scalar().gemv(&x, &b, k, n, &skip, &D);
        let want = DenseKernels.gemm(&x, &b, 1, k, n, &D, &D);
        assert_eq!(got, want);
    });
}

/// Large-enough shapes to actually cross the kernels' parallel threshold
/// (the quickcheck shapes above mostly run inline): exercises the worker
/// pool path end-to-end and re-checks dense parity there.
#[test]
fn parallel_path_matches_dense() {
    let mut rng = Rng::new(1234);
    let (m, k, n) = (64, 256, 192);
    let pat = RowPattern::new(k, 2, 1);
    let mut a = gen_vec_f32(&mut rng, m * k, -1.0, 1.0);
    mask_cols(&mut a, m, k, &pat);
    let b = gen_vec_f32(&mut rng, k * n, -1.0, 1.0);
    let s = SparseKernels::scalar();
    let got = s.gemm(&a, &b, m, k, n, &Skip::Rows(pat), &D);
    let want = DenseKernels.gemm(&a, &b, m, k, n, &D, &D);
    assert_eq!(got, want);

    let b2 = gen_vec_f32(&mut rng, m * n, -1.0, 1.0);
    let mut got = vec![0f32; k * n];
    s.gemm_tn_acc(&a, &b2, m, k, n, &Skip::Rows(pat), &D, &mut got);
    let mut want = vec![0f32; k * n];
    DenseKernels.gemm_tn_acc(&a, &b2, m, k, n, &D, &D, &mut want);
    assert_eq!(got, want);
}

// ---------------------------------------------------------------------------
// SIMD microkernel suite (skips loudly when the CPU has no SIMD)
// ---------------------------------------------------------------------------

/// The tentpole property: for randomized shapes, skips, and tilings,
/// every kernel under the SIMD microkernels agrees with the scalar
/// kernels within the 1e-5 relative contract, covering all four kernel
/// entry points and all three skip families.
#[test]
fn simd_matches_scalar_on_randomized_shapes_skips_tilings() {
    let Some(s) = SparseKernels::simd() else {
        eprintln!("SKIP: no SIMD microkernel on this CPU \
                   (simd_matches_scalar_on_randomized_shapes_skips_tilings)");
        return;
    };
    let sc = SparseKernels::scalar();
    assert_ne!(s.microkernel(), sc.microkernel());
    testkit::quickcheck("simd vs scalar, all kernels", |rng| {
        let m = gen_range(rng, 1, 12);
        let dp = *gen_choice(rng, &[1usize, 2, 3, 4]);
        let k = dp * gen_range(rng, 1, 20);
        let n = gen_range(rng, 1, 48);
        let b0 = gen_range(rng, 0, dp);
        let pat = RowPattern::new(k, dp, b0);
        let row_skip = Skip::Rows(pat);
        let mut a = gen_vec_f32(rng, m * k, -1.0, 1.0);
        mask_cols(&mut a, m, k, &pat);
        let b = gen_vec_f32(rng, k * n, -1.0, 1.0);

        // GEMM, row-skip on the shared dim.
        assert_close(&s.gemm(&a, &b, m, k, n, &row_skip, &D),
                     &sc.gemm(&a, &b, m, k, n, &row_skip, &D),
                     1e-5, "gemm rows");

        // GEMM with kept-column packing on the output.
        let dpo = *gen_choice(rng, &[2usize, 4]);
        let no = dpo * gen_range(rng, 1, 12);
        let q = RowPattern::new(no, dpo, gen_range(rng, 0, dpo));
        let bo = gen_vec_f32(rng, k * no, -1.0, 1.0);
        assert_close(
            &s.gemm(&a, &bo, m, k, no, &row_skip, &Skip::Rows(q)),
            &sc.gemm(&a, &bo, m, k, no, &row_skip, &Skip::Rows(q)),
            1e-5, "gemm rows+cols");

        // NT, output columns restricted.
        let a2 = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let bt = gen_vec_f32(rng, k * n, -1.0, 1.0);
        assert_close(&s.gemm_nt(&a2, &bt, m, n, k, &row_skip),
                     &sc.gemm_nt(&a2, &bt, m, n, k, &row_skip),
                     1e-5, "nt rows");

        // TN accumulation onto a nonzero prior.
        let b2 = gen_vec_f32(rng, m * n, -1.0, 1.0);
        let mut got = vec![0.125f32; k * n];
        let mut want = got.clone();
        s.gemm_tn_acc(&a, &b2, m, k, n, &row_skip, &D, &mut got);
        sc.gemm_tn_acc(&a, &b2, m, k, n, &row_skip, &D, &mut want);
        assert_close(&got, &want, 1e-5, "tn rows");

        // Tile-skip GEMM / NT / TN on a random tiling.
        let (tk, tn) = gen_tile_dims(rng);
        let dpt = *gen_choice(rng, &[2usize, 4]);
        let tpat = TilePattern::new(tk, tn, dpt,
                                    gen_range(rng, 0, dpt), 16);
        let tskip = Skip::Tiles(tpat);
        let at = gen_vec_f32(rng, m * tk, -1.0, 1.0);
        let w = gen_vec_f32(rng, tk * tn, -1.0, 1.0);
        assert_close(&s.gemm(&at, &w, m, tk, tn, &tskip, &D),
                     &sc.gemm(&at, &w, m, tk, tn, &tskip, &D),
                     1e-5, "gemm tiles");
        let an = gen_vec_f32(rng, m * tn, -1.0, 1.0);
        assert_close(&s.gemm_nt(&an, &w, m, tn, tk, &tskip),
                     &sc.gemm_nt(&an, &w, m, tn, tk, &tskip),
                     1e-5, "nt tiles");
        let bn = gen_vec_f32(rng, m * tn, -1.0, 1.0);
        let mut got = vec![0.5f32; tk * tn];
        let mut want = got.clone();
        s.gemm_tn_acc(&at, &bn, m, tk, tn, &tskip, &D, &mut got);
        sc.gemm_tn_acc(&at, &bn, m, tk, tn, &tskip, &D, &mut want);
        assert_close(&got, &want, 1e-5, "tn tiles");

        // GEMV rides the same row-skip path.
        let x1 = &a[..k];
        assert_close(&s.gemv(x1, &b, k, n, &row_skip, &D),
                     &sc.gemv(x1, &b, k, n, &row_skip, &D),
                     1e-5, "gemv");
    });
}

/// SIMD results are bit-stable across repetitions (the bench harness's
/// precondition: rep-to-rep variance is time, never values).
#[test]
fn simd_results_bit_stable_across_reps() {
    let Some(s) = SparseKernels::simd() else {
        eprintln!("SKIP: no SIMD microkernel on this CPU \
                   (simd_results_bit_stable_across_reps)");
        return;
    };
    let mut rng = Rng::new(99);
    let (m, k, n) = (16, 128, 96);
    let pat = RowPattern::new(k, 2, 0);
    let mut a = gen_vec_f32(&mut rng, m * k, -1.0, 1.0);
    mask_cols(&mut a, m, k, &pat);
    let b = gen_vec_f32(&mut rng, k * n, -1.0, 1.0);
    let skip = Skip::Rows(pat);
    let first = s.gemm(&a, &b, m, k, n, &skip, &D);
    for rep in 0..3 {
        let again = s.gemm(&a, &b, m, k, n, &skip, &D);
        assert_eq!(first, again, "rep {rep} differed");
    }
    let tpat = TilePattern::new(128, 96, 2, 1, 16);
    let w = gen_vec_f32(&mut rng, 128 * 96, -1.0, 1.0);
    let a2 = gen_vec_f32(&mut rng, m * 96, -1.0, 1.0);
    let first = s.gemm_nt(&a2, &w, m, 96, 128, &Skip::Tiles(tpat));
    for rep in 0..3 {
        let again = s.gemm_nt(&a2, &w, m, 96, 128, &Skip::Tiles(tpat));
        assert_eq!(first, again, "nt rep {rep} differed");
    }
}
