//! `artifacts/manifest.json` loader — the contract between the AOT python
//! side and the Rust runtime. Every executable's exact input/output tensor
//! order, shapes, dtypes and semantic kinds live here; the coordinator is
//! generic over variants and architectures because of it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

/// Semantic role of a tensor in the train-step calling convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Param,
    Momentum,
    X,
    Y,
    Mask,
    Scale,
    Bias, // pattern bias scalar b0
    Lr,
    Loss,
    Correct,
}

impl Kind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "param" => Kind::Param,
            "momentum" => Kind::Momentum,
            "x" => Kind::X,
            "y" => Kind::Y,
            "mask" => Kind::Mask,
            "scale" => Kind::Scale,
            "bias" => Kind::Bias,
            "lr" => Kind::Lr,
            "loss" => Kind::Loss,
            "correct" => Kind::Correct,
            other => bail!("unknown tensor kind {other}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub kind: Kind,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub enum ArchMeta {
    Mlp { n_in: usize, hidden: Vec<usize>, n_out: usize, batch: usize },
    Lstm { vocab: usize, hidden: usize, layers: usize, seq: usize,
           batch: usize },
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub model: String,   // "mlp" | "lstm"
    pub variant: String, // "conv" | "eval" | "rdp" | "tdp"
    pub dp: Vec<usize>,
    pub sites: usize,
    pub arch: ArchMeta,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

impl ArtifactMeta {
    pub fn n_params(&self) -> usize {
        self.inputs.iter().filter(|t| t.kind == Kind::Param).count()
    }

    pub fn param_metas(&self) -> Vec<&TensorMeta> {
        self.inputs.iter().filter(|t| t.kind == Kind::Param).collect()
    }

    pub fn batch(&self) -> usize {
        match &self.arch {
            ArchMeta::Mlp { batch, .. } => *batch,
            ArchMeta::Lstm { batch, .. } => *batch,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dp_support: Vec<usize>,
    pub momentum: f64,
    pub tile: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    let name = j.get("name").and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor missing name"))?.to_string();
    let shape = j.get("shape").and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(
        j.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?;
    let kind = Kind::parse(
        j.get("kind").and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor {name} missing kind"))?)?;
    Ok(TensorMeta { name, shape, dtype, kind })
}

fn arch_meta(model: &str, j: &Json) -> Result<ArchMeta> {
    let u = |key: &str| -> Result<usize> {
        j.get(key).and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("arch missing {key}"))
    };
    Ok(match model {
        "mlp" => ArchMeta::Mlp {
            n_in: u("n_in")?,
            hidden: j.get("hidden").and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("mlp arch missing hidden"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            n_out: u("n_out")?,
            batch: u("batch")?,
        },
        "lstm" => ArchMeta::Lstm {
            vocab: u("vocab")?,
            hidden: u("hidden")?,
            layers: u("layers")?,
            seq: u("seq")?,
            batch: u("batch")?,
        },
        other => bail!("unknown model {other}"),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts").and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a.get("name").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let model = a.get("model").and_then(Json::as_str)
                .unwrap_or("mlp").to_string();
            let meta = ArtifactMeta {
                file: a.get("file").and_then(Json::as_str)
                    .unwrap_or(&format!("{name}.hlo.txt")).to_string(),
                model: model.clone(),
                variant: a.get("variant").and_then(Json::as_str)
                    .unwrap_or("conv").to_string(),
                dp: a.get("dp").and_then(Json::as_arr).unwrap_or(&[])
                    .iter().filter_map(Json::as_usize).collect(),
                sites: a.get("sites").and_then(Json::as_usize).unwrap_or(0),
                arch: arch_meta(&model,
                                a.get("arch")
                                    .ok_or_else(|| anyhow!("missing arch"))?)?,
                inputs: a.get("inputs").and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing inputs"))?
                    .iter().map(tensor_meta).collect::<Result<_>>()?,
                outputs: a.get("outputs").and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing outputs"))?
                    .iter().map(tensor_meta).collect::<Result<_>>()?,
                name: name.clone(),
            };
            artifacts.insert(name, meta);
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            dp_support: root.get("dp_support").and_then(Json::as_arr)
                .unwrap_or(&[]).iter().filter_map(Json::as_usize).collect(),
            momentum: root.get("momentum").and_then(Json::as_f64)
                .unwrap_or(0.9),
            tile: root.get("tile").and_then(Json::as_usize).unwrap_or(32),
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!("artifact '{name}' not in manifest \
                     ({} known)", self.artifacts.len())
        })
    }

    /// Path of an artifact's HLO text file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Artifact naming convention (mirrors aot.py): `<tag>_<variant>` or
    /// `<tag>_<variant>_<dp1>[_<dp2>...]`.
    pub fn artifact_name(tag: &str, variant: &str, dp: &[usize]) -> String {
        if dp.is_empty() {
            format!("{tag}_{variant}")
        } else {
            let dps: Vec<String> = dp.iter().map(|d| d.to_string()).collect();
            format!("{tag}_{variant}_{}", dps.join("_"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("manifest");
        assert!(!m.artifacts.is_empty());
        assert_eq!(m.tile, 128);
        assert!((m.momentum - 0.9).abs() < 1e-9);
        assert!(m.dp_support.contains(&2));
    }

    #[test]
    fn tiny_mlp_entry_shape() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let a = m.get("mlptest_conv").unwrap();
        assert_eq!(a.model, "mlp");
        assert_eq!(a.variant, "conv");
        assert_eq!(a.n_params(), 6);
        // inputs: 6 params + 6 momenta + x + y + 2 masks + 2 scales + lr
        assert_eq!(a.inputs.len(), 19);
        // outputs: 6 + 6 + loss + correct
        assert_eq!(a.outputs.len(), 14);
        let w1 = &a.inputs[0];
        assert_eq!(w1.name, "w1");
        assert_eq!(w1.shape, vec![32, 64]);
        assert_eq!(w1.kind, Kind::Param);
    }

    #[test]
    fn rdp_entry_has_bias_inputs() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let a = m.get("mlptest_rdp_2_2").unwrap();
        assert_eq!(a.dp, vec![2, 2]);
        let biases: Vec<_> =
            a.inputs.iter().filter(|t| t.kind == Kind::Bias).collect();
        assert_eq!(biases.len(), 2);
        assert_eq!(biases[0].dtype, Dtype::I32);
    }

    #[test]
    fn naming_convention() {
        assert_eq!(Manifest::artifact_name("mlp2048x2048", "rdp", &[2, 4]),
                   "mlp2048x2048_rdp_2_4");
        assert_eq!(Manifest::artifact_name("x", "eval", &[]), "x_eval");
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.get("nonexistent").is_err());
    }
}
