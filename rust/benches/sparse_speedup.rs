//! The paper's Figure-level speedup claim, reproduced in-repo: dense
//! (conventional masked dropout) vs **row-skip** (RDP) vs **tile-skip**
//! (TDP) train-step wall-clock on the structured-sparse backend, at
//! global dropout rates 0.3 / 0.5 / 0.7, on the `mlpsyn` and `lstmsyn`
//! archs.
//!
//! All three configurations run the identical coordinator path and the
//! identical step program (`runtime::step`); the only difference is what
//! the kernels may skip — conventional dropout's Bernoulli masks have no
//! structure, so its steps pay full dense math plus per-step mask
//! generation, exactly the baseline the paper measures against.
//!
//! Output: a paper-style table on stdout plus machine-readable
//! `BENCH_sparse.json` (repo root, or `$AD_BENCH_OUT/`) through the
//! shared `bench::report` writer.
//!
//! Knobs: `AD_BENCH_SMOKE=1` (tiny rep counts, CI smoke job),
//! `AD_BENCH_REPS` (timed steps per configuration), `AD_THREADS`
//! (sparse worker pool size).

use anyhow::Result;

use approx_dropout::bench::drivers::env_usize;
use approx_dropout::bench::{bench, fmt_time, BenchReport, Table};
use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::sparse::threads_from_env;
use approx_dropout::runtime::Manifest;
use approx_dropout::util::json::Json;

const SUPPORT: &[usize] = &[1, 2, 4];
const RATES: &[f64] = &[0.3, 0.5, 0.7];

struct Cfg {
    label: &'static str,
    variant: Variant,
}

const CFGS: &[Cfg] = &[
    Cfg { label: "dense", variant: Variant::Conv },
    Cfg { label: "row-skip", variant: Variant::Rdp },
    Cfg { label: "tile-skip", variant: Variant::Tdp },
];

fn main() -> Result<()> {
    let smoke = env_usize("AD_BENCH_SMOKE", 0) == 1;
    let reps = env_usize("AD_BENCH_REPS", if smoke { 3 } else { 40 });
    let warm = if smoke { 1 } else { 5 };
    let threads = threads_from_env();

    let cache = ExecutorCache::sparse(Manifest::builtin_test());
    let (mnist, _) = MnistSyn::train_test(512, 64, 42);
    let corpus = Corpus::generate(64, 8000, 800, 800, 9);

    let mut table = Table::new(&["arch", "rate", "config", "median step",
                                 "steps/s", "speedup"]);
    let mut report =
        BenchReport::new("sparse_speedup", "rust/benches/sparse_speedup.rs");
    report
        .set("backend", Json::str("sparse"))
        .set("threads", Json::num(threads as f64))
        .set("smoke", Json::Bool(smoke))
        .set("reps", Json::num(reps as f64))
        .set("support", Json::Arr(
            SUPPORT.iter().map(|&d| Json::num(d as f64)).collect()));

    for arch in ["mlpsyn", "lstmsyn"] {
        for &rate in RATES {
            let mut dense_s = f64::NAN;
            for cfg in CFGS {
                let r = match arch {
                    "mlpsyn" => {
                        let schedule = Schedule::new(
                            cfg.variant, &[rate, rate], SUPPORT, false)?;
                        let mut tr = MlpTrainer::new(
                            &cache, arch, schedule, mnist.n, 0.01, 7)?;
                        tr.warmup()?;
                        bench(cfg.label, warm, reps,
                              || tr.step(&mnist).unwrap())
                    }
                    _ => {
                        let shared = cfg.variant != Variant::Conv;
                        let schedule = Schedule::new(
                            cfg.variant, &[rate, rate], SUPPORT, shared)?;
                        let mut tr = LstmTrainer::new(
                            &cache, arch, schedule, &corpus.train, 0.1,
                            13)?;
                        tr.warmup()?;
                        bench(cfg.label, warm, reps,
                              || tr.step().unwrap())
                    }
                };
                if cfg.label == "dense" {
                    dense_s = r.median_s;
                }
                let speedup = dense_s / r.median_s;
                table.row(&[arch.to_string(), format!("{rate}"),
                            cfg.label.to_string(), fmt_time(r.median_s),
                            format!("{:.1}", r.per_sec()),
                            format!("{speedup:.2}x")]);
                report.row(vec![
                    ("arch", Json::str(arch)),
                    ("rate", Json::num(rate)),
                    ("config", Json::str(cfg.label)),
                    ("variant", Json::str(cfg.variant.as_str())),
                    ("median_step_s", Json::num(r.median_s)),
                    ("mad_s", Json::num(r.mad_s)),
                    ("mean_step_s", Json::num(r.mean_s)),
                    ("reps", Json::num(r.reps as f64)),
                    ("speedup_vs_dense", Json::num(speedup)),
                ]);
            }
        }
    }

    println!("== sparse speedup (dense vs row-skip vs tile-skip, \
              {threads} thread(s)) ==");
    table.print();
    let path = report.write_default("BENCH_sparse.json")?;
    println!("\nwrote {} ({} rows)", path.display(), report.n_rows());
    println!("interpretation: the paper's claim is that regular dropout \
              patterns turn dropped rows/tiles into *skipped* work; \
              speedup should grow with the dropout rate and tile-skip \
              should track row-skip (fig. 7/8).");
    Ok(())
}
