"""MLP train-step graphs vs pure-jnp mask-based references: each pattern
variant must be numerically identical to conventional dropout with the
equivalent dense 0/1 mask (the paper's core equivalence)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, patterns

ARCH = model.MlpArch(hidden=(64, 64), n_in=32, n_out=10, batch=8,
                     tile=16)


@pytest.fixture(scope="module")
def setup():
    specs = model.mlp_param_specs(ARCH)
    params = [jax.random.normal(jax.random.PRNGKey(i), s) * 0.1
              for i, (n, s) in enumerate(specs)]
    moms = [jnp.zeros(s) for _, s in specs]
    x = jax.random.normal(jax.random.PRNGKey(99), (8, 32))
    y = jnp.arange(8, dtype=jnp.int32) % 10
    return params, moms, x, y


S1, S2 = 2.0, 2.0  # runtime inverted-dropout scales (1/(1-p))


def ref_rdp_loss(params, x, y, dp1, b01, dp2, b02):
    w1, b1, w2, b2, w3, b3 = params
    m1 = patterns.row_mask(64, dp1, b01) * S1
    m2 = patterns.row_mask(64, dp2, b02) * S2
    h1 = jax.nn.relu(x @ w1 + b1) * m1
    h2 = jax.nn.relu(h1 @ w2 + b2) * m2
    return model.softmax_xent(h2 @ w3 + b3, y)


def ref_tdp_loss(params, x, y, dp1, b01, dp2, b02):
    w1, b1, w2, b2, w3, b3 = params
    tm1 = patterns.tile_mask(32, 64, dp1, b01, ARCH.tile)
    tm2 = patterns.tile_mask(64, 64, dp2, b02, ARCH.tile)
    s1, s2 = S1, S2
    h1 = jax.nn.relu((x @ (w1 * tm1)) * s1 + b1)
    h2 = jax.nn.relu((h1 @ (w2 * tm2)) * s2 + b2)
    return model.softmax_xent(h2 @ w3 + b3, y)


@pytest.mark.parametrize("dp1,dp2,b01,b02", [
    (2, 2, 0, 1), (2, 4, 1, 3), (4, 2, 2, 0), (1, 1, 0, 0),
])
def test_rdp_step_equals_masked_reference(setup, dp1, dp2, b01, b02):
    params, moms, x, y = setup
    lr = jnp.float32(0.05)
    step = model.mlp_train_step_rdp(ARCH, dp1, dp2)
    out = step(*params, *moms, x, y, jnp.int32(b01), jnp.int32(b02),
               jnp.float32(S1), jnp.float32(S2), lr)

    (loss_r, corr_r), grads = jax.value_and_grad(
        lambda ps: ref_rdp_loss(ps, x, y, dp1, jnp.int32(b01), dp2,
                                jnp.int32(b02)),
        has_aux=True)(params)
    new_p, new_m = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[12], loss_r, rtol=1e-5, atol=1e-6)
    assert float(out[13]) == float(corr_r)
    for a, b in zip(out[:6], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    for a, b in zip(out[6:12], new_m):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dp,b01,b02", [(2, 0, 1), (2, 1, 0)])
def test_tdp_step_equals_masked_reference(setup, dp, b01, b02):
    params, moms, x, y = setup
    lr = jnp.float32(0.05)
    step = model.mlp_train_step_tdp(ARCH, dp, dp)
    out = step(*params, *moms, x, y, jnp.int32(b01), jnp.int32(b02),
               jnp.float32(S1), jnp.float32(S2), lr)
    (loss_r, _), grads = jax.value_and_grad(
        lambda ps: ref_tdp_loss(ps, x, y, dp, jnp.int32(b01), dp,
                                jnp.int32(b02)),
        has_aux=True)(params)
    new_p, _ = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[12], loss_r, rtol=1e-5, atol=1e-6)
    for a, b in zip(out[:6], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_conv_step_equals_plain_dropout(setup):
    params, moms, x, y = setup
    lr = jnp.float32(0.05)
    m1 = (jax.random.uniform(jax.random.PRNGKey(5), (8, 64))
          > 0.5).astype(jnp.float32)
    m2 = (jax.random.uniform(jax.random.PRNGKey(6), (8, 64))
          > 0.5).astype(jnp.float32)
    step = model.mlp_train_step_conv(ARCH)
    out = step(*params, *moms, x, y, m1, m2, jnp.float32(2.0),
               jnp.float32(2.0), lr)

    def ref(ps):
        w1, b1, w2, b2, w3, b3 = ps
        h1 = jax.nn.relu(x @ w1 + b1)
        h2 = jax.nn.relu((h1 * m1 * 2.0) @ w2 + b2)
        return model.softmax_xent((h2 * m2 * 2.0) @ w3 + b3, y)

    (loss_r, _), grads = jax.value_and_grad(ref, has_aux=True)(params)
    new_p, _ = model.sgd_momentum(params, moms, grads, lr)
    np.testing.assert_allclose(out[12], loss_r, rtol=1e-5, atol=1e-6)
    for a, b in zip(out[:6], new_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_rdp_dp1_equals_no_dropout_eval(setup):
    # dp = (1,1) keeps everything with scale 1 — the train forward must
    # match the eval graph's forward exactly.
    params, moms, x, y = setup
    step = model.mlp_train_step_rdp(ARCH, 1, 1)
    out = step(*params, *moms, x, y, jnp.int32(0), jnp.int32(0),
               jnp.float32(1.0), jnp.float32(1.0),
               jnp.float32(0.0))  # scale 1, lr=0: params unchanged
    ev = model.mlp_eval(ARCH)
    loss_e, corr_e = ev(*params, x, y)
    np.testing.assert_allclose(out[12], loss_e, rtol=1e-5)
    assert float(out[13]) == float(corr_e)
    for a, b in zip(out[:6], params):
        np.testing.assert_allclose(a, b, atol=1e-7)


def test_momentum_accumulates_across_steps(setup):
    params, moms, x, y = setup
    lr = jnp.float32(0.01)
    step = model.mlp_train_step_rdp(ARCH, 2, 2)
    s_ = jnp.float32(2.0)
    out1 = step(*params, *moms, x, y, jnp.int32(0), jnp.int32(0), s_, s_,
                lr)
    p1, m1_ = list(out1[:6]), list(out1[6:12])
    out2 = step(*p1, *m1_, x, y, jnp.int32(0), jnp.int32(0), s_, s_, lr)
    m2_ = out2[6:12]
    # Momentum after step2 = mu * m1 + g2; with identical data g2 != 0 so
    # |m2| should generally exceed |mu * m1| in early training.
    n1 = sum(float(jnp.sum(jnp.abs(m))) for m in m1_)
    n2 = sum(float(jnp.sum(jnp.abs(m))) for m in m2_)
    assert n2 > 0.9 * n1


def test_loss_decreases_under_training(setup):
    params, moms, x, y = setup
    lr = jnp.float32(0.1)
    step = jax.jit(model.mlp_train_step_rdp(ARCH, 2, 2))
    ps, ms = list(params), list(moms)
    first = None
    for i in range(25):
        out = step(*ps, *ms, x, y, jnp.int32(i % 2), jnp.int32((i + 1) % 2),
                   jnp.float32(2.0), jnp.float32(2.0), lr)
        ps, ms = list(out[:6]), list(out[6:12])
        if first is None:
            first = float(out[12])
    last = float(out[12])
    assert last < first, f"loss did not decrease: {first} -> {last}"
