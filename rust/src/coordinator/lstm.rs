//! LSTM front (paper section IV-C): word-level language modeling with
//! per-iteration dropout patterns on the non-recurrent connections. Same
//! dispatch structure as the MLP — that structure lives once, in the
//! generic [`Trainer`] driver; this front only assembles inputs. LSTM
//! schedules use a single shared dp per iteration (the artifact set covers
//! equal-dp combinations; see aot.py), so artifact names truncate the dp
//! combination to its first element.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::driver::{push_bias_tracks, push_scale_scalars,
                                 ModelFront, StepInput, Trainer};
use crate::coordinator::metrics::perplexity;
use crate::coordinator::pool::ExecutorCache;
use crate::coordinator::schedule::{Schedule, Variant};
use crate::data::BpttBatcher;
use crate::patterns::{Choice, TimeWindow};
use crate::runtime::{ArchMeta, HostTensor, Manifest, TrainState};
use crate::service::checkpoint::{rng_state_from_json, rng_state_to_json};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The LSTM trainer is the generic driver over [`LstmFront`].
pub type LstmTrainer = Trainer<LstmFront>;

pub struct LstmFront {
    pub tag: String,
    pub schedule: Schedule,
    batcher: BpttBatcher,
    hidden: usize,
    batch: usize,
    seq: usize,
    /// Construction seed — hashed into checkpoints because callers
    /// regenerate the corpus from it (see `MlpFront::seed`).
    seed: u64,
    rng: Rng,
    /// Time-window draw policy (`AD_TIME_WINDOW`); the default `W = seq`
    /// reproduces the pre-windowing stream bit for bit.
    window: TimeWindow,
    /// Multi-step window carry (`W = k * seq`): the choices held from the
    /// window-start step, and how many more steps reuse them. Both are
    /// checkpointed so a resume mid-window stays bit-exact.
    held_choices: Vec<Choice>,
    held_left: usize,
}

impl ModelFront for LstmFront {
    /// The token stream lives in the front's BPTT batcher, so steps take
    /// no per-call data.
    type Data = ();
    type EvalData = [i32];

    fn tag(&self) -> &str {
        &self.tag
    }

    fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    fn artifact_for(&self, dp: &[usize]) -> String {
        // LSTM artifacts are named by the single shared dp.
        Manifest::artifact_name(&self.tag, self.schedule.variant.as_str(),
                                &dp[..1])
    }

    fn assemble(&mut self, _data: &()) -> Result<StepInput> {
        // Multi-step windows (W = k * seq) hold one (dp, b0) draw for k
        // consecutive steps; on held steps `Schedule::sample` is skipped
        // entirely, so the RNG stream advances only at window starts.
        // With steps_per_draw == 1 (the default and all W <= seq) this is
        // exactly today's one-sample-per-step stream.
        let choices = {
            let _sp = crate::obs::trace::span("sample");
            if self.window.steps_per_draw() > 1 && self.held_left > 0 {
                self.held_left -= 1;
                self.held_choices.clone()
            } else {
                let c = self.schedule.sample(&mut self.rng);
                if self.window.steps_per_draw() > 1 {
                    self.held_choices = c.clone();
                    self.held_left = self.window.steps_per_draw() - 1;
                }
                c
            }
        };
        let prev_epoch = self.batcher.epoch;
        // Owned buffers (the pipelined path ships them across a thread);
        // same copy count as building literals from borrowed slices.
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.batcher.next_window_into(&mut x, &mut y);

        let mut tail = Vec::with_capacity(2 + 2 * self.schedule.sites());
        tail.push(HostTensor::i32(&[self.batch, self.seq], x));
        tail.push(HostTensor::i32(&[self.batch, self.seq], y));

        let name = match self.schedule.variant {
            Variant::Conv => {
                for site in 0..self.schedule.sites() {
                    let keep = 1.0 - self.schedule.rates[site];
                    let m = self.rng
                        .mask_vec(keep, self.batch * self.hidden);
                    tail.push(HostTensor::f32(&[self.batch, self.hidden],
                                              m));
                }
                push_scale_scalars(&mut tail, &self.schedule.rates);
                format!("{}_conv", self.tag)
            }
            _ => {
                // Per-site [seq] b0 tracks: window 0 reuses the sampled
                // b0, extra windows draw fresh ones (no extra draws at
                // the default W = seq — see patterns::window docs).
                let tracks =
                    self.window.expand_b0_tracks(&choices, &mut self.rng);
                push_bias_tracks(&mut tail, &tracks);
                push_scale_scalars(&mut tail, &self.schedule.rates);
                self.artifact_for(&[choices[0].dp])
            }
        };

        Ok(StepInput {
            name,
            tail,
            examples: self.batch * self.seq,
            // BpttBatcher bumps `epoch` only when a pass over the tracks
            // completes — every bump is a finished epoch.
            epoch_boundary: self.batcher.epoch != prev_epoch,
        })
    }

    fn eval_num_batches(&self, tokens: &[i32]) -> usize {
        // windows_per_epoch over `batch` contiguous tracks, without
        // materializing a batcher: track b is tokens[b*track_len..].
        let track_len = tokens.len() / self.batch;
        track_len.saturating_sub(1) / self.seq
    }

    fn eval_batch(&self, tokens: &[i32], bi: usize)
                  -> Result<Vec<HostTensor>> {
        let track_len = tokens.len() / self.batch;
        let pos = bi * self.seq;
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for b in 0..self.batch {
            let base = b * track_len + pos;
            x.extend_from_slice(&tokens[base..base + self.seq]);
            y.extend_from_slice(&tokens[base + 1..base + self.seq + 1]);
        }
        Ok(vec![
            HostTensor::i32(&[self.batch, self.seq], x),
            HostTensor::i32(&[self.batch, self.seq], y),
        ])
    }

    fn eval_examples_per_batch(&self) -> usize {
        self.batch * self.seq
    }

    fn config_line(&self) -> String {
        let base = format!(
            "lstm tag={} variant={} rates={:?} shared_dp={} \
             combos={:?} batch={} seq={} hidden={} seed={}",
            self.tag, self.schedule.variant.as_str(),
            self.schedule.rates, self.schedule.shared_dp,
            self.schedule.dp_combos(), self.batch, self.seq,
            self.hidden, self.seed);
        // The window term is appended ONLY off the default so that
        // checkpoints written before time-windowing existed keep their
        // config hash and stay resumable.
        if self.window.is_per_step() {
            base
        } else {
            format!("{base} window={}", self.window.w())
        }
    }

    fn snapshot(&self) -> Json {
        let (pos, epoch) = self.batcher.snapshot();
        let mut fields = vec![
            ("kind", Json::str("lstm")),
            ("rng", rng_state_to_json(self.rng.state())),
            ("pos", Json::num(pos as f64)),
            ("epoch", Json::num(epoch as f64)),
            ("track_len", Json::num(self.batcher.track_len() as f64)),
        ];
        // Multi-step window carry: present only when a hold is live, so
        // default-window snapshots are byte-identical to the old format.
        if self.held_left > 0 {
            fields.push(("held_left", Json::num(self.held_left as f64)));
            fields.push(("held_dp", Json::Arr(
                self.held_choices.iter()
                    .map(|c| Json::num(c.dp as f64)).collect())));
            fields.push(("held_b0", Json::Arr(
                self.held_choices.iter()
                    .map(|c| Json::num(c.b0 as f64)).collect())));
        }
        Json::obj(fields)
    }

    fn restore(&mut self, snap: &Json) -> Result<()> {
        if snap.get("kind").and_then(Json::as_str) != Some("lstm") {
            bail!("front snapshot is not an LSTM state");
        }
        let rng = Rng::from_state(rng_state_from_json(
            snap.get("rng").ok_or_else(|| anyhow!("snapshot: no rng"))?)?)
            .ok_or_else(|| anyhow!("snapshot: dead rng state"))?;
        let pos = snap.get("pos").and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("snapshot: no pos"))?;
        let epoch = snap.get("epoch").and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("snapshot: no epoch"))?;
        if let Some(tl) = snap.get("track_len").and_then(Json::as_usize) {
            if tl != self.batcher.track_len() {
                bail!("snapshot was taken over a corpus with track \
                       length {tl}, this trainer has {} — the resumed \
                       token stream would differ", self.batcher.track_len());
            }
        }
        // Window carry (absent in pre-windowing snapshots → no hold).
        let held_left = snap.get("held_left").and_then(Json::as_usize)
            .unwrap_or(0);
        let held_choices = if held_left > 0 {
            let dps = snap.get("held_dp").and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("snapshot: held_left without \
                                        held_dp"))?;
            let b0s = snap.get("held_b0").and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("snapshot: held_left without \
                                        held_b0"))?;
            if dps.len() != b0s.len() || dps.len() != self.schedule.sites() {
                bail!("snapshot: held choice arrays have {} / {} entries, \
                       schedule has {} sites",
                      dps.len(), b0s.len(), self.schedule.sites());
            }
            dps.iter().zip(b0s)
                .map(|(d, b)| -> Result<Choice> {
                    let dp = d.as_usize()
                        .ok_or_else(|| anyhow!("snapshot: bad held_dp"))?;
                    let b0 = b.as_usize()
                        .ok_or_else(|| anyhow!("snapshot: bad held_b0"))?;
                    if dp == 0 || b0 >= dp {
                        bail!("snapshot: held choice dp={dp} b0={b0} \
                               out of range");
                    }
                    Ok(Choice { dp, b0 })
                })
                .collect::<Result<Vec<_>>>()?
        } else {
            Vec::new()
        };
        if held_left >= self.window.steps_per_draw() {
            bail!("snapshot: held_left={held_left} exceeds this window's \
                   steps_per_draw={} — checkpoint was written under a \
                   different AD_TIME_WINDOW", self.window.steps_per_draw());
        }
        self.batcher.restore(pos, epoch)?;
        self.rng = rng;
        self.held_left = held_left;
        self.held_choices = held_choices;
        Ok(())
    }
}

impl Trainer<LstmFront> {
    /// Construct with the time-window policy taken from `AD_TIME_WINDOW`
    /// (read once, here — the runtime never consults the environment).
    pub fn new(cache: &ExecutorCache, tag: &str, schedule: Schedule,
               train_tokens: &[i32], lr: f32, seed: u64)
               -> Result<LstmTrainer> {
        Trainer::build(cache, tag, schedule, train_tokens, lr, seed, None,
                       true)
    }

    /// Construct with an explicit window override (`None` = per-step
    /// default). Benches and tests use this instead of mutating the
    /// process environment, which is racy under parallel test threads.
    pub fn new_with_window(cache: &ExecutorCache, tag: &str,
                           schedule: Schedule, train_tokens: &[i32],
                           lr: f32, seed: u64, window: Option<usize>)
                           -> Result<LstmTrainer> {
        Trainer::build(cache, tag, schedule, train_tokens, lr, seed,
                       window, false)
    }

    fn build(cache: &ExecutorCache, tag: &str, schedule: Schedule,
             train_tokens: &[i32], lr: f32, seed: u64,
             window: Option<usize>, from_env: bool)
             -> Result<LstmTrainer> {
        let conv = cache.manifest().get(&format!("{tag}_conv"))?;
        let (hidden, layers, batch, seq) = match &conv.arch {
            ArchMeta::Lstm { hidden, layers, batch, seq, .. } =>
                (*hidden, *layers, *batch, *seq),
            _ => bail!("artifact {tag} is not an LSTM"),
        };
        if schedule.sites() != layers {
            bail!("schedule has {} sites, LSTM has {} layers",
                  schedule.sites(), layers);
        }
        let mut rng = Rng::new(seed);
        let state = TrainState::init(conv, &mut rng,
                                     cache.backend().as_ref())?;
        let win = if from_env {
            TimeWindow::from_env(seq)
        } else {
            TimeWindow::resolve(window, seq)
        };
        let front = LstmFront {
            tag: tag.to_string(),
            schedule,
            batcher: BpttBatcher::new(train_tokens, batch, seq)?,
            hidden,
            batch,
            seq,
            seed,
            rng,
            window: win,
            held_choices: Vec::new(),
            held_left: 0,
        };
        Ok(Trainer::from_parts(cache, front, state, lr))
    }

    /// One training iteration over a [batch, seq] BPTT window.
    /// Returns (loss nats/token, token accuracy).
    pub fn step(&mut self) -> Result<(f64, f64)> {
        self.step_with(&())
    }

    /// Run `n` steps; returns mean loss over the window.
    pub fn train(&mut self, n: usize) -> Result<f64> {
        self.train_with(&(), n)
    }

    /// Evaluate on a token stream through the eval graph. Returns
    /// (mean loss nats/token, perplexity, token accuracy).
    pub fn evaluate(&mut self, tokens: &[i32]) -> Result<(f64, f64, f64)> {
        let (xent, acc) = self.evaluate_with(tokens)?;
        Ok((xent, perplexity(xent), acc))
    }
}
