//! Process-wide executor cache: one compiled PJRT executable per
//! (model, variant, dp) artifact, compiled lazily on first use and shared
//! by every trainer in the process. This mirrors the paper's setup where
//! the pattern distribution (and hence the set of matrix shapes) is fixed
//! before training starts — compilation is a one-time cost off the
//! steady-state hot path, and a baseline-vs-variant comparison (the
//! paper's headline measurement) compiles each artifact exactly once no
//! matter how many trainers run.
//!
//! The handle is cheap to clone (`Arc` all the way down); clones share the
//! underlying map. Lookups take a read lock on the hit path and upgrade to
//! a write lock only to compile, using the `HashMap` entry API so a miss
//! costs a single hash probe under the write lock.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::Result;

use crate::runtime::{Engine, Executable, Manifest};
use crate::util::Timer;

#[derive(Clone)]
pub struct ExecutorCache {
    engine: Arc<Engine>,
    manifest: Arc<Manifest>,
    exes: Arc<RwLock<HashMap<String, Arc<Executable>>>>,
    /// Compile wall-clock per artifact (diagnostics / EXPERIMENTS Perf).
    compile_log: Arc<Mutex<Vec<(String, f64)>>>,
}

impl ExecutorCache {
    pub fn new(engine: Engine, manifest: Manifest) -> Self {
        Self::from_arcs(Arc::new(engine), Arc::new(manifest))
    }

    pub fn from_arcs(engine: Arc<Engine>, manifest: Arc<Manifest>) -> Self {
        ExecutorCache {
            engine,
            manifest,
            exes: Arc::new(RwLock::new(HashMap::new())),
            compile_log: Arc::new(Mutex::new(Vec::new())),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (compiling if needed) the executable for `name`. The returned
    /// `Arc` is independent of the cache's locks, so callers hold no borrow
    /// across the subsequent execute.
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.exes.read().expect("cache lock").get(name) {
            return Ok(Arc::clone(exe));
        }
        // Compilation runs under the write lock on purpose: it guarantees
        // each artifact compiles exactly once process-wide (the invariant
        // the benches and tests assert via `compile_times_s`). Readers
        // briefly queue behind a first-time compile; steady-state hits
        // never touch the write lock.
        let mut map = self.exes.write().expect("cache lock");
        match map.entry(name.to_string()) {
            // Another trainer may have compiled it between the locks.
            Entry::Occupied(e) => Ok(Arc::clone(e.get())),
            Entry::Vacant(slot) => {
                let t = Timer::start();
                let exe = Arc::new(self.engine.load(&self.manifest, name)?);
                let dt = t.elapsed_s();
                crate::debug!("compiled {name} in {dt:.2}s");
                self.compile_log
                    .lock()
                    .expect("compile log lock")
                    .push((name.to_string(), dt));
                Ok(Arc::clone(slot.insert(exe)))
            }
        }
    }

    /// Pre-compile a list of artifacts (e.g. every dp combo a schedule can
    /// sample) so training loops never stall on compilation.
    pub fn warm(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn len(&self) -> usize {
        self.exes.read().expect("cache lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of (artifact name, compile seconds), one entry per compile
    /// actually performed — a shared cache therefore lists each artifact
    /// at most once.
    pub fn compile_times_s(&self) -> Vec<(String, f64)> {
        self.compile_log.lock().expect("compile log lock").clone()
    }

    /// Total compilation wall-clock absorbed by this cache.
    pub fn total_compile_s(&self) -> f64 {
        self.compile_log
            .lock()
            .expect("compile log lock")
            .iter()
            .map(|(_, s)| s)
            .sum()
    }
}
