//! Data-parallel training invariants (hermetic; no artifacts, no PJRT).
//!
//! The contract under test — the tentpole of the sharded trainer: for a
//! fixed seed and config, runs at `--workers` N ∈ {1, 2, 4} produce
//! **bit-identical** loss trajectories, dispatch sequences, and final
//! checkpoint tensors, on both hermetic backends and both
//! architectures; and a checkpoint saved at one N resumes at another N
//! (elastic resume) reproducing the uninterrupted trajectory exactly.
//! The CI worker matrix re-runs this suite under `AD_WORKERS={1,4}` and
//! an elastic-resume smoke drives the same contract through the CLI.

use approx_dropout::coordinator::{ExecutorCache, LstmTrainer, MlpTrainer,
                                  ModelFront, Schedule, Variant};
use approx_dropout::data::{Corpus, MnistSyn};
use approx_dropout::runtime::Manifest;

fn host_caches() -> [ExecutorCache; 2] {
    [ExecutorCache::reference(Manifest::builtin_test()),
     ExecutorCache::sparse(Manifest::builtin_test())]
}

/// Everything the worker-count-invariance contract covers, in exact
/// bits: per-step losses, the artifact dispatch sequence, and the final
/// checkpoint's parameter/momentum payloads.
#[derive(PartialEq, Debug)]
struct Trajectory {
    losses: Vec<u64>,
    dispatched: Vec<String>,
    ckpt_bits: Vec<Vec<u32>>,
    step: u64,
}

fn ckpt_bits(ckpt: &approx_dropout::service::Checkpoint) -> Vec<Vec<u32>> {
    ckpt.params
        .iter()
        .chain(&ckpt.momenta)
        .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn fresh_mlp(cache: &ExecutorCache) -> (MlpTrainer, MnistSyn) {
    let schedule =
        Schedule::new(Variant::Rdp, &[0.25, 0.25], &[1, 2], false).unwrap();
    let (train, _) = MnistSyn::train_test(256, 64, 42);
    let tr = MlpTrainer::new(cache, "mlpsyn", schedule, train.n, 0.01, 7)
        .unwrap();
    (tr, train)
}

fn run_mlp_sharded(cache: &ExecutorCache, workers: usize, steps: usize)
                   -> Trajectory {
    let (mut tr, train) = fresh_mlp(cache);
    tr.warmup().unwrap();
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (loss, acc) =
            tr.sharded(workers).unwrap().step_with(&train).unwrap();
        assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
        losses.push(loss.to_bits());
    }
    let ckpt = tr.checkpoint().unwrap();
    Trajectory {
        losses,
        dispatched: tr.metrics.dispatched.clone(),
        ckpt_bits: ckpt_bits(&ckpt),
        step: ckpt.step,
    }
}

fn run_lstm_sharded(cache: &ExecutorCache, workers: usize, steps: usize)
                    -> Trajectory {
    let schedule =
        Schedule::new(Variant::Rdp, &[0.5, 0.5], &[1, 2], true).unwrap();
    let corpus = Corpus::generate(64, 8000, 800, 800, 9);
    let mut tr =
        LstmTrainer::new(cache, "lstmsyn", schedule, &corpus.train, 0.1,
                         13)
        .unwrap();
    tr.warmup().unwrap();
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (loss, _) =
            tr.sharded(workers).unwrap().step_with(&()).unwrap();
        assert!(loss.is_finite());
        losses.push(loss.to_bits());
    }
    let ckpt = tr.checkpoint().unwrap();
    Trajectory {
        losses,
        dispatched: tr.metrics.dispatched.clone(),
        ckpt_bits: ckpt_bits(&ckpt),
        step: ckpt.step,
    }
}

/// The leaf count is a pure function of batch geometry: largest divisor
/// of the batch that is at most 8. Worker counts never enter — that is
/// what makes the reduction order (and so the trajectory) elastic.
#[test]
fn shard_leaves_is_the_largest_divisor_at_most_eight() {
    let cache = &host_caches()[0];
    let (tr, _) = fresh_mlp(cache);
    for (batch, want) in [(16, 8), (8, 8), (4, 4), (7, 7), (9, 3),
                          (13, 1), (1, 1), (24, 8), (20, 5)] {
        assert_eq!(tr.front.shard_leaves(batch), want,
                   "batch {batch}");
    }
}

#[test]
fn zero_workers_is_rejected() {
    let cache = &host_caches()[0];
    let (mut tr, _) = fresh_mlp(cache);
    let err = tr.sharded(0).unwrap_err().to_string();
    assert!(err.contains(">= 1"), "pointed message, got: {err}");
}

/// MLP: N ∈ {1, 2, 4} runs are bit-identical in losses, dispatch
/// sequence, and checkpoint payload, on both hermetic backends.
#[test]
fn mlp_sharded_runs_are_bitwise_identical_across_worker_counts() {
    for cache in host_caches() {
        let base = run_mlp_sharded(&cache, 1, 8);
        assert_eq!(base.dispatched.len(), 8);
        for n in [2, 4] {
            let t = run_mlp_sharded(&cache, n, 8);
            assert_eq!(base, t,
                       "workers={n} diverged on {}",
                       cache.backend().name());
        }
    }
}

/// LSTM: same contract (the bias-track variants shard over batch tracks
/// whose recurrences evolve independently).
#[test]
fn lstm_sharded_runs_are_bitwise_identical_across_worker_counts() {
    for cache in host_caches() {
        let base = run_lstm_sharded(&cache, 1, 6);
        for n in [2, 4] {
            let t = run_lstm_sharded(&cache, n, 6);
            assert_eq!(base, t,
                       "workers={n} diverged on {}",
                       cache.backend().name());
        }
    }
}

/// Elastic resume: train at N=1, checkpoint, resume the SAME config at
/// N=4 — the combined trajectory and final tensors match an
/// uninterrupted N=1 run bit for bit. This is why the worker count is
/// excluded from the checkpoint config hash.
#[test]
fn elastic_resume_reshards_onto_more_workers_bitwise() {
    for cache in host_caches() {
        // Uninterrupted baseline: 12 sharded steps at N=1.
        let full = run_mlp_sharded(&cache, 1, 12);

        // First half at N=1 ...
        let (mut a, train) = fresh_mlp(&cache);
        a.warmup().unwrap();
        for _ in 0..6 {
            a.sharded(1).unwrap().step_with(&train).unwrap();
        }
        let mid = a.checkpoint().unwrap();

        // ... resumed at N=4 for the second half.
        let (mut b, train_b) = fresh_mlp(&cache);
        b.warmup().unwrap();
        b.restore(&mid).unwrap();
        let mut tail_losses = Vec::new();
        for _ in 0..6 {
            let (loss, _) =
                b.sharded(4).unwrap().step_with(&train_b).unwrap();
            tail_losses.push(loss.to_bits());
        }
        let end = b.checkpoint().unwrap();

        assert_eq!(tail_losses, full.losses[6..],
                   "resumed tail diverged on {}", cache.backend().name());
        assert_eq!(ckpt_bits(&end), full.ckpt_bits,
                   "final tensors diverged on {}", cache.backend().name());
        assert_eq!(end.step, full.step);
    }
}
