//! Structured-sparse execution backend (`AD_BACKEND=sparse`): the shared
//! step interpreter (`runtime::step::StepProgram`) over the row-/tile-
//! skipping kernel library ([`kernels::SparseKernels`]), its SIMD
//! microkernel layer ([`simd`], selected by `AD_SIMD` + CPU feature
//! detection), and its worker pool ([`pool`], sized by `AD_THREADS`).
//!
//! This subsystem is the in-repo realization of the paper's performance
//! claim: because RDP/TDP patterns are *regular*, the surviving
//! computation of a dropout iteration is a smaller dense problem whose
//! dropped rows/tiles need never be loaded or multiplied. The reference
//! backend demonstrates the statistics of Approximate Random Dropout;
//! this backend demonstrates the speedup — `rust/benches/sparse_speedup.rs`
//! measures dense vs row-skip vs tile-skip wall-clock and emits
//! `BENCH_sparse.json`.
//!
//! Contracts:
//! * **Semantics** — identical step programs to the reference backend
//!   (same `runtime::step` code); outputs agree to <= 1e-5 relative on
//!   full train steps and dispatch sequences are identical
//!   (`rust/tests/hermetic.rs`).
//! * **Sparsity** — dropped coordinates are never touched: no multiply,
//!   no load; dropped gradient rows/tiles stay exactly zero, so dropped
//!   parameter/momentum rows are bit-frozen exactly as the hermetic
//!   suite pins for the reference backend.
//! * **Determinism** — results are bit-stable across `AD_THREADS`
//!   settings (disjoint-output partitioning, fixed accumulation order;
//!   see `pool` and `kernels` docs) and across repetitions (the
//!   microkernel selection is pinned once per process; see `simd`).

pub mod kernels;
pub mod pool;
pub mod simd;

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::backend::{Backend, Executor, HostTensor, Value};
use crate::runtime::manifest::Manifest;
use crate::runtime::step::StepProgram;

pub use kernels::SparseKernels;
pub use pool::{threads_from_env, ThreadPool};

/// The structured-sparse CPU backend. Values stay host-side (like the
/// reference backend); only the element math differs.
#[derive(Clone, Copy, Debug)]
pub struct SparseBackend {
    kernels: SparseKernels,
}

impl SparseBackend {
    /// Backend over the process-wide microkernel selection (`AD_SIMD` +
    /// CPU feature detection).
    pub fn new() -> Self {
        Self::with_kernels(SparseKernels::auto())
    }

    /// Backend over an explicitly chosen kernel set — how tests and the
    /// speedup bench pin the scalar path without touching process env.
    pub fn with_kernels(kernels: SparseKernels) -> Self {
        SparseBackend { kernels }
    }

    /// The kernel set this backend compiles programs against.
    pub fn kernels(&self) -> SparseKernels {
        self.kernels
    }
}

impl Default for SparseBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for SparseBackend {
    fn name(&self) -> &'static str {
        "sparse"
    }

    fn compile(&self, manifest: &Manifest, name: &str)
               -> Result<Arc<dyn Executor>> {
        Ok(Arc::new(StepProgram::new(manifest, name,
                                     Arc::new(self.kernels))?))
    }

    fn upload(&self, t: &HostTensor) -> Result<Value> {
        Ok(Value::Host(t.clone()))
    }

    fn ingest(&self, t: HostTensor) -> Result<Value> {
        Ok(Value::Host(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiles_builtin_artifacts() {
        let m = Manifest::builtin_test();
        let be = SparseBackend::new();
        assert_eq!(be.name(), "sparse");
        assert!(!be.kernels().microkernel().is_empty());
        let scalar = SparseBackend::with_kernels(SparseKernels::scalar());
        assert_eq!(scalar.kernels().microkernel(), "scalar");
        for name in ["mlpsyn_conv", "mlpsyn_rdp_2_2", "mlpsyn_tdp_2_2",
                     "lstmsyn_conv", "lstmsyn_rdp_2", "lstmsyn_tdp_2",
                     "mlpsyn_eval", "lstmsyn_eval"] {
            let exe = be.compile(&m, name).unwrap();
            assert_eq!(exe.meta().name, name);
        }
        assert!(be.compile(&m, "nonexistent").is_err());
    }
}
