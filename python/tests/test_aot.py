"""AOT export: registry consistency and HLO-text round-trip."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_registry_names_unique_and_well_formed():
    arts = aot.build_registry("all")
    names = [a.name for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for a in arts:
        assert a.meta["variant"] in ("conv", "eval", "rdp", "tdp")
        kinds = [t.kind for t in a.inputs]
        if a.meta["variant"] != "eval":
            # params, momenta ... then lr last
            assert kinds[-1] == "lr"
            n_p = kinds.count("param")
            assert kinds.count("momentum") == n_p
            out_kinds = [t.kind for t in a.outputs]
            assert out_kinds[-2:] == ["loss", "correct"]
        assert any(t.kind == "x" for t in a.inputs)
        assert any(t.kind == "y" for t in a.inputs)


def test_variant_extras_match_convention():
    arts = {a.name: a for a in aot.build_registry("all")}
    conv = arts["mlptest_conv"]
    kinds = [t.kind for t in conv.inputs]
    assert kinds.count("mask") == 2 and kinds.count("scale") == 2
    rdp = arts["mlptest_rdp_2_2"]
    kinds = [t.kind for t in rdp.inputs]
    assert kinds.count("bias") == 2 and kinds.count("scale") == 2
    assert all(t.dtype == "i32" for t in rdp.inputs if t.kind == "bias")


def test_hlo_text_roundtrip(tmp_path):
    # Lower the tiny eval graph and verify the text is XLA-parseable HLO
    # (ENTRY + parameters) of the expected arity.
    arts = {a.name: a for a in aot.build_registry("all")}
    a = arts["mlptest_eval"]
    text = aot.to_hlo_text(a.fn, [t.sds() for t in a.inputs])
    assert "ENTRY" in text and "parameter(0)" in text
    assert f"parameter({len(a.inputs) - 1})" in text
    assert f"parameter({len(a.inputs)})" not in text


def test_manifest_write(tmp_path, monkeypatch):
    monkeypatch.chdir(os.path.dirname(os.path.dirname(__file__)))
    out = tmp_path / "arts"
    rc = aot.main(["--set", "test", "--out", str(out), "--only",
                   "mlptest_eval"])
    assert rc == 0
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert manifest["dp_support"] == aot.DP_SUPPORT
    entry = [x for x in manifest["artifacts"]
             if x["name"] == "mlptest_eval"][0]
    assert (out / entry["file"]).exists()
    assert entry["arch"]["hidden"] == [64, 64]


def test_skip_cache_behaviour(tmp_path, monkeypatch):
    monkeypatch.chdir(os.path.dirname(os.path.dirname(__file__)))
    out = tmp_path / "arts"
    aot.main(["--set", "test", "--out", str(out), "--only", "mlptest_eval"])
    f = out / "mlptest_eval.hlo.txt"
    mtime = f.stat().st_mtime_ns
    aot.main(["--set", "test", "--out", str(out), "--only", "mlptest_eval"])
    assert f.stat().st_mtime_ns == mtime, "cached artifact was rebuilt"


def test_scales_exact_for_supported_dps():
    # Inverted-dropout scales baked into graphs must be exact ratios.
    assert model.row_scale(2048, 4) == 4.0
    assert model.tile_scale(2048, 2048, 8) == 8.0
    assert model.tile_scale(784, 2048, 4) == 4.0
