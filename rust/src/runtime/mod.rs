//! Runtime layer: the execution-backend abstraction (backend), its
//! implementations (PJRT engine behind the `pjrt` feature, pure-Rust
//! reference interpreter, structured-sparse compute engine), the shared
//! step interpreter they both plug kernels into, the artifact manifest
//! contract, and the backend-resident training state.
//!
//! Flow: `Manifest::load` (or `Manifest::builtin_test`) ->
//! `Backend::compile(name)` -> `Executor::run_raw` with values uploaded
//! from coordinator-assembled `HostTensor`s. One executor per
//! (model, variant, dp) — compiled lazily, once per process, by the
//! shared `coordinator::ExecutorCache`.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod plan;
pub mod reference;
pub mod sparse;
pub mod state;
pub mod step;

pub use backend::{backend_from_env, backend_kind_from_env,
                  env_selects_hermetic, Backend, BackendKind, Executor,
                  GradOut, HostTensor, LeafSpec, Value};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable, PjrtBackend};
pub use manifest::{lstm_artifacts, mlp_artifacts, ArchMeta, ArtifactMeta,
                   Dtype, Kind, LstmArchSpec, Manifest, MlpArchSpec,
                   TensorMeta};
pub use plan::{DynMask, Feed, FeedRun, GemmNode, Kept, NtNode,
               SparsityPlan, TnNode};
pub use reference::ReferenceBackend;
pub use sparse::{SparseBackend, SparseKernels};
pub use state::{InferOut, TrainState};
pub use step::{DenseKernels, Kernels, Skip, StepProgram};
