//! Reference execution backend: the shared step interpreter
//! (`runtime::step::StepProgram`) over masked-**dense** element math
//! ([`DenseKernels`]). No artifacts, no Python, no PJRT — this is what
//! makes the end-to-end coordinator loop testable hermetically.
//!
//! The model semantics (manifest calling convention, RDP/TDP masked-dense
//! interpretation, BPTT, Caffe SGD-momentum) live in `runtime::step`;
//! this file only binds them to the dense kernels and the host `Value`
//! representation. The structurally identical sibling is
//! `runtime::sparse::SparseBackend`, which binds the *same* program to
//! row-/tile-skipping kernels — `rust/tests/hermetic.rs` pins that the
//! two agree on full train steps.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::backend::{Backend, Executor, HostTensor, Value};
use crate::runtime::manifest::Manifest;
use crate::runtime::step::{DenseKernels, StepProgram};

/// The always-available pure-Rust backend.
#[derive(Clone, Debug, Default)]
pub struct ReferenceBackend;

impl ReferenceBackend {
    pub fn new() -> Self {
        ReferenceBackend
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn compile(&self, manifest: &Manifest, name: &str)
               -> Result<Arc<dyn Executor>> {
        Ok(Arc::new(StepProgram::new(manifest, name,
                                     Arc::new(DenseKernels))?))
    }

    fn upload(&self, t: &HostTensor) -> Result<Value> {
        Ok(Value::Host(t.clone()))
    }

    fn ingest(&self, t: HostTensor) -> Result<Value> {
        Ok(Value::Host(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiles_builtin_artifacts() {
        let m = Manifest::builtin_test();
        let be = ReferenceBackend::new();
        assert_eq!(be.name(), "reference");
        for name in ["mlptest_conv", "mlptest_eval", "mlptest_rdp_2_2",
                     "mlptest_tdp_2_2", "lstmtest_conv", "lstmtest_eval",
                     "lstmtest_rdp_2", "lstmtest_tdp_2"] {
            let exe = be.compile(&m, name).unwrap();
            assert_eq!(exe.meta().name, name);
        }
        assert!(be.compile(&m, "nonexistent").is_err());
    }

    #[test]
    fn values_stay_host_side() {
        let be = ReferenceBackend::new();
        let t = HostTensor::f32(&[2], vec![1.0, 2.0]);
        let v = be.upload(&t).unwrap();
        assert_eq!(v.to_f32().unwrap(), vec![1.0, 2.0]);
        let v2 = be.ingest(t).unwrap();
        assert!(v2.as_host().is_ok());
    }
}
