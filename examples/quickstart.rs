//! Quickstart: the whole stack in ~60 lines.
//!
//! Loads the tiny `mlptest` artifacts, runs Algorithm 1 for a 0.5 target
//! rate, trains a few dozen iterations with the Row-based Dropout Pattern,
//! and evaluates. Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use approx_dropout::coordinator::{Schedule, Variant};
use approx_dropout::runtime::state::{lit_f32, lit_i32, lit_scalar_f32,
                                     lit_scalar_i32};
use approx_dropout::runtime::{Engine, Manifest, TrainState};
use approx_dropout::search::{self, SearchConfig};
use approx_dropout::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Load the artifact manifest and bring up the PJRT CPU client.
    let manifest = Manifest::load(&approx_dropout::artifacts_dir())?;
    let engine = Engine::cpu()?;
    println!("platform: {}", engine.platform());

    // 2. Algorithm 1: distribution K over divisors for target rate 0.5.
    let result = search::search(0.5, &[1, 2], &SearchConfig::default());
    println!("pattern distribution K: {:?} (rate {:.4})",
             result.distribution.probs, result.achieved_rate);

    // 3. Compile the RDP executable for dp = (2, 2) and init state.
    let exe = engine.load(&manifest, "mlptest_rdp_2_2")?;
    let mut rng = Rng::new(42);
    let mut state = TrainState::init(manifest.get("mlptest_rdp_2_2")?,
                                     &mut rng);

    // 4. Train 50 iterations on random data, sampling a bias per step.
    let schedule = Schedule::new(Variant::Rdp, &[0.5, 0.5], &[2], true)?;
    let batch = 8;
    for step in 0..50 {
        let choices = schedule.sample(&mut rng);
        let x: Vec<f32> = (0..batch * 32).map(|_| rng.next_f32()).collect();
        let y: Vec<i32> =
            (0..batch).map(|i| ((i + step) % 10) as i32).collect();
        let tail = vec![
            lit_f32(&[batch, 32], &x)?,
            lit_i32(&[batch], &y)?,
            lit_scalar_i32(choices[0].b0 as i32),
            lit_scalar_i32(choices[1].b0 as i32),
            lit_scalar_f32(2.0), // 1/(1-p) for p = 0.5
            lit_scalar_f32(2.0),
            lit_scalar_f32(0.05),
        ];
        let (loss, _) = state.step(&exe, &tail)?;
        if step % 10 == 0 {
            println!("step {step:>3}: loss {loss:.4} \
                      (pattern b0 = {}, {})",
                     choices[0].b0, choices[1].b0);
        }
    }
    println!("quickstart OK — see examples/mlp_mnist.rs for the full \
              training driver");
    Ok(())
}
