import os
import sys

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
