//! Fig. 6(b) — batch-size sweep on the 3-layer LSTM @ rate 0.5 (RDP):
//! speedup and perplexity as the batch grows 20 -> 40.
//!
//! Paper shape to reproduce: speedup INCREASES with batch size (matrix
//! work grows while the pattern bookkeeping is constant), while quality
//! degrades slightly (one pattern per iteration covers more samples, so
//! fewer distinct sub-models are visited per epoch).

use approx_dropout::bench::drivers::{fmt_opt_ppl, run_lstm_support,
                                     BenchCtx};
use approx_dropout::bench::{fmt_time, Table};
use approx_dropout::coordinator::{speedup, Variant};
use approx_dropout::data::Corpus;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new()?;
    println!("== Fig 6b: lstm3x512v10240, batch sweep @ rate 0.5, {} \
              timed steps/config ==", ctx.timed_steps);
    let corpus = Corpus::generate(10_240, 200_000, 20_000, 20_000, 13);

    let mut table = Table::new(&["batch", "conv step", "RDP step",
                                 "speedup", "RDP ppl"]);
    for &b in &[20usize, 25, 30, 35, 40] {
        let tag = format!("lstm3x512v10240b{b}");
        let (t_conv, _) = run_lstm_support(&ctx, &tag, Variant::Conv, 0.5,
                                           3, &corpus, 0.1, 42, &[1, 2, 4])?;
        let (t_rdp, q_rdp) = run_lstm_support(&ctx, &tag, Variant::Rdp, 0.5,
                                              3, &corpus, 0.1, 42,
                                              &[1, 2, 4])?;
        table.row(&[format!("{b}"), fmt_time(t_conv), fmt_time(t_rdp),
                    format!("{:.2}x", speedup(t_conv, t_rdp)),
                    fmt_opt_ppl(q_rdp)]);
        println!("  batch {b}: {:.2}x", speedup(t_conv, t_rdp));
    }
    println!();
    table.print();
    println!("\npaper: speedup rises with batch size; perplexity rises \
              slightly (sub-model dilution)");
    Ok(())
}
