//! Jobs manifest: the TOML document the `serve` subcommand consumes.
//!
//! Layout (parsed with `util::toml`, dotted-path keys):
//!
//! ```toml
//! [service]
//! workers = 2            # concurrent backend slots
//! tick_steps = 10        # fairness quantum (steps per slot hold)
//! checkpoint_every = 20  # steps between checkpoint writes (0 = final only)
//! ckpt_dir = "ckpts"     # enables checkpoint/resume
//! out_dir = "reports"    # per-job REPORT_<name>.json land here
//!
//! [jobs.mlp-rdp]
//! model = "mlp"
//! tag = "mlpsyn"
//! variant = "rdp"
//! rates = [0.5, 0.5]     # or: rate = 0.5 (expanded to every site)
//! support = [1, 2]
//! steps = 40             # absolute target — resume-aware
//! workers = 2            # data-parallel gradient threads (0 = plain)
//! lr = 0.01
//! seed = 7
//! n_train = 256
//! n_test = 64
//!
//! [jobs.lstm-base]
//! model = "lstm"
//! tag = "lstmsyn"
//! variant = "conv"
//! rate = 0.5
//! steps = 30
//! lr = 0.5
//! tokens = 20000
//! ```

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::schedule::Variant;
use crate::runtime::{ArchMeta, Manifest};
use crate::util::toml::{self, TomlDoc};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Lstm,
}

impl ModelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Lstm => "lstm",
        }
    }

    pub fn parse(s: &str) -> Result<ModelKind> {
        Ok(match s {
            "mlp" => ModelKind::Mlp,
            "lstm" => ModelKind::Lstm,
            other => bail!("unknown model '{other}' (expected mlp|lstm)"),
        })
    }
}

/// One training job. `steps` is the *absolute* step target: a job resumed
/// from a step-30 checkpoint with `steps = 40` runs 10 more steps.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub model: ModelKind,
    pub tag: String,
    pub variant: Variant,
    /// Per-site rates; a single entry is expanded to every site at
    /// session-build time (site count comes from the artifact manifest).
    pub rates: Vec<f64>,
    pub support: Vec<usize>,
    pub shared_dp: bool,
    pub steps: usize,
    pub lr: f64,
    pub lr_decay: f64,
    pub decay_after: usize,
    pub seed: u64,
    /// MLP dataset sizes (images).
    pub n_train: usize,
    pub n_test: usize,
    /// LSTM corpus size (tokens).
    pub tokens: usize,
    /// Data-parallel gradient workers for this job; 0 (the default)
    /// keeps the single-threaded step path. N >= 1 routes every step
    /// through the sharded trainer, and the SlotGate accounts the extra
    /// N-1 threads as best-effort additional slot holds. Elastic: not
    /// part of the checkpoint config hash, so the same job can resume
    /// at a different N. Distinct from `[service] workers` (backend
    /// slots).
    pub workers: usize,
}

impl JobSpec {
    /// Defaults for one job named `name` (MLP flavor; lstm jobs override).
    pub fn named(name: &str) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            model: ModelKind::Mlp,
            tag: "mlpsyn".into(),
            variant: Variant::Rdp,
            rates: vec![0.5],
            support: vec![1, 2],
            shared_dp: false,
            steps: 40,
            lr: 0.01,
            lr_decay: 1.0,
            decay_after: usize::MAX,
            seed: 42,
            n_train: 256,
            n_test: 64,
            tokens: 20_000,
            workers: 0,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty()
            || !self.name.chars().all(
                |c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            bail!("job name '{}' must be non-empty [A-Za-z0-9_-] (it \
                   names checkpoint and report files)", self.name);
        }
        if self.rates.is_empty()
            || self.rates.iter().any(|&r| !(0.0..1.0).contains(&r))
        {
            bail!("job '{}': rates must be non-empty and in [0, 1), got \
                   {:?}", self.name, self.rates);
        }
        if self.support.is_empty() || self.support.contains(&0) {
            bail!("job '{}': bad divisor support {:?}", self.name,
                  self.support);
        }
        if self.lr <= 0.0 {
            bail!("job '{}': lr must be positive", self.name);
        }
        if self.steps == 0 {
            bail!("job '{}': steps must be positive", self.name);
        }
        Ok(())
    }

    /// Second-phase validation against the tag's compiled geometry:
    /// dataset sizing the batchers and the eval loop require. Without
    /// this, an undersized `n_test` or `tokens` passes [`JobSpec::validate`]
    /// and only surfaces as a setup quarantine (or an eval-time
    /// "zero eval batches" failure) deep inside the fleet run.
    pub fn validate_sizing(&self, manifest: &Manifest) -> Result<()> {
        let meta = manifest.get(&format!("{}_conv", self.tag))?;
        let arch_name = match &meta.arch {
            ArchMeta::Mlp { .. } => "mlp",
            ArchMeta::Lstm { .. } => "lstm",
        };
        if arch_name != self.model.as_str() {
            bail!("job '{}': model = {} but tag '{}' is an {} \
                   architecture", self.name, self.model.as_str(),
                  self.tag, arch_name);
        }
        match &meta.arch {
            ArchMeta::Mlp { batch, .. } => {
                if self.n_train < *batch {
                    bail!("job '{}': n_train = {} is smaller than tag \
                           '{}'s batch {} — training needs at least one \
                           full batch of images", self.name, self.n_train,
                          self.tag, batch);
                }
                if self.n_test < *batch {
                    bail!("job '{}': n_test = {} is smaller than tag \
                           '{}'s batch {} — evaluation needs at least \
                           one full batch of images", self.name,
                          self.n_test, self.tag, batch);
                }
            }
            ArchMeta::Lstm { seq, batch, .. } => {
                // Train split: `tokens` tokens over `batch` tracks; BPTT
                // needs each track longer than one unroll window.
                let track = self.tokens / batch;
                if track <= *seq {
                    bail!("job '{}': tokens = {} gives {}-token tracks \
                           over tag '{}'s batch {}, but BPTT unrolls seq \
                           = {} — need tokens > batch * seq", self.name,
                          self.tokens, track, self.tag, batch, seq);
                }
                // Validation split is tokens/10; the eval loop needs at
                // least one full (seq + 1)-token window per track.
                let valid = self.tokens / 10;
                if valid < batch * (seq + 1) {
                    bail!("job '{}': the validation split (tokens/10 = \
                           {}) yields zero eval batches for tag '{}' \
                           (needs at least batch {} * (seq {} + 1) = {} \
                           tokens)", self.name, valid, self.tag, batch,
                          seq, batch * (seq + 1));
                }
            }
        }
        Ok(())
    }
}

/// Fleet-level configuration (the `[service]` table).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Concurrent backend slots — at most this many sessions step (or
    /// compile, or evaluate) at any instant.
    pub slots: usize,
    /// Fairness quantum: steps a session runs per slot hold before
    /// re-queuing behind its siblings.
    pub tick_steps: usize,
    /// Steps between periodic checkpoint writes; 0 = checkpoint only on
    /// completion. Only meaningful with `ckpt_dir`.
    pub checkpoint_every: usize,
    /// Directory for `<job>.ckpt` files; enables crash-resume (a rerun of
    /// the same manifest picks every job up from its last checkpoint).
    pub ckpt_dir: Option<PathBuf>,
    /// Directory for per-job `REPORT_<job>.json` files.
    pub out_dir: Option<PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            slots: 2,
            tick_steps: 10,
            checkpoint_every: 0,
            ckpt_dir: None,
            out_dir: None,
        }
    }
}

/// Read a usize field, rejecting negatives loudly: `steps = -1` must be
/// a manifest error, not a two's-complement ~1.8e19-step job.
fn usize_field(doc: &TomlDoc, key: &str, default: usize) -> Result<usize> {
    match doc.get(key).and_then(|v| v.as_i64()) {
        None => Ok(default),
        Some(v) if v >= 0 => Ok(v as usize),
        Some(v) => bail!("{key}: must be non-negative, got {v}"),
    }
}

/// Parse a jobs manifest document into (jobs in name order, service cfg).
pub fn jobs_from_doc(doc: &TomlDoc) -> Result<(Vec<JobSpec>, ServiceConfig)> {
    let d = ServiceConfig::default();
    let cfg = ServiceConfig {
        slots: usize_field(doc, "service.workers", d.slots)?,
        tick_steps: usize_field(doc, "service.tick_steps", d.tick_steps)?,
        checkpoint_every: usize_field(doc, "service.checkpoint_every",
                                      d.checkpoint_every)?,
        ckpt_dir: doc.get("service.ckpt_dir")
            .and_then(|v| v.as_str())
            .map(PathBuf::from),
        out_dir: doc.get("service.out_dir")
            .and_then(|v| v.as_str())
            .map(PathBuf::from),
    };
    if cfg.slots == 0 || cfg.tick_steps == 0 {
        bail!("[service]: workers and tick_steps must be positive");
    }
    let names: BTreeSet<String> = doc
        .keys_under("jobs")
        .iter()
        .filter_map(|k| {
            k.strip_prefix("jobs.")
                .and_then(|r| r.split('.').next())
                .map(str::to_string)
        })
        .collect();
    if names.is_empty() {
        bail!("jobs manifest defines no [jobs.<name>] tables");
    }
    let mut jobs = Vec::with_capacity(names.len());
    for name in names {
        jobs.push(job_from_doc(doc, &name)?);
    }
    Ok((jobs, cfg))
}

fn job_from_doc(doc: &TomlDoc, name: &str) -> Result<JobSpec> {
    let key = |field: &str| format!("jobs.{name}.{field}");
    let model = ModelKind::parse(doc.str_or(&key("model"), "mlp"))?;
    let mut j = JobSpec::named(name);
    j.model = model;
    if model == ModelKind::Lstm {
        j.tag = "lstmsyn".into();
        j.lr = 0.5;
    }
    j.tag = doc.str_or(&key("tag"), &j.tag).to_string();
    j.variant = Variant::parse(doc.str_or(&key("variant"), "rdp"))?;
    // Malformed array entries are hard errors, never silently dropped:
    // a typo'd `rates = [0.5, "0.7"]` must not quietly become a
    // different experiment.
    if let Some(arr) = doc.get(&key("rates")).and_then(|v| v.as_arr()) {
        j.rates = arr
            .iter()
            .map(|x| x.as_f64().ok_or_else(
                || anyhow!("jobs.{name}.rates: non-numeric entry {x:?}")))
            .collect::<Result<_>>()?;
    }
    if let Some(r) = doc.get(&key("rate")).and_then(|v| v.as_f64()) {
        j.rates = vec![r];
    }
    if let Some(arr) = doc.get(&key("support")).and_then(|v| v.as_arr()) {
        j.support = arr
            .iter()
            .map(|x| match x.as_i64() {
                Some(v) if v >= 1 => Ok(v as usize),
                _ => Err(anyhow!("jobs.{name}.support: entries must be \
                                  positive integers, got {x:?}")),
            })
            .collect::<Result<_>>()?;
    }
    j.shared_dp = doc.bool_or(&key("shared_dp"), j.shared_dp);
    j.steps = usize_field(doc, &key("steps"), j.steps)?;
    j.lr = doc.f64_or(&key("lr"), j.lr);
    j.lr_decay = doc.f64_or(&key("lr_decay"), j.lr_decay);
    j.decay_after = usize_field(doc, &key("decay_after"), j.decay_after)?;
    j.seed = usize_field(doc, &key("seed"), j.seed as usize)? as u64;
    j.n_train = usize_field(doc, &key("n_train"), j.n_train)?;
    j.n_test = usize_field(doc, &key("n_test"), j.n_test)?;
    j.tokens = usize_field(doc, &key("tokens"), j.tokens)?;
    j.workers = usize_field(doc, &key("workers"), j.workers)?;
    j.validate()?;
    Ok(j)
}

/// Load a jobs manifest from a TOML file.
pub fn load_jobs_manifest(path: &Path)
                          -> Result<(Vec<JobSpec>, ServiceConfig)> {
    let doc = toml::parse_file(path)?;
    jobs_from_doc(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = "\
[service]
workers = 3
tick_steps = 5
checkpoint_every = 10
ckpt_dir = \"ckpts\"
out_dir = \"reports\"

[jobs.alpha]
model = \"mlp\"
variant = \"rdp\"
rates = [0.25, 0.25]
support = [1, 2]
steps = 12
seed = 5
workers = 2

[jobs.beta]
model = \"lstm\"
variant = \"conv\"
rate = 0.3
steps = 8
tokens = 9000
";

    #[test]
    fn parses_manifest_with_defaults_and_overrides() {
        let doc = toml::parse(MANIFEST).unwrap();
        let (jobs, cfg) = jobs_from_doc(&doc).unwrap();
        assert_eq!(cfg.slots, 3);
        assert_eq!(cfg.tick_steps, 5);
        assert_eq!(cfg.checkpoint_every, 10);
        assert_eq!(cfg.ckpt_dir.as_deref(),
                   Some(Path::new("ckpts")));
        assert_eq!(jobs.len(), 2);
        let a = &jobs[0];
        assert_eq!(a.name, "alpha");
        assert_eq!(a.model, ModelKind::Mlp);
        assert_eq!(a.rates, vec![0.25, 0.25]);
        assert_eq!(a.steps, 12);
        assert_eq!(a.tag, "mlpsyn", "default tag by model");
        assert_eq!(a.workers, 2, "per-job data-parallel workers");
        let b = &jobs[1];
        assert_eq!(b.workers, 0, "workers defaults to the plain path");
        assert_eq!(b.model, ModelKind::Lstm);
        assert_eq!(b.tag, "lstmsyn");
        assert_eq!(b.variant, Variant::Conv);
        assert_eq!(b.rates, vec![0.3], "scalar rate expands at build");
        assert_eq!(b.tokens, 9000);
        assert_eq!(b.lr, 0.5, "lstm default lr");
    }

    #[test]
    fn rejects_bad_manifests() {
        let no_jobs = toml::parse("[service]\nworkers = 2\n").unwrap();
        assert!(jobs_from_doc(&no_jobs).is_err());
        let bad_rate = toml::parse("[jobs.a]\nrate = 1.5\n").unwrap();
        assert!(jobs_from_doc(&bad_rate).is_err());
        let bad_model =
            toml::parse("[jobs.a]\nmodel = \"cnn\"\n").unwrap();
        assert!(jobs_from_doc(&bad_model).is_err());
        let bad_workers =
            toml::parse("[service]\nworkers = 0\n[jobs.a]\nsteps = 1\n")
                .unwrap();
        assert!(jobs_from_doc(&bad_workers).is_err());
        // Negative integers must error, not wrap through `as usize`.
        for doc in ["[jobs.a]\nsteps = -1\n",
                    "[jobs.a]\nn_train = -5\n",
                    "[jobs.a]\nseed = -2\n",
                    "[jobs.a]\nsupport = [1, -2]\n",
                    "[jobs.a]\nworkers = -4\n",
                    "[service]\nworkers = -1\n[jobs.a]\nsteps = 1\n"] {
            let doc = toml::parse(doc).unwrap();
            assert!(jobs_from_doc(&doc).is_err(), "negatives must fail");
        }
        // Malformed array entries error instead of silently dropping.
        let typo =
            toml::parse("[jobs.a]\nrates = [0.5, \"0.7\"]\n").unwrap();
        assert!(jobs_from_doc(&typo).is_err(), "typo'd rate must fail");
    }

    #[test]
    fn sizing_is_validated_against_the_tag() {
        let m = Manifest::builtin_test();
        // mlptest batch is 8: an undersized eval set must be rejected up
        // front, not discovered as a batcher failure mid-fleet.
        let mut j = JobSpec::named("tiny");
        j.tag = "mlptest".into();
        j.n_test = 4;
        let err = j.validate_sizing(&m).unwrap_err().to_string();
        assert!(err.contains("n_test"), "names the bad field: {err}");
        j.n_test = 8;
        j.validate_sizing(&m).unwrap();
        j.n_train = 7;
        assert!(j.validate_sizing(&m).is_err(), "n_train below batch");
        // Model/tag architecture mismatch is a spec error.
        j.n_train = 256;
        j.model = ModelKind::Lstm;
        assert!(j.validate_sizing(&m).is_err(), "lstm model, mlp tag");

        // lstmtest: batch 4, seq 5.
        let mut l = JobSpec::named("corpus");
        l.model = ModelKind::Lstm;
        l.tag = "lstmtest".into();
        l.tokens = 16; // 4-token tracks, seq 5: BPTT can't unroll.
        assert!(l.validate_sizing(&m).is_err(), "tracks shorter than seq");
        l.tokens = 100; // tracks ok, but valid split 10 < 4 * (5 + 1).
        let err = l.validate_sizing(&m).unwrap_err().to_string();
        assert!(err.contains("zero eval batches"), "{err}");
        l.tokens = 400; // valid split 40 >= 24.
        l.validate_sizing(&m).unwrap();
    }

    #[test]
    fn job_name_charset_is_enforced() {
        let doc = toml::parse("[jobs.bad name]\nsteps = 1\n");
        // Our TOML subset folds "bad name" into the key; the validator
        // rejects it either way.
        if let Ok(doc) = doc {
            assert!(jobs_from_doc(&doc).is_err());
        }
        let mut j = JobSpec::named("ok-job_1");
        j.validate().unwrap();
        j.name = "no/slash".into();
        assert!(j.validate().is_err());
    }
}
