//! LSTM training coordinator (paper section IV-C): word-level language
//! modeling with per-iteration dropout patterns on the non-recurrent
//! connections. Same dispatch structure as the MLP trainer; LSTM schedules
//! use a single shared dp per iteration (the artifact set covers equal-dp
//! combinations; see aot.py).

use anyhow::{bail, Result};

use crate::coordinator::metrics::{perplexity, TrainMetrics};
use crate::coordinator::pool::ExecutorPool;
use crate::coordinator::schedule::{Schedule, Variant};
use crate::data::BpttBatcher;
use crate::patterns::MaskGen;
use crate::runtime::state::{lit_f32, lit_i32, lit_scalar_f32,
                            lit_scalar_i32};
use crate::runtime::{ArchMeta, Engine, Manifest, TrainState};
use crate::util::rng::Rng;
use crate::util::Timer;

pub struct LstmTrainer<'e> {
    pool: ExecutorPool<'e>,
    pub tag: String,
    pub schedule: Schedule,
    pub state: TrainState,
    pub metrics: TrainMetrics,
    pub lr: f32,
    /// Multiplied into lr after each `train` epoch beyond `decay_after`.
    pub lr_decay: f32,
    pub decay_after: usize,
    batcher: BpttBatcher,
    hidden: usize,
    /// Layer count (== dropout sites); kept for diagnostics.
    #[allow(dead_code)]
    layers: usize,
    batch: usize,
    seq: usize,
    rng: Rng,
    maskgen: Vec<MaskGen>,
    epochs_done: usize,
}

impl<'e> LstmTrainer<'e> {
    pub fn new(engine: &'e Engine, manifest: &'e Manifest, tag: &str,
               schedule: Schedule, train_tokens: &[i32], lr: f32,
               seed: u64) -> Result<LstmTrainer<'e>> {
        let conv = manifest.get(&format!("{tag}_conv"))?;
        let (hidden, layers, batch, seq) = match &conv.arch {
            ArchMeta::Lstm { hidden, layers, batch, seq, .. } =>
                (*hidden, *layers, *batch, *seq),
            _ => bail!("artifact {tag} is not an LSTM"),
        };
        if schedule.sites() != layers {
            bail!("schedule has {} sites, LSTM has {} layers",
                  schedule.sites(), layers);
        }
        let mut rng = Rng::new(seed);
        let state = TrainState::init(conv, &mut rng);
        Ok(LstmTrainer {
            pool: ExecutorPool::new(engine, manifest),
            tag: tag.to_string(),
            schedule,
            state,
            metrics: TrainMetrics::default(),
            lr,
            lr_decay: 1.0,
            decay_after: usize::MAX,
            batcher: BpttBatcher::new(train_tokens, batch, seq),
            hidden,
            layers,
            batch,
            seq,
            rng,
            maskgen: (0..layers).map(|_| MaskGen::new()).collect(),
            epochs_done: 0,
        })
    }

    pub fn executable_names(&self) -> Vec<String> {
        match self.schedule.variant {
            Variant::Conv => vec![format!("{}_conv", self.tag)],
            v => self
                .schedule
                .dp_combos()
                .iter()
                .map(|dp| {
                    // LSTM artifacts are named by the single shared dp.
                    Manifest::artifact_name(&self.tag, v.as_str(), &dp[..1])
                })
                .collect(),
        }
    }

    pub fn warmup(&mut self) -> Result<()> {
        let names = self.executable_names();
        self.pool.warm(&names)
    }

    /// One training iteration over a [batch, seq] BPTT window.
    /// Returns (loss nats/token, token accuracy).
    pub fn step(&mut self) -> Result<(f64, f64)> {
        let t = Timer::start();
        let choices = self.schedule.sample(&mut self.rng);
        let prev_epoch = self.batcher.epoch;
        let (x, y) = self.batcher.next_batch();

        let mut tail: Vec<xla::Literal> = Vec::with_capacity(8);
        tail.push(lit_i32(&[self.batch, self.seq], x)?);
        tail.push(lit_i32(&[self.batch, self.seq], y)?);

        let name = match self.schedule.variant {
            Variant::Conv => {
                for (site, rate) in
                    self.schedule.rates.clone().iter().enumerate()
                {
                    let keep = 1.0 - rate;
                    let m = self.maskgen[site]
                        .fill(&mut self.rng, keep, self.batch * self.hidden);
                    tail.push(lit_f32(&[self.batch, self.hidden], m)?);
                }
                for rate in &self.schedule.rates {
                    tail.push(lit_scalar_f32((1.0 / (1.0 - rate)) as f32));
                }
                format!("{}_conv", self.tag)
            }
            v => {
                for c in &choices {
                    tail.push(lit_scalar_i32(c.b0 as i32));
                }
                // Inverted-dropout correction: constant 1/(1-p) of the
                // site's long-run rate (Caffe semantics), NOT the
                // per-iteration 1/dp — see model.py _mlp_logits_rdp.
                for rate in &self.schedule.rates {
                    tail.push(lit_scalar_f32((1.0 / (1.0 - rate)) as f32));
                }
                Manifest::artifact_name(&self.tag, v.as_str(),
                                        &[choices[0].dp])
            }
        };
        tail.push(lit_scalar_f32(self.lr));

        let exe = self.pool.get(&name)?;
        let (loss, correct) = self.state.step(exe, &tail)?;
        let tokens = (self.batch * self.seq) as f64;
        self.metrics.record(self.state.step, loss, correct,
                            self.batch * self.seq, t.elapsed_s());
        if self.batcher.epoch != prev_epoch {
            self.epochs_done += 1;
            if self.epochs_done > self.decay_after {
                self.lr *= self.lr_decay;
            }
        }
        Ok((loss, correct / tokens))
    }

    pub fn train(&mut self, n: usize) -> Result<f64> {
        let mut sum = 0.0;
        for _ in 0..n {
            sum += self.step()?.0;
        }
        Ok(sum / n.max(1) as f64)
    }

    /// Evaluate on a token stream through the eval graph. Returns
    /// (mean loss nats/token, perplexity, token accuracy).
    pub fn evaluate(&mut self, tokens: &[i32]) -> Result<(f64, f64, f64)> {
        let name = format!("{}_eval", self.tag);
        let mut b = BpttBatcher::new(tokens, self.batch, self.seq);
        let windows = b.windows_per_epoch();
        let mut total_loss = 0.0;
        let mut total_correct = 0.0;
        let mut n = 0.0f64;
        for _ in 0..windows {
            let (x, y) = b.next_batch();
            let x_l = lit_i32(&[self.batch, self.seq], x)?;
            let y_l = lit_i32(&[self.batch, self.seq], y)?;
            let mut refs = self.state.param_refs();
            refs.push(&x_l);
            refs.push(&y_l);
            let exe = self.pool.get(&name)?;
            let out = exe.run_raw(&refs)?;
            total_loss += out[0].get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("loss: {e:?}"))? as f64;
            total_correct += out[1].get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("correct: {e:?}"))? as f64;
            n += 1.0;
        }
        let xent = total_loss / n.max(1.0);
        let acc = total_correct / (n.max(1.0) * (self.batch * self.seq) as f64);
        Ok((xent, perplexity(xent), acc))
    }
}
